"""UBT packet codec: packetize/reassemble bucket payloads (DESIGN §7).

A bucket payload (raw fp32 gradients or HTQuant uint8 codes — the wire does
not care, it moves ``dtype`` elements) is split into fixed-size sequenced
datagrams of ``packet_elems`` elements each, the same packet granularity the
synthetic drop model uses (``OptiReduceConfig.packet_elems``), so an
observed arrival mask is *bit-compatible* with a ``core/drops.py`` mask:
packet ``seq`` covers elements ``[seq*packet_elems, (seq+1)*packet_elems)``
and a missing packet zeroes exactly that mask span (the tail packet is
short when ``n_elems % packet_elems != 0``, matching ``drops._expand``).

Header (16 bytes, network byte order)::

    version  B   wire-format version (`WIRE_VERSION`)
    kind     B   DATA1 (stage-1 shard) | DATA2 (stage-2 broadcast) | CTRL
    sender   H   sending peer's rank
    step     I   training step (stale packets are discarded on mismatch)
    bucket   H   bucket index within the step
    round    H   TAR round the payload belongs to
    seq      H   packet index within the stream
    n_seq    H   total packets in the stream

Reassembly is order-free: duplicates are ignored, out-of-order arrivals
land by ``seq``, and a stream is never *blocked* on a missing packet — the
receiver evaluates whatever arrived before its deadline and masks the rest
(the UBT semantics the compensated mean absorbs).
"""
from __future__ import annotations

import dataclasses
import struct

import numpy as np

WIRE_VERSION = 1
HEADER_FMT = "!BBHIHHHH"
HEADER_BYTES = struct.calcsize(HEADER_FMT)          # 16

KIND_DATA1 = 1      # stage-1 shard exchange payload
KIND_DATA2 = 2      # stage-2 aggregated-shard broadcast payload
KIND_CTRL = 3       # small reliable-ish control payloads (HTQuant amax)
KIND_RELAY = 4      # dead-link reroute: payload is a complete inner datagram

_KINDS = (KIND_DATA1, KIND_DATA2, KIND_CTRL, KIND_RELAY)


class WireError(ValueError):
    """A datagram that cannot belong to this wire format."""


@dataclasses.dataclass(frozen=True)
class PacketHeader:
    """Decoded header of one datagram (see module docstring)."""
    kind: int
    sender: int
    step: int
    bucket: int
    round: int
    seq: int
    n_seq: int

    def encode(self) -> bytes:
        return struct.pack(HEADER_FMT, WIRE_VERSION, self.kind, self.sender,
                           self.step, self.bucket, self.round, self.seq,
                           self.n_seq)

    @classmethod
    def decode(cls, datagram: bytes) -> tuple["PacketHeader", bytes]:
        """Split a datagram into (header, payload fragment)."""
        if len(datagram) < HEADER_BYTES:
            raise WireError(f"datagram of {len(datagram)} bytes is shorter "
                            f"than the {HEADER_BYTES}-byte header")
        version, kind, sender, step, bucket, rnd, seq, n_seq = \
            struct.unpack_from(HEADER_FMT, datagram)
        if version != WIRE_VERSION:
            raise WireError(f"wire version {version} != {WIRE_VERSION}")
        if kind not in _KINDS:
            raise WireError(f"unknown packet kind {kind}")
        return cls(kind=kind, sender=sender, step=step, bucket=bucket,
                   round=rnd, seq=seq, n_seq=n_seq), datagram[HEADER_BYTES:]

    def stream(self) -> tuple[int, int, int, int]:
        """The reassembly stream this packet belongs to."""
        return (self.kind, self.bucket, self.round, self.sender)


def n_packets(n_elems: int, packet_elems: int) -> int:
    """Packets needed for a stream of ``n_elems`` elements."""
    return max(1, -(-n_elems // packet_elems))


def wrap_relay(relay_src: int, final_dst: int, step: int,
               inner: bytes) -> bytes:
    """Wrap a datagram for a two-hop dead-link reroute.

    The outer header's ``sender`` is the peer posting the wrap (so fabric
    accounting stays truthful) and ``bucket`` carries the *final*
    destination rank; the payload is the complete inner datagram, which the
    relay peer re-sends verbatim — the receiver sees the original sender's
    header, and any per-(src, dst) drop schedule sees the relay hop's
    physical endpoints, which is exactly why the reroute survives a dead
    directed edge.
    """
    hdr = PacketHeader(kind=KIND_RELAY, sender=relay_src, step=step,
                       bucket=final_dst, round=0, seq=0, n_seq=1)
    return hdr.encode() + inner


def unwrap_relay(datagram: bytes) -> tuple[int, bytes]:
    """(final_dst, inner datagram) of a ``KIND_RELAY`` wrap."""
    hdr, inner = PacketHeader.decode(datagram)
    if hdr.kind != KIND_RELAY:
        raise WireError(f"not a relay datagram (kind {hdr.kind})")
    return hdr.bucket, inner


def packetize(payload: np.ndarray, *, kind: int, sender: int, step: int,
              bucket: int, round: int, packet_elems: int) -> list[bytes]:
    """Split a flat array into sequenced datagrams (header + raw bytes)."""
    payload = np.ascontiguousarray(payload)
    if payload.ndim != 1:
        raise WireError(f"payload must be flat, got shape {payload.shape}")
    n = payload.shape[0]
    total = n_packets(n, packet_elems)
    out = []
    for seq in range(total):
        frag = payload[seq * packet_elems:(seq + 1) * packet_elems]
        hdr = PacketHeader(kind=kind, sender=sender, step=step, bucket=bucket,
                           round=round, seq=seq, n_seq=total)
        out.append(hdr.encode() + frag.tobytes())
    return out


class Reassembly:
    """Order-free reassembly of one stream into payload + arrival mask.

    ``payload()`` zero-fills missing spans (the compensated mean never reads
    them — the mask excludes the span) and ``mask()`` is bit-compatible with
    a ``core/drops.py`` mask row: per-packet arrival expanded to element
    granularity with the same repeat-then-truncate rule as ``drops._expand``.
    """

    def __init__(self, n_elems: int, dtype, packet_elems: int):
        if n_elems <= 0 or packet_elems <= 0:
            raise WireError("n_elems and packet_elems must be positive")
        self.n_elems = int(n_elems)
        self.dtype = np.dtype(dtype)
        self.packet_elems = int(packet_elems)
        self.n_seq = n_packets(self.n_elems, self.packet_elems)
        self._buf = np.zeros(self.n_elems, self.dtype)
        self._have = np.zeros(self.n_seq, bool)
        self.duplicates = 0

    def _frag_elems(self, seq: int) -> int:
        lo = seq * self.packet_elems
        return min(self.packet_elems, self.n_elems - lo)

    def add(self, header: PacketHeader, fragment: bytes) -> bool:
        """Accept one datagram's payload; False for duplicates/garbage."""
        if header.n_seq != self.n_seq or not 0 <= header.seq < self.n_seq:
            return False                         # not this stream's geometry
        if self._have[header.seq]:
            self.duplicates += 1
            return False
        want = self._frag_elems(header.seq) * self.dtype.itemsize
        if len(fragment) != want:
            return False                         # truncated/padded garbage
        lo = header.seq * self.packet_elems
        frag = np.frombuffer(fragment, self.dtype)
        self._buf[lo:lo + frag.shape[0]] = frag
        self._have[header.seq] = True
        return True

    @property
    def complete(self) -> bool:
        return bool(self._have.all())

    @property
    def received_packets(self) -> int:
        return int(self._have.sum())

    def frac_received(self) -> float:
        return self.received_packets / self.n_seq

    def payload(self) -> np.ndarray:
        """The reassembled stream, zeros where packets are missing."""
        return self._buf

    def packet_mask(self) -> np.ndarray:
        return self._have.astype(np.float32)

    def mask(self) -> np.ndarray:
        """(n_elems,) 0/1 arrival mask — drops-mask bit-compatible."""
        m = np.repeat(self.packet_mask(), self.packet_elems)
        return m[:self.n_elems]
