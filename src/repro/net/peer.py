"""HostPeer: one rank of the host wire datapath (DESIGN §7).

A peer executes the existing TAR round schedule *over the wire*: it encodes
its bucket with the strategy's codec, packetizes each stage-1 shard into
sequenced datagrams, exchanges them through a :class:`~repro.net.backend.
Backend`, reassembles whatever arrived before the adaptive per-round
deadline into a received matrix plus an observed arrival mask, and runs the
same drop-compensated reduce / stage-2 broadcast / decode the in-JAX
pipeline runs.

Bitwise parity with the in-JAX ``Lossy`` path (the subsystem's load-bearing
correctness result) comes from structure, not luck: the peer's compute is
organized into jitted stage functions that mirror the device program's
XLA fusion regions — encode (pre-collective), reduce+re-encode (between
all_to_all and all_gather), decode (post-collective) — calling the *same*
codec objects; the only cross-peer math, the HTQuant grid ``pmax``, is an
elementwise max and therefore order-free, so max-sharing the amax vectors
over the wire reproduces the fabric ``pmax`` exactly.

Telemetry is the other product: per-round stage completion times, t_B
expiry flags, and received fractions (exactly ``AdaptiveTimeout.update``'s
inputs), plus per-sender last-arrival times (the straggler detector's
signal), accumulate in a :class:`PeerReport` per exchange.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tar as tar_lib
from repro.core.pipeline import (Encoded, HTQuant, OptiReduceConfig,
                                 SyncContext, TarTopology, resolve_spec)
from repro.core.ubt import AdaptiveTimeout, LossBudget
from repro.obs import trace as obs_trace

from .backend import Backend
from .wire import (KIND_CTRL, KIND_DATA1, KIND_DATA2, KIND_RELAY,
                   PacketHeader, Reassembly, WireError, n_packets, packetize,
                   unwrap_relay, wrap_relay)


@dataclasses.dataclass
class RoundReport:
    """One receive round as this peer observed it."""
    time: float                 # completion (or expiry) time, stage-relative
    timed_out: bool             # missing packets at the deadline
    frac_received: float        # fraction of expected packets that arrived


@dataclasses.dataclass
class PeerReport:
    """One exchange's observations at this receiver."""
    rounds: list[RoundReport] = dataclasses.field(default_factory=list)
    # last-arrival time per sender (NaN = nothing observed; a fully-dropped
    # sender is charged the deadline — waiting on it cost exactly that)
    sender_last_t: np.ndarray | None = None
    dropped: float = 0.0        # stage-1 mask entries lost
    total: float = 0.0          # stage-1 mask entries expected
    stage2_dropped: float = 0.0
    stage2_total: float = 0.0
    stage_time: float = 0.0     # sum of round completion times
    # senders skipped because the membership view says they are dead: their
    # rounds cost nothing, their mask rows are excluded from loss accounting
    # (a known-dead peer is degradation the control plane already decided,
    # not packet loss for the Hadamard/incast controllers to react to)
    skipped_senders: tuple[int, ...] = ()
    # directed (src, dst=this receiver) links observed *fully* lossy while
    # at least one other sender's stream completed — a link-fault suspect
    # (not a straggler: a slow peer still lands some packets), folded into
    # StepTelemetry.dead_link_events by ``host_ring.aggregate_reports``
    lost_links: tuple[tuple[int, int], ...] = ()

    def merge(self, other: "PeerReport") -> None:
        self.rounds.extend(other.rounds)
        self.lost_links = tuple(sorted(set(self.lost_links)
                                       | set(other.lost_links)))
        if other.sender_last_t is not None:
            if self.sender_last_t is None:
                self.sender_last_t = other.sender_last_t.copy()
            else:
                self.sender_last_t = np.fmax(self.sender_last_t,
                                             other.sender_last_t)
        self.dropped += other.dropped
        self.total += other.total
        self.stage2_dropped += other.stage2_dropped
        self.stage2_total += other.stage2_total
        self.stage_time += other.stage_time
        self.skipped_senders = self.skipped_senders + other.skipped_senders


class _PacketStore:
    """Per-peer buffer of drained datagrams keyed by reassembly stream."""

    def __init__(self):
        self._streams: dict[tuple, list[tuple[PacketHeader, bytes, float]]] \
            = {}

    def ingest(self, datagrams: list[tuple[bytes, float]], step: int) -> None:
        for dgram, t in datagrams:
            try:
                hdr, frag = PacketHeader.decode(dgram)
            except Exception:
                continue                      # garbage datagram: drop it
            if hdr.step != step:
                continue                      # stale step: discard
            self._streams.setdefault(hdr.stream(), []).append((hdr, frag, t))

    def take(self, stream: tuple) -> list[tuple[PacketHeader, bytes, float]]:
        return self._streams.pop(stream, [])

    def clear(self) -> None:
        self._streams.clear()


class HostPeer:
    """One rank's engine over a wire backend (see module docstring)."""

    def __init__(self, rank: int, backend: Backend, cfg: OptiReduceConfig, *,
                 timeout: AdaptiveTimeout | None = None,
                 default_deadline: float | None = None,
                 budget: LossBudget | None = None,
                 membership=None,
                 shard_weights: tuple[int, ...] | None = None,
                 dead_links: tuple[tuple[int, int], ...] = ()):
        self.rank = int(rank)
        self.n = backend.n_peers
        self.backend = backend
        self.cfg = cfg
        # membership view (rendezvous client or StaticMembership): which of
        # the n rank slots are live *right now*.  None = fixed full world.
        # A dead rank's rounds are skipped outright — no deadline burned,
        # nothing sent its way — which is how rendezvous leave/death events
        # map onto the same degraded-participation schedules the
        # ControlPlane's ejections use (DESIGN §9).
        self.membership = membership
        spec = resolve_spec(cfg)
        if not isinstance(spec.topology, TarTopology):
            raise ValueError(
                f"strategy {cfg.strategy!r} resolves to "
                f"{type(spec.topology).__name__}; the host wire datapath "
                "executes TAR schedules (ring/tree reduce in flight — "
                "there is no receive stage to mask)")
        if cfg.pod_axis is not None or cfg.active_peers is not None:
            raise ValueError("host wire datapath: single data axis, "
                             "full participation only")
        self.codec = spec.codec
        # straggler-proportional shard ownership: rank p owns shard_weights[p]
        # units of the bucket (uniform normalizes to None so the default
        # wire trace stays bitwise-identical to the seed)
        if shard_weights is not None:
            w = tuple(int(u) for u in shard_weights)
            if len(w) != self.n:
                raise ValueError(f"shard_weights has {len(w)} entries for "
                                 f"{self.n} peers")
            if any(u < 1 for u in w):
                raise ValueError("shard_weights must be positive")
            if not self.codec.linear:
                raise ValueError(
                    "shard_weights require a linear codec: a quantizing "
                    "codec keys its grids on uniform shard geometry")
            if cfg.recovery != "none":
                raise ValueError("shard_weights: recovery codecs assume "
                                 "uniform shard geometry")
            shard_weights = None if len(set(w)) == 1 else w
        self.shard_weights = shard_weights
        # directed edges the control plane declared dead: sends crossing one
        # are relay-wrapped through a live third peer instead of ejecting
        # either endpoint
        dl = set()
        for (src, dst) in dead_links:
            src, dst = int(src), int(dst)
            if not (0 <= src < self.n and 0 <= dst < self.n) or src == dst:
                raise ValueError(f"dead link ({src}, {dst}) is not a "
                                 f"directed edge between distinct ranks "
                                 f"< {self.n}")
            dl.add((src, dst))
        self.dead_links = tuple(sorted(dl))
        # padding denominator: total shard units (== n when uniform)
        self._pad_n = (self.n if self.shard_weights is None
                       else sum(self.shard_weights))
        self.timeout = timeout
        self.budget = budget
        self.default_deadline = (default_deadline if default_deadline
                                 is not None else
                                 (1.0 if backend.virtual_time else 0.25))
        self.packet_elems = cfg.packet_elems
        self._store = _PacketStore()
        self._build_stage_fns()
        # in-flight state between phases of one exchange
        self._held: dict = {}
        # last exchange's observed (n, s) stage-1 / stage-2 arrival masks —
        # what the EF residual accounting reconstructs lost mass from
        self.last_mask1: np.ndarray | None = None
        self.last_mask2: np.ndarray | None = None

    # ---------------------------------------------------- jitted stage fns
    def _ctx(self, key) -> SyncContext:
        return SyncContext(cfg=self.cfg, key=key)

    def _build_stage_fns(self) -> None:
        codec, cfg = self.codec, self.cfg
        # pad to the shard-unit total, not the peer count: with weighted
        # shards each unit must stay block-aligned (uniform: pad_n == n)
        pad_n = self._pad_n

        if isinstance(codec, HTQuant):
            def enc_local(x, key):
                x, _ = tar_lib.pad_for_tar(x, pad_n, codec.block(cfg))
                return codec.local_amax(x, self._ctx(key))

            def enc_finish(x1, amax, key):
                e = codec.encode_given_amax(x1, amax, self._ctx(key))
                return e.data, e.lo, e.step
            self._enc_local = jax.jit(enc_local)
            self._enc_finish = jax.jit(enc_finish)
        else:
            def enc(x, key, stale):
                # `stale` is the previous step's decoded bucket (StaleFill
                # recovery, DESIGN §8) — None traces the plain variant
                ctx = SyncContext(cfg=self.cfg, key=key, stale=stale)
                x, _ = tar_lib.pad_for_tar(x, pad_n, codec.block(cfg))
                e = codec.encode(x, ctx, cfg.data_axis)
                return e.data, e.stale
            self._enc = jax.jit(enc)

        def red(received, mask, me, lo, step, stale_w, key):
            ctx = self._ctx(key)
            enc = Encoded(None, lo=lo, step=step, stale=stale_w)
            own = codec.reduce(received, mask, me, enc, ctx)
            return codec.encode_shard(own, me, enc, ctx)
        self._red = jax.jit(red)

        def dec(gathered, lo, step, key):
            return codec.decode_gathered(
                gathered, Encoded(None, lo=lo, step=step), self._ctx(key))
        self._dec = jax.jit(dec)

    # ------------------------------------------------------- receive loop
    def _ingest(self, step: int) -> None:
        """Drain the backend mailbox into the packet store, forwarding any
        relay-wrapped datagram (dead-link reroute) to its final destination
        — this peer is the relay hop for it, not the receiver."""
        me = self.rank
        direct: list[tuple[bytes, float]] = []
        for dgram, t in self.backend.poll(me):
            if len(dgram) >= 2 and dgram[1] == KIND_RELAY:
                try:
                    dst, inner = unwrap_relay(dgram)
                except WireError:
                    continue              # garbage wrap: drop it
                if dst == me:             # degenerate wrap: just ingest
                    direct.append((inner, t))
                else:
                    self.backend.send(me, dst, inner)
            else:
                direct.append((dgram, t))
        self._store.ingest(direct, step)

    def relay_pump(self, step: int) -> None:
        """One explicit mailbox drain so relay-wrapped datagrams move on.

        Virtual-time backends deliver everything in a single drain and
        ``wait`` never blocks, so a relay hop that is itself busy in a
        send phase would otherwise forward its wrapped datagrams only
        after the final receiver stopped polling — the ring driver pumps
        every peer between send and receive phases to make two-hop
        delivery deterministic.  Direct datagrams drained here are kept
        in the packet store for the coming receive phase."""
        self._ingest(step)

    def round_deadline(self) -> float:
        if self.timeout is not None:
            d = self.timeout.round_deadline_or(self.default_deadline)
        else:
            d = self.default_deadline
        if self.budget is not None:
            # accept-or-extend (DESIGN §8): while the observed loss EMA
            # overruns the phase-tightening budget, wait up to max_stretch×
            # longer so late packets are recovered instead of masked out
            d = self.budget.stretch(d)
        return d

    #: fraction of a stream's packets counting as "last percentile seen"
    last_pctile = 0.99

    def _early_deadline(self, arrivals: dict, n_seq: int,
                        hard: float) -> float:
        """§3.2.1 early timeout: once the last-percentile markers of the
        stream are in, wait only x% of t_C more — bounded by the hard t_B
        budget (inactive until the AdaptiveTimeout is fully profiled)."""
        at = self.timeout
        if at is None or at.t_b is None or at.t_c is None:
            return hard
        need = min(n_seq, max(1, int(self.last_pctile * n_seq)))
        if len(arrivals) < need:
            return hard
        t_seen = sorted(rel for rel, _, _ in arrivals.values())[need - 1]
        return min(hard, t_seen + at.x * at.t_c)

    def _recv_stream(self, kind: int, step: int, bucket: int, rnd: int,
                     sender: int, n_elems: int, dtype, deadline: float,
                     packet_elems: int | None = None
                     ) -> tuple[Reassembly, float, float]:
        """Receive one (round, sender) stream until complete or expired.

        The budget is two-phase: the hard bound ``deadline`` (t_B), then —
        once the last-percentile of expected packets has arrived — the
        early deadline x%*t_C past that point.  Returns the reassembly,
        the last *accepted* arrival time relative to the round start (0.0
        when nothing arrived in time), and the effective deadline charged
        (what the receiver actually budgeted for this stream).
        """
        be, me = self.backend, self.rank
        t0 = be.now(me)
        pe = packet_elems or self.packet_elems
        n_seq = n_packets(n_elems, pe)
        stream = (kind, bucket, rnd, sender)
        # first arrival per seq; duplicates and beyond-hard-late packets
        # drop here, the rest replays through Reassembly after the
        # effective deadline is known (deterministic for virtual time too)
        arrivals: dict[int, tuple[float, PacketHeader, bytes]] = {}
        eff = deadline
        while True:
            self._ingest(step)
            for hdr, frag, t in self._store.take(stream):
                rel = max(0.0, t - t0)
                if rel <= deadline and 0 <= hdr.seq < n_seq \
                        and hdr.seq not in arrivals:
                    arrivals[hdr.seq] = (rel, hdr, frag)
            eff = self._early_deadline(arrivals, n_seq, deadline)
            if len(arrivals) >= n_seq:
                break
            if be.now(me) - t0 >= eff or not be.wait(me, 1e-3):
                break
        reas = Reassembly(n_elems, dtype, pe)
        last_t = 0.0
        for rel, hdr, frag in sorted(arrivals.values(), key=lambda a: a[0]):
            if rel <= eff and reas.add(hdr, frag):
                last_t = max(last_t, rel)
        return reas, last_t, eff

    def _recv_rounds(self, kind: int, step: int, bucket: int, n_elems,
                     dtype) -> tuple[dict[int, Reassembly], PeerReport]:
        """Run the N-1 receive rounds; round r expects sender (me-r)%n.

        ``n_elems`` is the expected stream length — an int when every
        sender's stream is the same size, or a callable ``sender -> int``
        for weighted shards (stage 2 receives each owner's own-size slice).
        """
        me, n = self.rank, self.n
        # hoisted tracer gate: one module-global read per exchange, then a
        # local ``is not None`` test per round (DESIGN §12)
        tr = obs_trace.get_tracer()
        report = PeerReport(sender_last_t=np.full(n, np.nan))
        report.sender_last_t[me] = 0.0
        streams: dict[int, Reassembly] = {}
        for r in range(1, n):
            sender = (me - r) % n
            if self.membership is not None \
                    and not self.membership.is_live(sender):
                # a known-dead sender costs nothing: no deadline burned,
                # its mask row stays zero (the compensated mean excludes
                # it) and its sender_last_t stays NaN (unobserved — the
                # straggler detector must not score a corpse)
                report.rounds.append(RoundReport(
                    time=0.0, timed_out=False, frac_received=1.0))
                report.skipped_senders += (sender,)
                continue
            deadline = self.round_deadline()
            ne = n_elems(sender) if callable(n_elems) else n_elems
            rt0 = self.backend.now(me) if tr is not None else 0.0
            reas, last_t, eff = self._recv_stream(kind, step, bucket, r,
                                                  sender, ne, dtype,
                                                  deadline)
            streams[sender] = reas
            # an incomplete round costs the receiver the effective deadline
            # (it kept waiting on the gap until expiry); the *sender* is
            # charged that only when nothing of its stream made it — a peer
            # with a few lost packets must not score as a straggler
            round_t = last_t if reas.complete else eff
            sender_t = last_t if reas.received_packets > 0 else eff
            frac = reas.frac_received()
            if tr is not None:
                tr.complete("round", "wire", ts=rt0, dur=min(round_t, eff),
                            tid=sender,
                            args={"step": step, "bucket": bucket,
                                  "kind": kind, "round": r, "sender": sender,
                                  "receiver": me, "frac_received": frac,
                                  "timed_out": not reas.complete,
                                  "deadline": deadline, "eff_deadline": eff})
                if not reas.complete:
                    tr.event("timeout", "wire", ts=rt0 + eff, tid=sender,
                             args={"step": step, "bucket": bucket,
                                   "round": r, "sender": sender,
                                   "receiver": me, "frac_received": frac})
            report.rounds.append(RoundReport(
                time=min(round_t, eff), timed_out=not reas.complete,
                frac_received=frac))
            report.sender_last_t[sender] = min(sender_t, eff)
            report.stage_time += min(round_t, eff)
        if any(reas.complete for reas in streams.values()):
            # a sender whose stream landed *zero* packets while another
            # sender's completed is a link-fault suspect, not a straggler
            # (a slow peer still lands some packets) and not an outage
            # (something got through): report the directed edge
            report.lost_links = tuple(sorted(
                (sender, me) for sender, reas in streams.items()
                if reas.received_packets == 0))
        return streams, report

    def _assemble(self, streams: dict[int, Reassembly], own: np.ndarray,
                  s: int, dtype, sizes=None) -> tuple[np.ndarray, np.ndarray]:
        """(n, s) received matrix + arrival mask in sender order.

        ``sizes[sender]`` (optional) is the valid prefix of each row under
        weighted shards; the zero tail is marked *arrived* (mask 1.0) — it
        is planned padding, not loss, and the compensated mean averages the
        zeros exactly like the in-JAX weighted rows do."""
        n, me = self.n, self.rank
        received = np.zeros((n, s), dtype)
        mask = np.zeros((n, s), np.float32)
        received[me] = own
        mask[me] = 1.0
        for sender, reas in streams.items():
            w = s if sizes is None else sizes[sender]
            received[sender, :w] = reas.payload()
            mask[sender, :w] = reas.mask()
            mask[sender, w:] = 1.0
        return received, mask

    # ------------------------------------------------------------- phases
    # One allreduce = four phases with a backend barrier between them (the
    # drivers in host_ring.py run them across peers threaded or in lockstep)

    def _send_datagram(self, dst: int, dgram: bytes, step: int) -> None:
        """Post one datagram, relay-wrapping it around a dead (me, dst)
        edge through the first live third peer (``tar.relay_via`` — the
        same relay the in-JAX schedule lowers to)."""
        me = self.rank
        if (me, dst) in self.dead_links:
            live = tuple(p for p in range(self.n)
                         if self.membership is None
                         or self.membership.is_live(p))
            m = tar_lib.relay_via(me, dst, live, self.dead_links)
            self.backend.send(me, m, wrap_relay(me, dst, step, dgram))
        else:
            self.backend.send(me, dst, dgram)

    def _send_shards(self, shards: np.ndarray, kind: int, step: int,
                     bucket: int, sizes=None) -> None:
        me, n = self.rank, self.n
        for r in range(1, n):
            dst = (me + r) % n
            if self.membership is not None \
                    and not self.membership.is_live(dst):
                continue                  # no socket to reach a dead rank
            row = shards[dst] if shards.ndim == 2 else shards
            if sizes is not None and shards.ndim == 2:
                row = row[:sizes[dst]]    # weighted: send the valid prefix
            for dgram in packetize(np.ascontiguousarray(row), kind=kind,
                                   sender=me, step=step, bucket=bucket,
                                   round=r, packet_elems=self.packet_elems):
                self._send_datagram(dst, dgram, step)

    def phase1_encode(self, x: np.ndarray, key, step: int, bucket: int,
                      stale: np.ndarray | None = None) -> None:
        """Encode the bucket; for quantizing codecs, advertise the local
        per-block amax on the control channel.  ``stale`` is the previous
        step's decoded bucket for StaleFill recovery codecs (ignored — and
        unreachable — for quantized codecs: ``wrap_codec`` rejects them)."""
        tr = obs_trace.get_tracer()
        t0 = self.backend.now(self.rank) if tr is not None else 0.0
        self._store.clear()
        xj = jnp.asarray(x)
        if isinstance(self.codec, HTQuant):
            x1, amax = self._enc_local(xj, key)
            amax_np = np.asarray(amax, np.float32)
            for dgram in packetize(amax_np, kind=KIND_CTRL, sender=self.rank,
                                   step=step, bucket=bucket, round=0,
                                   packet_elems=max(1, amax_np.shape[0])):
                for dst in range(self.n):
                    if dst == self.rank or (self.membership is not None and
                                            not self.membership.is_live(dst)):
                        continue
                    self._send_datagram(dst, dgram, step)
            self._held = {"x1": x1, "amax": amax_np, "key": key,
                          "stale_w": None, "length": x.shape[-1]}
        else:
            stale_j = None if stale is None else jnp.asarray(stale)
            data, stale_w = self._enc(xj, key, stale_j)
            self._held = {"wire1": np.asarray(data), "lo": None, "step": None,
                          "stale_w": stale_w, "key": key,
                          "length": x.shape[-1]}
        if tr is not None:
            tr.complete("encode", "wire", ts=t0,
                        dur=self.backend.now(self.rank) - t0, tid=self.rank,
                        args={"step": step, "bucket": bucket})

    def phase2_send_stage1(self, step: int, bucket: int) -> None:
        """Finish the encode (grid max-share for quantizing codecs) and put
        every stage-1 shard on the wire."""
        tr = obs_trace.get_tracer()
        t0 = self.backend.now(self.rank) if tr is not None else 0.0
        h = self._held
        if isinstance(self.codec, HTQuant):
            shared = h["amax"].copy()
            nblk = shared.shape[0]
            deadline = self.round_deadline()
            for p in range(self.n):
                if p == self.rank or (self.membership is not None and
                                      not self.membership.is_live(p)):
                    continue
                reas, _, _ = self._recv_stream(KIND_CTRL, step, bucket, 0, p,
                                               nblk, np.float32, deadline,
                                               packet_elems=max(1, nblk))
                if reas.complete:     # a lost grid degrades, never blocks
                    shared = np.maximum(shared, reas.payload())
            data, lo, stp = self._enc_finish(h["x1"], jnp.asarray(shared),
                                             h["key"])
            h["wire1"], h["lo"], h["step"] = np.asarray(data), lo, stp
            del h["x1"], h["amax"]
        wire1 = h["wire1"]
        if self.shard_weights is not None:
            # weighted shard geometry: rank p owns the contiguous slice
            # [offsets[p], offsets[p]+sizes[p]) — rows are zero-padded to
            # the static s_max exactly like ``tar.weighted_rows``
            plan = tar_lib.shard_plan(wire1.shape[0], self.shard_weights,
                                      self.codec.block(self.cfg))
            if plan.padded != wire1.shape[0]:
                raise ValueError(
                    f"encoded bucket of {wire1.shape[0]} elements is not "
                    f"padded for weights {self.shard_weights} "
                    f"(need a multiple of {plan.padded})")
            shards = np.zeros((self.n, plan.s_max), wire1.dtype)
            for p in range(self.n):
                shards[p, :plan.sizes[p]] = \
                    wire1[plan.offsets[p]:plan.offsets[p] + plan.sizes[p]]
            h["plan"], h["shards"] = plan, shards
            self._send_shards(shards, KIND_DATA1, step, bucket,
                              sizes=plan.sizes)
        else:
            s = wire1.shape[0] // self.n
            h["plan"] = None
            h["shards"] = wire1.reshape(self.n, s)
            self._send_shards(h["shards"], KIND_DATA1, step, bucket)
        if tr is not None:
            tr.complete("send_stage1", "wire", ts=t0,
                        dur=self.backend.now(self.rank) - t0, tid=self.rank,
                        args={"step": step, "bucket": bucket})

    def phase3_reduce_send_stage2(self, step: int, bucket: int) -> PeerReport:
        """Receive stage 1 under the per-round deadlines, run the codec's
        compensated reduce, and broadcast the re-encoded shard."""
        tr = obs_trace.get_tracer()
        t0 = self.backend.now(self.rank) if tr is not None else 0.0
        h = self._held
        plan = h["plan"]
        s = h["shards"].shape[1]
        # under weighted shards every sender posts me *my* slice — a stream
        # of sizes[me] elements — into a row zero-padded to the static s_max
        valid = s if plan is None else plan.sizes[self.rank]
        streams, report = self._recv_rounds(KIND_DATA1, step, bucket, valid,
                                            h["wire1"].dtype)
        sizes1 = None if plan is None else (valid,) * self.n
        received, mask = self._assemble(streams, h["shards"][self.rank], s,
                                        h["wire1"].dtype, sizes=sizes1)
        # skipped (known-dead) senders' all-zero rows are planned
        # degradation, not packet loss: exclude them from both counters so
        # loss_frac keeps driving the Hadamard/incast controllers correctly
        # (weighted: count only the valid prefixes — padding cannot drop)
        skipped = len(report.skipped_senders)
        report.dropped = float(np.sum(1.0 - mask[:, :valid])) \
            - skipped * valid
        report.total = float(self.n * valid) - skipped * valid
        wire2 = np.asarray(self._red(
            jnp.asarray(received), jnp.asarray(mask),
            jnp.asarray(self.rank, jnp.int32), h["lo"], h["step"],
            h["stale_w"], h["key"]))
        h["wire2"], h["mask1"] = wire2, mask
        self.last_mask1 = mask            # observed arrival mask, kept for
        # EF accounting; weighted broadcasts only the owned valid prefix
        out2 = wire2 if plan is None else wire2[:valid]
        self._send_shards(out2, KIND_DATA2, step, bucket)
        if tr is not None:
            tr.complete("exchange", "wire", ts=t0,
                        dur=self.backend.now(self.rank) - t0, tid=self.rank,
                        args={"step": step, "bucket": bucket,
                              "dropped": report.dropped,
                              "total": report.total})
        return report

    def phase4_decode(self, step: int, bucket: int
                      ) -> tuple[np.ndarray, PeerReport]:
        """Receive the stage-2 broadcast, reassemble the flat bucket, and
        decode.  A missing stage-2 span stays zero — a real gap the codec
        decodes through (drops are modeled on stage 1; see DESIGN §2) —
        and is charged to ``stage2_dropped``."""
        tr = obs_trace.get_tracer()
        t0 = self.backend.now(self.rank) if tr is not None else 0.0
        h = self._held
        plan = h["plan"]
        s2 = h["wire2"].shape[0]
        if plan is None:
            streams, report = self._recv_rounds(KIND_DATA2, step, bucket, s2,
                                                h["wire2"].dtype)
            gathered, mask2 = self._assemble(streams, h["wire2"], s2,
                                             h["wire2"].dtype)
            skipped = len(report.skipped_senders)
            report.stage2_dropped = float(np.sum(1.0 - mask2)) - skipped * s2
            report.stage2_total = float(mask2.size) - skipped * s2
            flat = gathered.reshape(-1)
        else:
            # each owner q broadcast its own-size slice: per-sender stream
            # lengths, per-row valid prefixes, and a weighted_flat-style
            # concatenation of the prefixes back into the flat bucket
            sizes = plan.sizes
            streams, report = self._recv_rounds(
                KIND_DATA2, step, bucket, lambda q: sizes[q],
                h["wire2"].dtype)
            gathered, mask2 = self._assemble(streams, h["wire2"], s2,
                                             h["wire2"].dtype, sizes=sizes)
            skip_elems = float(sum(sizes[p] for p in report.skipped_senders))
            drop2 = float(sum(np.sum(1.0 - mask2[p, :sizes[p]])
                              for p in range(self.n)))
            report.stage2_dropped = drop2 - skip_elems
            report.stage2_total = float(sum(sizes)) - skip_elems
            flat = np.concatenate([gathered[p, :sizes[p]]
                                   for p in range(self.n)])
        self.last_mask2 = mask2
        out = np.asarray(self._dec(jnp.asarray(flat),
                                   h["lo"], h["step"], h["key"]))
        out = out[:h["length"]]
        self._held = {}
        if tr is not None:
            tr.complete("decode", "wire", ts=t0,
                        dur=self.backend.now(self.rank) - t0, tid=self.rank,
                        args={"step": step, "bucket": bucket,
                              "stage2_dropped": report.stage2_dropped,
                              "stage2_total": report.stage2_total})
        return out, report

    # ------------------------------------------------------- bridge mode
    def bridge_receive(self, shards: np.ndarray, step: int, bucket: int
                       ) -> tuple[np.ndarray, PeerReport]:
        """One receiver's half of a bridge exchange whose sends are already
        posted (the HostRing completer drives every peer's sends first,
        then each receive, in one thread — no cross-thread rendezvous
        anywhere): receive stage 1 under the adaptive deadlines and return
        the observed (n, s) arrival mask (the in-JAX all_to_all moves the
        authoritative bytes)."""
        n, me = self.n, self.rank
        if shards.shape[0] != n:
            raise ValueError(f"bridge expects (n={n}, s) shards, "
                             f"got {shards.shape}")
        s = shards.shape[1]
        streams, report = self._recv_rounds(KIND_DATA1, step, bucket, s,
                                            shards.dtype)
        _, mask = self._assemble(streams, shards[me], s, shards.dtype)
        skipped = len(report.skipped_senders)
        report.dropped = float(np.sum(1.0 - mask)) - skipped * s
        report.total = float(mask.size) - skipped * s
        return mask, report

    def bridge_send(self, shards: np.ndarray, step: int, bucket: int) -> None:
        """Post this peer's stage-1 sends for a bridge exchange."""
        self._store.clear()
        self._send_shards(shards, KIND_DATA1, step, bucket)
