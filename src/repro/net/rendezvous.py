"""Socket rendezvous: rank assignment, elastic membership, barriers (DESIGN §9).

The multi-process peer runtime's control endpoint.  One coordinator (a TCP
server, or its in-memory twin for socket-free CI) owns the authoritative
*membership*: which uids currently hold which of the ``world_size`` rank
slots.  Every membership change — a JOIN claiming the lowest free slot, an
explicit LEAVE, a death detected by TCP EOF or heartbeat silence — bumps a
monotonic **generation** number, so any two views of the world are ordered
and a stale UPDATE can never roll a client backwards.

The coordinator also runs the launch-critical **phase barriers**: a peer
step is ``PHASES_PER_STEP`` fenced phases and barrier *tag* ``step * PHASES
_PER_STEP + phase`` is a total order over the run.  Each member carries a
``since`` tag — the first barrier it is required at (0 for the initial
cohort, the next step boundary for a rejoiner) — and a tag releases when
every *live* member with ``since <= tag`` has arrived.  That single rule
gives elasticity for free: a crashed peer stops being required the moment
its death is processed (the survivors' next fence releases degraded), and
a restarted peer is only awaited from its own future step boundary, so a
rejoin can never deadlock fences already in flight.

Message codec mirrors ``wire.py`` discipline — a fixed 16-byte struct
header (+ a length-prefixed payload), property-tested for roundtrip,
chunked-delivery invariance, and generation monotonicity.

Layering: :class:`RendezvousState` is the pure, transport-free state
machine (what the property tests drive); :class:`RendezvousServer` /
:class:`RendezvousClient` are its TCP shell; :class:`LocalCoordinator` /
:class:`LocalClient` the in-memory shell behind ``repro.launch.multiproc
--backend=inproc``.  Clients double as the **membership view** the
refactored :class:`~repro.net.peer.HostPeer` consumes (``is_live`` /
``generation`` / ``addr_of``) in place of a fixed peer list.
"""
from __future__ import annotations

import dataclasses
import selectors
import socket
import struct
import threading
import time
from collections import deque

RENDEZVOUS_VERSION = 1

#: one peer step = 4 fenced phases (encode | send1 | reduce+send2 | decode)
PHASES_PER_STEP = 4

# header: version, kind, rank (signed; -1 = unassigned), world_size,
# generation, seq (barrier tag / since tag / event code), payload length
MSG_HEADER_FMT = "!BBhHIIH"
MSG_HEADER_BYTES = struct.calcsize(MSG_HEADER_FMT)          # 16

MSG_JOIN = 1        # client -> server: claim a rank (payload: uid/host/port)
MSG_WELCOME = 2     # server -> client: assigned rank + membership blob
MSG_UPDATE = 3      # server -> client: membership changed (seq = event code)
MSG_HEARTBEAT = 4   # client -> server: liveness
MSG_LEAVE = 5       # client -> server: graceful departure
MSG_BARRIER = 6     # client -> server: arrived at barrier tag `seq`
MSG_RELEASE = 7     # server -> client: barrier tag `seq` released
MSG_REJECT = 8      # server -> client: join refused (payload: reason)

_MSG_KINDS = (MSG_JOIN, MSG_WELCOME, MSG_UPDATE, MSG_HEARTBEAT, MSG_LEAVE,
              MSG_BARRIER, MSG_RELEASE, MSG_REJECT)

EV_JOIN = 1
EV_LEAVE = 2
EV_DEATH = 3
_EVENT_NAMES = {EV_JOIN: "join", EV_LEAVE: "leave", EV_DEATH: "death"}

_JOIN_FMT = "!QH"                                   # uid, advertised port
_MEMBER_FMT = "!HQHIB"                              # rank, uid, port, since,
_BLOB_FMT = "!IHH"                                  # generation, world, count


class RendezvousError(Exception):
    """A message or transition that cannot belong to this protocol."""


class RendezvousFull(RendezvousError):
    """JOIN with no free rank slot."""


class RendezvousTimeout(RendezvousError):
    """A bounded wait (join, barrier) expired."""


# ---------------------------------------------------------------- messages
@dataclasses.dataclass(frozen=True)
class RendezvousMessage:
    """One coordinator-protocol message (see module docstring)."""
    kind: int
    rank: int = -1
    world: int = 0
    generation: int = 0
    seq: int = 0
    payload: bytes = b""

    def encode(self) -> bytes:
        if len(self.payload) > 0xFFFF:
            raise RendezvousError(f"payload of {len(self.payload)} bytes "
                                  "exceeds the 16-bit length field")
        return struct.pack(MSG_HEADER_FMT, RENDEZVOUS_VERSION, self.kind,
                           self.rank, self.world, self.generation,
                           self.seq, len(self.payload)) + self.payload

    @classmethod
    def decode(cls, buf: bytes) -> tuple["RendezvousMessage", int] | None:
        """Decode one message from a byte stream prefix.

        Returns ``(message, bytes_consumed)``, or None when ``buf`` holds
        only a partial message (stream framing: wait for more bytes).
        Raises :class:`RendezvousError` for bytes that cannot be a message.
        """
        if len(buf) < MSG_HEADER_BYTES:
            return None
        version, kind, rank, world, generation, seq, plen = \
            struct.unpack_from(MSG_HEADER_FMT, buf)
        if version != RENDEZVOUS_VERSION:
            raise RendezvousError(
                f"rendezvous version {version} != {RENDEZVOUS_VERSION}")
        if kind not in _MSG_KINDS:
            raise RendezvousError(f"unknown message kind {kind}")
        end = MSG_HEADER_BYTES + plen
        if len(buf) < end:
            return None
        return cls(kind=kind, rank=rank, world=world, generation=generation,
                   seq=seq, payload=bytes(buf[MSG_HEADER_BYTES:end])), end


class FrameBuffer:
    """Accumulate an arbitrarily-chunked byte stream into whole messages.

    TCP delivers a byte stream, not datagrams; :meth:`feed` is invariant to
    how the stream was chunked (the property the hypothesis suite pins).
    """

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> list[RendezvousMessage]:
        self._buf.extend(data)
        out = []
        while True:
            got = RendezvousMessage.decode(bytes(self._buf))
            if got is None:
                return out
            msg, used = got
            del self._buf[:used]
            out.append(msg)


def encode_join(uid: int, host: str, port: int) -> bytes:
    hb = host.encode()
    return struct.pack(_JOIN_FMT, uid, port) + hb


def decode_join(payload: bytes) -> tuple[int, str, int]:
    if len(payload) < struct.calcsize(_JOIN_FMT):
        raise RendezvousError("truncated JOIN payload")
    uid, port = struct.unpack_from(_JOIN_FMT, payload)
    return uid, payload[struct.calcsize(_JOIN_FMT):].decode(), port


# -------------------------------------------------------------- membership
@dataclasses.dataclass(frozen=True)
class Member:
    """One live rank slot."""
    rank: int
    uid: int
    host: str = ""
    port: int = 0
    since: int = 0          # first barrier tag this member is required at


@dataclasses.dataclass(frozen=True)
class Membership:
    """A generation-stamped snapshot of the live world."""
    generation: int
    world_size: int
    members: tuple[Member, ...] = ()

    def live_ranks(self) -> tuple[int, ...]:
        return tuple(m.rank for m in self.members)

    def is_live(self, rank: int) -> bool:
        return any(m.rank == rank for m in self.members)

    def addr_of(self, rank: int) -> tuple[str, int] | None:
        for m in self.members:
            if m.rank == rank:
                return (m.host, m.port)
        return None

    def encode(self) -> bytes:
        out = [struct.pack(_BLOB_FMT, self.generation, self.world_size,
                           len(self.members))]
        for m in self.members:
            hb = m.host.encode()
            if len(hb) > 0xFF:
                raise RendezvousError(f"host {m.host!r} too long")
            out.append(struct.pack(_MEMBER_FMT, m.rank, m.uid, m.port,
                                   m.since, len(hb)) + hb)
        return b"".join(out)

    @classmethod
    def decode(cls, payload: bytes) -> "Membership":
        base = struct.calcsize(_BLOB_FMT)
        if len(payload) < base:
            raise RendezvousError("truncated membership blob")
        generation, world, count = struct.unpack_from(_BLOB_FMT, payload)
        off, members = base, []
        msz = struct.calcsize(_MEMBER_FMT)
        for _ in range(count):
            if len(payload) < off + msz:
                raise RendezvousError("truncated membership member")
            rank, uid, port, since, hlen = struct.unpack_from(
                _MEMBER_FMT, payload, off)
            off += msz
            if len(payload) < off + hlen:
                raise RendezvousError("truncated member host")
            host = payload[off:off + hlen].decode()
            off += hlen
            members.append(Member(rank=rank, uid=uid, host=host, port=port,
                                  since=since))
        return cls(generation=generation, world_size=world,
                   members=tuple(members))


class StaticMembership:
    """The fixed-world view: every rank of an ``n``-peer job is live.

    What a :class:`~repro.net.peer.HostPeer` without a rendezvous gets —
    exactly the pre-refactor "fixed peer list" behavior.
    """

    def __init__(self, n: int):
        self.n = int(n)
        self.generation = 0

    def is_live(self, rank: int) -> bool:
        return 0 <= rank < self.n

    def addr_of(self, rank: int) -> tuple[str, int] | None:
        return None


# ----------------------------------------------------- pure state machine
@dataclasses.dataclass
class _Slot:
    uid: int
    host: str
    port: int
    since: int
    last_seen: float


class RendezvousState:
    """Transport-free membership + barrier core (see module docstring).

    Every mutation is synchronous and deterministic; the TCP and in-memory
    shells serialize calls (one server thread / one lock), and the property
    suite drives this class directly with arbitrary interleavings.
    """

    def __init__(self, world_size: int, *,
                 phases_per_step: int = PHASES_PER_STEP,
                 heartbeat_timeout: float = 6.0,
                 wait_for: int | None = None):
        if world_size < 1:
            raise ValueError("world_size must be >= 1")
        self.world_size = int(world_size)
        self.phases = int(phases_per_step)
        self.heartbeat_timeout = float(heartbeat_timeout)
        #: members needed before the *first* barrier may release (the
        #: initial gather — torch-style init waits for the full world)
        self.wait_for = self.world_size if wait_for is None else int(wait_for)
        self.generation = 0
        self.started = False
        self.max_tag = -1
        self._slots: dict[int, _Slot] = {}
        self._arrivals: dict[int, set[int]] = {}

    # ------------------------------------------------------------- queries
    def live_ranks(self) -> tuple[int, ...]:
        return tuple(sorted(self._slots))

    def latest_step(self) -> int:
        return self.max_tag // self.phases if self.max_tag >= 0 else -1

    def membership(self) -> Membership:
        return Membership(
            generation=self.generation, world_size=self.world_size,
            members=tuple(Member(rank=r, uid=s.uid, host=s.host, port=s.port,
                                 since=s.since)
                          for r, s in sorted(self._slots.items())))

    # ----------------------------------------------------------- mutations
    def join(self, uid: int, host: str, port: int,
             now: float) -> tuple[int, int]:
        """Claim the lowest free rank slot; returns ``(rank, since_tag)``.

        The initial cohort (pre-start) is required from tag 0; a joiner of
        a running group only from the next step boundary — fences already
        in flight must never start waiting on it retroactively.
        """
        free = [r for r in range(self.world_size) if r not in self._slots]
        if not free:
            raise RendezvousFull(
                f"all {self.world_size} rank slots are held")
        rank = free[0]
        since = 0 if not self.started else \
            (self.max_tag // self.phases + 1) * self.phases
        self._slots[rank] = _Slot(uid=uid, host=host, port=port, since=since,
                                  last_seen=now)
        self.generation += 1
        self._maybe_start()
        return rank, since

    def leave(self, rank: int) -> bool:
        return self._remove(rank)

    def dead(self, rank: int) -> bool:
        return self._remove(rank)

    def _remove(self, rank: int) -> bool:
        if rank not in self._slots:
            return False
        del self._slots[rank]
        self.generation += 1
        return True

    def heartbeat(self, rank: int, now: float) -> None:
        slot = self._slots.get(rank)
        if slot is not None:
            slot.last_seen = now

    def expire(self, now: float) -> list[int]:
        """Ranks silent past the heartbeat timeout, removed as deaths."""
        gone = [r for r, s in self._slots.items()
                if now - s.last_seen > self.heartbeat_timeout]
        for r in gone:
            self._remove(r)
        return gone

    # ------------------------------------------------------------ barriers
    def barrier_arrive(self, rank: int, tag: int) -> None:
        if rank not in self._slots:
            return
        self.max_tag = max(self.max_tag, int(tag))
        self._arrivals.setdefault(int(tag), set()).add(rank)

    def _maybe_start(self) -> None:
        if not self.started and len(self._slots) >= self.wait_for:
            self.started = True

    def release_ready(self) -> dict[int, tuple[int, ...]]:
        """Barrier tags whose every required live member has arrived.

        Returns ``{tag: ranks_to_notify}`` (arrived ranks still live) and
        retires those tags.  Call after every arrival *and* every
        membership change — a death is what releases a fence the group was
        holding for the dead peer.
        """
        self._maybe_start()
        if not self.started:
            return {}
        out = {}
        for tag in sorted(self._arrivals):
            need = {r for r, s in self._slots.items() if s.since <= tag}
            arrived = self._arrivals[tag]
            if need and need <= arrived:
                out[tag] = tuple(sorted(arrived & set(self._slots)))
        for tag in out:
            del self._arrivals[tag]
        return out


# ----------------------------------------------------------- TCP transport
def tcp_available() -> bool:
    """Can this process bind a localhost TCP socket?"""
    try:
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        try:
            s.bind(("127.0.0.1", 0))
        finally:
            s.close()
        return True
    except OSError:
        return False


class _Conn:
    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.fb = FrameBuffer()
        self.rank: int | None = None


class RendezvousServer:
    """TCP shell around :class:`RendezvousState` — one coordinator thread.

    Death detection is two-layer: a SIGKILLed peer's socket EOF arrives
    within one select tick (the fast path the smoke test exercises), and
    heartbeat expiry catches half-open connections the kernel never
    closes.
    """

    def __init__(self, world_size: int, *, host: str = "127.0.0.1",
                 port: int = 0, phases_per_step: int = PHASES_PER_STEP,
                 heartbeat_timeout: float = 6.0, wait_for: int | None = None,
                 tick: float = 0.2):
        self._lock = threading.Lock()
        self.state = RendezvousState(world_size,
                                     phases_per_step=phases_per_step,
                                     heartbeat_timeout=heartbeat_timeout,
                                     wait_for=wait_for)
        self.tick = float(tick)
        self._sel = selectors.DefaultSelector()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(world_size * 2 + 4)
        self._listener.setblocking(False)
        self.addr: tuple[str, int] = self._listener.getsockname()
        self._sel.register(self._listener, selectors.EVENT_READ, None)
        self._conns: dict[socket.socket, _Conn] = {}
        self._closing = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="rendezvous-server")
        self._thread.start()

    # ------------------------------------------------------ parent queries
    def latest_step(self) -> int:
        with self._lock:
            return self.state.latest_step()

    def live_ranks(self) -> tuple[int, ...]:
        with self._lock:
            return self.state.live_ranks()

    def generation(self) -> int:
        with self._lock:
            return self.state.generation

    def close(self) -> None:
        self._closing = True
        self._thread.join(timeout=5.0)
        for conn in list(self._conns):
            self._drop_sock(conn)
        try:
            self._sel.unregister(self._listener)
        except (KeyError, ValueError):
            pass
        self._listener.close()
        self._sel.close()

    # --------------------------------------------------------- server loop
    def _run(self) -> None:
        while not self._closing:
            for key, _ in self._sel.select(self.tick):
                if key.fileobj is self._listener:
                    self._accept()
                else:
                    self._read(key.fileobj)
            with self._lock:
                gone = self.state.expire(time.monotonic())
            for rank in gone:
                self._after_death(rank)

    def _accept(self) -> None:
        try:
            sock, _ = self._listener.accept()
        except OSError:
            return
        sock.setblocking(True)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._conns[sock] = _Conn(sock)
        self._sel.register(sock, selectors.EVENT_READ, None)

    def _read(self, sock: socket.socket) -> None:
        conn = self._conns.get(sock)
        if conn is None:
            return
        try:
            data = sock.recv(1 << 16)
        except OSError:
            data = b""
        if not data:                                # EOF = death
            self._drop_conn(conn)
            return
        try:
            msgs = conn.fb.feed(data)
        except RendezvousError:
            self._drop_conn(conn)
            return
        for msg in msgs:
            self._handle(conn, msg)

    def _drop_sock(self, sock: socket.socket) -> None:
        try:
            self._sel.unregister(sock)
        except (KeyError, ValueError):
            pass
        self._conns.pop(sock, None)
        try:
            sock.close()
        except OSError:
            pass

    def _drop_conn(self, conn: _Conn) -> None:
        rank = conn.rank
        self._drop_sock(conn.sock)
        if rank is None:
            return
        with self._lock:
            removed = self.state.dead(rank)
        if removed:
            self._after_death(rank)

    def _after_death(self, rank: int) -> None:
        self._broadcast_update(EV_DEATH, rank)
        self._release_ready()

    # ----------------------------------------------------------- messaging
    def _send(self, conn: _Conn, msg: RendezvousMessage) -> None:
        try:
            conn.sock.sendall(msg.encode())
        except OSError:
            self._drop_conn(conn)

    def _rank_conns(self) -> dict[int, _Conn]:
        return {c.rank: c for c in self._conns.values() if c.rank is not None}

    def _broadcast_update(self, event: int, subject_rank: int) -> None:
        with self._lock:
            mem = self.state.membership()
        msg = RendezvousMessage(kind=MSG_UPDATE, rank=subject_rank,
                                world=mem.world_size,
                                generation=mem.generation, seq=event,
                                payload=mem.encode())
        for conn in list(self._rank_conns().values()):
            if conn.rank != subject_rank:
                self._send(conn, msg)

    def _release_ready(self) -> None:
        with self._lock:
            ready = self.state.release_ready()
            mem = self.state.membership()
        if not ready:
            return
        by_rank = self._rank_conns()
        for tag, ranks in ready.items():
            msg = RendezvousMessage(kind=MSG_RELEASE, world=mem.world_size,
                                    generation=mem.generation, seq=tag,
                                    payload=mem.encode())
            for r in ranks:
                conn = by_rank.get(r)
                if conn is not None:
                    self._send(conn, msg)

    def _handle(self, conn: _Conn, msg: RendezvousMessage) -> None:
        if msg.kind == MSG_JOIN:
            uid, host, port = decode_join(msg.payload)
            if not host:
                host = conn.sock.getpeername()[0]
            try:
                with self._lock:
                    rank, since = self.state.join(uid, host, port,
                                                  time.monotonic())
                    mem = self.state.membership()
            except RendezvousFull as e:
                self._send(conn, RendezvousMessage(
                    kind=MSG_REJECT, payload=str(e).encode()))
                return
            conn.rank = rank
            self._send(conn, RendezvousMessage(
                kind=MSG_WELCOME, rank=rank, world=mem.world_size,
                generation=mem.generation, seq=since, payload=mem.encode()))
            self._broadcast_update(EV_JOIN, rank)
            self._release_ready()
        elif msg.kind == MSG_HEARTBEAT:
            if conn.rank is not None:
                with self._lock:
                    self.state.heartbeat(conn.rank, time.monotonic())
        elif msg.kind == MSG_LEAVE:
            rank = conn.rank
            conn.rank = None                  # a LEAVE'd conn is not a death
            self._drop_sock(conn.sock)
            if rank is not None:
                with self._lock:
                    removed = self.state.leave(rank)
                if removed:
                    self._broadcast_update(EV_LEAVE, rank)
                    self._release_ready()
        elif msg.kind == MSG_BARRIER:
            if conn.rank is not None:
                with self._lock:
                    self.state.barrier_arrive(conn.rank, msg.seq)
                self._release_ready()
        # WELCOME/UPDATE/RELEASE/REJECT are server->client only: ignore


class _ClientCore:
    """Shared client-side view state: max-generation membership snapshot,
    drained event queue, released barrier tags."""

    def __init__(self):
        self.cv = threading.Condition()
        self.membership: Membership | None = None
        self.events: deque[tuple[str, int, int]] = deque()
        self.released: set[int] = set()
        self.error: Exception | None = None

    def apply(self, mem: Membership, event: tuple[str, int, int] | None
              ) -> None:
        with self.cv:
            # duplicate / out-of-order UPDATE invariance: only a strictly
            # newer generation can move the snapshot
            if self.membership is None or \
                    mem.generation > self.membership.generation:
                self.membership = mem
            if event is not None:
                self.events.append(event)
            self.cv.notify_all()

    def release(self, tag: int, mem: Membership) -> None:
        with self.cv:
            if self.membership is None or \
                    mem.generation > self.membership.generation:
                self.membership = mem
            self.released.add(tag)
            if len(self.released) > 4 * PHASES_PER_STEP:
                for old in sorted(self.released)[:-2 * PHASES_PER_STEP]:
                    self.released.discard(old)
            self.cv.notify_all()

    def fail(self, exc: Exception) -> None:
        with self.cv:
            if self.error is None:
                self.error = exc
            self.cv.notify_all()


class RendezvousClient:
    """One peer's TCP connection to the coordinator + its membership view.

    Doubles as the :class:`~repro.net.peer.HostPeer` membership view
    (``is_live`` / ``generation``) and the :class:`~repro.net.udp.
    UdpProcessBackend` address resolver (``addr_of``).
    """

    def __init__(self, addr: tuple[str, int], *, uid: int,
                 peer_host: str = "127.0.0.1", peer_port: int = 0,
                 heartbeat_interval: float = 1.0,
                 connect_timeout: float = 20.0):
        self.uid = int(uid)
        self.peer_host = peer_host
        self.peer_port = int(peer_port)
        self.heartbeat_interval = float(heartbeat_interval)
        self.rank: int | None = None
        self.start_step: int | None = None
        self._core = _ClientCore()
        self._send_lock = threading.Lock()
        self._closed = False
        deadline = time.monotonic() + connect_timeout
        while True:                 # the coordinator may not be up yet
            try:
                self._sock = socket.create_connection(addr, timeout=2.0)
                break
            except OSError:
                if time.monotonic() >= deadline:
                    raise RendezvousTimeout(
                        f"could not reach coordinator at {addr}")
                time.sleep(0.05)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock.settimeout(0.2)
        self._reader = threading.Thread(target=self._read_loop, daemon=True,
                                        name=f"rendezvous-client-{uid}")
        self._reader.start()

    # ----------------------------------------------------------- transport
    def _send(self, msg: RendezvousMessage) -> None:
        with self._send_lock:
            try:
                self._sock.sendall(msg.encode())
            except OSError as e:
                self._core.fail(RendezvousError(f"coordinator send: {e}"))
                raise self._core.error from e

    def _read_loop(self) -> None:
        fb = FrameBuffer()
        last_hb = time.monotonic()
        while not self._closed:
            now = time.monotonic()
            if self.rank is not None and \
                    now - last_hb >= self.heartbeat_interval:
                last_hb = now
                try:
                    self._send(RendezvousMessage(kind=MSG_HEARTBEAT,
                                                 rank=self.rank))
                except RendezvousError:
                    return
            try:
                data = self._sock.recv(1 << 16)
            except socket.timeout:
                continue
            except OSError:
                data = b""
            if not data:
                if not self._closed:
                    self._core.fail(RendezvousError("coordinator hung up"))
                return
            try:
                msgs = fb.feed(data)
            except RendezvousError as e:
                self._core.fail(e)
                return
            for msg in msgs:
                self._dispatch(msg)

    def _dispatch(self, msg: RendezvousMessage) -> None:
        if msg.kind == MSG_WELCOME:
            mem = Membership.decode(msg.payload)
            with self._core.cv:
                self.rank = msg.rank
                self.start_step = msg.seq // PHASES_PER_STEP
            self._core.apply(mem, None)
        elif msg.kind == MSG_UPDATE:
            mem = Membership.decode(msg.payload)
            name = _EVENT_NAMES.get(msg.seq, "death")
            self._core.apply(mem, (name, msg.rank, msg.generation))
        elif msg.kind == MSG_RELEASE:
            self._core.release(msg.seq, Membership.decode(msg.payload))
        elif msg.kind == MSG_REJECT:
            self._core.fail(RendezvousFull(msg.payload.decode() or
                                           "join rejected"))

    # ------------------------------------------------------------ protocol
    def join(self, timeout: float = 30.0) -> tuple[int, Membership, int]:
        """Claim a rank; returns ``(rank, membership, start_step)``."""
        self._send(RendezvousMessage(
            kind=MSG_JOIN,
            payload=encode_join(self.uid, self.peer_host, self.peer_port)))
        deadline = time.monotonic() + timeout
        with self._core.cv:
            while self.rank is None:
                if self._core.error is not None:
                    raise self._core.error
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise RendezvousTimeout("join: no WELCOME from "
                                            "coordinator")
                self._core.cv.wait(remaining)
            return self.rank, self._core.membership, self.start_step

    def barrier(self, tag: int, timeout: float = 120.0) -> None:
        """Arrive at barrier ``tag`` and block until the coordinator
        releases it (all required live members arrived)."""
        self._send(RendezvousMessage(kind=MSG_BARRIER, rank=self.rank or 0,
                                     seq=tag))
        deadline = time.monotonic() + timeout
        with self._core.cv:
            while tag not in self._core.released:
                if self._core.error is not None:
                    raise self._core.error
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise RendezvousTimeout(f"barrier tag {tag} not "
                                            f"released in {timeout}s")
                self._core.cv.wait(remaining)
            self._core.released.discard(tag)

    def events(self) -> list[tuple[str, int, int]]:
        """Drain pending membership events: ``(kind, rank, generation)``."""
        with self._core.cv:
            out = list(self._core.events)
            self._core.events.clear()
        return out

    # ----------------------------------------------------- membership view
    @property
    def generation(self) -> int:
        with self._core.cv:
            return 0 if self._core.membership is None else \
                self._core.membership.generation

    def membership(self) -> Membership | None:
        with self._core.cv:
            return self._core.membership

    def is_live(self, rank: int) -> bool:
        with self._core.cv:
            return self._core.membership is None or \
                self._core.membership.is_live(rank)

    def addr_of(self, rank: int) -> tuple[str, int] | None:
        with self._core.cv:
            return None if self._core.membership is None else \
                self._core.membership.addr_of(rank)

    # ------------------------------------------------------------ shutdown
    def leave(self) -> None:
        try:
            self._send(RendezvousMessage(kind=MSG_LEAVE,
                                         rank=self.rank or 0))
        except RendezvousError:
            pass
        self.close()

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass


# ------------------------------------------------------- in-memory shell
class LocalCoordinator:
    """In-memory twin of :class:`RendezvousServer` for the socket-free
    ``--backend=inproc`` launch path: same :class:`RendezvousState`, same
    client API (:class:`LocalClient` mirrors :class:`RendezvousClient`),
    Condition-based instead of TCP.  A thread "process" that crashes calls
    :meth:`LocalClient.crash` — the EOF analogue."""

    def __init__(self, world_size: int, *,
                 phases_per_step: int = PHASES_PER_STEP,
                 wait_for: int | None = None):
        self._cv = threading.Condition()
        self.state = RendezvousState(world_size,
                                     phases_per_step=phases_per_step,
                                     wait_for=wait_for)
        self._released: dict[int, Membership] = {}
        self._clients: list["LocalClient"] = []

    def client(self, uid: int) -> "LocalClient":
        c = LocalClient(self, uid)
        with self._cv:
            self._clients.append(c)
        return c

    def latest_step(self) -> int:
        with self._cv:
            return self.state.latest_step()

    def live_ranks(self) -> tuple[int, ...]:
        with self._cv:
            return self.state.live_ranks()

    def close(self) -> None:
        pass

    # called with self._cv held
    def _after_change(self, event: tuple[str, int, int] | None,
                      subject: "LocalClient | None") -> None:
        for tag in self.state.release_ready():
            self._released[tag] = self.state.membership()
        if len(self._released) > 64:
            for old in sorted(self._released)[:-32]:
                del self._released[old]
        mem = self.state.membership()
        for c in self._clients:
            if c is subject or c.dead:
                continue
            c._membership = mem
            if event is not None:
                c._events.append(event)
        self._cv.notify_all()


class LocalClient:
    """In-memory mirror of :class:`RendezvousClient` (same duck type)."""

    def __init__(self, coord: LocalCoordinator, uid: int):
        self._coord = coord
        self.uid = int(uid)
        self.rank: int | None = None
        self.start_step: int | None = None
        self.dead = False
        self._membership: Membership | None = None
        self._events: deque[tuple[str, int, int]] = deque()

    def join(self, timeout: float = 30.0) -> tuple[int, Membership, int]:
        co, st = self._coord, self._coord.state
        with co._cv:
            rank, since = st.join(self.uid, "", 0, now=0.0)
            self.rank = rank
            self.start_step = since // st.phases
            self._membership = st.membership()
            co._after_change(("join", rank, st.generation), self)
            return rank, self._membership, self.start_step

    def barrier(self, tag: int, timeout: float = 120.0) -> None:
        co, st = self._coord, self._coord.state
        deadline = time.monotonic() + timeout
        with co._cv:
            st.barrier_arrive(self.rank, tag)
            co._after_change(None, None)
            while tag not in co._released:
                if self.dead:
                    raise RendezvousError("client crashed")
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise RendezvousTimeout(f"barrier tag {tag} not "
                                            f"released in {timeout}s")
                co._cv.wait(remaining)
            mem = co._released[tag]
            if self._membership is None or \
                    mem.generation > self._membership.generation:
                self._membership = mem

    def events(self) -> list[tuple[str, int, int]]:
        with self._coord._cv:
            out = list(self._events)
            self._events.clear()
        return out

    @property
    def generation(self) -> int:
        with self._coord._cv:
            return 0 if self._membership is None else \
                self._membership.generation

    def membership(self) -> Membership | None:
        with self._coord._cv:
            return self._membership

    def is_live(self, rank: int) -> bool:
        with self._coord._cv:
            return self._membership is None or self._membership.is_live(rank)

    def addr_of(self, rank: int) -> tuple[str, int] | None:
        return None

    def leave(self) -> None:
        self._end("leave")

    def crash(self) -> None:
        """Simulate a process death (the TCP-EOF analogue)."""
        self._end("death")

    def close(self) -> None:
        pass

    def _end(self, how: str) -> None:
        co, st = self._coord, self._coord.state
        with co._cv:
            if self.dead or self.rank is None:
                return
            self.dead = True
            removed = st.leave(self.rank) if how == "leave" \
                else st.dead(self.rank)
            if removed:
                co._after_change((how, self.rank, st.generation), self)
