"""Host-datapath UBT wire transport (DESIGN §7).

The paper's core artifact — best-effort packetized gradient exchange with
an adaptive per-round receive deadline, where *actually missing* packets
become the arrival mask the compensated mean absorbs — as a host-side
subsystem:

    wire.py        packet codec: sequenced datagrams <-> payload + mask
                   (bit-compatible with core/drops.py masks)
    backend.py     the Backend datagram-fabric protocol
    inproc.py      deterministic in-memory loopback (scripted drop/delay)
    udp.py         real non-blocking UDP sockets on localhost (threaded
                   UdpBackend + single-socket UdpProcessBackend)
    rendezvous.py  socket rendezvous: rank assignment, generation-numbered
                   elastic membership, heartbeat liveness, phase barriers
    peer.py        HostPeer: one rank's TAR schedule over the wire,
                   membership-view aware
    host_ring.py   HostRing: the N-peer driver + the io_callback bridge
                   feeding WireTransport / StepTelemetry

See ``repro.core.pipeline.WireTransport`` for the in-JAX side of the
bridge, ``launch/train.py --transport={lossy,inproc,udp}`` for the
launcher integration, and ``repro.launch.multiproc`` for the multi-process
peer runtime on top of the rendezvous.
"""
from .backend import Backend
from .host_ring import HostRing, aggregate_reports, make_backend, wire_spec
from .inproc import (InprocBackend, bernoulli_drops, burst_drops,
                     mask_scripted_drops, peer_factor_delays)
from .peer import HostPeer, PeerReport, RoundReport
from .rendezvous import (PHASES_PER_STEP, FrameBuffer, LocalCoordinator,
                         LocalClient, Member, Membership, RendezvousClient,
                         RendezvousError, RendezvousFull, RendezvousMessage,
                         RendezvousServer, RendezvousState, RendezvousTimeout,
                         StaticMembership, tcp_available)
from .udp import UdpBackend, UdpProcessBackend, udp_available
from .wire import (HEADER_BYTES, KIND_CTRL, KIND_DATA1, KIND_DATA2,
                   WIRE_VERSION, PacketHeader, Reassembly, WireError,
                   n_packets, packetize)

__all__ = [
    "Backend", "HostRing", "aggregate_reports", "make_backend", "wire_spec",
    "InprocBackend", "bernoulli_drops", "burst_drops", "mask_scripted_drops",
    "peer_factor_delays", "HostPeer", "PeerReport", "RoundReport",
    "UdpBackend", "UdpProcessBackend", "udp_available",
    "PHASES_PER_STEP", "FrameBuffer", "LocalCoordinator", "LocalClient",
    "Member", "Membership", "RendezvousClient", "RendezvousError",
    "RendezvousFull", "RendezvousMessage", "RendezvousServer",
    "RendezvousState", "RendezvousTimeout", "StaticMembership",
    "tcp_available",
    "HEADER_BYTES", "KIND_CTRL", "KIND_DATA1", "KIND_DATA2", "WIRE_VERSION",
    "PacketHeader", "Reassembly", "WireError", "n_packets", "packetize",
]
