"""HostRing: N host peers over one wire backend, plus the io_callback
bridge that feeds wire-observed masks into the in-JAX datapath (DESIGN §7).

Two modes of operation:

* **Standalone host datapath** — :meth:`HostRing.allreduce` runs one full
  TAR allreduce where every byte really crosses the backend: encode →
  packetized stage-1 exchange under adaptive deadlines → compensated
  reduce → packetized stage-2 broadcast → decode, one thread per peer with
  phase fences.  With the inproc backend and scripted drops this is
  bitwise-identical to the in-JAX ``Lossy`` pipeline given the same
  arrival masks (the subsystem's pinned parity result).

* **Bridge for the in-JAX pipeline** — :meth:`bridge_exchange` is the
  ``WireTransport`` io_callback target: each device *deposits* its stage-1
  shard matrix and gets back the previous exchange's observed arrival mask
  while a ring worker thread really exchanges the bytes (rendezvous-free —
  see the comment block at the bridge section for why anything blocking
  inside the callback can deadlock an oversubscribed host); the XLA
  collectives keep moving the authoritative data.  Per-peer/per-round
  telemetry accumulates on the ring and :meth:`drain_telemetry` folds it
  into a fully-populated :class:`~repro.runtime.StepTelemetry` for the
  ControlPlane — closing the ROADMAP item that the launcher only ever fed
  step wall-clock.

All telemetry times are in the backend's clock units (scripted virtual
seconds for inproc, monotonic seconds for UDP); the controllers only ever
compare them against each other.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.core.pipeline import (CollectiveSpec, OptiReduceConfig,
                                 WireTransport, resolve_spec)
from repro.core.ubt import AdaptiveTimeout, LossBudget
from repro.runtime import StepTelemetry

from .backend import Backend
from .inproc import InprocBackend
from .peer import HostPeer, PeerReport
from .udp import UdpBackend


def make_backend(kind: str | Backend, n_peers: int, *, drop_fn=None,
                 delay_fn=None, scramble_seed=None) -> Backend:
    """Build a backend by name (``inproc`` | ``udp``) or pass one through."""
    if isinstance(kind, Backend):
        return kind
    if kind == "inproc":
        return InprocBackend(n_peers, drop_fn=drop_fn, delay_fn=delay_fn)
    if kind == "udp":
        return UdpBackend(n_peers, drop_fn=drop_fn,
                          scramble_seed=scramble_seed)
    raise ValueError(f"unknown backend {kind!r} (inproc | udp)")


def aggregate_reports(reports: list[PeerReport], step: int) -> StepTelemetry:
    """Cross-receiver fold of per-peer wire observations: a round completes
    when its slowest receiver does; a peer's stage time is the worst any
    receiver waited on it.  Used by :class:`HostRing` (all N receivers in
    one process) and by ``repro.launch.multiproc`` workers (a single
    receiver's report — each process only observes its own rounds)."""
    n_rounds = max((len(r.rounds) for r in reports), default=0)
    round_times, round_to, round_frac = [], [], []
    for i in range(n_rounds):
        rs = [r.rounds[i] for r in reports if i < len(r.rounds)]
        round_times.append(max(x.time for x in rs))
        round_to.append(any(x.timed_out for x in rs))
        round_frac.append(float(np.mean([x.frac_received for x in rs])))
    # a report with no arrival observations at all carries
    # sender_last_t=None (e.g. a freshly-constructed PeerReport merged
    # from zero exchanges); fold only the observing reports, and when
    # none observed anything emit peer_stage_times=None — the
    # StragglerDetector holds state on missing input, exactly as on an
    # all-NaN column (a peer no receiver saw)
    observed = [r.sender_last_t for r in reports
                if r.sender_last_t is not None]
    if observed:
        last = np.stack(observed)                               # (R, n)
        # a rank no receiver observed (skipped as dead) keeps NaN without
        # the nanmax all-NaN-slice warning
        seen = ~np.all(np.isnan(last), axis=0)
        peer_times = np.full(last.shape[1], np.nan)
        peer_times[seen] = np.nanmax(last[:, seen], axis=0)     # (n,)
        peer_times = tuple(float(t) for t in peer_times)
    else:
        peer_times = None
    dropped = sum(r.dropped for r in reports)
    total = sum(r.total for r in reports)
    # union of link-fault suspects across receivers — the ControlPlane's
    # link-health tracker turns repeated observations into dead_links
    events = tuple(sorted({l for r in reports for l in r.lost_links}))
    return StepTelemetry.from_wire(
        step=step,
        dead_link_events=events,
        round_times=tuple(round_times),
        round_timed_out=tuple(round_to),
        round_frac_received=tuple(round_frac),
        peer_stage_times=peer_times,
        dropped=float(dropped), total=float(total),
        # the §3.2.1 warmup profiles *stage* (round) times — feed the
        # slowest COMPLETED round: an expired round only reports the
        # deadline itself (the receiver stopped waiting), and sampling
        # that would make t_B converge to whatever budget it started
        # with instead of the network's real pace.  A step where every
        # round was lossy contributes no sample (the ControlPlane falls
        # back to the per-peer arrival times).
        step_time=max((t for t, to in zip(round_times, round_to)
                       if not to), default=None))


class HostRing:
    """N host peers on one fabric (see module docstring)."""

    def __init__(self, n_peers: int, cfg: OptiReduceConfig, *,
                 backend: str | Backend = "inproc",
                 timeout: AdaptiveTimeout | None = None,
                 default_deadline: float | None = None,
                 budget: LossBudget | None = None,
                 drop_fn=None, delay_fn=None, scramble_seed=None,
                 membership=None, shard_weights=None, dead_links=()):
        self.n = int(n_peers)
        self.cfg = cfg
        self.backend = make_backend(backend, self.n, drop_fn=drop_fn,
                                    delay_fn=delay_fn,
                                    scramble_seed=scramble_seed)
        self.timeout = timeout
        self.budget = budget
        self.peers = [HostPeer(p, self.backend, cfg, timeout=timeout,
                               default_deadline=default_deadline,
                               budget=budget, membership=membership,
                               shard_weights=shard_weights,
                               dead_links=dead_links)
                      for p in range(self.n)]
        self._cv = threading.Condition()
        self._lock = self._cv                 # one lock guards all ring state
        self._bridge_calls = [0] * self.n
        self._deposits: dict[int, dict[int, object]] = {}
        self._results: dict[int, dict[int, tuple[np.ndarray, PeerReport]]] \
            = {}
        self._pending: list[list[PeerReport]] = [[] for _ in range(self.n)]
        self._jobs: list = []                 # completed deposit sets, FIFO
        self._worker: threading.Thread | None = None
        self._working = False                 # worker mid-exchange
        self._closing = False
        self.bridge_timeout = 10.0            # bounded wait; never a deadlock
        self.bridge_misses = 0
        self.bridge_error: Exception | None = None

    def close(self) -> None:
        with self._cv:
            self._closing = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
        self.backend.close()

    # ------------------------------------------------- standalone datapath
    def allreduce(self, buckets, key, *, step: int = 0, bucket: int = 0,
                  stale=None) -> tuple[np.ndarray, StepTelemetry]:
        """One full over-the-wire TAR allreduce of per-peer buckets.

        ``buckets``: (n, L) array (or list of n flat arrays) — peer p
        contributes row p.  ``key`` is the replicated per-step PRNG key
        (same at every peer, exactly like ``SyncContext.key``).  ``stale``
        is the replicated previous-step decoded bucket for StaleFill
        recovery codecs (``cfg.recovery != "none"``).  Returns the (n, L)
        per-peer synced results and the step's telemetry.
        """
        buckets = np.asarray(buckets)
        if buckets.ndim != 2 or buckets.shape[0] != self.n:
            raise ValueError(f"buckets must be ({self.n}, L), "
                             f"got {buckets.shape}")
        results: list = [None] * self.n
        reports: list = [None] * self.n
        errors: list = []

        def run(p: int) -> None:
            try:
                peer = self.peers[p]
                peer.phase1_encode(buckets[p], key, step, bucket,
                                   stale=stale)
                self.backend.barrier(timeout=60.0)
                peer.phase2_send_stage1(step, bucket)
                self.backend.barrier(timeout=60.0)
                # a relay hop's wrapped datagrams must be forwarded before
                # the final receivers stop polling (virtual-time backends
                # never block in wait) — every peer drains once, fenced, so
                # two-hop delivery lands inside the coming receive phase
                peer.relay_pump(step)
                self.backend.barrier(timeout=60.0)
                rep = peer.phase3_reduce_send_stage2(step, bucket)
                self.backend.barrier(timeout=60.0)
                peer.relay_pump(step)
                self.backend.barrier(timeout=60.0)
                out, rep2 = peer.phase4_decode(step, bucket)
                rep.merge(rep2)
                results[p], reports[p] = out, rep
            except Exception as e:           # surface, never hang the join
                errors.append((p, e))

        threads = [threading.Thread(target=run, args=(p,), daemon=True)
                   for p in range(self.n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120.0)
        if errors:
            raise RuntimeError(f"host peers failed: {errors}") from \
                errors[0][1]
        out = np.stack([np.asarray(r) for r in results])
        return out, self._aggregate([r for r in reports if r is not None],
                                    step)

    # ------------------------------------------------------- bridge mode
    # Every device calls bridge_exchange once per bucket in the same
    # program order, so call #k on each rank is the same logical exchange.
    #
    # The design is asynchronous on purpose, for two reasons learned the
    # hard way on an oversubscribed CPU host:
    #
    # * a blocking rendezvous inside an io_callback can interleave with
    #   XLA's own collective rendezvous (device A parked in the callback,
    #   device B parked in an independent all_gather that needs A) and
    #   deadlock the step;
    # * even *reading* the operand inside the callback can deadlock — the
    #   callback runs on an XLA worker thread, and materializing the
    #   payload waits on a ready-event whose producer task is queued on
    #   that same saturated pool.
    #
    # So the callback does neither: it deposits the still-unmaterialized
    # payload and immediately returns the observed mask of the *previous*
    # exchange (call k consumes exchange k-1's mask; call 0 primes with
    # all-ones).  A dedicated worker thread materializes the payloads and
    # really runs each exchange in deposit order.  The one-exchange lag is
    # the same next-round-from-last-round structure as the §3.2
    # controllers.  When the loss schedule ignores the exchange counter
    # (``mask_scripted_drops`` — the parity mechanism), exchange k-1's
    # mask equals exchange k's *bitwise*, which the bridge parity test
    # pins after one priming call; schedules keyed on the counter
    # (``bernoulli_drops`` in wire training) make the lagged mask an
    # equal-distribution sample of the loss process, not that exact
    # bucket's realization.  A mask not ready within ``bridge_timeout``
    # (or whose geometry changed between buckets) degrades to all-ones and
    # counts in ``bridge_misses`` — never a hang.

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._jobs and not self._closing:
                    self._cv.wait(0.5)
                if self._closing and not self._jobs:
                    return
                xid, dep = self._jobs.pop(0)
                self._working = True
            results = None
            try:
                step = xid & 0xFFFFFFFF
                # materializing here (off the XLA pool) is allowed to wait
                dep = {me: np.asarray(v) for me, v in dep.items()}
                for me in range(self.n):
                    self.peers[me].bridge_send(dep[me], step, 0)
                for me in range(self.n):
                    # forward relay-wrapped datagrams (dead-link reroute)
                    # before any receiver evaluates its deadline
                    self.peers[me].relay_pump(step)
                results = {me: self.peers[me].bridge_receive(dep[me], step, 0)
                           for me in range(self.n)}
            except Exception as e:      # a dead worker must not wedge flush
                self.bridge_error = e
            with self._cv:
                if results is not None:
                    self._results[xid] = results
                    for r in range(self.n):
                        self._pending[r].append(results[r][1])
                    for old in [k for k in self._results if k < xid - 3]:
                        del self._results[old]    # bound stale results
                self._working = False
                self._cv.notify_all()

    def bridge_exchange(self, me: int, shards) -> np.ndarray:
        """``WireTransport`` io_callback target: deposit this call's
        payload, return the previous exchange's observed (n, s) mask."""
        shape = tuple(shards.shape)
        with self._cv:
            xid = self._bridge_calls[me]
            self._bridge_calls[me] += 1
            dep = self._deposits.setdefault(xid, {})
            dep[me] = shards
            if len(dep) == self.n:
                del self._deposits[xid]
                self._jobs.append((xid, dep))
                if self._worker is None:
                    self._worker = threading.Thread(
                        target=self._worker_loop, daemon=True,
                        name="wire-bridge")
                    self._worker.start()
                self._cv.notify_all()
            if xid == 0:
                return np.ones(shape, np.float32)     # priming call
            deadline = time.monotonic() + self.bridge_timeout
            while xid - 1 not in self._results:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
            res = self._results.get(xid - 1)
        if res is None or res[me][0].shape != shape:
            with self._cv:
                self.bridge_misses += 1
            return np.ones(shape, np.float32)
        return res[me][0]

    def flush(self, timeout: float = 30.0) -> bool:
        """Wait (bounded) until every fully-deposited exchange has run —
        the launcher calls this at step end so drained telemetry covers
        the step's own exchanges."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while self._jobs or self._working:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._cv.wait(remaining)
        return True

    def drain_telemetry(self, step: int = 0) -> StepTelemetry | None:
        """Fold every bridge exchange since the last drain into one
        :class:`StepTelemetry` (None when nothing was exchanged)."""
        with self._lock:
            pending, self._pending = self._pending, \
                [[] for _ in range(self.n)]
        merged = []
        for reports in pending:
            if not reports:
                continue
            acc = PeerReport(sender_last_t=np.full(self.n, np.nan))
            for r in reports:
                acc.merge(r)
            merged.append(acc)
        if not merged:
            return None
        return self._aggregate(merged, step)

    # -------------------------------------------------------- aggregation
    def _aggregate(self, reports: list[PeerReport],
                   step: int) -> StepTelemetry:
        return aggregate_reports(reports, step)


def wire_spec(cfg: OptiReduceConfig, ring: HostRing) -> CollectiveSpec:
    """Resolve ``cfg.strategy`` and swap its transport for a
    :class:`WireTransport` bridged to ``ring`` — what ``launch/train.py
    --transport={inproc,udp}`` feeds the trainer."""
    spec = resolve_spec(cfg)
    return dataclasses.replace(spec, transport=WireTransport(
        ring.bridge_exchange))
