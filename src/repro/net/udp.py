"""Real UDP datagram backend on localhost (non-blocking sockets).

Each peer binds its own ``127.0.0.1`` socket (ephemeral port by default);
sends are fire-and-forget ``sendto`` calls and receives are non-blocking
drains timestamped on the monotonic clock, so the peer's receive loop
enforces the adaptive per-round deadline against *real* elapsed time —
packets genuinely in flight past the deadline are masked, exactly the UBT
semantics.  An optional ``drop_fn`` injects loss at the sender (localhost
UDP itself rarely drops; tests and the demo script use it to emulate a
lossy path), and CTRL-kind packets are sent ``ctrl_redundancy`` times —
the cheap stand-in for the reliable control channel (duplicates are
discarded by reassembly).

Two deployment shapes share the datagram mechanics:

* :class:`UdpBackend` — N sockets in *one* process (the HostRing's
  threaded peers), with a built-in phase fence;
* :class:`UdpProcessBackend` — *one* socket for one OS process (the
  ``repro.launch.multiproc`` worker), destination addresses resolved
  through a rendezvous membership view (``addr_of``) instead of a local
  socket list; phase fencing belongs to the rendezvous barriers, never
  this backend.

``scramble_seed`` adds deterministic *reordering* injection: DATA packets
of a stream are buffered until its last sequence number is offered, then
sent in a header-keyed shuffled order — real UDP on localhost virtually
never reorders, and the recovery suite needs to prove the reassembly path
is order-free under loss + reordering together.

Sandboxes commonly forbid socket binding; :func:`udp_available` probes
that so tests can auto-skip instead of fail.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Callable

from .backend import Backend, PhaseBarrier
from .wire import KIND_CTRL, PacketHeader

_RCVBUF = 1 << 22
_M64 = (1 << 64) - 1


def _mix64(h: int) -> int:
    h = (h + 0x9E3779B97F4A7C15) & _M64
    h = ((h ^ (h >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    h = ((h ^ (h >> 27)) * 0x94D049BB133111EB) & _M64
    return h ^ (h >> 31)


def _scramble_order(seed: int, src: int, dst: int, hdr: PacketHeader,
                    count: int) -> list[int]:
    """Header-keyed Fisher–Yates permutation of a stream's send order."""
    h = seed & _M64
    for v in (src, dst, hdr.kind, hdr.step, hdr.bucket, hdr.round):
        h = _mix64(h ^ v)
    order = list(range(count))
    for i in range(count - 1, 0, -1):
        h = _mix64(h)
        j = h % (i + 1)
        order[i], order[j] = order[j], order[i]
    return order


def udp_available() -> bool:
    """Can this process bind a localhost UDP socket?"""
    try:
        s = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        try:
            s.bind(("127.0.0.1", 0))
        finally:
            s.close()
        return True
    except OSError:
        return False


class UdpBackend(Backend):
    """Localhost UDP fabric (see module docstring)."""

    virtual_time = False

    def __init__(self, n_peers: int, *, drop_fn=None, ctrl_redundancy: int = 3,
                 poll_sleep: float = 2e-4, scramble_seed: int | None = None):
        self.n_peers = int(n_peers)
        self.drop_fn = drop_fn
        self.ctrl_redundancy = max(1, int(ctrl_redundancy))
        self.poll_sleep = float(poll_sleep)
        self.scramble_seed = scramble_seed
        self._pending: dict[tuple, list[bytes]] = {}
        self._fence = PhaseBarrier(self.n_peers)
        self._socks: list[socket.socket] = []
        self._addrs: list[tuple[str, int]] = []
        self.sent = 0
        self.dropped = 0
        self._lock = threading.Lock()
        try:
            for _ in range(self.n_peers):
                s = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
                s.bind(("127.0.0.1", 0))
                s.setblocking(False)
                try:
                    s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, _RCVBUF)
                except OSError:
                    pass                      # best-effort: default is fine
                self._socks.append(s)
                self._addrs.append(s.getsockname())
        except OSError:
            self.close()
            raise

    def send(self, src: int, dst: int, datagram: bytes) -> None:
        hdr, _ = PacketHeader.decode(datagram)
        with self._lock:
            self.sent += 1
        reps = self.ctrl_redundancy if hdr.kind == KIND_CTRL else 1
        dropped = hdr.kind != KIND_CTRL and self.drop_fn is not None \
            and self.drop_fn(src, dst, hdr)
        if dropped:
            with self._lock:
                self.dropped += 1
        if self.scramble_seed is not None and hdr.kind != KIND_CTRL:
            # reordering injection: hold the stream until its final seq is
            # offered (packetize emits seqs in order), then release in a
            # header-keyed shuffle — losses simply leave the buffer shorter
            key = (src, dst, hdr.kind, hdr.step, hdr.bucket, hdr.round)
            with self._lock:
                buf = self._pending.setdefault(key, [])
                if not dropped:
                    buf.append(datagram)
                if hdr.seq != hdr.n_seq - 1:
                    return
                del self._pending[key]
            order = _scramble_order(self.scramble_seed, src, dst, hdr,
                                    len(buf))
            for i in order:
                self._sendto(src, dst, buf[i])
            return
        if dropped:
            return
        for _ in range(reps):
            if not self._sendto(src, dst, datagram):
                return

    def _sendto(self, src: int, dst: int, datagram: bytes) -> bool:
        try:
            self._socks[src].sendto(datagram, self._addrs[dst])
            return True
        except (BlockingIOError, OSError):
            with self._lock:              # kernel buffer full = network loss
                self.dropped += 1
            return False

    def poll(self, me: int) -> list[tuple[bytes, float]]:
        out = []
        sock = self._socks[me]
        while True:
            try:
                data, _ = sock.recvfrom(1 << 16)
            except (BlockingIOError, OSError):
                break
            out.append((data, time.monotonic()))
        return out

    def now(self, me: int) -> float:
        return time.monotonic()

    def wait(self, me: int, timeout: float) -> bool:
        time.sleep(min(self.poll_sleep, max(timeout, 0.0)))
        return True

    def barrier(self, timeout: float | None = None) -> None:
        self._fence.wait(timeout=timeout)

    def close(self) -> None:
        for s in self._socks:
            try:
                s.close()
            except OSError:
                pass
        self._socks = []


class UdpProcessBackend(Backend):
    """One OS process's single-socket UDP fabric endpoint.

    The ``repro.launch.multiproc`` worker backend: binds one non-blocking
    socket *before* rank assignment (the advertised port rides the
    rendezvous JOIN), then :meth:`attach` wires in the assigned rank and a
    rendezvous address resolver — ``resolver(dst) -> (host, port) | None``,
    None meaning "that rank is not live" (the datagram is accounted as
    dropped; the membership-aware peer normally skips dead ranks before
    reaching here).  There is no in-process fence across peers to offer:
    :meth:`barrier` raises — multi-process phases fence through the
    rendezvous coordinator's barrier tags.
    """

    virtual_time = False

    def __init__(self, world_size: int, *, drop_fn=None,
                 ctrl_redundancy: int = 3, poll_sleep: float = 2e-4,
                 host: str = "127.0.0.1"):
        self.n_peers = int(world_size)
        self.drop_fn = drop_fn
        self.ctrl_redundancy = max(1, int(ctrl_redundancy))
        self.poll_sleep = float(poll_sleep)
        self.rank: int | None = None
        self._resolver: Callable[[int], tuple[str, int] | None] | None = None
        self.sent = 0
        self.dropped = 0
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self._sock.bind((host, 0))
        self._sock.setblocking(False)
        try:
            self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF,
                                  _RCVBUF)
        except OSError:
            pass                          # best-effort: default is fine
        self.addr: tuple[str, int] = self._sock.getsockname()

    @property
    def port(self) -> int:
        return self.addr[1]

    def attach(self, rank: int,
               resolver: Callable[[int], tuple[str, int] | None]) -> None:
        """Bind the rendezvous-assigned rank + peer address resolver."""
        self.rank = int(rank)
        self._resolver = resolver

    def send(self, src: int, dst: int, datagram: bytes) -> None:
        if self._resolver is None:
            raise RuntimeError("UdpProcessBackend.send before attach()")
        if src != self.rank:
            raise ValueError(f"process backend owns rank {self.rank}, "
                             f"cannot send as {src}")
        hdr, _ = PacketHeader.decode(datagram)
        self.sent += 1
        if hdr.kind != KIND_CTRL and self.drop_fn is not None \
                and self.drop_fn(src, dst, hdr):
            self.dropped += 1
            return
        addr = self._resolver(dst)
        if addr is None:                  # dead/unknown rank: nowhere to go
            self.dropped += 1
            return
        reps = self.ctrl_redundancy if hdr.kind == KIND_CTRL else 1
        for _ in range(reps):
            try:
                self._sock.sendto(datagram, tuple(addr))
            except (BlockingIOError, OSError):
                self.dropped += 1         # kernel buffer full = network loss
                return

    def poll(self, me: int) -> list[tuple[bytes, float]]:
        out = []
        while True:
            try:
                data, _ = self._sock.recvfrom(1 << 16)
            except (BlockingIOError, OSError):
                break
            out.append((data, time.monotonic()))
        return out

    def now(self, me: int) -> float:
        return time.monotonic()

    def wait(self, me: int, timeout: float) -> bool:
        time.sleep(min(self.poll_sleep, max(timeout, 0.0)))
        return True

    def barrier(self, timeout: float | None = None) -> None:
        raise RuntimeError("UdpProcessBackend has no in-process fence; "
                           "multi-process phases fence through the "
                           "rendezvous barrier tags")

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
