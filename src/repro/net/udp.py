"""Real UDP datagram backend on localhost (non-blocking sockets).

Each peer binds its own ``127.0.0.1`` socket (ephemeral port by default);
sends are fire-and-forget ``sendto`` calls and receives are non-blocking
drains timestamped on the monotonic clock, so the peer's receive loop
enforces the adaptive per-round deadline against *real* elapsed time —
packets genuinely in flight past the deadline are masked, exactly the UBT
semantics.  An optional ``drop_fn`` injects loss at the sender (localhost
UDP itself rarely drops; tests and the demo script use it to emulate a
lossy path), and CTRL-kind packets are sent ``ctrl_redundancy`` times —
the cheap stand-in for the reliable control channel (duplicates are
discarded by reassembly).

Sandboxes commonly forbid socket binding; :func:`udp_available` probes
that so tests can auto-skip instead of fail.
"""
from __future__ import annotations

import socket
import threading
import time

from .backend import Backend, PhaseBarrier
from .wire import KIND_CTRL, PacketHeader

_RCVBUF = 1 << 22


def udp_available() -> bool:
    """Can this process bind a localhost UDP socket?"""
    try:
        s = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        try:
            s.bind(("127.0.0.1", 0))
        finally:
            s.close()
        return True
    except OSError:
        return False


class UdpBackend(Backend):
    """Localhost UDP fabric (see module docstring)."""

    virtual_time = False

    def __init__(self, n_peers: int, *, drop_fn=None, ctrl_redundancy: int = 3,
                 poll_sleep: float = 2e-4):
        self.n_peers = int(n_peers)
        self.drop_fn = drop_fn
        self.ctrl_redundancy = max(1, int(ctrl_redundancy))
        self.poll_sleep = float(poll_sleep)
        self._fence = PhaseBarrier(self.n_peers)
        self._socks: list[socket.socket] = []
        self._addrs: list[tuple[str, int]] = []
        self.sent = 0
        self.dropped = 0
        self._lock = threading.Lock()
        try:
            for _ in range(self.n_peers):
                s = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
                s.bind(("127.0.0.1", 0))
                s.setblocking(False)
                try:
                    s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, _RCVBUF)
                except OSError:
                    pass                      # best-effort: default is fine
                self._socks.append(s)
                self._addrs.append(s.getsockname())
        except OSError:
            self.close()
            raise

    def send(self, src: int, dst: int, datagram: bytes) -> None:
        hdr, _ = PacketHeader.decode(datagram)
        with self._lock:
            self.sent += 1
        reps = self.ctrl_redundancy if hdr.kind == KIND_CTRL else 1
        if hdr.kind != KIND_CTRL and self.drop_fn is not None \
                and self.drop_fn(src, dst, hdr):
            with self._lock:
                self.dropped += 1
            return
        for _ in range(reps):
            try:
                self._socks[src].sendto(datagram, self._addrs[dst])
            except (BlockingIOError, OSError):
                with self._lock:          # kernel buffer full = network loss
                    self.dropped += 1
                return

    def poll(self, me: int) -> list[tuple[bytes, float]]:
        out = []
        sock = self._socks[me]
        while True:
            try:
                data, _ = sock.recvfrom(1 << 16)
            except (BlockingIOError, OSError):
                break
            out.append((data, time.monotonic()))
        return out

    def now(self, me: int) -> float:
        return time.monotonic()

    def wait(self, me: int, timeout: float) -> bool:
        time.sleep(min(self.poll_sleep, max(timeout, 0.0)))
        return True

    def barrier(self, timeout: float | None = None) -> None:
        self._fence.wait(timeout=timeout)

    def close(self) -> None:
        for s in self._socks:
            try:
                s.close()
            except OSError:
                pass
        self._socks = []
