"""The ``Backend`` protocol: how datagrams move between host peers.

A backend is a best-effort datagram fabric for ``n_peers`` ranks.  Sends
never block and may silently lose packets; receives are pull-based with a
clock, so the peer's receive loop can enforce the UBT per-round deadline
(``AdaptiveTimeout.round_deadline``) uniformly over both implementations:

* :class:`~repro.net.inproc.InprocBackend` — deterministic in-memory
  loopback with *virtual* time: every receive phase starts at t=0 and a
  packet's arrival time is its scripted delay, so CI runs are exactly
  reproducible (scripted per-peer drop/delay schedules stand in for the
  network).
* :class:`~repro.net.udp.UdpBackend` — real non-blocking UDP sockets on
  localhost with wall-clock (monotonic) time.

``barrier`` is the host-side phase fence the threaded drivers use between
send and receive phases (a real launcher gets the same fence from its
bootstrap rendezvous); CTRL-kind packets (quantization grids) bypass any
scripted loss — they model the small reliable control channel, not the
bulk gradient stream.
"""
from __future__ import annotations

import threading


class Backend:
    """Base datagram fabric (see module docstring for the contract)."""

    #: ranks this fabric connects
    n_peers: int = 0
    #: True when poll() after a phase fence returns every arrival at once
    #: (virtual time); False when time must really pass between polls
    virtual_time: bool = True

    def send(self, src: int, dst: int, datagram: bytes) -> None:
        """Best-effort, non-blocking: the datagram may never arrive."""
        raise NotImplementedError

    def poll(self, me: int) -> list[tuple[bytes, float]]:
        """Drain pending datagrams as (datagram, arrival_time) pairs."""
        raise NotImplementedError

    def now(self, me: int) -> float:
        """The receive clock poll() timestamps are measured on."""
        raise NotImplementedError

    def wait(self, me: int, timeout: float) -> bool:
        """Let time advance; False when no further arrivals can come
        (virtual-time backends return False after the phase's single
        drain — the receive loop must evaluate what it has)."""
        raise NotImplementedError

    def barrier(self, timeout: float | None = None) -> None:
        """Phase fence across all peers (threaded drivers only)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


class PhaseBarrier:
    """A reusable all-peer fence with a deadlock bound: on timeout every
    waiter gets ``BrokenBarrierError`` and the peer masks the whole phase
    instead of hanging (missing -> masked, never blocked)."""

    def __init__(self, n_peers: int):
        self._barrier = threading.Barrier(n_peers)

    def wait(self, timeout: float | None = None) -> None:
        self._barrier.wait(timeout=timeout)
