"""Deterministic in-memory loopback backend with scripted loss/delay.

The CI stand-in for a real network: per-peer mailboxes, a *virtual* clock
(each receive phase starts at t=0; a packet's arrival time is whatever the
delay schedule says), and scripted per-packet drop/delay functions of
``(src, dst, PacketHeader)`` — so a test can make the wire lose *exactly*
the packets a ``core/drops.py`` mask names (the bitwise-parity pin) or make
one peer persistently slow (the straggler-detector feed).

Scripts only apply to DATA-kind packets; CTRL packets (quantization grids)
always arrive with zero delay — they model the small reliable control
channel.  All scheduling is a pure function of the packet header, so runs
are exactly reproducible.
"""
from __future__ import annotations

import threading
from typing import Callable

import numpy as np

from .backend import Backend, PhaseBarrier
from .wire import KIND_CTRL, KIND_DATA1, PacketHeader

DropFn = Callable[[int, int, PacketHeader], bool]
DelayFn = Callable[[int, int, PacketHeader], float]


_M64 = (1 << 64) - 1


def _splitmix64(h: int) -> int:
    h = (h + 0x9E3779B97F4A7C15) & _M64
    h = ((h ^ (h >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    h = ((h ^ (h >> 27)) * 0x94D049BB133111EB) & _M64
    return h ^ (h >> 31)


def bernoulli_drops(rate: float, seed: int = 0) -> DropFn:
    """I.i.d. per-packet loss at ``rate``, deterministic in the header.

    The draw is a splitmix64 mix of the header fields, not a Generator —
    this runs per DATA packet on the send path (thousands per step in wire
    training), where constructing an ``np.random`` Generator each time is
    ~100x the cost for the same header-pure determinism.
    """
    threshold = int(rate * (1 << 64))

    def drop(src: int, dst: int, hdr: PacketHeader) -> bool:
        if rate <= 0.0:
            return False
        h = seed & _M64
        for v in (src, dst, hdr.step, hdr.bucket, hdr.round, hdr.seq):
            h = _splitmix64(h ^ v)
        return h < threshold
    return drop


def burst_drops(rate: float, seed: int = 0,
                mean_burst: float = 8.0) -> DropFn:
    """Gilbert–Elliott bursty loss, deterministic in the header.

    Mirrors ``core.drops.burst_mask``: a two-state Markov chain per packet
    *stream* — one chain per ``(src, dst, step, bucket, round)``, stepped
    along ``seq`` — with the shared ``gilbert_elliott_params(rate,
    mean_burst)`` parameterization, so wire bursts have the same run-length
    statistics as the in-JAX masks.  The chain is sequential in ``seq`` but
    header-pure: each stream's state prefix is cached and extended with
    splitmix64 uniforms keyed by (stream, seq), so out-of-order calls give
    the same answer and the amortized cost is one mix per packet.  Applies
    to stage-1 DATA packets only (drop scripts never touch CTRL).
    """
    from repro.core.drops import gilbert_elliott_params
    p, r = gilbert_elliott_params(rate, mean_burst)
    rate_c = min(max(rate, 0.0), 0.999)
    # per-stream loss-state prefix: stream key -> list of bools, state[i]
    # is the chain's Bad indicator for seq i
    prefixes: dict[tuple, list[bool]] = {}

    def uniform(stream_h: int, seq: int) -> float:
        return _splitmix64(stream_h ^ _splitmix64(seed ^ seq)) / float(1 << 64)

    def drop(src: int, dst: int, hdr: PacketHeader) -> bool:
        if rate_c <= 0.0 or hdr.kind != KIND_DATA1:
            return False
        stream = (src, dst, hdr.step, hdr.bucket, hdr.round)
        h = seed & _M64
        for v in stream:
            h = _splitmix64(h ^ v)
        states = prefixes.setdefault(stream, [])
        while len(states) <= hdr.seq:
            i = len(states)
            u = uniform(h, i)
            if i == 0:
                bad = u < rate_c                    # stationary start
            elif states[i - 1]:
                bad = u >= r                        # Bad: stay unless recover
            else:
                bad = u < p                         # Good: enter burst w.p. p
            states.append(bad)
        return states[hdr.seq]
    return drop


def mask_scripted_drops(masks: dict[int, np.ndarray],
                        packet_elems: int) -> DropFn:
    """Drop exactly the packets a per-receiver drops-mask names.

    ``masks[receiver]`` is the (n_peers, shard_elems) 0/1 arrival mask the
    in-JAX ``Lossy`` transport would generate for that receiver; stage-1
    packet ``seq`` of sender ``src`` is dropped iff the mask zeroes its
    span — what pins wire-observed masks bitwise to ``core/drops.py``
    masks.  Stage-2 packets always pass: the drop model applies to stage 1
    only (the aggregated shard is authoritative; DESIGN §2).
    """

    def drop(src: int, dst: int, hdr: PacketHeader) -> bool:
        if hdr.kind != KIND_DATA1:
            return False
        mask = masks.get(dst)
        if mask is None:
            return False
        return bool(mask[src, hdr.seq * packet_elems] == 0.0)
    return drop


def peer_factor_delays(base: float = 1e-4,
                       factors: tuple[float, ...] | None = None) -> DelayFn:
    """Per-sender latency: ``base * factors[src]`` plus a small
    header-hashed jitter (deterministic), mirroring
    ``sim.netsim.NetworkModel.peer_factors``."""

    def delay(src: int, dst: int, hdr: PacketHeader) -> float:
        f = 1.0 if factors is None else float(factors[src])
        jitter = ((src * 131 + dst * 17 + hdr.seq * 7 + hdr.round) % 97) / 97.0
        return base * f * (1.0 + 0.1 * jitter)
    return delay


class InprocBackend(Backend):
    """Deterministic loopback fabric (see module docstring)."""

    virtual_time = True

    def __init__(self, n_peers: int, *, drop_fn: DropFn | None = None,
                 delay_fn: DelayFn | None = None):
        self.n_peers = int(n_peers)
        self.drop_fn = drop_fn
        self.delay_fn = delay_fn or peer_factor_delays()
        self._lock = threading.Lock()
        self._mail: list[list[tuple[bytes, float]]] = \
            [[] for _ in range(self.n_peers)]
        self._fence = PhaseBarrier(self.n_peers)
        self.sent = 0
        self.dropped = 0

    def send(self, src: int, dst: int, datagram: bytes) -> None:
        hdr, _ = PacketHeader.decode(datagram)
        self.sent += 1
        if hdr.kind == KIND_CTRL:                   # reliable control channel
            t = 0.0
        else:
            if self.drop_fn is not None and self.drop_fn(src, dst, hdr):
                self.dropped += 1
                return
            t = float(self.delay_fn(src, dst, hdr))
        with self._lock:
            self._mail[dst].append((datagram, t))

    def poll(self, me: int) -> list[tuple[bytes, float]]:
        with self._lock:
            out, self._mail[me] = self._mail[me], []
        return out

    def now(self, me: int) -> float:
        return 0.0                                  # each phase starts at t=0

    def wait(self, me: int, timeout: float) -> bool:
        return False                                # one drain sees everything

    def barrier(self, timeout: float | None = None) -> None:
        self._fence.wait(timeout=timeout)
