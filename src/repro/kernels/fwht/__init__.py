from .ops import fwht, randomized_fwht
from .ref import fwht_ref, fwht_mxu_ref, hadamard_matrix, split_factors

__all__ = ["fwht", "randomized_fwht", "fwht_ref", "fwht_mxu_ref",
           "hadamard_matrix", "split_factors"]
