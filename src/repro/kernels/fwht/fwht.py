"""Pallas TPU kernel: blocked fast Walsh-Hadamard transform (MXU Kronecker form).

TPU adaptation of the paper's randomized Hadamard Transform (§3.3, implemented
on GPU via HazyResearch's CUDA butterfly). A warp-shuffle butterfly does not
map to the TPU; instead we exploit H_n = H_a (x) H_b so a length-n block,
reshaped to (a, b), transforms as two dense matmuls ``H_a @ X @ H_b`` that run
on the 128x128 MXU. For n = 16384 both factors are exactly 128x128.

Grid: one program per tile of ``block_rows`` rows; each program holds
(block_rows, n) of the input plus the two factor matrices in VMEM.

VMEM budget per program (fp32): block_rows*n*4*2 (in+out) + (a^2+b^2)*4,
e.g. block_rows=128, n=4096 -> 4.2 MB, well within ~16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime

from .ref import hadamard_matrix, split_factors

# one program per independent row tile: Mosaic may run grid iterations in
# any order / in parallel windows (no cross-iteration scratch state)
_ROW_GRID = pltpu.TPUCompilerParams(dimension_semantics=("parallel",))


def mxu_rotate_block(x, ha, hb, rows: int, a: int, b: int):
    """The blocked-FWHT body shared by every kernel that rotates: (rows, n)
    fp32 -> (rows, n) via the two Kronecker-factor MXU matmuls. The fused
    ht_quant kernels reuse this so there is exactly one copy of the
    rotation math on the Pallas side."""
    x3 = x.reshape(rows, a, b)
    t = jax.lax.dot_general(
        x3, hb, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (rows, a, b)
    # y[r, i, k] = sum_j Ha[i, j] t[r, j, k]
    y = jax.lax.dot_general(
        t, ha, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (rows, b, a)
    return y.transpose(0, 2, 1).reshape(rows, a * b)


def _fwht_kernel(x_ref, ha_ref, hb_ref, o_ref, *, rows: int, a: int, b: int):
    x = x_ref[...].astype(jnp.float32)  # (rows, n)
    y = mxu_rotate_block(x, ha_ref[...], hb_ref[...], rows, a, b)
    o_ref[...] = y.astype(o_ref.dtype)


def _fwht_sign_kernel(x_ref, sign_ref, ha_ref, hb_ref, o_ref, *, rows: int,
                      a: int, b: int, sign_mode: str):
    x = x_ref[...].astype(jnp.float32)
    sign = sign_ref[...].astype(jnp.float32)         # (1, n)
    if sign_mode == "pre":
        x = x * sign
    y = mxu_rotate_block(x, ha_ref[...], hb_ref[...], rows, a, b)
    if sign_mode == "post":
        y = y * sign
    o_ref[...] = y.astype(o_ref.dtype)


def fwht_pallas(x: jnp.ndarray,
                sign: jnp.ndarray | None = None,
                *,
                block_rows: int = 64,
                sign_mode: str = "none",
                interpret: bool | None = None) -> jnp.ndarray:
    """Orthonormal FWHT over the last axis of ``x`` (rows, n), n a power of 2.

    sign_mode: 'none' | 'pre' (encode: H @ (d*x)) | 'post' (decode: d * (H@y)).
    ``sign`` is required unless sign_mode == 'none'; shape (n,).
    ``interpret=None`` resolves the process kernel mode (kernels/runtime);
    the resolved flag is a static jit argument, so mode flips retrace.
    """
    if interpret is None:
        interpret = runtime.interpret_flag()
    return _fwht_call(x, sign, block_rows=block_rows, sign_mode=sign_mode,
                      interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("block_rows", "sign_mode", "interpret"))
def _fwht_call(x: jnp.ndarray,
               sign: jnp.ndarray | None = None,
               *,
               block_rows: int = 64,
               sign_mode: str = "none",
               interpret: bool = True) -> jnp.ndarray:
    if x.ndim != 2:
        raise ValueError("fwht_pallas expects (rows, n)")
    rows, n = x.shape
    a, b = split_factors(n)
    # Fold the orthonormal 1/sqrt(n) into the factor matrices.
    ha = hadamard_matrix(a)      # 1/sqrt(a)
    hb = hadamard_matrix(b)      # 1/sqrt(b); product gives 1/sqrt(n)

    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (x.shape[0] // br,)

    if sign_mode == "none":
        kernel = functools.partial(_fwht_kernel, rows=br, a=a, b=b)
        in_specs = [
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ]
        args = (x, ha, hb)
    else:
        if sign is None:
            raise ValueError("sign required for sign_mode != 'none'")
        kernel = functools.partial(_fwht_sign_kernel, rows=br, a=a, b=b,
                                   sign_mode=sign_mode)
        in_specs = [
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ]
        args = (x, sign.reshape(1, n).astype(jnp.float32), ha, hb)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=_ROW_GRID,
        interpret=interpret,
    )(*args)
    if pad:
        out = out[:rows]
    return out
