"""Jit'd public wrappers for the FWHT kernel.

``fwht(x)`` operates on the last axis (any leading shape); the Pallas kernel
is used when requested / on TPU, the Kronecker jnp form otherwise (identical
math, so the dry-run HLO carries the kernel's FLOP structure).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .fwht import fwht_pallas
from .ref import fwht_mxu_ref, split_factors  # noqa: F401 (re-export)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel", "block_rows"))
def fwht(x: jnp.ndarray, *, use_kernel: bool = False,
         block_rows: int = 64) -> jnp.ndarray:
    """Orthonormal FWHT over the last axis. Involution: fwht(fwht(x)) == x."""
    shape = x.shape
    n = shape[-1]
    x2 = x.reshape(-1, n)
    if use_kernel:
        y = fwht_pallas(x2, block_rows=block_rows,
                        interpret=_default_interpret())
    else:
        y = fwht_mxu_ref(x2)
    return y.reshape(shape)


@functools.partial(jax.jit, static_argnames=("mode", "use_kernel", "block_rows"))
def randomized_fwht(x: jnp.ndarray, sign: jnp.ndarray, *, mode: str,
                    use_kernel: bool = False,
                    block_rows: int = 64) -> jnp.ndarray:
    """Randomized HT: encode = H @ (d*x); decode = d * (H @ y) (exact inverse)."""
    shape = x.shape
    n = shape[-1]
    x2 = x.reshape(-1, n)
    if use_kernel:
        sign_mode = {"encode": "pre", "decode": "post"}[mode]
        y = fwht_pallas(x2, sign, sign_mode=sign_mode, block_rows=block_rows,
                        interpret=_default_interpret())
    else:
        if mode == "encode":
            y = fwht_mxu_ref(x2 * sign[None, :])
        elif mode == "decode":
            y = fwht_mxu_ref(x2) * sign[None, :]
        else:
            raise ValueError(f"unknown mode {mode!r}")
    return y.reshape(shape)
