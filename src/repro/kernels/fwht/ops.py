"""Jit'd public wrappers for the FWHT kernel.

``fwht(x)`` operates on the last axis (any leading shape); the Pallas kernel
is used when requested, the Kronecker jnp form otherwise (identical math, so
the dry-run HLO carries the kernel's FLOP structure).  Whether the Pallas
path runs interpreted or Mosaic-compiled resolves through the process
kernel-mode policy (kernels/runtime) outside the jit boundary, so the
resolved flag is part of the cache key.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime

from .fwht import fwht_pallas
from .ref import fwht_mxu_ref, split_factors  # noqa: F401 (re-export)


@functools.partial(jax.jit,
                   static_argnames=("use_kernel", "block_rows", "interpret"))
def _fwht(x: jnp.ndarray, *, use_kernel: bool, block_rows: int,
          interpret: bool) -> jnp.ndarray:
    shape = x.shape
    n = shape[-1]
    x2 = x.reshape(-1, n)
    if use_kernel:
        y = fwht_pallas(x2, block_rows=block_rows, interpret=interpret)
    else:
        y = fwht_mxu_ref(x2)
    return y.reshape(shape)


def fwht(x: jnp.ndarray, *, use_kernel: bool = False,
         block_rows: int = 64) -> jnp.ndarray:
    """Orthonormal FWHT over the last axis. Involution: fwht(fwht(x)) == x."""
    return _fwht(x, use_kernel=use_kernel, block_rows=block_rows,
                 interpret=runtime.interpret_flag() if use_kernel else True)


def _randomized_fwht_impl(x: jnp.ndarray, sign: jnp.ndarray, *, mode: str,
                          use_kernel: bool, block_rows: int,
                          interpret: bool) -> jnp.ndarray:
    shape = x.shape
    n = shape[-1]
    x2 = x.reshape(-1, n)
    if use_kernel:
        sign_mode = {"encode": "pre", "decode": "post"}[mode]
        y = fwht_pallas(x2, sign, sign_mode=sign_mode, block_rows=block_rows,
                        interpret=interpret)
    else:
        if mode == "encode":
            y = fwht_mxu_ref(x2 * sign[None, :])
        elif mode == "decode":
            y = fwht_mxu_ref(x2) * sign[None, :]
        else:
            raise ValueError(f"unknown mode {mode!r}")
    return y.reshape(shape)


# keep the nested-jit lowering name: the schedule tests identify the codec
# kernels in lowered HLO by their "randomized_fwht*" callee specializations
_randomized_fwht_impl.__name__ = "randomized_fwht"
_randomized_fwht = functools.partial(
    jax.jit, static_argnames=("mode", "use_kernel", "block_rows", "interpret"),
)(_randomized_fwht_impl)


def randomized_fwht(x: jnp.ndarray, sign: jnp.ndarray, *, mode: str,
                    use_kernel: bool = False,
                    block_rows: int = 64) -> jnp.ndarray:
    """Randomized HT: encode = H @ (d*x); decode = d * (H @ y) (exact inverse)."""
    return _randomized_fwht(
        x, sign, mode=mode, use_kernel=use_kernel, block_rows=block_rows,
        interpret=runtime.interpret_flag() if use_kernel else True)
