"""Pure-jnp oracle for the (randomized) fast Walsh-Hadamard transform.

Two reference implementations:

* ``fwht_ref``      — classic O(n log n) butterfly, the ground-truth oracle.
* ``fwht_mxu_ref``  — the Kronecker/MXU formulation (H_n = H_a (x) H_b, so the
  transform of a length-n block is two dense matmuls on a (a, b) reshape).
  This is the *same math the Pallas kernel implements*; it is what the
  distributed train_step uses under jit on non-TPU backends so that the
  dry-run HLO carries the kernel's true FLOP structure.

Both are orthonormal: ``fwht(fwht(x)) == x``.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


def _log2(n: int) -> int:
    k = int(n).bit_length() - 1
    if (1 << k) != n:
        raise ValueError(f"block size must be a power of two, got {n}")
    return k


@functools.lru_cache(maxsize=32)
def hadamard_matrix_np(n: int) -> np.ndarray:
    """Unnormalized n x n Hadamard (Sylvester construction), float32."""
    _log2(n)
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def hadamard_matrix(n: int, *, orthonormal: bool = True) -> jnp.ndarray:
    h = hadamard_matrix_np(n)
    if orthonormal:
        h = h / np.sqrt(n).astype(np.float32)
    return jnp.asarray(h)


def split_factors(n: int) -> tuple[int, int]:
    """n = a * b with a, b powers of two and a >= b (a = 2^ceil(k/2))."""
    k = _log2(n)
    a = 1 << ((k + 1) // 2)
    b = 1 << (k // 2)
    return a, b


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal FWHT over the last axis (butterfly oracle)."""
    orig_shape = x.shape
    orig_dtype = x.dtype
    n = orig_shape[-1]
    _log2(n)
    y = x.astype(jnp.float32).reshape(-1, n)
    h = 1
    while h < n:
        y = y.reshape(-1, n // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2).reshape(-1, n)
        h *= 2
    y = y / jnp.sqrt(jnp.float32(n))
    return y.reshape(orig_shape).astype(orig_dtype)


def fwht_mxu_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal FWHT over the last axis, Kronecker-factored (MXU form).

    H_n = H_a (x) H_b (Sylvester ordering: index i*b + j), hence for a block
    reshaped to X[a, b]:  Y = H_a @ X @ H_b.
    """
    orig_shape = x.shape
    orig_dtype = x.dtype
    n = orig_shape[-1]
    a, b = split_factors(n)
    ha = hadamard_matrix(a)
    hb = hadamard_matrix(b)
    xr = x.astype(jnp.float32).reshape(-1, a, b)
    t = jnp.einsum("rjl,lk->rjk", xr, hb, preferred_element_type=jnp.float32)
    y = jnp.einsum("ij,rjk->rik", ha, t, preferred_element_type=jnp.float32)
    return y.reshape(orig_shape).astype(orig_dtype)


def randomized_fwht_ref(
    x: jnp.ndarray, sign: jnp.ndarray, *, mode: str
) -> jnp.ndarray:
    """Randomized HT oracle. mode='encode': H @ (d * x); mode='decode': d * (H @ y).

    With orthonormal H, (H D)^-1 = D H, so decode inverts encode exactly.
    """
    if mode == "encode":
        return fwht_ref(x * sign)
    if mode == "decode":
        return fwht_ref(x) * sign
    raise ValueError(f"unknown mode {mode!r}")
