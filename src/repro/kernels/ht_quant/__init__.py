from .ops import (ht_amax, ht_amax_ref, ht_encode_fused, ht_quant,
                  ht_quant_ref, ht_rotate_ref)

__all__ = ["ht_amax", "ht_amax_ref", "ht_encode_fused", "ht_quant",
           "ht_quant_ref", "ht_rotate_ref"]
