"""Pallas TPU kernels: fused randomized-Hadamard encode + THC quantization.

The unfused OptiReduce-Q encode path costs three full HBM round trips per
bucket: FWHT encode (read x, write rotated), per-block amax (read rotated),
quantize (read rotated + noise, write codes) — the rotated fp32 copy is
materialized purely to be re-read twice. The fused engine never writes it:

  ht_amax   — sign-flip + blocked MXU FWHT + per-block |.|max in one
              VMEM-resident pass (reads x once, writes one scalar per block).
  ht_quant  — sign-flip + blocked MXU FWHT + shared-grid stochastic uniform
              quantization in one VMEM-resident pass (reads x + noise once,
              writes uint8 codes). The rotation is recomputed (MXU FLOPs are
              free next to HBM here), so per bucket the encode side touches
              HBM exactly twice per input byte instead of four times and
              emits 1/4 the bytes.

The grids arrive as per-row (= per-Hadamard-block) ``lo``/``step`` operands
because THC needs them pmax-shared across workers *between* the amax and the
quantization — that collective is the only thing that cannot fuse.

Each program holds (block_rows, n) of x in VMEM plus the two Kronecker
factor matrices (H_n = H_a (x) H_b, two dense MXU matmuls — see
kernels/fwht). VMEM per program (fp32, block_rows=64, n=4096): ~3.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fwht.fwht import mxu_rotate_block
from repro.kernels.fwht.ref import hadamard_matrix, split_factors


def _rotate(x, sign, ha, hb, rows: int, a: int, b: int):
    """sign-flip + blocked FWHT of (rows, n), sharing the fwht kernel's
    rotation body (single copy of the MXU math on the Pallas side)."""
    return mxu_rotate_block(x.astype(jnp.float32) * sign, ha, hb, rows, a, b)


def _ht_amax_kernel(x_ref, sign_ref, ha_ref, hb_ref, o_ref, *, rows: int,
                    a: int, b: int):
    y = _rotate(x_ref[...], sign_ref[...].astype(jnp.float32),
                ha_ref[...], hb_ref[...], rows, a, b)
    o_ref[...] = jnp.max(jnp.abs(y), axis=1, keepdims=True)


def _ht_quant_kernel(x_ref, sign_ref, noise_ref, lo_ref, step_ref,
                     ha_ref, hb_ref, o_ref, *, rows: int, a: int, b: int,
                     levels: int):
    y = _rotate(x_ref[...], sign_ref[...].astype(jnp.float32),
                ha_ref[...], hb_ref[...], rows, a, b)
    u = noise_ref[...].astype(jnp.float32)
    lo = lo_ref[...]                                 # (rows, 1)
    step = step_ref[...]                             # (rows, 1)
    q = jnp.floor((y - lo) / step + u)
    o_ref[...] = jnp.clip(q, 0, levels).astype(o_ref.dtype)


def _factors(n: int):
    a, b = split_factors(n)
    return a, b, hadamard_matrix(a), hadamard_matrix(b)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ht_amax_pallas(x: jnp.ndarray, sign: jnp.ndarray, *,
                   block_rows: int = 64,
                   interpret: bool = True) -> jnp.ndarray:
    """Per-block amax of the rotated blocks. x: (rows, n) -> (rows,) fp32."""
    if x.ndim != 2:
        raise ValueError("ht_amax_pallas expects (rows, n)")
    rows, n = x.shape
    a, b, ha, hb = _factors(n)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_ht_amax_kernel, rows=br, a=a, b=b),
        grid=(x.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(x, sign.reshape(1, n).astype(jnp.float32), ha, hb)
    return out[:rows, 0]


@functools.partial(jax.jit,
                   static_argnames=("bits", "block_rows", "interpret"))
def ht_quant_pallas(x: jnp.ndarray, sign: jnp.ndarray, noise: jnp.ndarray,
                    lo: jnp.ndarray, step: jnp.ndarray, *, bits: int = 8,
                    block_rows: int = 64,
                    interpret: bool = True) -> jnp.ndarray:
    """Fused encode: codes = clip(floor((H(d*x) - lo)/step + noise)).

    x/noise: (rows, n); lo/step: (rows,) per-block grid bounds (already
    pmax-shared across workers). Returns (rows, n) uint8 codes.
    """
    if x.ndim != 2 or noise.shape != x.shape:
        raise ValueError("x and noise must both be (rows, n)")
    rows, n = x.shape
    a, b, ha, hb = _factors(n)
    levels = (1 << bits) - 1
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        noise = jnp.pad(noise, ((0, pad), (0, 0)))
        lo = jnp.pad(lo.reshape(-1), (0, pad))
        step = jnp.pad(step.reshape(-1), (0, pad), constant_values=1.0)
    out = pl.pallas_call(
        functools.partial(_ht_quant_kernel, rows=br, a=a, b=b, levels=levels),
        grid=(x.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.uint8),
        interpret=interpret,
    )(x, sign.reshape(1, n).astype(jnp.float32), noise,
      lo.reshape(-1, 1).astype(jnp.float32),
      step.reshape(-1, 1).astype(jnp.float32), ha, hb)
    return out[:rows]
