"""Pallas TPU kernels: fused randomized-Hadamard encode + THC quantization.

The unfused OptiReduce-Q encode path costs three full HBM round trips per
bucket: FWHT encode (read x, write rotated), per-block amax (read rotated),
quantize (read rotated + noise, write codes) — the rotated fp32 copy is
materialized purely to be re-read twice. The fused engine never writes it:

  ht_amax   — sign-flip + blocked MXU FWHT + per-block |.|max in one
              VMEM-resident pass (reads x once, writes one scalar per block).
  ht_quant  — sign-flip + blocked MXU FWHT + shared-grid stochastic uniform
              quantization in one VMEM-resident pass (reads x + noise once,
              writes uint8 codes). The rotation is recomputed (MXU FLOPs are
              free next to HBM here), so per bucket the encode side touches
              HBM exactly twice per input byte instead of four times and
              emits 1/4 the bytes.

Both kernels are *double-buffered*: the operands sit in ``ANY`` (HBM) memory
space and each grid iteration's row block is streamed into a two-slot
revolving VMEM buffer with explicit async copies, so block i+1's HBM loads
are in flight while block i rotates on the MXU — the codec kernels overlap
their own HBM traffic instead of serializing load → rotate → store per
block.  One shared pipeline body (``kernels/dma.py``) carries the DMA
schedule for both kernels; the per-kernel difference is only the epilogue
consuming the rotated block (amax-reduce vs quantize), so there is exactly
one copy of the revolving-buffer logic and of the rotation math
(``mxu_rotate_block``) on the Pallas side.

The grids arrive as per-row (= per-Hadamard-block) ``lo``/``step`` operands
because THC needs them pmax-shared across workers *between* the amax and the
quantization — that collective now rides the pipelined schedule's exchange
stage (core/pipeline.py) instead of splitting the encode.

VMEM per program (fp32, block_rows=64, n=4096): two x slots (2 MB) + two
noise slots (2 MB, ht_quant only) + factors + the pipelined output block —
~5 MB, well within ~16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime
from repro.kernels.dma import SEQUENTIAL_GRID, revolving_pipeline, row_loads
from repro.kernels.fwht.fwht import mxu_rotate_block
from repro.kernels.fwht.ref import hadamard_matrix, split_factors


def _rotate(x, sign, ha, hb, rows: int, a: int, b: int):
    """sign-flip + blocked FWHT of (rows, n), sharing the fwht kernel's
    rotation body (single copy of the MXU math on the Pallas side)."""
    return mxu_rotate_block(x.astype(jnp.float32) * sign, ha, hb, rows, a, b)


def _rotation_pipeline(nblk: int, streams, sem, epilogue):
    """Two-slot revolving-buffer schedule over row blocks (kernels/dma)."""
    revolving_pipeline(
        nblk, functools.partial(row_loads, streams, sem), epilogue)


def _ht_amax_kernel(x_hbm, sign_ref, ha_ref, hb_ref, o_ref, xbuf, sem, *,
                    nblk: int, rows: int, a: int, b: int):
    def epilogue(slot):
        y = _rotate(xbuf[slot], sign_ref[...].astype(jnp.float32),
                    ha_ref[...], hb_ref[...], rows, a, b)
        o_ref[...] = jnp.max(jnp.abs(y), axis=1, keepdims=True)

    _rotation_pipeline(nblk, [(x_hbm, xbuf, rows)], sem, epilogue)


def _ht_quant_kernel(x_hbm, sign_ref, noise_hbm, lo_hbm, step_hbm,
                     ha_ref, hb_ref, o_ref, xbuf, nbuf, lobuf, stepbuf, sem,
                     *, nblk: int, rows: int, a: int, b: int, levels: int):
    def epilogue(slot):
        y = _rotate(xbuf[slot], sign_ref[...].astype(jnp.float32),
                    ha_ref[...], hb_ref[...], rows, a, b)
        u = nbuf[slot].astype(jnp.float32)
        lo = lobuf[slot]                             # (rows, 1)
        step = stepbuf[slot]                         # (rows, 1)
        q = jnp.floor((y - lo) / step + u)
        o_ref[...] = jnp.clip(q, 0, levels).astype(o_ref.dtype)

    _rotation_pipeline(
        nblk,
        [(x_hbm, xbuf, rows), (noise_hbm, nbuf, rows),
         (lo_hbm, lobuf, rows), (step_hbm, stepbuf, rows)],
        sem, epilogue)


def _factors(n: int):
    a, b = split_factors(n)
    return a, b, hadamard_matrix(a), hadamard_matrix(b)


def ht_amax_pallas(x: jnp.ndarray, sign: jnp.ndarray, *,
                   block_rows: int = 64,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Per-block amax of the rotated blocks. x: (rows, n) -> (rows,) fp32.

    ``interpret=None`` resolves the process kernel mode (kernels/runtime).
    """
    if interpret is None:
        interpret = runtime.interpret_flag()
    return _ht_amax_call(x, sign, block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _ht_amax_call(x: jnp.ndarray, sign: jnp.ndarray, *,
                  block_rows: int = 64,
                  interpret: bool = True) -> jnp.ndarray:
    if x.ndim != 2:
        raise ValueError("ht_amax_pallas expects (rows, n)")
    rows, n = x.shape
    a, b, ha, hb = _factors(n)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    nblk = x.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_ht_amax_kernel, nblk=nblk, rows=br, a=a, b=b),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),    # x: streamed manually
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((2, br, n), x.dtype),
                        pltpu.SemaphoreType.DMA((1, 2))],
        compiler_params=SEQUENTIAL_GRID,
        interpret=interpret,
    )(x, sign.reshape(1, n).astype(jnp.float32), ha, hb)
    return out[:rows, 0]


def ht_quant_pallas(x: jnp.ndarray, sign: jnp.ndarray, noise: jnp.ndarray,
                    lo: jnp.ndarray, step: jnp.ndarray, *, bits: int = 8,
                    block_rows: int = 64,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Fused encode: codes = clip(floor((H(d*x) - lo)/step + noise)).

    x/noise: (rows, n); lo/step: (rows,) per-block grid bounds (already
    pmax-shared across workers). Returns (rows, n) uint8 codes.
    ``interpret=None`` resolves the process kernel mode (kernels/runtime).
    """
    if interpret is None:
        interpret = runtime.interpret_flag()
    return _ht_quant_call(x, sign, noise, lo, step, bits=bits,
                          block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("bits", "block_rows", "interpret"))
def _ht_quant_call(x: jnp.ndarray, sign: jnp.ndarray, noise: jnp.ndarray,
                   lo: jnp.ndarray, step: jnp.ndarray, *, bits: int = 8,
                   block_rows: int = 64,
                   interpret: bool = True) -> jnp.ndarray:
    if x.ndim != 2 or noise.shape != x.shape:
        raise ValueError("x and noise must both be (rows, n)")
    rows, n = x.shape
    a, b, ha, hb = _factors(n)
    levels = (1 << bits) - 1
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        noise = jnp.pad(noise, ((0, pad), (0, 0)))
        lo = jnp.pad(lo.reshape(-1), (0, pad))
        step = jnp.pad(step.reshape(-1), (0, pad), constant_values=1.0)
    nblk = x.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_ht_quant_kernel, nblk=nblk, rows=br, a=a, b=b,
                          levels=levels),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),    # x: streamed manually
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),    # noise: streamed
            pl.BlockSpec(memory_space=pltpu.ANY),    # lo: streamed
            pl.BlockSpec(memory_space=pltpu.ANY),    # step: streamed
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.uint8),
        scratch_shapes=[pltpu.VMEM((2, br, n), x.dtype),
                        pltpu.VMEM((2, br, n), noise.dtype),
                        pltpu.VMEM((2, br, 1), jnp.float32),
                        pltpu.VMEM((2, br, 1), jnp.float32),
                        pltpu.SemaphoreType.DMA((4, 2))],
        compiler_params=SEQUENTIAL_GRID,
        interpret=interpret,
    )(x, sign.reshape(1, n).astype(jnp.float32), noise,
      lo.reshape(-1, 1).astype(jnp.float32),
      step.reshape(-1, 1).astype(jnp.float32), ha, hb)
    return out[:rows]
