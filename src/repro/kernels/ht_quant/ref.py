"""Pure-jnp oracles for the fused HT-encode + quantize kernels.

Each oracle composes the existing building blocks (``fwht_mxu_ref`` — the
same MXU Kronecker math the Pallas kernel runs — and the THC uniform
quantizer) so the fused kernels have a bit-exact reference: fused output ==
composed-pipeline output, the parity contract the tests assert.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fwht.ref import fwht_mxu_ref


def ht_rotate_ref(x: jnp.ndarray, sign: jnp.ndarray) -> jnp.ndarray:
    """sign-flip + blocked FWHT of (rows, n) — the encode rotation."""
    return fwht_mxu_ref(x.astype(jnp.float32) * sign[None, :])


def ht_amax_ref(x: jnp.ndarray, sign: jnp.ndarray) -> jnp.ndarray:
    """Per-block amax of rotated blocks. (rows, n) -> (rows,) fp32."""
    return jnp.max(jnp.abs(ht_rotate_ref(x, sign)), axis=1)


def ht_quant_ref(x: jnp.ndarray, sign: jnp.ndarray, noise: jnp.ndarray,
                 lo: jnp.ndarray, step: jnp.ndarray, *,
                 bits: int) -> jnp.ndarray:
    """Rotate then quantize onto per-block [lo, lo + levels*step] grids.

    lo/step: (rows,) — already pmax-shared across workers by the caller.
    """
    levels = (1 << bits) - 1
    y = ht_rotate_ref(x, sign)
    q = jnp.floor((y - lo[:, None]) / step[:, None] + noise)
    return jnp.clip(q, 0, levels).astype(jnp.uint8)
