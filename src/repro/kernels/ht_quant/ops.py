"""Jit'd public wrappers for the fused HT-encode + quantize kernels.

``ht_amax`` / ``ht_quant`` operate on (rows, block) — one Hadamard block per
row, the layout ``core.allreduce`` already uses. ``use_kernel`` selects the
Pallas kernel; the jnp oracle is identical math.  Whether the Pallas path
runs interpreted or Mosaic-compiled resolves through the process kernel-mode
policy (kernels/runtime) outside the jit boundary, so the resolved flag is
part of the cache key.

The unquantized fused variant of the engine is the existing sign+FWHT
single-pass kernel (``randomized_fwht(..., use_kernel=True)``); ``ht_encode
_fused`` re-exports it here so the sync engine has one dispatch surface for
both the quantized (bits>0) and unquantized (bits=0) encode stages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.fwht import randomized_fwht

from .ht_quant import ht_amax_pallas, ht_quant_pallas
from .ref import ht_amax_ref, ht_quant_ref, ht_rotate_ref  # noqa: F401


@functools.partial(jax.jit,
                   static_argnames=("use_kernel", "block_rows", "interpret"))
def _ht_amax(x: jnp.ndarray, sign: jnp.ndarray, *, use_kernel: bool,
             block_rows: int, interpret: bool) -> jnp.ndarray:
    if use_kernel:
        return ht_amax_pallas(x, sign, block_rows=block_rows,
                              interpret=interpret)
    return ht_amax_ref(x, sign)


def ht_amax(x: jnp.ndarray, sign: jnp.ndarray, *, use_kernel: bool = False,
            block_rows: int = 64) -> jnp.ndarray:
    """Per-block amax of the rotated blocks, without materializing them.

    x: (rows, block) -> (rows,) fp32.
    """
    return _ht_amax(
        x, sign, use_kernel=use_kernel, block_rows=block_rows,
        interpret=runtime.interpret_flag() if use_kernel else True)


@functools.partial(jax.jit, static_argnames=("bits", "use_kernel",
                                             "block_rows", "interpret"))
def _ht_quant(x: jnp.ndarray, sign: jnp.ndarray, noise: jnp.ndarray,
              lo: jnp.ndarray, step: jnp.ndarray, *, bits: int,
              use_kernel: bool, block_rows: int,
              interpret: bool) -> jnp.ndarray:
    if use_kernel:
        return ht_quant_pallas(x, sign, noise, lo, step, bits=bits,
                               block_rows=block_rows, interpret=interpret)
    return ht_quant_ref(x, sign, noise, lo.reshape(-1), step.reshape(-1),
                        bits=bits)


def ht_quant(x: jnp.ndarray, sign: jnp.ndarray, noise: jnp.ndarray,
             lo: jnp.ndarray, step: jnp.ndarray, *, bits: int = 8,
             use_kernel: bool = False, block_rows: int = 64) -> jnp.ndarray:
    """Fused sign-flip + FWHT + stochastic uniform quantization.

    x/noise: (rows, block); lo/step: (rows,) shared grids -> uint8 codes.
    """
    return _ht_quant(
        x, sign, noise, lo, step, bits=bits, use_kernel=use_kernel,
        block_rows=block_rows,
        interpret=runtime.interpret_flag() if use_kernel else True)


def ht_encode_fused(x: jnp.ndarray, sign: jnp.ndarray, *,
                    use_kernel: bool = False) -> jnp.ndarray:
    """Unquantized fused encode (sign+FWHT one-pass): the bits=0 stage."""
    return randomized_fwht(x, sign, mode="encode", use_kernel=use_kernel)
