"""Kernel dispatch policy: run Pallas kernels interpreted or compiled.

Every Pallas call site in the repo used to hard-code ``interpret=True`` —
correct on the CPU CI box, silently wrong on a real TPU (the fused engine
would run in the interpreter even with Mosaic available). This module is
the single resolution point:

  ``interpret``  force the Pallas interpreter (any backend; bit-exact to
                 the historical behaviour).
  ``compile``    force Mosaic compilation; raises immediately on a backend
                 without Mosaic support instead of surfacing a cryptic
                 lowering failure from inside a kernel.
  ``auto``       compile iff ``jax.default_backend() == "tpu"`` (default).

Precedence: an explicit :func:`set_kernel_mode` (the ``--kernel-mode``
launcher flag / ``TrainConfig.kernel_mode``) > the ``REPRO_KERNEL_MODE``
environment variable > ``auto``.

The public kernel wrappers (each package's ``ops.py``) resolve the flag
*outside* their ``jax.jit`` boundary and pass it through as a static
argument, so the resolved mode is part of every kernel's jit cache key and
flipping the mode mid-process cannot hit a stale trace.  Caveat: a caller
that jits a *larger* step function around the wrappers bakes the mode in at
its own trace time — set the mode before building train steps.
"""
from __future__ import annotations

import contextlib
import logging
import os

import jax

ENV_VAR = "REPRO_KERNEL_MODE"
MODES = ("auto", "interpret", "compile")

logger = logging.getLogger("repro.kernels.runtime")

_explicit: str | None = None      # set_kernel_mode override
_logged_resolution: str | None = None


def _check(mode: str) -> str:
    mode = str(mode).strip().lower()
    if mode not in MODES:
        raise ValueError(
            f"unknown kernel mode {mode!r}; expected one of {MODES}")
    return mode


def _mosaic_available() -> bool:
    """Whether this process can compile Pallas TPU kernels (Mosaic)."""
    return jax.default_backend() == "tpu"


def kernel_mode() -> str:
    """The *configured* mode: explicit override > $REPRO_KERNEL_MODE > auto."""
    if _explicit is not None:
        return _explicit
    env = os.environ.get(ENV_VAR, "")
    if env.strip():
        return _check(env)
    return "auto"


def set_kernel_mode(mode: str | None) -> None:
    """Set (or, with ``None``, clear) the process-wide explicit mode."""
    global _explicit, _logged_resolution
    _explicit = None if mode is None else _check(mode)
    _logged_resolution = None      # re-log on the next resolve


@contextlib.contextmanager
def kernel_mode_scope(mode: str | None):
    """Temporarily pin the kernel mode (tests / benchmark sweeps)."""
    prev = _explicit
    set_kernel_mode(mode)
    try:
        yield
    finally:
        set_kernel_mode(prev)


def resolve() -> str:
    """'interpret' or 'compile' for this process, validated against the
    backend — ``compile`` without Mosaic is an immediate, legible error."""
    mode = kernel_mode()
    backend = jax.default_backend()
    if mode == "compile" and not _mosaic_available():
        raise RuntimeError(
            f"kernel_mode='compile' needs a TPU (Mosaic) backend but "
            f"jax.default_backend() is {backend!r}. Use "
            f"kernel_mode='interpret' to run the kernels in the Pallas "
            f"interpreter here, or 'auto' to pick per-backend.")
    resolved = mode if mode != "auto" else (
        "compile" if _mosaic_available() else "interpret")
    global _logged_resolution
    if _logged_resolution != resolved:
        _logged_resolution = resolved
        logger.info("kernel dispatch: mode=%s -> %s (backend=%s)",
                    mode, resolved, backend)
    return resolved


def interpret_flag() -> bool:
    """The ``interpret=`` value a ``pl.pallas_call`` should receive now."""
    return resolve() == "interpret"
