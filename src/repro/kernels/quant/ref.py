"""Pure-jnp oracle for THC-style uniform stochastic quantization.

THC (Li et al., NSDI'24) quantizes Hadamard-rotated gradients onto a *shared*
uniform grid so that aggregation is homomorphic: codes can be summed across
workers and dequantized once. We reproduce the table-free uniform variant:

    step   = (hi - lo) / (2^bits - 1)
    code   = floor((x - lo) / step + u),  u ~ U[0, 1)   (stochastic rounding)
    dequant(code) = lo + code * step                    (unbiased: E = x)

The rotation uses the shared FWHT kernel (THC is itself Hadamard-based, which
is why the paper calls OptiReduce orthogonal to it).
"""
from __future__ import annotations

import jax.numpy as jnp


def uniform_quant_ref(x: jnp.ndarray, noise: jnp.ndarray, lo: jnp.ndarray,
                      hi: jnp.ndarray, *, bits: int) -> jnp.ndarray:
    levels = (1 << bits) - 1
    step = (hi - lo) / levels
    q = jnp.floor((x.astype(jnp.float32) - lo) / step + noise)
    return jnp.clip(q, 0, levels).astype(jnp.uint8)


def grid_quant_ref(x: jnp.ndarray, noise: jnp.ndarray, lo: jnp.ndarray,
                   step: jnp.ndarray, *, bits: int) -> jnp.ndarray:
    """Per-row-grid variant: one Hadamard block per row.

    x/noise: (rows, C); lo/step: (rows,) — each row quantizes onto its own
    [lo_r, lo_r + levels*step_r] grid (the grids are already pmax-shared
    across workers by the collective layer).
    """
    levels = (1 << bits) - 1
    q = jnp.floor((x.astype(jnp.float32) - lo[:, None]) / step[:, None]
                  + noise)
    return jnp.clip(q, 0, levels).astype(jnp.uint8)


def uniform_dequant_ref(codes: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                        *, bits: int,
                        nsum: int = 1) -> jnp.ndarray:
    """Dequantize (a sum of ``nsum`` workers' codes): lo*nsum + codes*step."""
    levels = (1 << bits) - 1
    step = (hi - lo) / levels
    return (codes.astype(jnp.float32) * step + lo * nsum)
