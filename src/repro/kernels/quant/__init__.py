from .ops import uniform_quant, uniform_dequant
from .ref import uniform_quant_ref, uniform_dequant_ref

__all__ = ["uniform_quant", "uniform_dequant", "uniform_quant_ref",
           "uniform_dequant_ref"]
