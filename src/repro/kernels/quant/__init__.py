from .ops import grid_quant, uniform_quant, uniform_dequant
from .ref import grid_quant_ref, uniform_quant_ref, uniform_dequant_ref

__all__ = ["grid_quant", "uniform_quant", "uniform_dequant",
           "grid_quant_ref", "uniform_quant_ref", "uniform_dequant_ref"]
