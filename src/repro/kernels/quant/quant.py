"""Pallas TPU kernel: fused uniform stochastic quantization (THC baseline).

Fuses the subtract/scale/stochastic-round/clip chain into one VMEM pass so the
compression epilogue after the FWHT rotation costs a single HBM round-trip.

Grid: one program per (TILE_R, C) row-tile. lo/hi are scalars broadcast as a
(1, 1) operand (shared quantization range across workers — the property THC
needs for homomorphic aggregation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime

# independent row tiles: no cross-iteration state, Mosaic may parallelize
_ROW_GRID = pltpu.TPUCompilerParams(dimension_semantics=("parallel",))


def _quant_kernel(x_ref, n_ref, r_ref, o_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)
    u = n_ref[...].astype(jnp.float32)
    lo = r_ref[0, 0]
    hi = r_ref[0, 1]
    step = (hi - lo) / levels
    q = jnp.floor((x - lo) / step + u)
    o_ref[...] = jnp.clip(q, 0, levels).astype(o_ref.dtype)


def _grid_quant_kernel(x_ref, n_ref, lo_ref, step_ref, o_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)
    u = n_ref[...].astype(jnp.float32)
    lo = lo_ref[...]                                   # (rows, 1) per-row
    step = step_ref[...]
    q = jnp.floor((x - lo) / step + u)
    o_ref[...] = jnp.clip(q, 0, levels).astype(o_ref.dtype)


def grid_quant_pallas(x: jnp.ndarray, noise: jnp.ndarray, lo: jnp.ndarray,
                      step: jnp.ndarray, *, bits: int = 8,
                      block_rows: int = 128,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Quantize (rows, C) onto per-row [lo_r, lo_r + levels*step_r] grids.

    The grid-aware sibling of :func:`uniform_quant_pallas`: lo/step are
    (rows,) operands tiled alongside the data, so one pass covers every
    Hadamard block of a shard (TAR stage-2 re-quantization).
    ``interpret=None`` resolves the process kernel mode (kernels/runtime)."""
    if interpret is None:
        interpret = runtime.interpret_flag()
    return _grid_quant_call(x, noise, lo, step, bits=bits,
                            block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "block_rows", "interpret"))
def _grid_quant_call(x: jnp.ndarray, noise: jnp.ndarray, lo: jnp.ndarray,
                     step: jnp.ndarray, *, bits: int = 8,
                     block_rows: int = 128,
                     interpret: bool = True) -> jnp.ndarray:
    if x.ndim != 2 or noise.shape != x.shape:
        raise ValueError("x and noise must both be (rows, C)")
    rows, c = x.shape
    levels = (1 << bits) - 1
    br = min(block_rows, rows)
    pad = (-rows) % br
    lo2 = lo.reshape(rows, 1).astype(jnp.float32)
    step2 = step.reshape(rows, 1).astype(jnp.float32)
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        noise = jnp.pad(noise, ((0, pad), (0, 0)))
        lo2 = jnp.pad(lo2, ((0, pad), (0, 0)))
        step2 = jnp.pad(step2, ((0, pad), (0, 0)),
                        constant_values=1.0)           # avoid 0-div pad rows
    out = pl.pallas_call(
        functools.partial(_grid_quant_kernel, levels=levels),
        grid=(x.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.uint8),
        compiler_params=_ROW_GRID,
        interpret=interpret,
    )(x, noise, lo2, step2)
    if pad:
        out = out[:rows]
    return out


def uniform_quant_pallas(x: jnp.ndarray, noise: jnp.ndarray,
                         lohi: jnp.ndarray, *, bits: int = 8,
                         block_rows: int = 128,
                         interpret: bool | None = None) -> jnp.ndarray:
    """Quantize (rows, C) onto the shared [lo, hi] grid. lohi: shape (2,).
    ``interpret=None`` resolves the process kernel mode (kernels/runtime)."""
    if interpret is None:
        interpret = runtime.interpret_flag()
    return _uniform_quant_call(x, noise, lohi, bits=bits,
                               block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "block_rows", "interpret"))
def _uniform_quant_call(x: jnp.ndarray, noise: jnp.ndarray,
                        lohi: jnp.ndarray, *, bits: int = 8,
                        block_rows: int = 128,
                        interpret: bool = True) -> jnp.ndarray:
    if x.ndim != 2 or noise.shape != x.shape:
        raise ValueError("x and noise must both be (rows, C)")
    rows, c = x.shape
    levels = (1 << bits) - 1
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        noise = jnp.pad(noise, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_quant_kernel, levels=levels),
        grid=(x.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.uint8),
        compiler_params=_ROW_GRID,
        interpret=interpret,
    )(x, noise, lohi.reshape(1, 2).astype(jnp.float32))
    if pad:
        out = out[:rows]
    return out
