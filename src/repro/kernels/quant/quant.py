"""Pallas TPU kernel: fused uniform stochastic quantization (THC baseline).

Fuses the subtract/scale/stochastic-round/clip chain into one VMEM pass so the
compression epilogue after the FWHT rotation costs a single HBM round-trip.

Grid: one program per (TILE_R, C) row-tile. lo/hi are scalars broadcast as a
(1, 1) operand (shared quantization range across workers — the property THC
needs for homomorphic aggregation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, n_ref, r_ref, o_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)
    u = n_ref[...].astype(jnp.float32)
    lo = r_ref[0, 0]
    hi = r_ref[0, 1]
    step = (hi - lo) / levels
    q = jnp.floor((x - lo) / step + u)
    o_ref[...] = jnp.clip(q, 0, levels).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_rows", "interpret"))
def uniform_quant_pallas(x: jnp.ndarray, noise: jnp.ndarray,
                         lohi: jnp.ndarray, *, bits: int = 8,
                         block_rows: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """Quantize (rows, C) onto the shared [lo, hi] grid. lohi: shape (2,)."""
    if x.ndim != 2 or noise.shape != x.shape:
        raise ValueError("x and noise must both be (rows, C)")
    rows, c = x.shape
    levels = (1 << bits) - 1
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        noise = jnp.pad(noise, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_quant_kernel, levels=levels),
        grid=(x.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.uint8),
        interpret=interpret,
    )(x, noise, lohi.reshape(1, 2).astype(jnp.float32))
    if pad:
        out = out[:rows]
    return out
