"""Jit'd public wrappers for the THC quantization kernel.

The Pallas paths' interpret/compile flag resolves through the process
kernel-mode policy (kernels/runtime) outside the jit boundary, so the
resolved flag is part of the cache key.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime

from .quant import grid_quant_pallas, uniform_quant_pallas
from .ref import grid_quant_ref, uniform_dequant_ref, uniform_quant_ref


@functools.partial(jax.jit,
                   static_argnames=("bits", "use_kernel", "interpret"))
def _uniform_quant(x: jnp.ndarray, noise: jnp.ndarray, lohi: jnp.ndarray, *,
                   bits: int, use_kernel: bool,
                   interpret: bool) -> jnp.ndarray:
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim > 2 else x.reshape(1, -1) if x.ndim == 1 else x
    n2 = noise.reshape(x2.shape)
    if use_kernel:
        out = uniform_quant_pallas(x2, n2, lohi, bits=bits,
                                   interpret=interpret)
    else:
        out = uniform_quant_ref(x2, n2, lohi[0], lohi[1], bits=bits)
    return out.reshape(shape)


def uniform_quant(x: jnp.ndarray, noise: jnp.ndarray, lohi: jnp.ndarray, *,
                  bits: int = 8, use_kernel: bool = False) -> jnp.ndarray:
    return _uniform_quant(
        x, noise, lohi, bits=bits, use_kernel=use_kernel,
        interpret=runtime.interpret_flag() if use_kernel else True)


@functools.partial(jax.jit,
                   static_argnames=("bits", "use_kernel", "interpret"))
def _grid_quant(x: jnp.ndarray, noise: jnp.ndarray, lo: jnp.ndarray,
                step: jnp.ndarray, *, bits: int, use_kernel: bool,
                interpret: bool) -> jnp.ndarray:
    if use_kernel:
        return grid_quant_pallas(x, noise, lo, step, bits=bits,
                                 interpret=interpret)
    return grid_quant_ref(x, noise, lo, step, bits=bits)


def grid_quant(x: jnp.ndarray, noise: jnp.ndarray, lo: jnp.ndarray,
               step: jnp.ndarray, *, bits: int = 8,
               use_kernel: bool = False) -> jnp.ndarray:
    """Quantize (rows, C) onto per-row [lo_r, lo_r + levels*step_r] grids.

    The shard-side (TAR stage-2) quantization stage of the fused sync
    engine: one Hadamard block per row, grids already pmax-shared. Kernel
    and jnp paths are bit-identical.
    """
    return _grid_quant(
        x, noise, lo, step, bits=bits, use_kernel=use_kernel,
        interpret=runtime.interpret_flag() if use_kernel else True)


def uniform_dequant(codes: jnp.ndarray, lohi: jnp.ndarray, *, bits: int = 8,
                    nsum: int = 1) -> jnp.ndarray:
    """Elementwise dequant — XLA fuses this; no kernel needed."""
    return uniform_dequant_ref(codes, lohi[0], lohi[1], bits=bits, nsum=nsum)
