"""Jit'd public wrappers for the THC quantization kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .quant import grid_quant_pallas, uniform_quant_pallas
from .ref import grid_quant_ref, uniform_dequant_ref, uniform_quant_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bits", "use_kernel"))
def uniform_quant(x: jnp.ndarray, noise: jnp.ndarray, lohi: jnp.ndarray, *,
                  bits: int = 8, use_kernel: bool = False) -> jnp.ndarray:
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim > 2 else x.reshape(1, -1) if x.ndim == 1 else x
    n2 = noise.reshape(x2.shape)
    if use_kernel:
        out = uniform_quant_pallas(x2, n2, lohi, bits=bits,
                                   interpret=_default_interpret())
    else:
        out = uniform_quant_ref(x2, n2, lohi[0], lohi[1], bits=bits)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("bits", "use_kernel"))
def grid_quant(x: jnp.ndarray, noise: jnp.ndarray, lo: jnp.ndarray,
               step: jnp.ndarray, *, bits: int = 8,
               use_kernel: bool = False) -> jnp.ndarray:
    """Quantize (rows, C) onto per-row [lo_r, lo_r + levels*step_r] grids.

    The shard-side (TAR stage-2) quantization stage of the fused sync
    engine: one Hadamard block per row, grids already pmax-shared. Kernel
    and jnp paths are bit-identical.
    """
    if use_kernel:
        return grid_quant_pallas(x, noise, lo, step, bits=bits,
                                 interpret=_default_interpret())
    return grid_quant_ref(x, noise, lo, step, bits=bits)


def uniform_dequant(codes: jnp.ndarray, lohi: jnp.ndarray, *, bits: int = 8,
                    nsum: int = 1) -> jnp.ndarray:
    """Elementwise dequant — XLA fuses this; no kernel needed."""
    return uniform_dequant_ref(codes, lohi[0], lohi[1], bits=bits, nsum=nsum)
