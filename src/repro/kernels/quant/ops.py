"""Jit'd public wrappers for the THC quantization kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .quant import uniform_quant_pallas
from .ref import uniform_dequant_ref, uniform_quant_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bits", "use_kernel"))
def uniform_quant(x: jnp.ndarray, noise: jnp.ndarray, lohi: jnp.ndarray, *,
                  bits: int = 8, use_kernel: bool = False) -> jnp.ndarray:
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim > 2 else x.reshape(1, -1) if x.ndim == 1 else x
    n2 = noise.reshape(x2.shape)
    if use_kernel:
        out = uniform_quant_pallas(x2, n2, lohi, bits=bits,
                                   interpret=_default_interpret())
    else:
        out = uniform_quant_ref(x2, n2, lohi[0], lohi[1], bits=bits)
    return out.reshape(shape)


def uniform_dequant(codes: jnp.ndarray, lohi: jnp.ndarray, *, bits: int = 8,
                    nsum: int = 1) -> jnp.ndarray:
    """Elementwise dequant — XLA fuses this; no kernel needed."""
    return uniform_dequant_ref(codes, lohi[0], lohi[1], bits=bits, nsum=nsum)
