"""Jit'd public wrapper for the fused dequant + compensated reduction.

Accepts the per-Hadamard-block grids the collective layer carries
((nblk,)-shaped ``lo``/``step``) and expands them to per-column rows before
dispatching to the Pallas kernel or the jnp oracle.  Whether the Pallas path
runs interpreted or Mosaic-compiled resolves through the process kernel-mode
policy (kernels/runtime) outside the jit boundary, so the resolved flag is
part of the cache key.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime

from .dequant_reduce import dequant_masked_mean_pallas
from .ref import dequant_masked_mean_ref


@functools.partial(jax.jit, static_argnames=("block", "use_kernel", "tile",
                                             "interpret"))
def _dequant_masked_mean(codes: jnp.ndarray, lo: jnp.ndarray,
                         step: jnp.ndarray,
                         mask: jnp.ndarray | None = None, *, block: int,
                         use_kernel: bool, tile: int,
                         interpret: bool) -> jnp.ndarray:
    n, length = codes.shape
    nblk = length // block
    lo_row = jnp.broadcast_to(lo.reshape(nblk, 1), (nblk, block)).reshape(-1)
    step_row = jnp.broadcast_to(step.reshape(nblk, 1),
                                (nblk, block)).reshape(-1)
    if use_kernel:
        return dequant_masked_mean_pallas(codes, lo_row, step_row, mask,
                                          tile=tile, interpret=interpret)
    return dequant_masked_mean_ref(codes, lo_row, step_row, mask)


def dequant_masked_mean(codes: jnp.ndarray, lo: jnp.ndarray,
                        step: jnp.ndarray,
                        mask: jnp.ndarray | None = None, *, block: int,
                        use_kernel: bool = False,
                        tile: int = 2048) -> jnp.ndarray:
    """Drop-compensated mean over N peers' dequantized codes.

    codes: (N, S) with S = nblk*block; lo/step: (nblk,) or (nblk, 1)
    per-block grids; mask: (N, S) arrivals or None. Returns (S,) fp32.
    """
    return _dequant_masked_mean(
        codes, lo, step, mask, block=block, use_kernel=use_kernel, tile=tile,
        interpret=runtime.interpret_flag() if use_kernel else True)
