"""Pure-jnp oracle for the fused dequant + drop-compensated mean.

Composes the THC dequant (codes * step + lo on per-column grids) with the
``masked_sum`` compensated-mean estimator — the exact unfused pipeline the
kernel replaces, kept as its parity reference.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.masked_sum import masked_mean_ref


def dequant_masked_mean_ref(codes: jnp.ndarray, lo_row: jnp.ndarray,
                            step_row: jnp.ndarray,
                            mask: jnp.ndarray | None = None) -> jnp.ndarray:
    vals = (codes.astype(jnp.float32) * step_row[None, :].astype(jnp.float32)
            + lo_row[None, :].astype(jnp.float32))
    if mask is None:
        return jnp.mean(vals, axis=0)
    return masked_mean_ref(vals, mask)
