from .ops import dequant_masked_mean
from .ref import dequant_masked_mean_ref

__all__ = ["dequant_masked_mean", "dequant_masked_mean_ref"]
