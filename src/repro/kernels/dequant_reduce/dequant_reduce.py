"""Pallas TPU kernel: fused per-block dequantization + drop-compensated mean.

The receive side of OptiReduce-Q dequantizes the (N, S) uint8 peer codes into
an (N, S) float32 intermediate and then reduces it — 5 bytes of HBM traffic
per received byte plus a full-size transient. This kernel fuses both: each
program loads an (N, TILE) slab of codes (+ mask), dequantizes in VMEM with
the per-column grid rows, and emits the compensated mean — one HBM read per
operand byte, no (N, S) float32 ever materialized.

The kernel is *double-buffered* (kernels/dma.py): operands live in ``ANY``
(HBM) memory space and each grid iteration's column slab is streamed into
two-slot revolving VMEM buffers with explicit async copies, so slab i+1's
HBM loads overlap slab i's dequant + reduction.

``lo``/``step`` arrive pre-broadcast as (1, S) rows (a per-Hadamard-block
value repeated ``block`` times — S fp32, negligible next to N*S codes), so
tile boundaries need no alignment with quantization blocks.

VMEM per program: 2 slots of N*TILE (codes u8) + N*TILE*4 (mask) + 2*TILE*4
(grids); N=16, TILE=2048 -> ~360 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime
from repro.kernels.dma import SEQUENTIAL_GRID, col_loads, revolving_pipeline
from repro.kernels.masked_sum.masked_sum import compensated_mean_cols


def _slab_pipeline(nblk: int, streams, sem, epilogue):
    """Two-slot revolving-buffer schedule over column slabs (kernels/dma)."""
    revolving_pipeline(
        nblk, functools.partial(col_loads, streams, sem), epilogue)


def _dequant_masked_mean_kernel(c_hbm, lo_hbm, step_hbm, m_hbm, o_ref,
                                cbuf, lobuf, stepbuf, mbuf, sem, *,
                                nblk: int, tile: int):
    def epilogue(slot):
        x = cbuf[slot].astype(jnp.float32)          # (N, TILE)
        x = x * stepbuf[slot] + lobuf[slot]         # grids broadcast over rows
        m = mbuf[slot].astype(jnp.float32)          # (N, TILE)
        out = compensated_mean_cols(x, m)
        o_ref[...] = out[None, :].astype(o_ref.dtype)

    _slab_pipeline(
        nblk,
        [(c_hbm, cbuf, tile), (lo_hbm, lobuf, tile),
         (step_hbm, stepbuf, tile), (m_hbm, mbuf, tile)],
        sem, epilogue)


def _dequant_mean_kernel(c_hbm, lo_hbm, step_hbm, o_ref,
                         cbuf, lobuf, stepbuf, sem, *, nblk: int, tile: int):
    def epilogue(slot):
        x = cbuf[slot].astype(jnp.float32)
        x = x * stepbuf[slot] + lobuf[slot]
        o_ref[...] = jnp.mean(x, axis=0, keepdims=True).astype(o_ref.dtype)

    _slab_pipeline(
        nblk,
        [(c_hbm, cbuf, tile), (lo_hbm, lobuf, tile), (step_hbm, stepbuf, tile)],
        sem, epilogue)


def dequant_masked_mean_pallas(codes: jnp.ndarray, lo_row: jnp.ndarray,
                               step_row: jnp.ndarray,
                               mask: jnp.ndarray | None = None, *,
                               tile: int = 2048,
                               interpret: bool | None = None) -> jnp.ndarray:
    """Compensated mean of dequantized peer codes.

    codes: (N, S) uint; lo_row/step_row: (S,) per-column grids;
    mask: (N, S) 0/1 arrivals or None (lossless). Returns (S,) fp32.
    ``interpret=None`` resolves the process kernel mode (kernels/runtime).
    """
    if interpret is None:
        interpret = runtime.interpret_flag()
    return _dequant_masked_mean_call(codes, lo_row, step_row, mask,
                                     tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _dequant_masked_mean_call(codes: jnp.ndarray, lo_row: jnp.ndarray,
                              step_row: jnp.ndarray,
                              mask: jnp.ndarray | None = None, *,
                              tile: int = 2048,
                              interpret: bool = True) -> jnp.ndarray:
    if codes.ndim != 2:
        raise ValueError("codes must be (N, S)")
    n, length = codes.shape
    t = min(tile, length)
    pad = (-length) % t
    lo2 = lo_row.reshape(1, length).astype(jnp.float32)
    step2 = step_row.reshape(1, length).astype(jnp.float32)
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad)))
        lo2 = jnp.pad(lo2, ((0, 0), (0, pad)))
        step2 = jnp.pad(step2, ((0, 0), (0, pad)))
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    padded = codes.shape[1]
    nblk = padded // t
    col = pl.BlockSpec((1, t), lambda i: (0, i))
    hbm = pl.BlockSpec(memory_space=pltpu.ANY)       # streamed manually
    grid_bufs = [pltpu.VMEM((2, 1, t), jnp.float32),
                 pltpu.VMEM((2, 1, t), jnp.float32)]
    if mask is None:
        kernel, args = _dequant_mean_kernel, (codes, lo2, step2)
        in_specs = [hbm, hbm, hbm]
        scratch = [pltpu.VMEM((2, n, t), codes.dtype), *grid_bufs,
                   pltpu.SemaphoreType.DMA((3, 2))]
    else:
        kernel = _dequant_masked_mean_kernel
        args = (codes, lo2, step2, mask)
        in_specs = [hbm, hbm, hbm, hbm]
        scratch = [pltpu.VMEM((2, n, t), codes.dtype), *grid_bufs,
                   pltpu.VMEM((2, n, t), mask.dtype),
                   pltpu.SemaphoreType.DMA((4, 2))]
    out = pl.pallas_call(
        functools.partial(kernel, nblk=nblk, tile=t),
        grid=(nblk,),
        in_specs=in_specs,
        out_specs=col,
        out_shape=jax.ShapeDtypeStruct((1, padded), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=SEQUENTIAL_GRID,
        interpret=interpret,
    )(*args)
    out = out[0]
    if pad:
        out = out[:length]
    return out
