"""Pallas TPU kernel: fused per-block dequantization + drop-compensated mean.

The receive side of OptiReduce-Q dequantizes the (N, S) uint8 peer codes into
an (N, S) float32 intermediate and then reduces it — 5 bytes of HBM traffic
per received byte plus a full-size transient. This kernel fuses both: each
program loads an (N, TILE) slab of codes (+ mask), dequantizes in VMEM with
the per-column grid rows, and emits the compensated mean — one HBM read per
operand byte, no (N, S) float32 ever materialized.

``lo``/``step`` arrive pre-broadcast as (1, S) rows (a per-Hadamard-block
value repeated ``block`` times — S fp32, negligible next to N*S codes), so
tile boundaries need no alignment with quantization blocks.

VMEM per program: N*TILE (codes u8) + N*TILE*4 (mask) + 2*TILE*4 (grids);
N=16, TILE=2048 -> ~180 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.masked_sum.masked_sum import compensated_mean_cols


def _dequant_masked_mean_kernel(c_ref, lo_ref, step_ref, m_ref, o_ref):
    x = c_ref[...].astype(jnp.float32)          # (N, TILE)
    x = x * step_ref[...] + lo_ref[...]         # grids broadcast over rows
    m = m_ref[...].astype(jnp.float32)          # (N, TILE)
    out = compensated_mean_cols(x, m)
    o_ref[...] = out[None, :].astype(o_ref.dtype)


def _dequant_mean_kernel(c_ref, lo_ref, step_ref, o_ref):
    x = c_ref[...].astype(jnp.float32)
    x = x * step_ref[...] + lo_ref[...]
    o_ref[...] = jnp.mean(x, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def dequant_masked_mean_pallas(codes: jnp.ndarray, lo_row: jnp.ndarray,
                               step_row: jnp.ndarray,
                               mask: jnp.ndarray | None = None, *,
                               tile: int = 2048,
                               interpret: bool = True) -> jnp.ndarray:
    """Compensated mean of dequantized peer codes.

    codes: (N, S) uint; lo_row/step_row: (S,) per-column grids;
    mask: (N, S) 0/1 arrivals or None (lossless). Returns (S,) fp32.
    """
    if codes.ndim != 2:
        raise ValueError("codes must be (N, S)")
    n, length = codes.shape
    t = min(tile, length)
    pad = (-length) % t
    lo2 = lo_row.reshape(1, length).astype(jnp.float32)
    step2 = step_row.reshape(1, length).astype(jnp.float32)
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad)))
        lo2 = jnp.pad(lo2, ((0, 0), (0, pad)))
        step2 = jnp.pad(step2, ((0, 0), (0, pad)))
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    padded = codes.shape[1]
    grid = (padded // t,)
    col = pl.BlockSpec((1, t), lambda i: (0, i))
    slab = pl.BlockSpec((n, t), lambda i: (0, i))
    if mask is None:
        kernel, args = _dequant_mean_kernel, (codes, lo2, step2)
        in_specs = [slab, col, col]
    else:
        kernel = _dequant_masked_mean_kernel
        args = (codes, lo2, step2, mask)
        in_specs = [slab, col, col, slab]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=col,
        out_shape=jax.ShapeDtypeStruct((1, padded), jnp.float32),
        interpret=interpret,
    )(*args)
    out = out[0]
    if pad:
        out = out[:length]
    return out
