"""Pure-jnp oracle for the drop-compensated shard reduction.

Given ``shards`` (N, L) — the N peers' contributions for the shard this node
owns — and a 0/1 ``mask`` (N, L) marking which entries actually arrived before
the UBT timeout, produce the mean over *received* contributions:

    out[j] = sum_i mask[i,j] * shards[i,j] / max(1, sum_i mask[i,j])

This is the unbiased estimator of the true mean when drops are independent of
gradient values (the paper's assumption; HT makes it hold by construction).
Entries nobody delivered reduce to 0 (equivalent to skipping that coordinate's
update this round, per §3.4).
"""
from __future__ import annotations

import jax.numpy as jnp


def masked_mean_ref(shards: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    x = shards.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    cnt = jnp.sum(m, axis=0)
    s = jnp.sum(x * m, axis=0)
    out = jnp.where(cnt > 0, s / jnp.maximum(cnt, 1.0), 0.0)
    return out.astype(shards.dtype)
