"""Jit'd public wrapper for the drop-compensated shard reduction."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .masked_sum import masked_mean_pallas
from .ref import masked_mean_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel", "tile"))
def masked_mean(shards: jnp.ndarray, mask: jnp.ndarray, *,
                use_kernel: bool = False, tile: int = 2048) -> jnp.ndarray:
    """Drop-compensated mean over N peer shards. (N, L) x (N, L) -> (L,)."""
    if use_kernel:
        return masked_mean_pallas(shards, mask, tile=tile,
                                  interpret=_default_interpret())
    return masked_mean_ref(shards, mask)
