"""Jit'd public wrapper for the drop-compensated shard reduction.

The Pallas path's interpret/compile flag resolves through the process
kernel-mode policy (kernels/runtime) outside the jit boundary, so the
resolved flag is part of the cache key.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime

from .masked_sum import masked_mean_pallas
from .ref import masked_mean_ref


@functools.partial(jax.jit,
                   static_argnames=("use_kernel", "tile", "interpret"))
def _masked_mean(shards: jnp.ndarray, mask: jnp.ndarray, *,
                 use_kernel: bool, tile: int, interpret: bool) -> jnp.ndarray:
    if use_kernel:
        return masked_mean_pallas(shards, mask, tile=tile,
                                  interpret=interpret)
    return masked_mean_ref(shards, mask)


def masked_mean(shards: jnp.ndarray, mask: jnp.ndarray, *,
                use_kernel: bool = False, tile: int = 2048) -> jnp.ndarray:
    """Drop-compensated mean over N peer shards. (N, L) x (N, L) -> (L,)."""
    return _masked_mean(
        shards, mask, use_kernel=use_kernel, tile=tile,
        interpret=runtime.interpret_flag() if use_kernel else True)
