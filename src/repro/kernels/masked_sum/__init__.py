from .ops import masked_mean
from .ref import masked_mean_ref

__all__ = ["masked_mean", "masked_mean_ref"]
