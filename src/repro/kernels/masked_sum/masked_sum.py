"""Pallas TPU kernel: fused drop-compensated shard reduction.

The paper (§6a) identifies the reduction stage as the next bottleneck and
proposes SmartNIC offload; the TPU-native answer is a single VMEM-resident
fused kernel: load a (N, TILE) slab of peer shards + masks, compute the
received-count, the masked sum and the compensated mean in one pass — one
HBM read per operand byte, no intermediate (N, L) products materialized.

Grid: one program per TILE columns. VMEM per program (fp32):
N * TILE * 4 * 2 (shards + mask) + TILE * 4; N=16, TILE=2048 -> ~260 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime

# independent column tiles: no cross-iteration state, Mosaic may parallelize
_COL_GRID = pltpu.TPUCompilerParams(dimension_semantics=("parallel",))


def compensated_mean_cols(x, m):
    """Drop-compensated mean over peers for an (N, TILE) slab -> (TILE,).
    The single copy of the compensation rule on the Pallas side — the fused
    dequant_reduce kernel reuses it."""
    cnt = jnp.sum(m, axis=0)                    # (TILE,)
    s = jnp.sum(x * m, axis=0)                  # (TILE,)
    return jnp.where(cnt > 0, s / jnp.maximum(cnt, 1.0), 0.0)


def _masked_mean_kernel(x_ref, m_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (N, TILE)
    m = m_ref[...].astype(jnp.float32)          # (N, TILE)
    out = compensated_mean_cols(x, m)
    o_ref[...] = out[None, :].astype(o_ref.dtype)


def masked_mean_pallas(shards: jnp.ndarray, mask: jnp.ndarray, *,
                       tile: int = 2048,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Mean over received contributions. shards/mask: (N, L) -> (L,).

    ``interpret=None`` resolves the process kernel mode (kernels/runtime).
    """
    if interpret is None:
        interpret = runtime.interpret_flag()
    return _masked_mean_call(shards, mask, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _masked_mean_call(shards: jnp.ndarray, mask: jnp.ndarray, *,
                      tile: int = 2048,
                      interpret: bool = True) -> jnp.ndarray:
    if shards.ndim != 2 or mask.shape != shards.shape:
        raise ValueError("shards and mask must both be (N, L)")
    n, length = shards.shape
    t = min(tile, length)
    pad = (-length) % t
    if pad:
        shards = jnp.pad(shards, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    padded = shards.shape[1]
    out = pl.pallas_call(
        _masked_mean_kernel,
        grid=(padded // t,),
        in_specs=[
            pl.BlockSpec((n, t), lambda i: (0, i)),
            pl.BlockSpec((n, t), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, padded), shards.dtype),
        compiler_params=_COL_GRID,
        interpret=interpret,
    )(shards, mask)
    out = out[0]
    if pad:
        out = out[:length]
    return out
