"""Pallas TPU kernels for OptiReduce's compute hot-spots.

fwht        — blocked fast Walsh-Hadamard transform (MXU Kronecker form)
masked_sum  — fused drop-compensated shard reduction
quant       — fused uniform stochastic quantization (THC baseline)
"""
