"""Pallas TPU kernels for OptiReduce's compute hot-spots.

fwht           — blocked fast Walsh-Hadamard transform (MXU Kronecker form)
masked_sum     — fused drop-compensated shard reduction
quant          — fused uniform stochastic quantization (THC baseline)
ht_quant       — fused sign+FWHT+quantize encode (single-pass, no rotated
                 fp32 intermediate) + the rotate-and-amax grid pass
dequant_reduce — fused per-block dequant + drop-compensated mean (receive
                 side, no (N, S) float32 intermediate)
"""
