"""Two-slot revolving-buffer DMA schedule for double-buffered Pallas kernels.

The double-buffered codec kernels (ht_quant, dequant_reduce) keep their bulk
operands in ``ANY`` (HBM) memory space and stream one grid block at a time
into two-slot VMEM scratch buffers with explicit async copies: while block i
computes out of slot ``i % 2``, block i+1's loads are already in flight into
slot ``(i + 1) % 2``.  This module holds the single copy of that schedule —
kernels differ only in how a block is sliced (rows vs column slabs) and in
the epilogue consuming the landed slots.

Because the revolving slots and in-flight DMAs are threaded through scratch
refs *across* grid iterations, any grid using this schedule must be marked
sequential (``SEQUENTIAL_GRID``) so Mosaic neither reorders nor parallelizes
the iterations.
"""
from __future__ import annotations

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SEQUENTIAL_GRID = pltpu.TPUCompilerParams(dimension_semantics=("arbitrary",))


def row_loads(streams, sem, slot: int, idx):
    """The async HBM->VMEM copies landing row block ``idx`` in ``slot``.

    ``streams`` is a list of (hbm_ref, vmem_buf, rows_per_block) triples all
    indexed by the same row-block axis; stream k signals ``sem[k, slot]``.
    """
    return [pltpu.make_async_copy(hbm.at[pl.ds(idx * br, br)],
                                  buf.at[slot], sem.at[k, slot])
            for k, (hbm, buf, br) in enumerate(streams)]


def col_loads(streams, sem, slot: int, idx):
    """Column-slab sibling of :func:`row_loads`: stream k is a
    (hbm_ref, vmem_buf, cols_per_slab) triple sliced along axis 1."""
    return [pltpu.make_async_copy(hbm.at[:, pl.ds(idx * t, t)],
                                  buf.at[slot], sem.at[k, slot])
            for k, (hbm, buf, t) in enumerate(streams)]


def revolving_pipeline(nblk: int, loads, epilogue):
    """One grid iteration of the two-slot revolving-buffer schedule.

    ``loads(slot, idx)`` returns the async copies landing block ``idx`` in
    ``slot`` (see :func:`row_loads` / :func:`col_loads`); block i+1's loads
    are issued *before* block i's are awaited, so the next block's HBM
    traffic overlaps this block's compute.  ``epilogue(slot)`` consumes the
    landed VMEM slots.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():                               # warm-up: land block 0
        for dma in loads(0, 0):
            dma.start()

    @pl.when(i + 1 < nblk)
    def _():                               # prefetch block i+1
        for dma in loads((i + 1) % 2, i + 1):
            dma.start()

    for dma in loads(i % 2, i):
        dma.wait()
    epilogue(i % 2)
