"""Gradient-drop models (the TPU stand-in for UBT packet loss, DESIGN §2).

A mask entry of 0 means "this sender's packet for these entries did not
arrive before the adaptive timeout". Masks are generated at *packet*
granularity (``packet_elems`` consecutive entries share one fate, matching
MTU-sized gradient packets) and then expanded elementwise.

Patterns:
  * ``bernoulli``  — i.i.d. packet loss at the configured rate.
  * ``tail``       — tail-drop: the last fraction of each peer's shard is cut
    (what a timeout does to an in-flight stream; the pattern HT exists for).
  * ``straggler``  — whole peers miss the round with some probability
    (compute stragglers / failed nodes).
  * ``burst``      — Gilbert–Elliott two-state Markov loss: packets drop in
    correlated bursts (mean length ``BURST_MEAN_PKTS`` packets) at the same
    stationary rate. Real fabrics lose packets this way — queue overflows
    and link flaps kill runs of consecutive packets, not i.i.d. singletons.

All generators are deterministic functions of (key, receiver), so the whole
step stays jit-compatible and reproducible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand(packet_mask: jnp.ndarray, n_elems: int,
            packet_elems: int) -> jnp.ndarray:
    m = jnp.repeat(packet_mask, packet_elems, axis=-1)
    return m[..., :n_elems]


def bernoulli_mask(key: jax.Array, n_peers: int, n_elems: int, *,
                   rate: float, packet_elems: int = 256) -> jnp.ndarray:
    """(n_peers, n_elems) 0/1 mask; P(drop a packet) = rate."""
    n_pkts = -(-n_elems // packet_elems)
    keep = jax.random.bernoulli(key, 1.0 - rate, (n_peers, n_pkts))
    return _expand(keep.astype(jnp.float32), n_elems, packet_elems)


def tail_mask(key: jax.Array, n_peers: int, n_elems: int, *,
              rate: float, packet_elems: int = 256) -> jnp.ndarray:
    """Drop the trailing packets of a random subset of peers.

    Each peer independently times out with probability min(1, 4*rate); a
    timed-out peer loses its last ceil(rate*4) fraction of packets, so the
    expected element loss matches ``rate`` while the *pattern* is bursty.
    """
    n_pkts = -(-n_elems // packet_elems)
    k_to, k_len = jax.random.split(key)
    p_timeout = jnp.minimum(1.0, 4.0 * rate)
    timed_out = jax.random.bernoulli(k_to, p_timeout, (n_peers, 1))
    cut_frac = jnp.where(p_timeout > 0, rate / jnp.maximum(p_timeout, 1e-9), 0.0)
    cut_start = jnp.floor((1.0 - cut_frac) * n_pkts)
    idx = jnp.arange(n_pkts)[None, :]
    keep = jnp.where(timed_out & (idx >= cut_start), 0.0, 1.0)
    return _expand(keep.astype(jnp.float32), n_elems, packet_elems)


def straggler_mask(key: jax.Array, n_peers: int, n_elems: int, *,
                   rate: float, packet_elems: int = 256) -> jnp.ndarray:
    """Whole peers miss the round with probability ``rate``."""
    del packet_elems
    keep = jax.random.bernoulli(key, 1.0 - rate, (n_peers, 1))
    return jnp.broadcast_to(keep.astype(jnp.float32), (n_peers, n_elems))


# Default mean burst length for the Gilbert–Elliott pattern, in packets.
# Matches the multi-packet loss episodes reported for cloud fabrics (a queue
# overflow or link flap takes out a run of MTUs, not one).
BURST_MEAN_PKTS = 8.0


def gilbert_elliott_params(rate: float, mean_burst: float = BURST_MEAN_PKTS
                           ) -> tuple[float, float]:
    """(p, r) transition probabilities for a two-state Gilbert–Elliott chain.

    ``p`` = P(Good -> Bad), ``r`` = P(Bad -> Good). Chosen so the stationary
    loss probability p/(p+r) equals ``rate`` and the mean bad-run length 1/r
    equals ``mean_burst``. Shared by the synthetic masks here, the inproc
    backend's header-pure drop functions, and sim/netsim's NetworkModel so
    all three layers describe the same loss process.
    """
    rate = min(max(float(rate), 0.0), 0.999)
    r = 1.0 / max(float(mean_burst), 1.0)
    p = min(1.0, r * rate / max(1.0 - rate, 1e-6))
    return p, r


def burst_mask(key: jax.Array, n_peers: int, n_elems: int, *,
               rate: float, packet_elems: int = 256,
               mean_burst: float = BURST_MEAN_PKTS) -> jnp.ndarray:
    """Gilbert–Elliott bursty loss, packet-granular, per peer stream.

    Each peer row is an independent two-state Markov chain over packets:
    Good keeps the packet, Bad drops it. The initial state is drawn from the
    stationary distribution so every packet's marginal loss equals ``rate``
    while consecutive losses cluster into mean-``mean_burst`` runs. Pure
    ``lax.scan`` over the packet axis — jit/vmap compatible like the rest.
    """
    n_pkts = -(-n_elems // packet_elems)
    p, r = gilbert_elliott_params(rate, mean_burst)
    k0, k1 = jax.random.split(key)
    bad0 = jax.random.uniform(k0, (n_peers,)) < min(rate, 0.999)
    u = jax.random.uniform(k1, (n_pkts, n_peers))

    def step(bad, u_t):
        nxt = jnp.where(bad, u_t >= r, u_t < p)
        return nxt, nxt

    _, bad_seq = jax.lax.scan(step, bad0, u)          # (n_pkts, n_peers)
    keep = 1.0 - bad_seq.T.astype(jnp.float32)
    return _expand(keep, n_elems, packet_elems)


_PATTERNS = {
    "bernoulli": bernoulli_mask,
    "tail": tail_mask,
    "straggler": straggler_mask,
    "burst": burst_mask,
}


def make_mask(pattern: str, key: jax.Array, n_peers: int, n_elems: int, *,
              rate: float, packet_elems: int = 256,
              self_index: jnp.ndarray | None = None) -> jnp.ndarray:
    """Dispatch on drop pattern. A node never drops its own contribution
    (it is local), so row ``self_index`` is forced to 1 when provided."""
    if rate <= 0.0:
        return jnp.ones((n_peers, n_elems), jnp.float32)
    mask = _PATTERNS[pattern](key, n_peers, n_elems, rate=rate,
                              packet_elems=packet_elems)
    if self_index is not None:
        own = jnp.arange(n_peers) == self_index
        mask = jnp.where(own[:, None], 1.0, mask)
    return mask


def loss_fraction(mask: jnp.ndarray) -> jnp.ndarray:
    """Fraction of gradient entries lost this round (monitored by §3.4)."""
    return 1.0 - jnp.mean(mask)
