"""Gradient-drop models (the TPU stand-in for UBT packet loss, DESIGN §2).

A mask entry of 0 means "this sender's packet for these entries did not
arrive before the adaptive timeout". Masks are generated at *packet*
granularity (``packet_elems`` consecutive entries share one fate, matching
MTU-sized gradient packets) and then expanded elementwise.

Patterns:
  * ``bernoulli``  — i.i.d. packet loss at the configured rate.
  * ``tail``       — tail-drop: the last fraction of each peer's shard is cut
    (what a timeout does to an in-flight stream; the pattern HT exists for).
  * ``straggler``  — whole peers miss the round with some probability
    (compute stragglers / failed nodes).

All generators are deterministic functions of (key, receiver), so the whole
step stays jit-compatible and reproducible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand(packet_mask: jnp.ndarray, n_elems: int,
            packet_elems: int) -> jnp.ndarray:
    m = jnp.repeat(packet_mask, packet_elems, axis=-1)
    return m[..., :n_elems]


def bernoulli_mask(key: jax.Array, n_peers: int, n_elems: int, *,
                   rate: float, packet_elems: int = 256) -> jnp.ndarray:
    """(n_peers, n_elems) 0/1 mask; P(drop a packet) = rate."""
    n_pkts = -(-n_elems // packet_elems)
    keep = jax.random.bernoulli(key, 1.0 - rate, (n_peers, n_pkts))
    return _expand(keep.astype(jnp.float32), n_elems, packet_elems)


def tail_mask(key: jax.Array, n_peers: int, n_elems: int, *,
              rate: float, packet_elems: int = 256) -> jnp.ndarray:
    """Drop the trailing packets of a random subset of peers.

    Each peer independently times out with probability min(1, 4*rate); a
    timed-out peer loses its last ceil(rate*4) fraction of packets, so the
    expected element loss matches ``rate`` while the *pattern* is bursty.
    """
    n_pkts = -(-n_elems // packet_elems)
    k_to, k_len = jax.random.split(key)
    p_timeout = jnp.minimum(1.0, 4.0 * rate)
    timed_out = jax.random.bernoulli(k_to, p_timeout, (n_peers, 1))
    cut_frac = jnp.where(p_timeout > 0, rate / jnp.maximum(p_timeout, 1e-9), 0.0)
    cut_start = jnp.floor((1.0 - cut_frac) * n_pkts)
    idx = jnp.arange(n_pkts)[None, :]
    keep = jnp.where(timed_out & (idx >= cut_start), 0.0, 1.0)
    return _expand(keep.astype(jnp.float32), n_elems, packet_elems)


def straggler_mask(key: jax.Array, n_peers: int, n_elems: int, *,
                   rate: float, packet_elems: int = 256) -> jnp.ndarray:
    """Whole peers miss the round with probability ``rate``."""
    del packet_elems
    keep = jax.random.bernoulli(key, 1.0 - rate, (n_peers, 1))
    return jnp.broadcast_to(keep.astype(jnp.float32), (n_peers, n_elems))


_PATTERNS = {
    "bernoulli": bernoulli_mask,
    "tail": tail_mask,
    "straggler": straggler_mask,
}


def make_mask(pattern: str, key: jax.Array, n_peers: int, n_elems: int, *,
              rate: float, packet_elems: int = 256,
              self_index: jnp.ndarray | None = None) -> jnp.ndarray:
    """Dispatch on drop pattern. A node never drops its own contribution
    (it is local), so row ``self_index`` is forced to 1 when provided."""
    if rate <= 0.0:
        return jnp.ones((n_peers, n_elems), jnp.float32)
    mask = _PATTERNS[pattern](key, n_peers, n_elems, rate=rate,
                              packet_elems=packet_elems)
    if self_index is not None:
        own = jnp.arange(n_peers) == self_index
        mask = jnp.where(own[:, None], 1.0, mask)
    return mask


def loss_fraction(mask: jnp.ndarray) -> jnp.ndarray:
    """Fraction of gradient entries lost this round (monitored by §3.4)."""
    return 1.0 - jnp.mean(mask)
