"""Transpose AllReduce (TAR) — the paper's collective, on TPU axes (§3.1).

All functions run *inside* a ``jax.shard_map`` body; ``axis`` names a mesh
axis. A "bucket" is a flat per-device array that is identical (replicated in
value) across the axis before the call — i.e. each worker's local gradients.

Stage mapping (DESIGN §2):
  stage 1 (shard exchange, P2P)   -> jax.lax.all_to_all (tiled)
  reduce (colocated PS)           -> drop-compensated masked mean
  stage 2 (broadcast aggregated)  -> jax.lax.all_gather (tiled)

The round-based variant reproduces the paper's 2*ceil((N-1)/I) round schedule
with ``collective_permute`` so the lowered HLO carries the exact round
structure (used by the round/incast experiments); the all_to_all form is the
production path (XLA/ICI schedules it better — see EXPERIMENTS §Perf).

Hierarchical 2D TAR (§3.1.2) maps groups onto the ``pod`` axis: intra-pod TAR
reduce-scatter, inter-pod same-rank aggregation, intra-pod broadcast —
2(N/G-1) + (G-1) logical rounds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from repro.kernels.masked_sum import masked_mean as _masked_mean_kernel
from repro.kernels.masked_sum import masked_mean_ref


def axis_size(axis: str) -> int:
    return compat.axis_size(axis)


def pad_for_tar(x: jnp.ndarray, n: int, block: int = 1) -> tuple[jnp.ndarray, int]:
    """Pad flat x so len % (n * block) == 0 (block-aligned shards)."""
    length = x.shape[0]
    quantum = n * block
    pad = (-length) % quantum
    if pad:
        x = jnp.pad(x, (0, pad))
    return x, length


def masked_mean(received: jnp.ndarray, mask: jnp.ndarray | None,
                use_kernel: bool = False) -> jnp.ndarray:
    """Drop-compensated mean over the peer axis. received: (N, S).

    The public reduction every codec shares: no mask -> plain mean; with an
    arrival mask -> the masked compensated mean, dispatching to the Pallas
    kernel under ``use_kernel``.
    """
    if mask is None:
        return jnp.mean(received, axis=0)
    if use_kernel:
        return _masked_mean_kernel(received, mask, use_kernel=True)
    return masked_mean_ref(received, mask)


def tar_reduce_scatter(x: jnp.ndarray, axis: str, *,
                       mask: jnp.ndarray | None = None,
                       use_kernel: bool = False) -> jnp.ndarray:
    """TAR stage 1 + reduce: returns this node's aggregated shard (S,).

    x: flat (L,), L % N == 0. mask: (N, S) — which peers' packets arrived
    at *this* receiver (row self is always 1; see drops.make_mask).
    """
    n = axis_size(axis)
    s = x.shape[0] // n
    shards = x.reshape(n, s)
    received = jax.lax.all_to_all(shards, axis, split_axis=0, concat_axis=0,
                                  tiled=True)          # (N, S): row p = peer p's shard for me
    return masked_mean(received, mask, use_kernel)


def tar_allreduce(x: jnp.ndarray, axis: str, *,
                  mask: jnp.ndarray | None = None,
                  use_kernel: bool = False) -> jnp.ndarray:
    """Full TAR: all_to_all -> compensated reduce -> all_gather. (L,)->(L,)."""
    own = tar_reduce_scatter(x, axis, mask=mask, use_kernel=use_kernel)
    return jax.lax.all_gather(own, axis, axis=0, tiled=True)


def _grouped_rounds(axis: str, n: int, incast: int, send_for_round,
                    perm_for_round=None):
    """Run rounds 1..N-1 with <= incast permutes in flight per group.

    In round r (r = 1..N-1) node j sends to node (j+r) mod N and receives
    from (j-r) mod N — a round-robin schedule where a node-pair never
    repeats. ``incast`` is the paper's I: rounds are issued in groups of I
    permutes in flight concurrently, and group g+1's sends are gated on
    group g's arrivals (an ``optimization_barrier`` chain), so the lowered
    HLO carries the real ceil((N-1)/I) round schedule instead of one flat
    burst.  ``perm_for_round`` overrides the per-round permutation (the
    degraded-participation schedules route over a virtual ring of active
    peers; ``n`` is then the *virtual* ring size).
    """
    rows = []
    pending = []
    token = None
    for r in range(1, n):
        # node j sends to node (j + r) % n in round r
        if perm_for_round is None:
            perm = [(j, (j + r) % n) for j in range(n)]
        else:
            perm = perm_for_round(r)
        send = send_for_round(r)
        if token is not None:           # gate on the previous group's recvs
            send, token = compat.optimization_barrier((send, token))
        recv = jax.lax.ppermute(send, axis, perm)      # from (i - r) % n
        pending.append(recv)
        if len(pending) == incast or r == n - 1:
            pending = list(compat.optimization_barrier(tuple(pending)))
            rows.extend(pending)
            token = pending[-1]
            pending = []
    return rows


# ----------------------------------------------- degraded participation
def peer_lookup(active: tuple[int, ...], n: int):
    """Static lookup arrays for a degraded-participation set.

    Returns ``(vpos, is_active)``: ``vpos[p]`` is peer p's position on the
    virtual ring of active peers (0 for ejected peers — only ever read
    behind an ``is_active`` guard) and ``is_active[p]`` is 1.0/0.0.
    """
    vpos = [0] * n
    ind = [0.0] * n
    for k, p in enumerate(active):
        vpos[p] = k
        ind[p] = 1.0
    return jnp.asarray(vpos, jnp.int32), jnp.asarray(ind, jnp.float32)


def _ring_perms(active: tuple[int, ...], n: int):
    """perm_for_round over the active virtual ring: active peer at position
    j sends to position (j+r) % A; ejected peers self-loop (their sends
    never enter the schedule)."""
    a = len(active)
    ejected = [p for p in range(n) if p not in set(active)]

    def perm_for_round(r: int):
        return ([(active[j], active[(j + r) % a]) for j in range(a)]
                + [(e, e) for e in ejected])
    return perm_for_round


def graft_inactive(full: jnp.ndarray, axis: str,
                   active: tuple[int, ...]) -> jnp.ndarray:
    """Deliver the assembled result to ejected peers.

    A degraded schedule assembles the full reduced bucket only on active
    peers; ejected peers must still *receive* it (they keep training — that
    is what makes probationary readmission a policy flip instead of a
    checkpoint restore).  ``ceil(E/A)`` extra graft rounds pair each ejected
    peer with an active sender (a ppermute destination not named receives
    zeros, so summing the rounds routes each peer exactly its copy), and a
    final select keeps active peers' locally-assembled bytes.
    """
    n = axis_size(axis)
    ejected = [p for p in range(n) if p not in set(active)]
    if not ejected:
        return full
    a = len(active)
    _, is_active = peer_lookup(active, n)
    got = jnp.zeros_like(full)
    for t in range(0, len(ejected), a):
        pairs = [(active[j], e) for j, e in enumerate(ejected[t:t + a])]
        got = got + jax.lax.ppermute(full, axis, pairs)
    keep = jnp.take(is_active, jax.lax.axis_index(axis))
    return jnp.where(keep > 0.5, full, got)


def _sender_order(i: jnp.ndarray, n: int) -> jnp.ndarray:
    # row r of a by-distance stack came from (i - r) % n
    return (i - jnp.arange(n)) % n


def tar_exchange_rounds(shards: jnp.ndarray, axis: str, *, incast: int = 1,
                        active: tuple[int, ...] | None = None) -> jnp.ndarray:
    """Stage-1 shard exchange on the explicit round schedule (Fig 5b).

    shards: (N, S), row j = this node's contribution to peer j's shard.
    Returns the (N, S) received matrix in *sender* order (row p = peer p's
    shard for me) — the same layout the tiled all_to_all form produces.

    With a degraded-participation set ``active`` (a proper subset of the
    axis), the schedule is generated over the *virtual ring of active
    peers*: shards has A = len(active) rows (virtual position k's shard),
    rounds run r = 1..A-1, ejected peers self-loop (they neither contribute
    nor are waited on), and the returned (A, S) matrix is in virtual-sender
    order.  Ejected peers execute the same program on garbage rows; their
    result is replaced by :func:`graft_inactive` after stage 2.
    """
    n = axis_size(axis)
    incast = max(1, int(incast))
    if active is None:
        i = jax.lax.axis_index(axis)
        own_rows = [jnp.take(shards, i, axis=0)]       # my own contribution
        own_rows += _grouped_rounds(axis, n, incast,
                                    lambda r: jnp.take(shards, (i + r) % n,
                                                       axis=0))
        # rows arrive ordered by sender distance r; reorder to sender index
        received_by_dist = jnp.stack(own_rows)         # row r = from (i-r)%n
        senders = _sender_order(i, n)
        return jnp.zeros_like(received_by_dist).at[senders] \
                  .set(received_by_dist)
    a = len(active)
    vpos, _ = peer_lookup(active, n)
    k = jnp.take(vpos, jax.lax.axis_index(axis))       # my virtual position
    own_rows = [jnp.take(shards, k, axis=0)]
    if a > 1:
        own_rows += _grouped_rounds(
            axis, a, incast,
            lambda r: jnp.take(shards, (k + r) % a, axis=0),
            perm_for_round=_ring_perms(active, n))
    received_by_dist = jnp.stack(own_rows)             # row r = virt (k-r)%A
    senders = (k - jnp.arange(a)) % a
    return jnp.zeros_like(received_by_dist).at[senders].set(received_by_dist)


def tar_broadcast_rounds(own: jnp.ndarray, axis: str, *, incast: int = 1,
                         active: tuple[int, ...] | None = None) -> jnp.ndarray:
    """Stage-2 broadcast of the aggregated shard, mirrored round schedule.

    own: (S,) this node's aggregated shard. Returns the reassembled flat
    (N*S,) bucket — the same layout the tiled all_gather form produces.
    With ``active`` set, the mirror of the degraded exchange: A-1 rounds on
    the virtual ring assembling the flat (A*S,) bucket on active peers
    (virtual-position order); route it to ejected peers afterwards with
    :func:`graft_inactive`.
    """
    n = axis_size(axis)
    incast = max(1, int(incast))
    if active is None:
        i = jax.lax.axis_index(axis)
        out_rows = [own]
        out_rows += _grouped_rounds(axis, n, incast, lambda r: own)
        got_by_dist = jnp.stack(out_rows)              # row r = shard of (i-r)%n
        senders = _sender_order(i, n)
        out = jnp.zeros_like(got_by_dist).at[senders].set(got_by_dist)
        return out.reshape(n * own.shape[0])
    a = len(active)
    vpos, _ = peer_lookup(active, n)
    k = jnp.take(vpos, jax.lax.axis_index(axis))
    out_rows = [own]
    if a > 1:
        out_rows += _grouped_rounds(axis, a, incast, lambda r: own,
                                    perm_for_round=_ring_perms(active, n))
    got_by_dist = jnp.stack(out_rows)                  # row r = virt (k-r)%A
    senders = (k - jnp.arange(a)) % a
    out = jnp.zeros_like(got_by_dist).at[senders].set(got_by_dist)
    return out.reshape(a * own.shape[0])


def tar_allreduce_rounds(x: jnp.ndarray, axis: str, *, incast: int = 1,
                         mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Round-structured TAR via collective_permute (paper Fig 5b):
    exchange -> compensated mean -> mirrored broadcast, 2*ceil((N-1)/I)
    rounds total.  The composable pipeline reaches the two stages directly
    (:func:`tar_exchange_rounds` / :func:`tar_broadcast_rounds`) so codecs
    can interpose; this wrapper is the plain value-domain form.
    """
    n = axis_size(axis)
    s = x.shape[0] // n
    received = tar_exchange_rounds(x.reshape(n, s), axis, incast=incast)
    if mask is None:
        own = jnp.mean(received, axis=0)
    else:
        own = masked_mean_ref(received, mask)
    return tar_broadcast_rounds(own, axis, incast=incast)


def tar_allreduce_2d(x: jnp.ndarray, inner_axis: str, outer_axis: str, *,
                     mask: jnp.ndarray | None = None,
                     outer_mask: jnp.ndarray | None = None,
                     use_kernel: bool = False) -> jnp.ndarray:
    """Hierarchical 2D TAR (§3.1.2 / App. A): groups = pods.

    1. intra-group: TAR reduce-scatter over ``inner_axis``  (N/G - 1 rounds)
    2. inter-group: same-rank aggregation over ``outer_axis``  (G - 1 rounds)
    3. intra-group broadcast over ``inner_axis``            (N/G - 1 rounds)
    """
    own = tar_reduce_scatter(x, inner_axis, mask=mask, use_kernel=use_kernel)
    g = axis_size(outer_axis)
    if g > 1:
        s = own.shape[0]
        if s % g == 0:
            # TAR across pods too: shard my shard over the outer axis.
            own = tar_allreduce(own, outer_axis, mask=outer_mask,
                                use_kernel=use_kernel)
        else:
            own = jax.lax.pmean(own, outer_axis)
    return jax.lax.all_gather(own, inner_axis, axis=0, tiled=True)
