"""Transpose AllReduce (TAR) — the paper's collective, on TPU axes (§3.1).

All functions run *inside* a ``jax.shard_map`` body; ``axis`` names a mesh
axis. A "bucket" is a flat per-device array that is identical (replicated in
value) across the axis before the call — i.e. each worker's local gradients.

Stage mapping (DESIGN §2):
  stage 1 (shard exchange, P2P)   -> jax.lax.all_to_all (tiled)
  reduce (colocated PS)           -> drop-compensated masked mean
  stage 2 (broadcast aggregated)  -> jax.lax.all_gather (tiled)

The round-based variant reproduces the paper's 2*ceil((N-1)/I) round schedule
with ``collective_permute`` so the lowered HLO carries the exact round
structure (used by the round/incast experiments); the all_to_all form is the
production path (XLA/ICI schedules it better — see EXPERIMENTS §Perf).

Hierarchical 2D TAR (§3.1.2) maps groups onto the ``pod`` axis: intra-pod TAR
reduce-scatter, inter-pod same-rank aggregation, intra-pod broadcast —
2(N/G-1) + (G-1) logical rounds.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro import compat

from repro.kernels.masked_sum import masked_mean as _masked_mean_kernel
from repro.kernels.masked_sum import masked_mean_ref


def axis_size(axis: str) -> int:
    return compat.axis_size(axis)


def pad_for_tar(x: jnp.ndarray, n: int, block: int = 1) -> tuple[jnp.ndarray, int]:
    """Pad flat x so len % (n * block) == 0 (block-aligned shards)."""
    length = x.shape[0]
    quantum = n * block
    pad = (-length) % quantum
    if pad:
        x = jnp.pad(x, (0, pad))
    return x, length


def masked_mean(received: jnp.ndarray, mask: jnp.ndarray | None,
                use_kernel: bool = False) -> jnp.ndarray:
    """Drop-compensated mean over the peer axis. received: (N, S).

    The public reduction every codec shares: no mask -> plain mean; with an
    arrival mask -> the masked compensated mean, dispatching to the Pallas
    kernel under ``use_kernel``.
    """
    if mask is None:
        return jnp.mean(received, axis=0)
    if use_kernel:
        return _masked_mean_kernel(received, mask, use_kernel=True)
    return masked_mean_ref(received, mask)


def tar_reduce_scatter(x: jnp.ndarray, axis: str, *,
                       mask: jnp.ndarray | None = None,
                       use_kernel: bool = False) -> jnp.ndarray:
    """TAR stage 1 + reduce: returns this node's aggregated shard (S,).

    x: flat (L,), L % N == 0. mask: (N, S) — which peers' packets arrived
    at *this* receiver (row self is always 1; see drops.make_mask).
    """
    n = axis_size(axis)
    s = x.shape[0] // n
    shards = x.reshape(n, s)
    received = jax.lax.all_to_all(shards, axis, split_axis=0, concat_axis=0,
                                  tiled=True)          # (N, S): row p = peer p's shard for me
    return masked_mean(received, mask, use_kernel)


def tar_allreduce(x: jnp.ndarray, axis: str, *,
                  mask: jnp.ndarray | None = None,
                  use_kernel: bool = False) -> jnp.ndarray:
    """Full TAR: all_to_all -> compensated reduce -> all_gather. (L,)->(L,)."""
    own = tar_reduce_scatter(x, axis, mask=mask, use_kernel=use_kernel)
    return jax.lax.all_gather(own, axis, axis=0, tiled=True)


def relay_via(src: int, dst: int, participants: Sequence[int],
              dead_links) -> int:
    """First participant that can relay src->dst around a dead edge.

    Both relay hops (src->m and m->dst) must themselves be live; raises
    when the dead-link set isolates the pair (the caller must eject one
    endpoint instead of rerouting).
    """
    dead = set(dead_links)
    for m in participants:
        if m in (src, dst):
            continue
        if (src, m) not in dead and (m, dst) not in dead:
            return m
    raise ValueError(f"no live relay for dead link {(src, dst)} "
                     f"among participants {tuple(participants)}")


def _grouped_rounds(axis: str, n: int, incast: int, send_for_round,
                    perm_for_round=None, dead_links=(), participants=None):
    """Run rounds 1..N-1 with <= incast permutes in flight per group.

    In round r (r = 1..N-1) node j sends to node (j+r) mod N and receives
    from (j-r) mod N — a round-robin schedule where a node-pair never
    repeats. ``incast`` is the paper's I: rounds are issued in groups of I
    permutes in flight concurrently, and group g+1's sends are gated on
    group g's arrivals (an ``optimization_barrier`` chain), so the lowered
    HLO carries the real ceil((N-1)/I) round schedule instead of one flat
    burst.  ``perm_for_round`` overrides the per-round permutation (the
    degraded-participation schedules route over a virtual ring of active
    peers; ``n`` is then the *virtual* ring size).

    ``dead_links`` is a set of directed (src, dst) edges that must not be
    used: any round whose permutation would traverse a dead edge has that
    pair removed from the main ppermute and replaced by a two-hop relay
    through a live intermediate (two extra single-pair ppermutes). The
    receiver's row is bit-identical either way — a ppermute destination
    not named receives zeros, so ``direct + relayed`` routes exactly the
    payload.
    """
    dead = {(int(s), int(d)) for (s, d) in dead_links}
    rows = []
    pending = []
    token = None
    for r in range(1, n):
        # node j sends to node (j + r) % n in round r
        if perm_for_round is None:
            perm = [(j, (j + r) % n) for j in range(n)]
        else:
            perm = perm_for_round(r)
        dead_pairs = [p for p in perm
                      if p[0] != p[1] and (p[0], p[1]) in dead]
        live = [p for p in perm if p not in dead_pairs] if dead_pairs else perm
        send = send_for_round(r)
        if token is not None:           # gate on the previous group's recvs
            send, token = compat.optimization_barrier((send, token))
        recv = jax.lax.ppermute(send, axis, live)      # from (i - r) % n
        for (src, dst) in dead_pairs:
            m = relay_via(src, dst, participants
                          if participants is not None else range(n), dead)
            mid = jax.lax.ppermute(send, axis, [(src, m)])
            recv = recv + jax.lax.ppermute(mid, axis, [(m, dst)])
        pending.append(recv)
        if len(pending) == incast or r == n - 1:
            pending = list(compat.optimization_barrier(tuple(pending)))
            rows.extend(pending)
            token = pending[-1]
            pending = []
    return rows


# ----------------------------------------------- degraded participation
def peer_lookup(active: tuple[int, ...], n: int):
    """Static lookup arrays for a degraded-participation set.

    Returns ``(vpos, is_active)``: ``vpos[p]`` is peer p's position on the
    virtual ring of active peers (0 for ejected peers — only ever read
    behind an ``is_active`` guard) and ``is_active[p]`` is 1.0/0.0.
    """
    vpos = [0] * n
    ind = [0.0] * n
    for k, p in enumerate(active):
        vpos[p] = k
        ind[p] = 1.0
    return jnp.asarray(vpos, jnp.int32), jnp.asarray(ind, jnp.float32)


def _ring_perms(active: tuple[int, ...], n: int):
    """perm_for_round over the active virtual ring: active peer at position
    j sends to position (j+r) % A; ejected peers self-loop (their sends
    never enter the schedule)."""
    a = len(active)
    ejected = [p for p in range(n) if p not in set(active)]

    def perm_for_round(r: int):
        return ([(active[j], active[(j + r) % a]) for j in range(a)]
                + [(e, e) for e in ejected])
    return perm_for_round


# ------------------------------------------- weighted (non-uniform) shards
class ShardPlan(NamedTuple):
    """Contiguous block-aligned ownership of a padded bucket.

    ``sizes[k]``/``offsets[k]`` describe the slice owned by virtual-ring
    position k; ``padded`` is the bucket length the plan covers and
    ``s_max`` the widest slice (the static row width every round moves —
    narrower slices ride zero-padded so the scanned strategy body stays
    static per policy).
    """
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]
    padded: int
    s_max: int


def shard_plan(length: int, weights: Sequence[int], block: int = 1) -> ShardPlan:
    """Cut a bucket into straggler-proportional contiguous shards.

    ``weights`` are positive integer shard units, one per virtual-ring
    position (a slow-but-alive peer gets fewer units, fast peers absorb
    the remainder).  ``length`` is padded up to a multiple of
    ``sum(weights) * block`` — exactly what ``pad_for_tar(x, sum(weights),
    block)`` produces — so every slice is ``w_k * unit`` elements with
    ``unit`` a multiple of ``block``: every element is owned by exactly
    one position and codec blocks never straddle an ownership boundary.
    """
    ws = tuple(int(w) for w in weights)
    if not ws or any(w < 1 for w in ws):
        raise ValueError(f"shard weights must be positive integers, got {weights}")
    total = sum(ws)
    quantum = total * block
    padded = length + ((-length) % quantum)
    unit = padded // total
    sizes = tuple(w * unit for w in ws)
    offsets = []
    off = 0
    for s in sizes:
        offsets.append(off)
        off += s
    return ShardPlan(sizes, tuple(offsets), padded, max(sizes))


def weighted_rows(x: jnp.ndarray, plan: ShardPlan) -> jnp.ndarray:
    """(padded,) flat bucket -> (A, s_max) row matrix; row k is the slice
    owned by virtual position k, zero-padded to the static row width."""
    rows = []
    for size, off in zip(plan.sizes, plan.offsets):
        row = x[off:off + size]
        if size < plan.s_max:
            row = jnp.pad(row, (0, plan.s_max - size))
        rows.append(row)
    return jnp.stack(rows)


def weighted_flat(rows: jnp.ndarray, plan: ShardPlan) -> jnp.ndarray:
    """(A, s_max) row matrix -> (padded,) flat bucket: the inverse of
    :func:`weighted_rows` (zero-pad tails are dropped)."""
    return jnp.concatenate([rows[k, :size]
                            for k, size in enumerate(plan.sizes)])


def ring_order(active: tuple[int, ...], dead_links) -> tuple[int, ...]:
    """Link-avoiding virtual-ring order.

    Returns a permutation of ``active`` in which no consecutive hop
    (including the wrap-around) traverses a dead directed edge — a failed
    (i -> j) edge reroutes the virtual ring around the edge instead of
    ejecting j.  When no dead edge touches consecutive active pairs the
    order is ``tuple(active)`` unchanged (the bitwise-parity fast path).
    Raises ValueError when the dead set leaves no Hamiltonian cycle (the
    caller must fall back to ejection).
    """
    act = tuple(active)
    a = len(act)
    if a <= 1:
        return act
    members = set(act)
    dead = {(int(s), int(d)) for (s, d) in dead_links
            if int(s) in members and int(d) in members}
    if not dead:
        return act
    hops = {(act[j], act[(j + 1) % a]) for j in range(a)}
    if not (hops & dead):
        return act
    # depth-first search for a Hamiltonian cycle avoiding the dead edges
    start = act[0]
    order = [start]
    rest = set(act) - {start}

    def extend() -> bool:
        if not rest:
            return (order[-1], start) not in dead
        cur = order[-1]
        for p in sorted(rest):
            if (cur, p) in dead:
                continue
            order.append(p)
            rest.discard(p)
            if extend():
                return True
            order.pop()
            rest.add(p)
        return False

    if not extend():
        raise ValueError(f"no dead-link-avoiding ring order for "
                         f"active={act} dead={sorted(dead)}")
    return tuple(order)


def graft_inactive(full: jnp.ndarray, axis: str,
                   active: tuple[int, ...]) -> jnp.ndarray:
    """Deliver the assembled result to ejected peers.

    A degraded schedule assembles the full reduced bucket only on active
    peers; ejected peers must still *receive* it (they keep training — that
    is what makes probationary readmission a policy flip instead of a
    checkpoint restore).  ``ceil(E/A)`` extra graft rounds pair each ejected
    peer with an active sender (a ppermute destination not named receives
    zeros, so summing the rounds routes each peer exactly its copy), and a
    final select keeps active peers' locally-assembled bytes.
    """
    n = axis_size(axis)
    ejected = [p for p in range(n) if p not in set(active)]
    if not ejected:
        return full
    a = len(active)
    _, is_active = peer_lookup(active, n)
    got = jnp.zeros_like(full)
    for t in range(0, len(ejected), a):
        pairs = [(active[j], e) for j, e in enumerate(ejected[t:t + a])]
        got = got + jax.lax.ppermute(full, axis, pairs)
    keep = jnp.take(is_active, jax.lax.axis_index(axis))
    return jnp.where(keep > 0.5, full, got)


def _sender_order(i: jnp.ndarray, n: int) -> jnp.ndarray:
    # row r of a by-distance stack came from (i - r) % n
    return (i - jnp.arange(n)) % n


def tar_exchange_rounds(shards: jnp.ndarray, axis: str, *, incast: int = 1,
                        active: tuple[int, ...] | None = None,
                        dead_links=()) -> jnp.ndarray:
    """Stage-1 shard exchange on the explicit round schedule (Fig 5b).

    shards: (N, S), row j = this node's contribution to peer j's shard.
    Returns the (N, S) received matrix in *sender* order (row p = peer p's
    shard for me) — the same layout the tiled all_to_all form produces.

    With a degraded-participation set ``active`` (a proper subset of the
    axis), the schedule is generated over the *virtual ring of active
    peers*: shards has A = len(active) rows (virtual position k's shard),
    rounds run r = 1..A-1, ejected peers self-loop (they neither contribute
    nor are waited on), and the returned (A, S) matrix is in virtual-sender
    order.  Ejected peers execute the same program on garbage rows; their
    result is replaced by :func:`graft_inactive` after stage 2.

    Non-uniform (weighted) shards are expressed entirely in the row
    matrix: build ``shards`` with :func:`weighted_rows` over a
    :func:`shard_plan` (rows zero-padded to the static width) and pass
    ``active`` explicitly — the schedule itself is weight-agnostic.
    ``dead_links`` reroutes any round traversing a failed directed edge
    through a two-hop relay (see :func:`_grouped_rounds`).
    """
    n = axis_size(axis)
    incast = max(1, int(incast))
    if active is None:
        i = jax.lax.axis_index(axis)
        own_rows = [jnp.take(shards, i, axis=0)]       # my own contribution
        own_rows += _grouped_rounds(axis, n, incast,
                                    lambda r: jnp.take(shards, (i + r) % n,
                                                       axis=0),
                                    dead_links=dead_links)
        # rows arrive ordered by sender distance r; reorder to sender index
        received_by_dist = jnp.stack(own_rows)         # row r = from (i-r)%n
        senders = _sender_order(i, n)
        return jnp.zeros_like(received_by_dist).at[senders] \
                  .set(received_by_dist)
    a = len(active)
    vpos, _ = peer_lookup(active, n)
    k = jnp.take(vpos, jax.lax.axis_index(axis))       # my virtual position
    own_rows = [jnp.take(shards, k, axis=0)]
    if a > 1:
        own_rows += _grouped_rounds(
            axis, a, incast,
            lambda r: jnp.take(shards, (k + r) % a, axis=0),
            perm_for_round=_ring_perms(active, n),
            dead_links=dead_links, participants=active)
    received_by_dist = jnp.stack(own_rows)             # row r = virt (k-r)%A
    senders = (k - jnp.arange(a)) % a
    return jnp.zeros_like(received_by_dist).at[senders].set(received_by_dist)


def tar_broadcast_rounds(own: jnp.ndarray, axis: str, *, incast: int = 1,
                         active: tuple[int, ...] | None = None,
                         dead_links=(),
                         plan: ShardPlan | None = None) -> jnp.ndarray:
    """Stage-2 broadcast of the aggregated shard, mirrored round schedule.

    own: (S,) this node's aggregated shard. Returns the reassembled flat
    (N*S,) bucket — the same layout the tiled all_gather form produces.
    With ``active`` set, the mirror of the degraded exchange: A-1 rounds on
    the virtual ring assembling the flat (A*S,) bucket on active peers
    (virtual-position order); route it to ejected peers afterwards with
    :func:`graft_inactive`.  With a weighted ``plan``, ``own`` is the
    zero-padded (s_max,) row and the reassembly concatenates each
    position's valid slice (:func:`weighted_flat`) instead of reshaping.
    """
    n = axis_size(axis)
    incast = max(1, int(incast))
    if active is None:
        i = jax.lax.axis_index(axis)
        out_rows = [own]
        out_rows += _grouped_rounds(axis, n, incast, lambda r: own,
                                    dead_links=dead_links)
        got_by_dist = jnp.stack(out_rows)              # row r = shard of (i-r)%n
        senders = _sender_order(i, n)
        out = jnp.zeros_like(got_by_dist).at[senders].set(got_by_dist)
        if plan is not None:
            return weighted_flat(out, plan)
        return out.reshape(n * own.shape[0])
    a = len(active)
    vpos, _ = peer_lookup(active, n)
    k = jnp.take(vpos, jax.lax.axis_index(axis))
    out_rows = [own]
    if a > 1:
        out_rows += _grouped_rounds(axis, a, incast, lambda r: own,
                                    perm_for_round=_ring_perms(active, n),
                                    dead_links=dead_links,
                                    participants=active)
    got_by_dist = jnp.stack(out_rows)                  # row r = virt (k-r)%A
    senders = (k - jnp.arange(a)) % a
    out = jnp.zeros_like(got_by_dist).at[senders].set(got_by_dist)
    if plan is not None:
        return weighted_flat(out, plan)
    return out.reshape(a * own.shape[0])


def tar_allreduce_rounds(x: jnp.ndarray, axis: str, *, incast: int = 1,
                         mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Round-structured TAR via collective_permute (paper Fig 5b):
    exchange -> compensated mean -> mirrored broadcast, 2*ceil((N-1)/I)
    rounds total.  The composable pipeline reaches the two stages directly
    (:func:`tar_exchange_rounds` / :func:`tar_broadcast_rounds`) so codecs
    can interpose; this wrapper is the plain value-domain form.
    """
    n = axis_size(axis)
    s = x.shape[0] // n
    received = tar_exchange_rounds(x.reshape(n, s), axis, incast=incast)
    if mask is None:
        own = jnp.mean(received, axis=0)
    else:
        own = masked_mean_ref(received, mask)
    return tar_broadcast_rounds(own, axis, incast=incast)


def tar_allreduce_2d(x: jnp.ndarray, inner_axis: str, outer_axis: str, *,
                     mask: jnp.ndarray | None = None,
                     outer_mask: jnp.ndarray | None = None,
                     use_kernel: bool = False) -> jnp.ndarray:
    """Hierarchical 2D TAR (§3.1.2 / App. A): groups = pods.

    1. intra-group: TAR reduce-scatter over ``inner_axis``  (N/G - 1 rounds)
    2. inter-group: same-rank aggregation over ``outer_axis``  (G - 1 rounds)
    3. intra-group broadcast over ``inner_axis``            (N/G - 1 rounds)
    """
    own = tar_reduce_scatter(x, inner_axis, mask=mask, use_kernel=use_kernel)
    g = axis_size(outer_axis)
    if g > 1:
        s = own.shape[0]
        if s % g == 0:
            # TAR across pods too: shard my shard over the outer axis.
            own = tar_allreduce(own, outer_axis, mask=outer_mask,
                                use_kernel=use_kernel)
        else:
            own = jax.lax.pmean(own, outer_axis)
    return jax.lax.all_gather(own, inner_axis, axis=0, tiled=True)
