"""Baseline collectives the paper compares against (§5.1.2), as real
``shard_map`` collectives: Gloo Ring, recursive halving-doubling ("NCCL
Tree" stand-in), BCube, and plain psum (XLA's native choice).

The ring implementation also supports per-hop drop masks so the loss-
propagation pathology of Ring (accumulated partial sums lost in one hop,
§5.3 MSE microbenchmark) is reproduced in the actual dataflow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def _n(axis: str) -> int:
    return compat.axis_size(axis)


def psum_mean(x: jnp.ndarray, axis) -> jnp.ndarray:
    return jax.lax.pmean(x, axis)


def ring_allreduce(x: jnp.ndarray, axis: str, *,
                   hop_masks: jnp.ndarray | None = None,
                   active: tuple[int, ...] | None = None,
                   weights: tuple[int, ...] | None = None) -> jnp.ndarray:
    """Bandwidth-optimal ring allreduce (Patarasuk-Yuan): N-1 reduce-scatter
    hops + N-1 all-gather hops over a fixed ring i -> i+1.

    x: flat (L,), L % N == 0. hop_masks: (2N-2, S) 0/1 — what survived each
    hop *into this node* (1 everywhere = lossless). A dropped hop loses the
    accumulated partial sum, which is exactly Ring's pathology.

    With a degraded-participation set ``active`` the ring is the *virtual
    ring of active peers* **in the order given** — callers route around
    failed links by passing a ``tar.ring_order``-ed tuple, since only
    consecutive (distance-1) hops are ever used: A chunks, 2(A-1) hops,
    mean over A contributions; ejected peers self-loop (their partial sums
    never enter the ring) and their garbage result must be replaced via
    ``tar.graft_inactive`` by the caller.  ``hop_masks`` then indexes the
    2(A-1) virtual hops.

    ``weights`` (positive shard units per virtual position, len A) makes
    chunk ownership straggler-proportional: x must be pre-padded to a
    multiple of ``sum(weights)`` and is cut by ``tar.shard_plan`` into
    contiguous slices that ride the ring zero-padded to the widest slice.
    """
    n = _n(axis)
    if active is None and weights is not None:
        active = tuple(range(n))
    if active is None:
        ring_n, k = n, jax.lax.axis_index(axis)
        perm = [(j, (j + 1) % n) for j in range(n)]
    else:
        from .tar import _ring_perms, peer_lookup
        ring_n = len(active)
        vpos, _ = peer_lookup(active, n)
        k = jnp.take(vpos, jax.lax.axis_index(axis))
        perm = _ring_perms(active, n)(1)
    if weights is not None:
        from .tar import shard_plan, weighted_flat, weighted_rows
        if len(weights) != ring_n:
            raise ValueError(f"weights {weights} do not match ring size {ring_n}")
        plan = shard_plan(x.shape[0], weights)
        if plan.padded != x.shape[0]:
            raise ValueError(f"bucket length {x.shape[0]} not a multiple of "
                             f"sum(weights)={sum(weights)}")
        chunks = weighted_rows(x, plan)
        s = plan.s_max
    else:
        plan = None
        s = x.shape[0] // ring_n
        chunks = x.reshape(ring_n, s)

    acc = chunks  # acc[c] = running partial sum of chunk c held at this node
    # reduce-scatter: after N-1 hops, node k owns the full sum of chunk (k+1)%n
    for h in range(ring_n - 1):
        send = jnp.take(acc, (k - h) % ring_n, axis=0)
        recv = jax.lax.ppermute(send, axis, perm)
        m = hop_masks[h] if hop_masks is not None else 1.0
        acc = acc.at[(k - h - 1) % ring_n].add(recv * m)
    own_idx = (k + 1) % ring_n
    own = jnp.take(acc, own_idx, axis=0) / ring_n

    # all-gather ring
    out = jnp.zeros_like(chunks).at[own_idx].set(own)
    cur = own
    for h in range(ring_n - 1):
        recv = jax.lax.ppermute(cur, axis, perm)
        m = hop_masks[ring_n - 1 + h] if hop_masks is not None else 1.0
        cur = recv * m
        out = out.at[(k - h) % ring_n].set(cur)
    if plan is not None:
        return weighted_flat(out, plan)
    return out.reshape(ring_n * s)


def tree_allreduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Recursive halving-doubling (the classic log-round tree allreduce;
    stands in for NCCL Tree): log2 N reduce-scatter + log2 N all-gather.
    After halving, node i owns segment i; doubling reassembles in order."""
    n = _n(axis)
    if n & (n - 1):
        return jax.lax.pmean(x, axis)
    i = jax.lax.axis_index(axis)
    buf = x
    d = n // 2
    while d >= 1:
        perm = [(j, j ^ d) for j in range(n)]
        half = buf.shape[0] // 2
        lo, hi = buf[:half], buf[half:]
        in_upper = (i & d) != 0
        mine = jnp.where(in_upper, hi, lo)      # half I keep reducing
        theirs = jnp.where(in_upper, lo, hi)    # half the partner owns
        recv = jax.lax.ppermute(theirs, axis, perm)
        buf = mine + recv
        d //= 2
    own = buf / n                               # (L/N,) segment i
    d = 1
    while d < n:
        perm = [(j, j ^ d) for j in range(n)]
        recv = jax.lax.ppermute(own, axis, perm)
        in_upper = (i & d) != 0
        own = jnp.where(in_upper,
                        jnp.concatenate([recv, own]),
                        jnp.concatenate([own, recv]))
        d *= 2
    return own


def bcube_allreduce(x: jnp.ndarray, axis: str, *, base: int = 4) -> jnp.ndarray:
    """Gloo-style BCube: k = log_base(N) stages. In each reduce stage, the
    ``base`` peers of a group (nodes differing only in one base-``base``
    digit) split their buffer into ``base`` parts and exchange so each
    member reduces the part matching its digit; the all-gather phase
    mirrors the stages in reverse. base=2 == recursive halving-doubling.
    """
    n = _n(axis)
    k, m = 0, n
    while m > 1:
        if m % base:
            return jax.lax.pmean(x, axis)       # N not a power of base
        m //= base
        k += 1
    i = jax.lax.axis_index(axis)
    buf = x
    strides = [base ** t for t in range(k)]

    def group_perm(stride: int, o: int) -> list[tuple[int, int]]:
        # every node j sends to the group member whose digit is digit(j)+o
        out = []
        for j in range(n):
            dj = (j // stride) % base
            out.append((j, j + ((((dj + o) % base) - dj) * stride)))
        return out

    for stride in strides:                       # reduce-scatter stages
        digit = (i // stride) % base
        parts = buf.reshape(base, -1)
        acc = jnp.take(parts, digit, axis=0)     # my digit's part, own contrib
        for o in range(1, base):
            send = jnp.take(parts, (digit + o) % base, axis=0)
            recv = jax.lax.ppermute(send, axis, group_perm(stride, o))
            acc = acc + recv                     # sender's part for my digit
        buf = acc
    own = buf / n

    for stride in reversed(strides):             # all-gather stages (mirror)
        digit = (i // stride) % base
        rows = [own]
        for o in range(1, base):
            rows.append(jax.lax.ppermute(own, axis, group_perm(stride, o)))
        stacked = jnp.stack(rows)                # row o = chunk of digit-(o) peer
        offs = (digit - jnp.arange(base)) % base # row o belongs at digit-o
        ordered = jnp.zeros_like(stacked).at[offs].set(stacked)
        own = ordered.reshape(-1)
    return own
