"""Randomized Hadamard Transform over gradient buckets (paper §3.3).

The bucket is processed in 2^k-element blocks (default 4096). Blockwise HT
commutes with TAR sharding as long as shard boundaries are block-aligned
(guaranteed by ``core.tar.pad_for_tar``), and the transform is linear, so

    decode(mean_i(encode(g_i))) == mean_i(g_i)        (exact, no drops)

while under drops the decoded error is spread across the whole block —
the paper's unbiased-estimate property (Fig 9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fwht import randomized_fwht


def rademacher_sign(key: jax.Array, block: int) -> jnp.ndarray:
    """The random +-1 diagonal D shared by all workers for one step."""
    return jnp.where(jax.random.bernoulli(key, 0.5, (block,)), 1.0, -1.0).astype(
        jnp.float32)


def ht_encode(x: jnp.ndarray, key: jax.Array, *, block: int = 4096,
              use_kernel: bool = False) -> jnp.ndarray:
    """Encode a flat, block-aligned bucket: per-block H @ (d * x)."""
    n = x.shape[-1]
    if n % block:
        raise ValueError(f"bucket length {n} not a multiple of block {block}")
    sign = rademacher_sign(key, block)
    y = randomized_fwht(x.reshape(-1, block), sign, mode="encode",
                        use_kernel=use_kernel)
    return y.reshape(x.shape)


def ht_decode(y: jnp.ndarray, key: jax.Array, *, block: int = 4096,
              use_kernel: bool = False) -> jnp.ndarray:
    """Inverse of ``ht_encode`` with the same key: per-block d * (H @ y)."""
    n = y.shape[-1]
    if n % block:
        raise ValueError(f"bucket length {n} not a multiple of block {block}")
    sign = rademacher_sign(key, block)
    x = randomized_fwht(y.reshape(-1, block), sign, mode="decode",
                        use_kernel=use_kernel)
    return x.reshape(y.shape)
