"""Randomized Hadamard Transform over gradient buckets (paper §3.3).

The bucket is processed in 2^k-element blocks (default 4096). Blockwise HT
commutes with TAR sharding as long as shard boundaries are block-aligned
(guaranteed by ``core.tar.pad_for_tar``), and the transform is linear, so

    decode(mean_i(encode(g_i))) == mean_i(g_i)        (exact, no drops)

while under drops the decoded error is spread across the whole block —
the paper's unbiased-estimate property (Fig 9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fwht import randomized_fwht
from repro.kernels.ht_quant import ht_amax, ht_encode_fused, ht_quant


def rademacher_sign(key: jax.Array, block: int) -> jnp.ndarray:
    """The random +-1 diagonal D shared by all workers for one step."""
    return jnp.where(jax.random.bernoulli(key, 0.5, (block,)), 1.0, -1.0).astype(
        jnp.float32)


def ht_encode(x: jnp.ndarray, key: jax.Array, *, block: int = 4096,
              use_kernel: bool = False) -> jnp.ndarray:
    """Encode a flat, block-aligned bucket: per-block H @ (d * x).

    Routes through the fused engine's unquantized encode stage
    (``ht_encode_fused``: sign-flip + FWHT in one pass when the kernel is
    on) — the bits=0 configuration of kernels/ht_quant.
    """
    n = x.shape[-1]
    if n % block:
        raise ValueError(f"bucket length {n} not a multiple of block {block}")
    sign = rademacher_sign(key, block)
    y = ht_encode_fused(x.reshape(-1, block), sign, use_kernel=use_kernel)
    return y.reshape(x.shape)


def ht_decode(y: jnp.ndarray, key: jax.Array, *, block: int = 4096,
              use_kernel: bool = False) -> jnp.ndarray:
    """Inverse of ``ht_encode`` with the same key: per-block d * (H @ y)."""
    n = y.shape[-1]
    if n % block:
        raise ValueError(f"bucket length {n} not a multiple of block {block}")
    sign = rademacher_sign(key, block)
    x = randomized_fwht(y.reshape(-1, block), sign, mode="decode",
                        use_kernel=use_kernel)
    return x.reshape(y.shape)


# ------------------------------------------------- fused encode-side stages
# Same key->sign derivation as ht_encode, but the rotated bucket is never
# materialized: the kernels rotate in VMEM and emit only the reduction
# (per-block amax) or the uint8 codes (see kernels/ht_quant).

def ht_encode_amax(x: jnp.ndarray, key: jax.Array, *, block: int = 4096,
                   use_kernel: bool = False) -> jnp.ndarray:
    """Per-block amax of ``ht_encode(x)`` without materializing it.

    x: flat block-aligned bucket -> (nblocks,) fp32 — the quantization-grid
    pass of the fused sync engine (pmax these across workers, then call
    :func:`ht_encode_quant` with the shared grids).
    """
    if x.shape[-1] % block:
        raise ValueError(f"bucket length {x.shape[-1]} not a multiple of "
                         f"block {block}")
    sign = rademacher_sign(key, block)
    return ht_amax(x.reshape(-1, block), sign, use_kernel=use_kernel)


def ht_encode_quant(x: jnp.ndarray, key: jax.Array, noise: jnp.ndarray,
                    lo: jnp.ndarray, step: jnp.ndarray, *,
                    block: int = 4096, bits: int = 8,
                    use_kernel: bool = False) -> jnp.ndarray:
    """Fused ``ht_encode`` + shared-grid stochastic quantization.

    x/noise: flat block-aligned; lo/step: (nblocks,) pmax-shared grids.
    Returns (nblocks, block) uint8 codes — one VMEM-resident pass.
    """
    sign = rademacher_sign(key, block)
    return ht_quant(x.reshape(-1, block), sign, noise.reshape(-1, block),
                    lo, step, bits=bits, use_kernel=use_kernel)
