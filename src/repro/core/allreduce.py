"""Gradient-sync entrypoints over the composable collective pipeline.

The strategy implementations live in :mod:`repro.core.pipeline`: every named
strategy is a :class:`~repro.core.pipeline.CollectiveSpec` composing three
orthogonal protocols — a **Topology** (psum / ring / tree / bcube / TAR with
all_to_all or explicit round schedules, 1D or hierarchical 2D pod×data), a
**Transport** (``Reliable``, ``Lossy`` = the UBT drop model + stats,
``AdaptiveTransport`` = the §3.2 controllers picking next-step codec/incast),
and a **Codec** (``Identity``, ``Hadamard``, ``HTQuant`` shared-grid
quantization, kernel-dispatched under ``cfg.use_kernels``).  See DESIGN.md
§3 for the layering and the strategy-author migration notes.

This module keeps the stable, config-driven surface:

  ``OptiReduceConfig`` / ``SyncContext``  — static knobs + per-step context
  ``sync_bucket``        — one flat bucket through the resolved spec
  ``sync_pytree``        — the fused BucketPlan engine (scan/vmap over a
                           packed (B, bucket_elems) batch)
  ``sync_pytree_unfused``— the seed bucketing loop, kept as the bitwise
                           parity oracle for the ``parity`` test suite
  ``reduce_scatter_axis``— the FSDP/ZeRO reduction (deferred stage 2),
                           resolved to a TAR spec with the rs-specific codec

Built-in strategy names (``strategies()``):

  psum        — XLA's native all-reduce (what a stock JAX program does)
  gloo_ring   — explicit ring reduce-scatter + all-gather (Gloo Ring)
  nccl_tree   — recursive halving-doubling (NCCL Tree stand-in)
  bcube       — Gloo BCube
  tar_tcp     — Transpose AllReduce, reliable (paper's TAR+TCP baseline)
  tar_rounds  — TAR with the paper's explicit round schedule (ppermute form)
  optireduce  — TAR + UBT drop model + compensated reduce + randomized HT
  optireduce_2d — hierarchical 2D TAR across (pod, data) for multi-pod meshes
  optireduce_q — TAR with THC-quantized shard exchange (beyond-paper)
  optireduce_rounds / tar_rounds_q / ring_ht — registered cross-product
                compositions (see pipeline.register_strategy)

Drops are applied on stage 1 only by default (the aggregated shard is then
authoritative and every replica receives identical bytes from the broadcast,
keeping replicas consistent; see DESIGN §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .bucket_plan import BucketPlan
from .pipeline import (CollectiveSpec, Hadamard, HTQuant, Identity, Lossy,
                       OptiReduceConfig, Reliable, SyncContext, TarTopology,
                       register_strategy, resolve_spec, strategy_names)

__all__ = [
    "OptiReduceConfig", "SyncContext", "CollectiveSpec", "register_strategy",
    "resolve_spec", "strategies", "sync_bucket", "sync_pytree",
    "sync_pytree_unfused", "reduce_scatter_axis",
]


def strategies() -> tuple[str, ...]:
    """Registered strategy names (see pipeline.register_strategy)."""
    return strategy_names()


def sync_bucket(bucket: jnp.ndarray, ctx: SyncContext,
                spec: CollectiveSpec | None = None) -> jnp.ndarray:
    """Reduce one flat bucket to its (approximate) DP mean.

    Resolves ``ctx.cfg.strategy`` through the spec registry unless an
    explicit ``spec`` is given (e.g. an unregistered composition or one
    holding a stateful :class:`~repro.core.pipeline.AdaptiveTransport`).
    """
    if spec is None:
        spec = resolve_spec(ctx.cfg)
    return spec.all_reduce(bucket, ctx)


def sync_pytree(grads, ctx: SyncContext, *, bucket_elems: int = 6_553_600,
                plan: BucketPlan | None = None, mode: str = "scan",
                spec: CollectiveSpec | None = None):
    """Sync a gradient pytree via fixed-size buckets (PyTorch uses 25 MB
    buckets == 6.55M fp32 entries; same default here).

    Buckets follow a static :class:`BucketPlan` (leaf->bucket layout from
    the treedef/shapes, computed once — pass ``plan`` to reuse it): leaves
    are packed into one ``(B, bucket_elems)`` batch and the strategy
    pipeline runs as a single traced body — constant HLO size in B and no
    second full-gradient materialization. ``mode`` picks the schedule
    tradeoff: ``'scan'`` (default) serializes buckets (smallest program;
    bucket k+1's collective waits on bucket k), ``'vmap'`` vectorizes over
    the bucket axis so the collectives stay batched/concurrent like the
    seed's unrolled loop. Both are bitwise-identical to
    :func:`sync_pytree_unfused`.
    """
    if mode not in ("scan", "vmap"):
        raise ValueError(f"unknown sync_pytree mode {mode!r}")
    if spec is None:
        spec = resolve_spec(ctx.cfg)
    if plan is None:
        plan = BucketPlan.for_tree(grads, bucket_elems)
    batch = plan.pack(grads)                         # (B, bucket_elems)
    keys = plan.bucket_keys(ctx.key)
    recorded = False

    def one_bucket(bucket, key):
        nonlocal recorded
        stats: dict = {}
        out = sync_bucket(bucket, SyncContext(cfg=ctx.cfg, key=key,
                                              stats=stats), spec=spec)
        recorded = recorded or ("total" in stats)
        return out, (stats.get("dropped", jnp.zeros(())),
                     stats.get("total", jnp.zeros(())))

    if plan.num_buckets == 1:
        synced, (dropped, total) = one_bucket(batch[0], keys[0])
        synced = synced[None]
    elif mode == "vmap":
        synced, (dropped, total) = jax.vmap(one_bucket)(batch, keys)
        dropped, total = jnp.sum(dropped), jnp.sum(total)
    else:
        def body(carry, inp):
            bucket, key = inp
            out, (d, t) = one_bucket(bucket, key)
            return (carry[0] + d, carry[1] + t), out

        (dropped, total), synced = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(())), (batch, keys))
    if recorded:
        ctx.stats["dropped"] = ctx.stats.get("dropped", 0.0) + dropped
        ctx.stats["total"] = ctx.stats.get("total", 0.0) + total
    return plan.unpack(synced)


def sync_pytree_unfused(grads, ctx: SyncContext, *,
                        bucket_elems: int = 6_553_600):
    """The seed bucketing loop — kept as the parity oracle for
    :func:`sync_pytree`: flatten leaves, slice fixed-size buckets, trace the
    strategy pipeline once per bucket (O(#buckets) HLO)."""
    spec = resolve_spec(ctx.cfg)
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [leaf.size for leaf in leaves]
    flat = jnp.concatenate([leaf.reshape(-1).astype(jnp.float32)
                            for leaf in leaves])
    total = flat.shape[0]
    out_parts = []
    start = 0
    bucket_idx = 0
    while start < total:
        end = min(start + bucket_elems, total)
        sub = jax.random.fold_in(ctx.key, bucket_idx)
        bucket_ctx = SyncContext(cfg=ctx.cfg, key=sub, stats=ctx.stats)
        out_parts.append(sync_bucket(flat[start:end], bucket_ctx, spec=spec))
        start = end
        bucket_idx += 1
    synced = jnp.concatenate(out_parts) if len(out_parts) > 1 else out_parts[0]
    new_leaves = []
    off = 0
    for leaf, size in zip(leaves, sizes):
        new_leaves.append(synced[off:off + size].reshape(leaf.shape)
                          .astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, new_leaves)


def rs_spec(cfg: OptiReduceConfig, *, with_drops: bool = True) -> CollectiveSpec:
    """The reduce-scatter spec for a config: TAR stage 1 with the rs codec.

    Codec selection mirrors the bucketed strategies but with the rs knobs:
    ``rs_wire_bits`` picks the shared-grid quantizer (rotation implied —
    quantization needs it), otherwise the Hadamard rotation rides along only
    when drops are live (``with_drops`` and a positive ``drop_rate``).  The
    quantizer draws its stochastic-rounding noise from fold_in(key, 9) so
    the rs wire never correlates with the bucketed stage-1 draws.
    """
    quant = cfg.rs_wire_bits
    use_ht = (with_drops and cfg.use_hadamard and cfg.drop_rate > 0) or \
        bool(quant)                                     # quant needs rotation
    if quant:
        codec = HTQuant(bits=quant, noise_salt=9)
    elif use_ht:
        codec = Hadamard()
    else:
        codec = Identity()
    return CollectiveSpec(TarTopology(), Lossy() if with_drops else Reliable(),
                          codec)


def reduce_scatter_axis(g: jnp.ndarray, axis: str, dim: int,
                        ctx: SyncContext, *,
                        with_drops: bool = True) -> jnp.ndarray:
    """OptiReduce as a reduce-scatter: TAR stage 1 + compensated reduce on an
    arbitrary tensor, scattering ``dim`` over ``axis`` (the FSDP/ZeRO grad
    reduction — the all_gather at next use is the deferred stage 2).

    g: full tensor; returns the local shard (dim size / axis size) holding
    the drop-compensated mean over the axis peers.
    """
    return rs_spec(ctx.cfg, with_drops=with_drops).reduce_scatter(
        g, axis, dim, ctx)
