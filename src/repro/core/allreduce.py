"""Gradient-sync entrypoints over the composable collective pipeline.

The strategy implementations live in :mod:`repro.core.pipeline`: every named
strategy is a :class:`~repro.core.pipeline.CollectiveSpec` composing three
orthogonal protocols — a **Topology** (psum / ring / tree / bcube / TAR with
all_to_all or explicit round schedules, 1D or hierarchical 2D pod×data), a
**Transport** (``Reliable``, ``Lossy`` = the UBT drop model + stats,
``AdaptiveTransport`` = the §3.2 controllers picking next-step codec/incast),
and a **Codec** (``Identity``, ``Hadamard``, ``HTQuant`` shared-grid
quantization, kernel-dispatched under ``cfg.use_kernels``).  See DESIGN.md
§3 for the layering and the strategy-author migration notes.

This module keeps the stable, config-driven surface:

  ``OptiReduceConfig`` / ``SyncContext``  — static knobs + per-step context
  ``sync_bucket``        — one flat bucket through the resolved spec
  ``sync_packed``        — the fused engine core on a pre-packed
                           (B, bucket_elems) batch: scan / vmap / the
                           stage-skewed ``pipelined`` software schedule
  ``sync_pytree``        — pack -> ``sync_packed`` -> unpack over a static
                           :class:`BucketPlan`
  ``sync_pytree_unfused``— the seed bucketing loop, kept as the bitwise
                           parity oracle for the ``parity`` test suite
  ``reduce_scatter_axis``— the FSDP/ZeRO reduction (deferred stage 2),
                           resolved to a TAR spec with the rs-specific codec

Built-in strategy names (``strategies()``):

  psum        — XLA's native all-reduce (what a stock JAX program does)
  gloo_ring   — explicit ring reduce-scatter + all-gather (Gloo Ring)
  nccl_tree   — recursive halving-doubling (NCCL Tree stand-in)
  bcube       — Gloo BCube
  tar_tcp     — Transpose AllReduce, reliable (paper's TAR+TCP baseline)
  tar_rounds  — TAR with the paper's explicit round schedule (ppermute form)
  optireduce  — TAR + UBT drop model + compensated reduce + randomized HT
  optireduce_2d — hierarchical 2D TAR across (pod, data) for multi-pod meshes
  optireduce_q — TAR with THC-quantized shard exchange (beyond-paper)
  optireduce_rounds / tar_rounds_q / ring_ht — registered cross-product
                compositions (see pipeline.register_strategy)

Drops are applied on stage 1 only by default (the aggregated shard is then
authoritative and every replica receives identical bytes from the broadcast,
keeping replicas consistent; see DESIGN §2).

``OptiReduceConfig.active_peers`` (set by the runtime control plane's
``SyncPolicy``, see repro/runtime/ and DESIGN §5) degrades participation:
a proper subset excludes the ejected peers' contributions — via the masked
compensated mean on a2a schedules, via round schedules regenerated over the
active peers' virtual ring on rounds/ring schedules — while ejected peers
still receive every reduced bucket.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .bucket_plan import BucketPlan, bucket_keys
from .pipeline import (CollectiveSpec, Hadamard, HTQuant, Identity, Lossy,
                       OptiReduceConfig, Reliable, SyncContext, TarTopology,
                       Topology, register_strategy, resolve_spec,
                       strategy_names)

__all__ = [
    "OptiReduceConfig", "SyncContext", "CollectiveSpec", "register_strategy",
    "resolve_spec", "strategies", "sync_bucket", "sync_packed", "sync_pytree",
    "sync_pytree_unfused", "reduce_scatter_axis",
]


def strategies() -> tuple[str, ...]:
    """Registered strategy names (see pipeline.register_strategy)."""
    return strategy_names()


def sync_bucket(bucket: jnp.ndarray, ctx: SyncContext,
                spec: CollectiveSpec | None = None) -> jnp.ndarray:
    """Reduce one flat bucket to its (approximate) DP mean.

    Resolves ``ctx.cfg.strategy`` through the spec registry unless an
    explicit ``spec`` is given (e.g. an unregistered composition or one
    holding a stateful :class:`~repro.core.pipeline.AdaptiveTransport`).
    """
    if spec is None:
        spec = resolve_spec(ctx.cfg)
    return spec.all_reduce(bucket, ctx)


def sync_pytree(grads, ctx: SyncContext, *, bucket_elems: int = 6_553_600,
                plan: BucketPlan | None = None, mode: str = "scan",
                spec: CollectiveSpec | None = None):
    """Sync a gradient pytree via fixed-size buckets (PyTorch uses 25 MB
    buckets == 6.55M fp32 entries; same default here).

    Buckets follow a static :class:`BucketPlan` (leaf->bucket layout from
    the treedef/shapes, computed once — pass ``plan`` to reuse it): leaves
    are packed into one ``(B, bucket_elems)`` batch and the strategy
    pipeline runs as a single traced body — constant HLO size in B and no
    second full-gradient materialization. ``mode`` picks the schedule
    tradeoff (see :func:`sync_packed`); all modes are bitwise-identical to
    :func:`sync_pytree_unfused`.
    """
    if plan is None:
        plan = BucketPlan.for_tree(grads, bucket_elems)
    batch = plan.pack(grads)                         # (B, bucket_elems)
    return plan.unpack(sync_packed(batch, ctx, mode=mode, spec=spec))


def _sync_pipelined(batch, keys, ctx: SyncContext, spec: CollectiveSpec,
                    stale=None):
    """Stage-skewed software pipeline over the bucket axis (depth-2 skew).

    Iteration k *encodes* bucket k, *exchanges* bucket k-1, and *decodes*
    bucket k-2; the in-flight encoded/exchanged payloads ride in the scan
    carry, so within each traced step the exchange collectives of bucket
    k-1 have no data dependency on the encode/decode kernels of buckets
    k / k-2 and XLA's async collectives genuinely overlap with neighboring
    buckets' Pallas (or MXU-form) codec work.  The first/last two buckets
    run as an unrolled prologue/epilogue; with B <= 3 the steady-state
    window is empty and the whole schedule unrolls (the skew is deeper than
    the bucket count).

    Per-bucket computation is the same encode/exchange/decode composition
    ``CollectiveSpec.all_reduce`` runs, so results are bitwise-identical to
    the scan/vmap modes and the unfused oracle (pinned by the ``parity``
    suite).  Returns ``(synced_batch, (dropped, total), recorded)``.
    """
    cfg = ctx.cfg
    nbuckets = batch.shape[0]
    length = batch.shape[-1]
    recorded = False

    def enc(bucket, key, stale_b=None):
        # the stale cache enters at encode time (re-encoded under the
        # bucket's key) and then rides the stage state through the skew —
        # the per-bucket pairing survives because encode/exchange/decode of
        # one bucket share the carried tuple, not the loop index
        sctx = SyncContext(cfg=cfg, key=key, stale=stale_b)
        return (key, spec.encode_stage(bucket, sctx))

    def exch(state):
        nonlocal recorded
        key, inner = state
        stats: dict = {}
        sctx = SyncContext(cfg=cfg, key=key, stats=stats)
        out = spec.exchange_stage(inner, sctx)
        recorded = recorded or ("total" in stats)
        return ((key, out), (stats.get("dropped", jnp.zeros(())),
                             stats.get("total", jnp.zeros(()))))

    def dec(state):
        key, inner = state
        sctx = SyncContext(cfg=cfg, key=key)
        return spec.decode_stage(inner, length, sctx)

    def stale_at(it):
        return None if stale is None else stale[it]

    dropped = total = jnp.zeros(())
    if nbuckets <= 3:
        # fully unrolled: prologue/epilogue swallow the steady-state window
        enc_live: dict = {}
        exch_live: dict = {}
        outs = [None] * nbuckets
        for it in range(nbuckets + 2):
            if it < nbuckets:
                enc_live[it] = enc(batch[it], keys[it], stale_at(it))
            if 0 <= it - 1 < nbuckets:
                exch_live[it - 1], (d, t) = exch(enc_live.pop(it - 1))
                dropped, total = dropped + d, total + t
            if 0 <= it - 2 < nbuckets:
                outs[it - 2] = dec(exch_live.pop(it - 2))
        return jnp.stack(outs), (dropped, total), recorded

    # prologue: fill the two pipeline registers
    e_carry = enc(batch[0], keys[0], stale_at(0))
    e_next = enc(batch[1], keys[1], stale_at(1))
    x_carry, (d, t) = exch(e_carry)
    dropped, total = dropped + d, total + t

    def body(carry, inp):
        (cd, ct), e_prev, x_prev = carry
        bucket, key = inp[0], inp[1]
        e_k = enc(bucket, key,                 # encode bucket k
                  inp[2] if stale is not None else None)
        x_k, (d, t) = exch(e_prev)             # exchange bucket k-1
        out = dec(x_prev)                      # decode bucket k-2
        return ((cd + d, ct + t), e_k, x_k), out

    xs = (batch[2:], keys[2:]) if stale is None else \
        (batch[2:], keys[2:], stale[2:])
    ((d2, t2), e_last, x_last), mid = jax.lax.scan(
        body, ((jnp.zeros(()), jnp.zeros(())), e_next, x_carry), xs)
    dropped, total = dropped + d2, total + t2

    # epilogue: drain the registers for the last two buckets
    x_fin, (d, t) = exch(e_last)
    dropped, total = dropped + d, total + t
    tail = jnp.stack([dec(x_last), dec(x_fin)])
    return jnp.concatenate([mid, tail]), (dropped, total), recorded


def sync_packed(batch: jnp.ndarray, ctx: SyncContext, *, mode: str = "scan",
                spec: CollectiveSpec | None = None,
                stale: jnp.ndarray | None = None) -> jnp.ndarray:
    """Sync an already-packed ``(B, bucket_elems)`` batch — the engine core
    behind :func:`sync_pytree`, exposed so the trainer's packed gradient
    arena can feed its accumulator straight in (no pack/unpack HBM passes
    around the sync).

    ``mode``:
      ``'scan'``      one ``lax.scan``'d strategy body; buckets strictly
                      serialized (smallest program).
      ``'vmap'``      vectorized over the bucket axis; collectives batched/
                      concurrent like the seed's unrolled loop.
      ``'pipelined'`` stage-skewed software pipeline: iteration k encodes
                      bucket k, exchanges bucket k-1, decodes bucket k-2,
                      so the exchange collectives overlap neighboring
                      buckets' encode/decode kernels (depth-2 skew with
                      unrolled prologue/epilogue).
    All modes are bitwise-identical per bucket (same stage composition).
    Per-bucket PRNG keys are ``fold_in(ctx.key, bucket_index)``, the seed
    loop's derivation.

    ``stale`` (optional, same shape as ``batch``): the previous step's
    decoded arena, threaded per-bucket into the stage pipeline as the
    cross-step prediction cache for a StaleFill recovery codec (DESIGN §8).
    ``None`` — the default, and the only value when recovery is off —
    leaves every code path byte-identical to the seed engine.
    """
    if mode not in ("scan", "vmap", "pipelined"):
        raise ValueError(f"unknown sync mode {mode!r}")
    if spec is None:
        spec = resolve_spec(ctx.cfg)
    nbuckets = batch.shape[0]
    keys = bucket_keys(ctx.key, nbuckets)
    recorded = False

    def one_bucket(bucket, key, stale_b=None):
        nonlocal recorded
        stats: dict = {}
        out = sync_bucket(bucket, SyncContext(cfg=ctx.cfg, key=key,
                                              stats=stats, stale=stale_b),
                          spec=spec)
        recorded = recorded or ("total" in stats)
        return out, (stats.get("dropped", jnp.zeros(())),
                     stats.get("total", jnp.zeros(())))

    if mode == "pipelined" and nbuckets > 1:
        # capability check up front: a Topology that only overrides
        # all_reduce (the PR-2 protocol) cannot be stage-skewed, and the
        # error should say so rather than surface from deep in the trace
        if type(spec.topology).encode_stage is Topology.encode_stage:
            raise NotImplementedError(
                f"mode='pipelined' needs the encode/exchange/decode stage "
                f"callables; topology {type(spec.topology).__name__} does "
                "not implement them (override the three stages — "
                "all_reduce alone only supports mode='scan'/'vmap')")
        synced, (dropped, total), recorded = _sync_pipelined(
            batch, keys, ctx, spec, stale)
    elif nbuckets == 1:
        synced, (dropped, total) = one_bucket(
            batch[0], keys[0], None if stale is None else stale[0])
        synced = synced[None]
    elif mode == "vmap":
        if stale is None:
            synced, (dropped, total) = jax.vmap(one_bucket)(batch, keys)
        else:
            synced, (dropped, total) = jax.vmap(one_bucket)(batch, keys,
                                                            stale)
        dropped, total = jnp.sum(dropped), jnp.sum(total)
    else:
        def body(carry, inp):
            out, (d, t) = one_bucket(inp[0], inp[1],
                                     inp[2] if stale is not None else None)
            return (carry[0] + d, carry[1] + t), out

        xs = (batch, keys) if stale is None else (batch, keys, stale)
        (dropped, total), synced = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(())), xs)
    if recorded:
        ctx.stats["dropped"] = ctx.stats.get("dropped", 0.0) + dropped
        ctx.stats["total"] = ctx.stats.get("total", 0.0) + total
    return synced


def sync_pytree_unfused(grads, ctx: SyncContext, *,
                        bucket_elems: int = 6_553_600):
    """The seed bucketing loop — kept as the parity oracle for
    :func:`sync_pytree`: flatten leaves, slice fixed-size buckets, trace the
    strategy pipeline once per bucket (O(#buckets) HLO)."""
    spec = resolve_spec(ctx.cfg)
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [leaf.size for leaf in leaves]
    flat = jnp.concatenate([leaf.reshape(-1).astype(jnp.float32)
                            for leaf in leaves])
    total = flat.shape[0]
    out_parts = []
    start = 0
    bucket_idx = 0
    while start < total:
        end = min(start + bucket_elems, total)
        sub = jax.random.fold_in(ctx.key, bucket_idx)
        bucket_ctx = SyncContext(cfg=ctx.cfg, key=sub, stats=ctx.stats)
        out_parts.append(sync_bucket(flat[start:end], bucket_ctx, spec=spec))
        start = end
        bucket_idx += 1
    synced = jnp.concatenate(out_parts) if len(out_parts) > 1 else out_parts[0]
    new_leaves = []
    off = 0
    for leaf, size in zip(leaves, sizes):
        new_leaves.append(synced[off:off + size].reshape(leaf.shape)
                          .astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, new_leaves)


def rs_spec(cfg: OptiReduceConfig, *, with_drops: bool = True) -> CollectiveSpec:
    """The reduce-scatter spec for a config: TAR stage 1 with the rs codec.

    Codec selection mirrors the bucketed strategies but with the rs knobs:
    ``rs_wire_bits`` picks the shared-grid quantizer (rotation implied —
    quantization needs it), otherwise the Hadamard rotation rides along only
    when drops are live (``with_drops`` and a positive ``drop_rate``).  The
    quantizer draws its stochastic-rounding noise from fold_in(key, 9) so
    the rs wire never correlates with the bucketed stage-1 draws.
    """
    quant = cfg.rs_wire_bits
    use_ht = (with_drops and cfg.use_hadamard and cfg.drop_rate > 0) or \
        bool(quant)                                     # quant needs rotation
    if quant:
        codec = HTQuant(bits=quant, noise_salt=9)
    elif use_ht:
        codec = Hadamard()
    else:
        codec = Identity()
    return CollectiveSpec(TarTopology(), Lossy() if with_drops else Reliable(),
                          codec)


def reduce_scatter_axis(g: jnp.ndarray, axis: str, dim: int,
                        ctx: SyncContext, *,
                        with_drops: bool = True) -> jnp.ndarray:
    """OptiReduce as a reduce-scatter: TAR stage 1 + compensated reduce on an
    arbitrary tensor, scattering ``dim`` over ``axis`` (the FSDP/ZeRO grad
    reduction — the all_gather at next use is the deferred stage 2).

    g: full tensor; returns the local shard (dim size / axis size) holding
    the drop-compensated mean over the axis peers.
    """
    return rs_spec(ctx.cfg, with_drops=with_drops).reduce_scatter(
        g, axis, dim, ctx)
