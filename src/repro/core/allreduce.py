"""Gradient-sync strategy registry — OptiReduce as a first-class feature.

Every strategy is a function ``(bucket, ctx) -> bucket`` mapping a flat
per-worker gradient bucket to its (approximate) mean over the data-parallel
axis/axes, callable inside a ``shard_map`` body. The trainer and the dry-run
select strategies by name:

  psum        — XLA's native all-reduce (what a stock JAX program does)
  gloo_ring   — explicit ring reduce-scatter + all-gather (Gloo Ring)
  nccl_tree   — recursive halving-doubling (NCCL Tree stand-in)
  bcube       — Gloo BCube
  tar_tcp     — Transpose AllReduce, reliable (paper's TAR+TCP baseline)
  tar_rounds  — TAR with the paper's explicit round schedule (ppermute form)
  optireduce  — TAR + UBT drop model + compensated reduce + randomized HT
  optireduce_2d — hierarchical 2D TAR across (pod, data) for multi-pod meshes

OptiReduce pipeline (one bucket):
  pad -> HT encode (Pallas FWHT) -> all_to_all -> masked compensated mean
  (Pallas masked_sum) -> all_gather -> HT decode -> unpad
Drops are applied on stage 1 only by default (the aggregated shard is then
authoritative and every replica receives identical bytes from the broadcast,
keeping replicas consistent; see DESIGN §2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat

from . import drops as drops_lib
from . import ring as ring_lib
from . import tar as tar_lib
from .bucket_plan import BucketPlan
from .hadamard import ht_decode, ht_encode, ht_encode_amax, ht_encode_quant
from repro.kernels.dequant_reduce import dequant_masked_mean


@dataclasses.dataclass(frozen=True)
class OptiReduceConfig:
    """Static (hashable) configuration for gradient sync."""
    strategy: str = "optireduce"
    data_axis: str = "data"
    pod_axis: str | None = None          # set for multi-pod meshes
    # UBT drop model (stand-in for timeouts/loss on a lossy fabric)
    drop_rate: float = 0.0
    drop_pattern: str = "tail"           # bernoulli | tail | straggler
    packet_elems: int = 256
    # Hadamard transform
    use_hadamard: bool = True
    hadamard_block: int = 4096
    # kernels: use Pallas (TPU) or the jnp MXU-form (identical math)
    use_kernels: bool = False
    # safeguards
    skip_threshold: float = 0.10
    # round-form incast (tar_rounds only)
    incast: int = 1
    # quantized TAR exchange (optireduce_q): THC-style shared-grid uniform
    # stochastic quantization of the HT-rotated shards — beyond-paper
    # optimization (the paper notes THC is orthogonal); cuts the wire bytes
    # of both TAR stages by 32/quant_bits
    quant_bits: int = 8
    # quantize the FSDP gradient reduce-scatter wire to this many bits
    # (0 = native dtype). Per-Hadamard-block grids, pmax-shared; §Perf H2.
    rs_wire_bits: int = 0


@dataclasses.dataclass
class SyncContext:
    """Per-step dynamic context threaded into the strategy."""
    cfg: OptiReduceConfig
    key: jax.Array                        # replicated per-step PRNG key
    stats: dict = dataclasses.field(default_factory=dict)

    def data_axes(self) -> tuple[str, ...]:
        if self.cfg.pod_axis is not None:
            return (self.cfg.pod_axis, self.cfg.data_axis)
        return (self.cfg.data_axis,)

    def loss_fraction(self) -> jnp.ndarray:
        """Observed entry-loss fraction this step, pmean'd across receivers
        (what the §3.4 safeguards and the UBT controller monitor)."""
        if "total" not in self.stats:
            return jnp.zeros(())
        frac = self.stats["dropped"] / jnp.maximum(self.stats["total"], 1.0)
        return jax.lax.pmean(frac, self.data_axes())


def _mask_for(ctx: SyncContext, n: int, s: int, axis: str) -> jnp.ndarray | None:
    """Receiver-specific (N, S) arrival mask for TAR stage 1."""
    cfg = ctx.cfg
    if cfg.drop_rate <= 0.0:
        return None
    me = jax.lax.axis_index(axis)
    key = jax.random.fold_in(ctx.key, me)
    return drops_lib.make_mask(cfg.drop_pattern, key, n, s,
                               rate=cfg.drop_rate,
                               packet_elems=cfg.packet_elems,
                               self_index=me)


# ----------------------------------------------------------------- strategies
def _psum(bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
    return jax.lax.pmean(bucket, ctx.data_axes())


def _gloo_ring(bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
    n = compat.axis_size(ctx.cfg.data_axis)
    x, length = tar_lib.pad_for_tar(bucket, n)
    out = ring_lib.ring_allreduce(x, ctx.cfg.data_axis)
    if ctx.cfg.pod_axis is not None:
        out = jax.lax.pmean(out, ctx.cfg.pod_axis)
    return out[:length]


def _nccl_tree(bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
    n = compat.axis_size(ctx.cfg.data_axis)
    x, length = tar_lib.pad_for_tar(bucket, n)
    out = ring_lib.tree_allreduce(x, ctx.cfg.data_axis)
    if ctx.cfg.pod_axis is not None:
        out = jax.lax.pmean(out, ctx.cfg.pod_axis)
    return out[:length]


def _bcube(bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
    n = compat.axis_size(ctx.cfg.data_axis)
    base = 4 if n % 4 == 0 else 2
    x, length = tar_lib.pad_for_tar(bucket, n)
    out = ring_lib.bcube_allreduce(x, ctx.cfg.data_axis, base=base)
    if ctx.cfg.pod_axis is not None:
        out = jax.lax.pmean(out, ctx.cfg.pod_axis)
    return out[:length]


def _tar_tcp(bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
    """Reliable TAR (no drops, no HT) — the paper's TAR+TCP baseline."""
    n = compat.axis_size(ctx.cfg.data_axis)
    x, length = tar_lib.pad_for_tar(bucket, n)
    if ctx.cfg.pod_axis is not None:
        out = tar_lib.tar_allreduce_2d(x, ctx.cfg.data_axis, ctx.cfg.pod_axis,
                                       use_kernel=ctx.cfg.use_kernels)
    else:
        out = tar_lib.tar_allreduce(x, ctx.cfg.data_axis,
                                    use_kernel=ctx.cfg.use_kernels)
    return out[:length]


def _tar_rounds(bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
    n = compat.axis_size(ctx.cfg.data_axis)
    x, length = tar_lib.pad_for_tar(bucket, n)
    out = tar_lib.tar_allreduce_rounds(x, ctx.cfg.data_axis,
                                       incast=ctx.cfg.incast)
    if ctx.cfg.pod_axis is not None:
        out = jax.lax.pmean(out, ctx.cfg.pod_axis)
    return out[:length]


def _optireduce(bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
    """The paper's system: TAR + UBT drop model + HT + compensated reduce."""
    cfg = ctx.cfg
    axis = cfg.data_axis
    n = compat.axis_size(axis)
    block = cfg.hadamard_block if cfg.use_hadamard else 1
    x, length = tar_lib.pad_for_tar(bucket, n, block)
    if cfg.use_hadamard:
        x = ht_encode(x, ctx.key, block=block, use_kernel=cfg.use_kernels)
    s = x.shape[0] // n
    mask = _mask_for(ctx, n, s, axis)
    if mask is not None:
        ctx.stats["dropped"] = ctx.stats.get("dropped", 0.0) + \
            jnp.sum(1.0 - mask)
        ctx.stats["total"] = ctx.stats.get("total", 0.0) + mask.size
    if cfg.pod_axis is not None:
        out = tar_lib.tar_allreduce_2d(x, axis, cfg.pod_axis, mask=mask,
                                       use_kernel=cfg.use_kernels)
    else:
        out = tar_lib.tar_allreduce(x, axis, mask=mask,
                                    use_kernel=cfg.use_kernels)
    if cfg.use_hadamard:
        out = ht_decode(out, ctx.key, block=block, use_kernel=cfg.use_kernels)
    return out[:length]


def _optireduce_q(bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
    """OptiReduce with THC-quantized shard exchange (beyond-paper §Perf).

    Pipeline: HT encode -> per-Hadamard-block uniform stochastic quantize
    -> all_to_all uint8 codes -> dequantize + drop-compensated mean ->
    all_gather aggregate codes -> dequant -> HT decode.

    The per-block [−amax_b, amax_b] grids are pmax'd across workers, so
    every node derives identical grids locally (no scale exchange) and the
    codes are homomorphic — the THC property, made cheap by the rotation
    (rotated blocks are near-Gaussian with comparable scales). Wire bytes:
    quant_bits/16 of the bf16 exchange.

    Under ``use_kernels`` the encode side runs the fused engine
    (kernels/ht_quant): a rotate-and-amax pass for the grids, then one
    sign+FWHT+quantize pass emitting uint8 — the rotated fp32 bucket is
    never written to HBM. The receive side fuses dequant with the
    drop-compensated mean (kernels/dequant_reduce), so no (N, S) float32
    intermediate exists either. The jnp path below is the parity oracle
    (identical math, same RNG draws).
    """
    cfg = ctx.cfg
    axis = cfg.data_axis
    n = compat.axis_size(axis)
    block = cfg.hadamard_block
    levels = (1 << cfg.quant_bits) - 1
    x, length = tar_lib.pad_for_tar(bucket, n, block)
    if cfg.use_kernels:
        amax = ht_encode_amax(x, ctx.key, block=block, use_kernel=True)
        xb = None                         # rotated bucket never materialized
    else:
        x = ht_encode(x, ctx.key, block=block, use_kernel=False)
        xb = x.reshape(-1, block)
        amax = jnp.max(jnp.abs(xb), axis=1)
    amax = jax.lax.pmax(amax, axis)
    if cfg.pod_axis is not None:
        amax = jax.lax.pmax(amax, cfg.pod_axis)
    amax = jnp.maximum(amax, 1e-12)
    step = 2.0 * amax / levels                          # (nblocks,)
    lo = -amax

    s = x.shape[0] // n
    noise = jax.random.uniform(jax.random.fold_in(ctx.key, 3),
                               (x.shape[0] // block, block))
    if cfg.use_kernels:
        codes = ht_encode_quant(x, ctx.key, noise, lo, step, block=block,
                                bits=cfg.quant_bits,
                                use_kernel=True).reshape(n, s)
    else:
        q = jnp.floor((xb - lo[:, None]) / step[:, None] + noise)
        codes = jnp.clip(q, 0, levels).astype(jnp.uint8).reshape(n, s)
    received = jax.lax.all_to_all(codes, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
    # this receiver's shard spans blocks [i*s/block, (i+1)*s/block)
    i = jax.lax.axis_index(axis)
    nblk_shard = s // block
    my_lo = jax.lax.dynamic_slice_in_dim(lo, i * nblk_shard, nblk_shard, 0)
    my_step = jax.lax.dynamic_slice_in_dim(step, i * nblk_shard,
                                           nblk_shard, 0)
    mask = _mask_for(ctx, n, s, axis)
    if mask is not None:
        ctx.stats["dropped"] = ctx.stats.get("dropped", 0.0) + \
            jnp.sum(1.0 - mask)
        ctx.stats["total"] = ctx.stats.get("total", 0.0) + mask.size
    if cfg.use_kernels:
        own = dequant_masked_mean(received, my_lo, my_step, mask,
                                  block=block, use_kernel=True)
    else:
        vals = (received.reshape(n, nblk_shard, block).astype(jnp.float32)
                * my_step[None, :, None] + my_lo[None, :, None]
                ).reshape(n, s)
        own = tar_lib._reduce(vals, mask, cfg.use_kernels)
    if cfg.pod_axis is not None:
        own = jax.lax.pmean(own, cfg.pod_axis)
    # stage 2: broadcast the aggregate, also quantized on the same grids
    ob = own.reshape(nblk_shard, block)
    oq = jnp.clip(jnp.floor((ob - my_lo[:, None]) / my_step[:, None] +
                            jax.random.uniform(jax.random.fold_in(ctx.key, 4),
                                               ob.shape)),
                  0, levels).astype(jnp.uint8)
    all_codes = jax.lax.all_gather(oq.reshape(s), axis, axis=0, tiled=True)
    out = (all_codes.reshape(-1, block).astype(jnp.float32) * step[:, None]
           + lo[:, None]).reshape(-1)
    out = ht_decode(out, ctx.key, block=block, use_kernel=cfg.use_kernels)
    return out[:length]


_STRATEGIES: dict[str, Callable] = {
    "psum": _psum,
    "gloo_ring": _gloo_ring,
    "nccl_tree": _nccl_tree,
    "bcube": _bcube,
    "tar_tcp": _tar_tcp,
    "tar_rounds": _tar_rounds,
    "optireduce": _optireduce,
    "optireduce_2d": _optireduce,   # pod_axis in cfg drives the 2D path
    "optireduce_q": _optireduce_q,  # quantized exchange (beyond-paper)
}


def strategies() -> tuple[str, ...]:
    return tuple(_STRATEGIES)


def sync_bucket(bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
    """Reduce one flat bucket to its (approximate) DP mean."""
    try:
        fn = _STRATEGIES[ctx.cfg.strategy]
    except KeyError:
        raise ValueError(
            f"unknown strategy {ctx.cfg.strategy!r}; one of {strategies()}")
    return fn(bucket, ctx)


def sync_pytree(grads, ctx: SyncContext, *, bucket_elems: int = 6_553_600,
                plan: BucketPlan | None = None, mode: str = "scan"):
    """Sync a gradient pytree via fixed-size buckets (PyTorch uses 25 MB
    buckets == 6.55M fp32 entries; same default here).

    Buckets follow a static :class:`BucketPlan` (leaf->bucket layout from
    the treedef/shapes, computed once — pass ``plan`` to reuse it): leaves
    are packed into one ``(B, bucket_elems)`` batch and the strategy
    pipeline runs as a single traced body — constant HLO size in B and no
    second full-gradient materialization. ``mode`` picks the schedule
    tradeoff: ``'scan'`` (default) serializes buckets (smallest program;
    bucket k+1's collective waits on bucket k), ``'vmap'`` vectorizes over
    the bucket axis so the collectives stay batched/concurrent like the
    seed's unrolled loop. Both are bitwise-identical to
    :func:`sync_pytree_unfused`.
    """
    if mode not in ("scan", "vmap"):
        raise ValueError(f"unknown sync_pytree mode {mode!r}")
    if plan is None:
        plan = BucketPlan.for_tree(grads, bucket_elems)
    batch = plan.pack(grads)                         # (B, bucket_elems)
    keys = plan.bucket_keys(ctx.key)
    recorded = False

    def one_bucket(bucket, key):
        nonlocal recorded
        stats: dict = {}
        out = sync_bucket(bucket, SyncContext(cfg=ctx.cfg, key=key,
                                              stats=stats))
        recorded = recorded or ("total" in stats)
        return out, (stats.get("dropped", jnp.zeros(())),
                     stats.get("total", jnp.zeros(())))

    if plan.num_buckets == 1:
        synced, (dropped, total) = one_bucket(batch[0], keys[0])
        synced = synced[None]
    elif mode == "vmap":
        synced, (dropped, total) = jax.vmap(one_bucket)(batch, keys)
        dropped, total = jnp.sum(dropped), jnp.sum(total)
    else:
        def body(carry, inp):
            bucket, key = inp
            out, (d, t) = one_bucket(bucket, key)
            return (carry[0] + d, carry[1] + t), out

        (dropped, total), synced = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(())), (batch, keys))
    if recorded:
        ctx.stats["dropped"] = ctx.stats.get("dropped", 0.0) + dropped
        ctx.stats["total"] = ctx.stats.get("total", 0.0) + total
    return plan.unpack(synced)


def sync_pytree_unfused(grads, ctx: SyncContext, *,
                        bucket_elems: int = 6_553_600):
    """The seed bucketing loop — kept as the parity oracle for
    :func:`sync_pytree`: flatten leaves, slice fixed-size buckets, trace the
    strategy pipeline once per bucket (O(#buckets) HLO)."""
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [leaf.size for leaf in leaves]
    flat = jnp.concatenate([leaf.reshape(-1).astype(jnp.float32)
                            for leaf in leaves])
    total = flat.shape[0]
    out_parts = []
    start = 0
    bucket_idx = 0
    while start < total:
        end = min(start + bucket_elems, total)
        sub = jax.random.fold_in(ctx.key, bucket_idx)
        bucket_ctx = SyncContext(cfg=ctx.cfg, key=sub, stats=ctx.stats)
        out_parts.append(sync_bucket(flat[start:end], bucket_ctx))
        start = end
        bucket_idx += 1
    synced = jnp.concatenate(out_parts) if len(out_parts) > 1 else out_parts[0]
    new_leaves = []
    off = 0
    for leaf, size in zip(leaves, sizes):
        new_leaves.append(synced[off:off + size].reshape(leaf.shape)
                          .astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, new_leaves)


def reduce_scatter_axis(g: jnp.ndarray, axis: str, dim: int,
                        ctx: SyncContext, *,
                        with_drops: bool = True) -> jnp.ndarray:
    """OptiReduce as a reduce-scatter: TAR stage 1 + compensated reduce on an
    arbitrary tensor, scattering ``dim`` over ``axis`` (the FSDP/ZeRO grad
    reduction — the all_gather at next use is the deferred stage 2).

    g: full tensor; returns the local shard (dim size / axis size) holding
    the drop-compensated mean over the axis peers.
    """
    cfg = ctx.cfg
    n = compat.axis_size(axis)
    g2 = jnp.moveaxis(g, dim, 0)
    lead = g2.shape[0]
    rest = g2.shape[1:]
    assert lead % n == 0, (lead, n)
    # keep the wire dtype (bf16 grads stay bf16): halves collective bytes
    # and the per-layer transients; the masked reduction and the FWHT both
    # accumulate in fp32 internally
    rows = g2.reshape(n, -1)                           # row j -> shard j
    row_len = rows.shape[1]
    quant = cfg.rs_wire_bits
    use_ht = (with_drops and cfg.use_hadamard and cfg.drop_rate > 0) or \
        bool(quant)                                     # quant needs rotation
    block = cfg.hadamard_block if use_ht else 1
    pad = (-row_len) % block
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad)))
    # fused engine (kernels/ht_quant): when quantizing with kernels enabled,
    # the rotation never materializes — a rotate+amax pass derives the
    # grids, then one sign+FWHT+quantize pass emits the wire codes
    fused_q = bool(quant) and cfg.use_kernels
    if use_ht and not fused_q:
        rows = ht_encode(rows.reshape(-1), ctx.key, block=block,
                         use_kernel=cfg.use_kernels).reshape(rows.shape)
    if quant:
        # per-block shared grids (pmax over the axis): int codes on the wire
        levels = (1 << quant) - 1
        if fused_q:
            amax = ht_encode_amax(rows.reshape(-1), ctx.key, block=block,
                                  use_kernel=True)
        else:
            amax = jnp.max(jnp.abs(rows.reshape(-1, block)), axis=1)
        amax = jnp.maximum(jax.lax.pmax(amax, axis), 1e-12)
        step_b = 2.0 * amax / levels                    # (nblocks,)
        lo_b = -amax
        u = jax.random.uniform(jax.random.fold_in(ctx.key, 9),
                               (rows.size // block, block))
        if fused_q:
            codes = ht_encode_quant(rows.reshape(-1), ctx.key, u, lo_b,
                                    step_b, block=block, bits=quant,
                                    use_kernel=True).reshape(rows.shape)
        else:
            rb = rows.reshape(-1, block)
            codes = jnp.clip(jnp.floor((rb.astype(jnp.float32)
                                        - lo_b[:, None]) / step_b[:, None]
                                       + u), 0, levels).astype(jnp.uint8)
            codes = codes.reshape(rows.shape)
        received = jax.lax.all_to_all(codes, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
        i = jax.lax.axis_index(axis)
        nblk = rows.shape[1] // block
        my_lo = jax.lax.dynamic_slice_in_dim(lo_b, i * nblk, nblk, 0)
        my_step = jax.lax.dynamic_slice_in_dim(step_b, i * nblk, nblk, 0)
        mask = (_mask_for(ctx, n, received.shape[1], axis)
                if with_drops else None)
        if cfg.use_kernels:
            own = dequant_masked_mean(received, my_lo, my_step, mask,
                                      block=block, use_kernel=True)
        else:
            vals = (received.reshape(n, nblk, block).astype(jnp.float32)
                    * my_step[None, :, None] + my_lo[None, :, None]
                    ).reshape(n, -1)
            own = tar_lib._reduce(vals, mask, cfg.use_kernels)
    else:
        received = jax.lax.all_to_all(rows, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
        mask = (_mask_for(ctx, n, received.shape[1], axis)
                if with_drops else None)
        own = tar_lib._reduce(received, mask, cfg.use_kernels)
    if mask is not None:
        ctx.stats["dropped"] = ctx.stats.get("dropped", 0.0) + \
            jnp.sum(1.0 - mask)
        ctx.stats["total"] = ctx.stats.get("total", 0.0) + mask.size
    if use_ht:
        own = ht_decode(own, ctx.key, block=block, use_kernel=cfg.use_kernels)
    if pad:
        own = own[:row_len]
    out = own.reshape((lead // n,) + rest)
    return jnp.moveaxis(out, 0, dim)
