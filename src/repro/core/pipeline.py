"""Composable collective pipeline: Topology × Transport × Codec (DESIGN §3).

OptiReduce is inherently layered — a transpose topology (TAR, §3.1), an
unreliable bounded transport (UBT, §3.2), and accuracy-preserving codecs
(randomized Hadamard §3.3, THC-style quantization) — and each axis varies
independently of the others (StragglAR swaps only the schedule; loss-bound
policies swap only the transport).  This module makes every gradient-sync
strategy a :class:`CollectiveSpec` composing three orthogonal protocols:

  Topology  — who exchanges with whom and in what schedule:
              :class:`PsumTopology` (XLA native), :class:`RingTopology`
              (ring / recursive-halving tree / bcube), :class:`TarTopology`
              (all_to_all or the paper's explicit round schedule;
              hierarchical 2D over a ``pod`` axis).
  Transport — what arrives: :class:`Reliable` (everything),
              :class:`Lossy` (the UBT drop-mask model + loss stats), and
              :class:`AdaptiveTransport` (the §3.2 controllers in the loop:
              observed loss feeds ``AdaptiveTimeout.hadamard_active`` and
              ``DynamicIncast`` to pick next-step codec/incast).
  Codec     — what goes on the wire: :class:`Identity`, :class:`Hadamard`
              (blockwise randomized HT), :class:`HTQuant` (shared-grid
              uniform stochastic quantization of the rotated blocks — the
              single implementation both the bucketed strategies and the
              FSDP ``reduce_scatter`` use, kernel-dispatched under
              ``cfg.use_kernels``).

A strategy *name* resolves through a registry of named specs; new
compositions are one-liners::

    register_strategy("ring_ht",
                      CollectiveSpec(RingTopology("ring"), Reliable(),
                                     Hadamard()))

or, for cfg-dependent composition, a decorated factory::

    @register_strategy("my_strategy")
    def _spec(cfg):
        return CollectiveSpec(TarTopology(), Lossy(),
                              Hadamard() if cfg.use_hadamard else Identity())

``core.allreduce`` keeps the stable entrypoints (``sync_bucket``,
``sync_pytree``, ``reduce_scatter_axis``) as thin wrappers that resolve to
specs; every pre-existing strategy name is bitwise-identical to the seed
monolithic implementations (the ``parity`` pytest suite pins this against
the ``sync_pytree_unfused`` oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat

from . import drops as drops_lib
from . import ring as ring_lib
from . import tar as tar_lib
from .hadamard import ht_decode, ht_encode, ht_encode_amax, ht_encode_quant
from .ubt import UbtState
from repro.kernels.dequant_reduce import dequant_masked_mean
from repro.kernels.quant import grid_quant


# ------------------------------------------------------------- configuration
@dataclasses.dataclass(frozen=True)
class OptiReduceConfig:
    """Static (hashable) configuration for gradient sync."""
    strategy: str = "optireduce"
    data_axis: str = "data"
    pod_axis: str | None = None          # set for multi-pod meshes
    # UBT drop model (stand-in for timeouts/loss on a lossy fabric)
    drop_rate: float = 0.0
    drop_pattern: str = "tail"           # bernoulli | tail | straggler
    packet_elems: int = 256
    # Hadamard transform
    use_hadamard: bool = True
    hadamard_block: int = 4096
    # kernels: use Pallas (TPU) or the jnp MXU-form (identical math)
    use_kernels: bool = False
    # safeguards
    skip_threshold: float = 0.10
    # round-form incast (rounds-scheduled topologies only)
    incast: int = 1
    # quantized TAR exchange (optireduce_q): THC-style shared-grid uniform
    # stochastic quantization of the HT-rotated shards — beyond-paper
    # optimization (the paper notes THC is orthogonal); cuts the wire bytes
    # of both TAR stages by 32/quant_bits
    quant_bits: int = 8
    # quantize the FSDP gradient reduce-scatter wire to this many bits
    # (0 = native dtype). Per-Hadamard-block grids, pmax-shared; §Perf H2.
    rs_wire_bits: int = 0


@dataclasses.dataclass
class SyncContext:
    """Per-step dynamic context threaded into the pipeline."""
    cfg: OptiReduceConfig
    key: jax.Array                        # replicated per-step PRNG key
    stats: dict = dataclasses.field(default_factory=dict)

    def data_axes(self) -> tuple[str, ...]:
        if self.cfg.pod_axis is not None:
            return (self.cfg.pod_axis, self.cfg.data_axis)
        return (self.cfg.data_axis,)

    def loss_fraction(self) -> jnp.ndarray:
        """Observed entry-loss fraction this step, pmean'd across receivers
        (what the §3.4 safeguards and the UBT controller monitor)."""
        if "total" not in self.stats:
            return jnp.zeros(())
        frac = self.stats["dropped"] / jnp.maximum(self.stats["total"], 1.0)
        return jax.lax.pmean(frac, self.data_axes())


def _mask_for(ctx: SyncContext, n: int, s: int, axis: str) -> jnp.ndarray | None:
    """Receiver-specific (N, S) arrival mask for TAR stage 1."""
    cfg = ctx.cfg
    if cfg.drop_rate <= 0.0:
        return None
    me = jax.lax.axis_index(axis)
    key = jax.random.fold_in(ctx.key, me)
    return drops_lib.make_mask(cfg.drop_pattern, key, n, s,
                               rate=cfg.drop_rate,
                               packet_elems=cfg.packet_elems,
                               self_index=me)


# ------------------------------------------------------------------- codecs
@dataclasses.dataclass
class Encoded:
    """A codec's wire representation of one flat bucket.

    ``data`` is what travels (fp values or uint8 codes, flat); ``lo`` /
    ``step`` are the per-Hadamard-block quantization grids (pmax-shared
    across the whole DP group) a quantizing codec needs on the receive side.
    """
    data: jnp.ndarray
    lo: jnp.ndarray | None = None
    step: jnp.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class Codec:
    """Identity codec — also the base class defining the codec protocol.

    Hooks, in pipeline order:
      ``encode``          full-bucket encode before the stage-1 exchange
      ``reduce``          decode + drop-compensated mean of the (N, S)
                          received matrix for this node's shard
      ``encode_shard``    re-encode the aggregated shard for stage 2
      ``decode_gathered`` full-bucket decode after the stage-2 broadcast
      ``decode_values``   value-domain decode of one shard (the deferred-
                          stage-2 ``reduce_scatter`` path)
    ``linear`` marks codecs whose decode commutes with averaging (so they
    compose with topologies that reduce internally, e.g. ring).
    """
    linear: bool = dataclasses.field(default=True, init=False)

    def block(self, cfg: OptiReduceConfig) -> int:
        return 1

    def encode(self, x: jnp.ndarray, ctx: SyncContext, axis: str) -> Encoded:
        return Encoded(x)

    def reduce(self, received: jnp.ndarray, mask: jnp.ndarray | None,
               shard_index: jnp.ndarray, enc: Encoded,
               ctx: SyncContext) -> jnp.ndarray:
        return tar_lib.masked_mean(received, mask, ctx.cfg.use_kernels)

    def encode_shard(self, own: jnp.ndarray, shard_index: jnp.ndarray,
                     enc: Encoded, ctx: SyncContext) -> jnp.ndarray:
        return own

    def decode_gathered(self, gathered: jnp.ndarray, enc: Encoded,
                        ctx: SyncContext) -> jnp.ndarray:
        return gathered

    def decode_values(self, vals: jnp.ndarray, enc: Encoded,
                      ctx: SyncContext) -> jnp.ndarray:
        return vals


class Identity(Codec):
    """Raw wire bytes: no rotation, no compression."""


class Hadamard(Codec):
    """Blockwise randomized Hadamard transform (§3.3): linear, so drops
    spread across the block and the decoded mean stays unbiased."""

    def block(self, cfg: OptiReduceConfig) -> int:
        return cfg.hadamard_block

    def encode(self, x, ctx, axis):
        return Encoded(ht_encode(x, ctx.key, block=ctx.cfg.hadamard_block,
                                 use_kernel=ctx.cfg.use_kernels))

    def decode_gathered(self, gathered, enc, ctx):
        return ht_decode(gathered, ctx.key, block=ctx.cfg.hadamard_block,
                         use_kernel=ctx.cfg.use_kernels)

    def decode_values(self, vals, enc, ctx):
        return ht_decode(vals, ctx.key, block=ctx.cfg.hadamard_block,
                         use_kernel=ctx.cfg.use_kernels)


@dataclasses.dataclass(frozen=True)
class HTQuant(Codec):
    """Hadamard rotation + THC-style shared-grid uniform stochastic
    quantization (beyond-paper §Perf).

    Per-block [−amax_b, amax_b] grids are pmax'd across the *whole* DP group
    (the exchange axis plus every other configured data axis), so all nodes
    derive identical grids locally (no scale exchange) and the codes are
    homomorphic — the THC property, made cheap by the rotation (rotated
    blocks are near-Gaussian with comparable scales).

    Under ``cfg.use_kernels`` all three quantization stages run fused
    kernels: rotate+amax (grids), sign+FWHT+quantize (stage-1 codes — the
    rotated fp32 bucket never hits HBM), dequant+compensated-mean (receive),
    and the stage-2 re-quantization of the aggregated shard dispatches to
    the grid-quantize kernel.  The jnp path is the bit-parity oracle.

    ``bits=None`` reads ``cfg.quant_bits``; ``reduce_scatter_axis`` passes
    ``bits=cfg.rs_wire_bits`` and its own ``noise_salt``.  Not ``linear``:
    decode does not commute with topologies that reduce internally.
    """
    bits: int | None = None
    noise_salt: int = 3        # stage-1 stochastic-rounding noise fold_in
    stage2_salt: int = 4       # stage-2 (broadcast) noise fold_in
    linear: bool = dataclasses.field(default=False, init=False)

    def _bits(self, cfg: OptiReduceConfig) -> int:
        return cfg.quant_bits if self.bits is None else self.bits

    def block(self, cfg: OptiReduceConfig) -> int:
        return cfg.hadamard_block

    def _grids(self, enc: Encoded, shard_index, nblk: int):
        lo = jax.lax.dynamic_slice_in_dim(enc.lo, shard_index * nblk, nblk, 0)
        step = jax.lax.dynamic_slice_in_dim(enc.step, shard_index * nblk,
                                            nblk, 0)
        return lo, step

    def encode(self, x, ctx, axis):
        cfg = ctx.cfg
        block = cfg.hadamard_block
        bits = self._bits(cfg)
        levels = (1 << bits) - 1
        if cfg.use_kernels:
            amax = ht_encode_amax(x, ctx.key, block=block, use_kernel=True)
            xb = None                     # rotated bucket never materialized
        else:
            x = ht_encode(x, ctx.key, block=block, use_kernel=False)
            xb = x.reshape(-1, block)
            amax = jnp.max(jnp.abs(xb), axis=1)
        amax = jax.lax.pmax(amax, axis)
        for extra in ctx.data_axes():     # grids shared by the full DP group
            if extra != axis:
                amax = jax.lax.pmax(amax, extra)
        amax = jnp.maximum(amax, 1e-12)
        step = 2.0 * amax / levels                      # (nblocks,)
        lo = -amax
        noise = jax.random.uniform(
            jax.random.fold_in(ctx.key, self.noise_salt),
            (x.shape[0] // block, block))
        if cfg.use_kernels:
            codes = ht_encode_quant(x, ctx.key, noise, lo, step, block=block,
                                    bits=bits, use_kernel=True).reshape(-1)
        else:
            q = jnp.floor((xb - lo[:, None]) / step[:, None] + noise)
            codes = jnp.clip(q, 0, levels).astype(jnp.uint8).reshape(-1)
        return Encoded(codes, lo=lo, step=step)

    def reduce(self, received, mask, shard_index, enc, ctx):
        cfg = ctx.cfg
        block = cfg.hadamard_block
        n, s = received.shape
        nblk = s // block
        my_lo, my_step = self._grids(enc, shard_index, nblk)
        if cfg.use_kernels:
            return dequant_masked_mean(received, my_lo, my_step, mask,
                                       block=block, use_kernel=True)
        vals = (received.reshape(n, nblk, block).astype(jnp.float32)
                * my_step[None, :, None] + my_lo[None, :, None]
                ).reshape(n, s)
        return tar_lib.masked_mean(vals, mask, cfg.use_kernels)

    def encode_shard(self, own, shard_index, enc, ctx):
        cfg = ctx.cfg
        block = cfg.hadamard_block
        nblk = own.shape[0] // block
        my_lo, my_step = self._grids(enc, shard_index, nblk)
        noise = jax.random.uniform(
            jax.random.fold_in(ctx.key, self.stage2_salt), (nblk, block))
        codes = grid_quant(own.reshape(nblk, block), noise, my_lo, my_step,
                           bits=self._bits(cfg), use_kernel=cfg.use_kernels)
        return codes.reshape(-1)

    def decode_gathered(self, gathered, enc, ctx):
        cfg = ctx.cfg
        block = cfg.hadamard_block
        out = (gathered.reshape(-1, block).astype(jnp.float32)
               * enc.step[:, None] + enc.lo[:, None]).reshape(-1)
        return ht_decode(out, ctx.key, block=block,
                         use_kernel=cfg.use_kernels)

    def decode_values(self, vals, enc, ctx):
        return ht_decode(vals, ctx.key, block=ctx.cfg.hadamard_block,
                         use_kernel=ctx.cfg.use_kernels)


# --------------------------------------------------------------- transports
class Reliable:
    """Everything arrives (TCP-class transports): no mask, no loss stats."""

    def arrival_mask(self, ctx: SyncContext, n: int, s: int,
                     axis: str) -> jnp.ndarray | None:
        return None

    def incast(self, ctx: SyncContext) -> int:
        return ctx.cfg.incast


class Lossy(Reliable):
    """UBT best-effort delivery: the drop-mask model (core/drops.py) decides
    per-receiver arrivals and the loss stats feed ``ctx.loss_fraction``."""

    def arrival_mask(self, ctx, n, s, axis):
        mask = _mask_for(ctx, n, s, axis)
        if mask is not None:
            ctx.stats["dropped"] = ctx.stats.get("dropped", 0.0) + \
                jnp.sum(1.0 - mask)
            ctx.stats["total"] = ctx.stats.get("total", 0.0) + mask.size
        return mask


class AdaptiveTransport(Lossy):
    """§3.2 control plane in the sync loop: a :class:`Lossy` transport whose
    next-step recommendations come from the UBT controllers.

    The controllers are host state (an XLA fabric cannot drop or time out;
    see core/ubt.py), so the loop is: run a step, call
    ``observe(loss_frac, stage_time=...)`` with the observed loss fraction,
    and when it returns True (recommendation changed) rebuild the step with
    ``apply(cfg)`` — Hadamard toggles on above the §3.2.1 2% threshold and
    ``DynamicIncast`` advertises the incast a rounds-scheduled topology
    should use next.  ``launch/train.py --adaptive`` wires this in.
    """

    def __init__(self, state: UbtState, use_hadamard: bool = False):
        self.state = state
        self.use_hadamard = use_hadamard

    @classmethod
    def create(cls, n_nodes: int, **kw) -> "AdaptiveTransport":
        return cls(state=UbtState.create(n_nodes=n_nodes, **kw))

    def incast(self, ctx: SyncContext | None = None) -> int:
        return max(1, self.state.incast.value)   # n_nodes=1 advertises I=0

    def observe(self, loss_frac: float, *, stage_time: float | None = None,
                timed_out: bool = False) -> bool:
        """Feed one step's observations; True if the recommendation moved."""
        before = (self.use_hadamard, self.state.incast.value)
        if stage_time is not None and not self.state.timeout.ready:
            self.state.timeout.observe_warmup(stage_time)
        self.state.incast.update(loss_frac=loss_frac, timed_out=timed_out)
        at = self.state.timeout
        if at.hadamard_active(loss_frac):
            self.use_hadamard = True
        elif loss_frac < at.ht_threshold / 2.0:
            # hysteresis band [thr/2, thr): loss hovering at the threshold
            # must not flap the codec (each flip retraces the step)
            self.use_hadamard = False
        return (self.use_hadamard, self.state.incast.value) != before

    def apply(self, cfg: OptiReduceConfig) -> OptiReduceConfig:
        """Fold the current recommendation into a sync config."""
        return dataclasses.replace(cfg, use_hadamard=self.use_hadamard,
                                   incast=self.incast())


# --------------------------------------------------------------- topologies
class Topology:
    """Exchange-schedule protocol: owns padding, the collectives, and the
    placement of the codec/transport hooks between them.

    Execution is split into three stage callables so schedules can be
    software-pipelined across buckets (``sync_pytree(mode="pipelined")``):

      ``encode_stage``    bucket -> wire state (pad + codec encode; the
                          Pallas-kernel-heavy producer side, no collectives)
      ``exchange_stage``  wire state -> gathered state (every collective of
                          the schedule, plus the small per-shard reduce
                          between TAR's two stages)
      ``decode_stage``    gathered state -> flat synced bucket (codec decode
                          + the unpad slice; kernel-heavy consumer side)

    Stage state is a flat tuple of arrays-or-None with a bucket-independent
    structure, so it can ride in a ``lax.scan`` carry.  ``all_reduce`` is
    exactly the three stages composed — every mode (scan / vmap / pipelined /
    the unfused oracle) runs the identical per-bucket computation, which is
    what keeps them bitwise-interchangeable.
    """

    def validate(self, transport: Reliable, codec: Codec) -> None:
        pass

    def encode_stage(self, bucket: jnp.ndarray, transport: Reliable,
                     codec: Codec, ctx: SyncContext) -> tuple:
        raise NotImplementedError

    def exchange_stage(self, state: tuple, transport: Reliable,
                       codec: Codec, ctx: SyncContext) -> tuple:
        raise NotImplementedError

    def decode_stage(self, state: tuple, length: int, transport: Reliable,
                     codec: Codec, ctx: SyncContext) -> jnp.ndarray:
        raise NotImplementedError

    def all_reduce(self, bucket: jnp.ndarray, transport: Reliable,
                   codec: Codec, ctx: SyncContext) -> jnp.ndarray:
        state = self.encode_stage(bucket, transport, codec, ctx)
        state = self.exchange_stage(state, transport, codec, ctx)
        return self.decode_stage(state, bucket.shape[-1], transport, codec,
                                 ctx)

    def reduce_scatter(self, g, axis, dim, transport, codec, ctx):
        raise NotImplementedError(
            f"{type(self).__name__} has no reduce_scatter form")


class PsumTopology(Topology):
    """XLA's native all-reduce (what a stock JAX program does)."""

    def validate(self, transport, codec):
        if not isinstance(codec, Identity) or isinstance(transport, Lossy):
            raise ValueError("psum is XLA-native: it bypasses the codec and "
                             "cannot model drops (use a TAR topology)")

    def encode_stage(self, bucket, transport, codec, ctx):
        return (bucket,)

    def exchange_stage(self, state, transport, codec, ctx):
        (bucket,) = state
        return (jax.lax.pmean(bucket, ctx.data_axes()),)

    def decode_stage(self, state, length, transport, codec, ctx):
        return state[0]


@dataclasses.dataclass(frozen=True)
class RingTopology(Topology):
    """Baseline schedules that reduce internally: Gloo Ring, recursive
    halving-doubling ("NCCL Tree"), Gloo BCube.  Compose with any *linear*
    codec (decode commutes with the internal averaging) and a reliable
    transport; a ``pod`` axis is folded in with a pmean."""
    kind: str = "ring"                   # ring | tree | bcube

    def __post_init__(self):
        if self.kind not in ("ring", "tree", "bcube"):
            raise ValueError(f"unknown ring topology kind {self.kind!r}")

    def validate(self, transport, codec):
        if isinstance(transport, Lossy):
            raise ValueError(
                f"{self.kind} reduces in-flight partial sums; the UBT drop "
                "model needs TAR's receive structure (Lossy -> TarTopology)")
        if not codec.linear:
            raise ValueError(
                f"codec {type(codec).__name__} does not commute with "
                f"{self.kind}'s internal reduction")

    def encode_stage(self, bucket, transport, codec, ctx):
        cfg = ctx.cfg
        n = compat.axis_size(cfg.data_axis)
        x, _ = tar_lib.pad_for_tar(bucket, n, codec.block(cfg))
        enc = codec.encode(x, ctx, cfg.data_axis)
        return (enc.data, enc.lo, enc.step)

    def exchange_stage(self, state, transport, codec, ctx):
        data, lo, step = state
        cfg = ctx.cfg
        n = compat.axis_size(cfg.data_axis)
        if self.kind == "ring":
            out = ring_lib.ring_allreduce(data, cfg.data_axis)
        elif self.kind == "tree":
            out = ring_lib.tree_allreduce(data, cfg.data_axis)
        else:
            base = 4 if n % 4 == 0 else 2
            out = ring_lib.bcube_allreduce(data, cfg.data_axis, base=base)
        if cfg.pod_axis is not None:
            out = jax.lax.pmean(out, cfg.pod_axis)
        return (out, lo, step)

    def decode_stage(self, state, length, transport, codec, ctx):
        data, lo, step = state
        # the stage-1 encode output is gone by now — only the grids survive
        # the exchange, so the Encoded carries data=None rather than lying
        out = codec.decode_values(data, Encoded(None, lo=lo, step=step), ctx)
        return out[:length]


@dataclasses.dataclass(frozen=True)
class TarTopology(Topology):
    """Transpose AllReduce (§3.1): stage-1 shard exchange → codec reduce →
    stage-2 broadcast, with the codec/transport hooks between the stages.

    ``schedule``: ``'a2a'`` lowers the stages as tiled all_to_all/all_gather
    (the production path); ``'rounds'`` lowers the paper's explicit
    2*ceil((N-1)/I) ppermute round schedule, taking I from the transport
    (so :class:`AdaptiveTransport` drives it).
    ``outer``: how a configured ``pod`` axis joins — ``'tar'`` nests a TAR
    over the pods between the stages (§3.1.2 hierarchical 2D), ``'pmean'``
    folds them with a plain pmean (what a quantizing codec needs: values,
    not codes, cross the pod boundary).
    """
    schedule: str = "a2a"                # a2a | rounds
    outer: str = "tar"                   # tar | pmean

    def __post_init__(self):
        if self.schedule not in ("a2a", "rounds"):
            raise ValueError(f"unknown TAR schedule {self.schedule!r}")
        if self.outer not in ("tar", "pmean"):
            raise ValueError(f"unknown TAR outer mode {self.outer!r}")

    def _outer_reduce(self, own, codec, ctx):
        cfg = ctx.cfg
        g = compat.axis_size(cfg.pod_axis)
        if g <= 1:
            return own
        if self.outer == "tar" and own.shape[0] % g == 0:
            return tar_lib.tar_allreduce(own, cfg.pod_axis,
                                         use_kernel=cfg.use_kernels)
        return jax.lax.pmean(own, cfg.pod_axis)

    def encode_stage(self, bucket, transport, codec, ctx):
        cfg = ctx.cfg
        n = compat.axis_size(cfg.data_axis)
        x, _ = tar_lib.pad_for_tar(bucket, n, codec.block(cfg))
        enc = codec.encode(x, ctx, cfg.data_axis)
        return (enc.data, enc.lo, enc.step)

    def exchange_stage(self, state, transport, codec, ctx):
        data, lo, step = state
        cfg = ctx.cfg
        axis = cfg.data_axis
        n = compat.axis_size(axis)
        enc = Encoded(data, lo=lo, step=step)
        s = data.shape[0] // n
        shards = data.reshape(n, s)
        if self.schedule == "rounds":
            received = tar_lib.tar_exchange_rounds(
                shards, axis, incast=transport.incast(ctx))
        else:
            received = jax.lax.all_to_all(shards, axis, split_axis=0,
                                          concat_axis=0, tiled=True)
        mask = transport.arrival_mask(ctx, n, s, axis)
        i = jax.lax.axis_index(axis)
        own = codec.reduce(received, mask, i, enc, ctx)
        if cfg.pod_axis is not None:
            own = self._outer_reduce(own, codec, ctx)
        wire = codec.encode_shard(own, i, enc, ctx)
        if self.schedule == "rounds":
            gathered = tar_lib.tar_broadcast_rounds(
                wire, axis, incast=transport.incast(ctx))
        else:
            gathered = jax.lax.all_gather(wire, axis, axis=0, tiled=True)
        return (gathered, lo, step)

    def decode_stage(self, state, length, transport, codec, ctx):
        data, lo, step = state
        # only the quantization grids survive the exchange; data=None marks
        # the stage-1 encode output as unavailable at decode time
        out = codec.decode_gathered(data, Encoded(None, lo=lo, step=step),
                                    ctx)
        return out[:length]

    def reduce_scatter(self, g, axis, dim, transport, codec, ctx):
        """TAR stage 1 + compensated reduce on an arbitrary tensor,
        scattering ``dim`` over ``axis`` — the FSDP/ZeRO grad reduction;
        the all_gather at next use is the deferred stage 2."""
        cfg = ctx.cfg
        n = compat.axis_size(axis)
        g2 = jnp.moveaxis(g, dim, 0)
        lead = g2.shape[0]
        rest = g2.shape[1:]
        assert lead % n == 0, (lead, n)
        # keep the wire dtype (bf16 grads stay bf16): halves collective
        # bytes and the per-layer transients; reductions accumulate in fp32
        rows = g2.reshape(n, -1)                       # row j -> shard j
        row_len = rows.shape[1]
        pad = (-row_len) % codec.block(cfg)
        if pad:
            rows = jnp.pad(rows, ((0, 0), (0, pad)))
        enc = codec.encode(rows.reshape(-1), ctx, axis)
        shards = enc.data.reshape(n, -1)
        received = jax.lax.all_to_all(shards, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
        mask = transport.arrival_mask(ctx, n, received.shape[1], axis)
        i = jax.lax.axis_index(axis)
        own = codec.reduce(received, mask, i, enc, ctx)
        own = codec.decode_values(own, enc, ctx)
        if pad:
            own = own[:row_len]
        out = own.reshape((lead // n,) + rest)
        return jnp.moveaxis(out, 0, dim)


# ------------------------------------------------------------ spec + registry
@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """One gradient-sync strategy = Topology × Transport × Codec."""
    topology: Topology
    transport: Reliable
    codec: Codec

    def __post_init__(self):
        self.topology.validate(self.transport, self.codec)

    def all_reduce(self, bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
        """Reduce one flat bucket to its (approximate) DP mean."""
        return self.topology.all_reduce(bucket, self.transport, self.codec,
                                        ctx)

    # stage callables for software-pipelined schedules (sync engine's
    # mode="pipelined"): encode -> exchange -> decode composes to all_reduce
    def encode_stage(self, bucket: jnp.ndarray, ctx: SyncContext) -> tuple:
        return self.topology.encode_stage(bucket, self.transport, self.codec,
                                          ctx)

    def exchange_stage(self, state: tuple, ctx: SyncContext) -> tuple:
        return self.topology.exchange_stage(state, self.transport,
                                            self.codec, ctx)

    def decode_stage(self, state: tuple, length: int,
                     ctx: SyncContext) -> jnp.ndarray:
        return self.topology.decode_stage(state, length, self.transport,
                                          self.codec, ctx)

    def reduce_scatter(self, g: jnp.ndarray, axis: str, dim: int,
                       ctx: SyncContext) -> jnp.ndarray:
        """Scatter ``dim`` over ``axis``, returning this node's reduced
        shard (the deferred-stage-2 / FSDP form)."""
        return self.topology.reduce_scatter(g, axis, dim, self.transport,
                                            self.codec, ctx)


_REGISTRY: dict[str, Callable[[OptiReduceConfig], CollectiveSpec]] = {}


def register_strategy(name: str, spec: CollectiveSpec | None = None):
    """Register a named strategy: either a spec instance
    (``register_strategy("x", spec)``) or, as a decorator, a factory
    ``cfg -> CollectiveSpec`` for cfg-dependent composition."""
    if spec is not None:
        _REGISTRY[name] = lambda cfg: spec
        return spec

    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def strategy_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_spec(cfg: OptiReduceConfig) -> CollectiveSpec:
    try:
        factory = _REGISTRY[cfg.strategy]
    except KeyError:
        raise ValueError(f"unknown strategy {cfg.strategy!r}; "
                         f"one of {strategy_names()}") from None
    return factory(cfg)


# ------------------------------------------------- the named strategy table
register_strategy("psum",
                  CollectiveSpec(PsumTopology(), Reliable(), Identity()))
register_strategy("gloo_ring",
                  CollectiveSpec(RingTopology("ring"), Reliable(), Identity()))
register_strategy("nccl_tree",
                  CollectiveSpec(RingTopology("tree"), Reliable(), Identity()))
register_strategy("bcube",
                  CollectiveSpec(RingTopology("bcube"), Reliable(),
                                 Identity()))
register_strategy("tar_tcp",
                  CollectiveSpec(TarTopology(), Reliable(), Identity()))
register_strategy("tar_rounds",
                  CollectiveSpec(TarTopology(schedule="rounds", outer="pmean"),
                                 Reliable(), Identity()))


@register_strategy("optireduce")
@register_strategy("optireduce_2d")   # pod_axis in cfg drives the 2D path
def _optireduce_spec(cfg: OptiReduceConfig) -> CollectiveSpec:
    return CollectiveSpec(TarTopology(), Lossy(),
                          Hadamard() if cfg.use_hadamard else Identity())


register_strategy("optireduce_q",     # quantized exchange (beyond-paper)
                  CollectiveSpec(TarTopology(outer="pmean"), Lossy(),
                                 HTQuant()))

# new cross-product compositions the layering opens (one-liners):
register_strategy("optireduce_rounds",   # paper round schedule + drops + HT
                  CollectiveSpec(TarTopology(schedule="rounds", outer="pmean"),
                                 Lossy(), Hadamard()))
register_strategy("tar_rounds_q",        # round schedule + THC quantization
                  CollectiveSpec(TarTopology(schedule="rounds", outer="pmean"),
                                 Lossy(), HTQuant()))
register_strategy("ring_ht",             # Gloo ring over rotated buckets
                  CollectiveSpec(RingTopology("ring"), Reliable(), Hadamard()))
