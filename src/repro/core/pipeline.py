"""Composable collective pipeline: Topology × Transport × Codec (DESIGN §3).

OptiReduce is inherently layered — a transpose topology (TAR, §3.1), an
unreliable bounded transport (UBT, §3.2), and accuracy-preserving codecs
(randomized Hadamard §3.3, THC-style quantization) — and each axis varies
independently of the others (StragglAR swaps only the schedule; loss-bound
policies swap only the transport).  This module makes every gradient-sync
strategy a :class:`CollectiveSpec` composing three orthogonal protocols:

  Topology  — who exchanges with whom and in what schedule:
              :class:`PsumTopology` (XLA native), :class:`RingTopology`
              (ring / recursive-halving tree / bcube), :class:`TarTopology`
              (all_to_all or the paper's explicit round schedule;
              hierarchical 2D over a ``pod`` axis).
  Transport — what arrives: :class:`Reliable` (everything),
              :class:`Lossy` (the UBT drop-mask model + loss stats), and
              :class:`AdaptiveTransport` (the §3.2 controllers in the loop:
              observed loss feeds ``AdaptiveTimeout.hadamard_active`` and
              ``DynamicIncast`` to pick next-step codec/incast).
  Codec     — what goes on the wire: :class:`Identity`, :class:`Hadamard`
              (blockwise randomized HT), :class:`HTQuant` (shared-grid
              uniform stochastic quantization of the rotated blocks — the
              single implementation both the bucketed strategies and the
              FSDP ``reduce_scatter`` use, kernel-dispatched under
              ``cfg.use_kernels``).

A strategy *name* resolves through a registry of named specs; new
compositions are one-liners::

    register_strategy("ring_ht",
                      CollectiveSpec(RingTopology("ring"), Reliable(),
                                     Hadamard()))

or, for cfg-dependent composition, a decorated factory::

    @register_strategy("my_strategy")
    def _spec(cfg):
        return CollectiveSpec(TarTopology(), Lossy(),
                              Hadamard() if cfg.use_hadamard else Identity())

``core.allreduce`` keeps the stable entrypoints (``sync_bucket``,
``sync_pytree``, ``reduce_scatter_axis``) as thin wrappers that resolve to
specs; every pre-existing strategy name is bitwise-identical to the seed
monolithic implementations (the ``parity`` pytest suite pins this against
the ``sync_pytree_unfused`` oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat

from . import drops as drops_lib
from . import ring as ring_lib
from . import tar as tar_lib
from .hadamard import ht_decode, ht_encode, ht_encode_amax, ht_encode_quant
from .ubt import UbtState
from repro.kernels.dequant_reduce import dequant_masked_mean
from repro.kernels.quant import grid_quant


# ------------------------------------------------------------- configuration
@dataclasses.dataclass(frozen=True)
class OptiReduceConfig:
    """Static (hashable) configuration for gradient sync."""
    strategy: str = "optireduce"
    data_axis: str = "data"
    pod_axis: str | None = None          # set for multi-pod meshes
    # UBT drop model (stand-in for timeouts/loss on a lossy fabric)
    drop_rate: float = 0.0
    drop_pattern: str = "tail"           # bernoulli | tail | straggler | burst
    packet_elems: int = 256
    # Hadamard transform
    use_hadamard: bool = True
    hadamard_block: int = 4096
    # kernels: use Pallas (TPU) or the jnp MXU-form (identical math)
    use_kernels: bool = False
    # safeguards
    skip_threshold: float = 0.10
    # round-form incast (rounds-scheduled topologies only)
    incast: int = 1
    # quantized TAR exchange (optireduce_q): THC-style shared-grid uniform
    # stochastic quantization of the HT-rotated shards — beyond-paper
    # optimization (the paper notes THC is orthogonal); cuts the wire bytes
    # of both TAR stages by 32/quant_bits
    quant_bits: int = 8
    # quantize the FSDP gradient reduce-scatter wire to this many bits
    # (0 = native dtype). Per-Hadamard-block grids, pmax-shared; §Perf H2.
    rs_wire_bits: int = 0
    # degraded participation (DESIGN §5): the SyncPolicy's active-peer set
    # on the data axis — None (or the full set) means everyone contributes.
    # A proper subset excludes the named-out peers' gradient contributions
    # (compensated by the masked-mean machinery) and, on round-scheduled
    # topologies, regenerates the round schedule over the active peers'
    # virtual ring.  Ejected peers still receive the reduced result (they
    # keep training, so probationary readmission is a pure policy flip).
    active_peers: tuple[int, ...] | None = None
    # straggler-proportional shard rebalancing (DESIGN §10): positive shard
    # units per *active* peer (aligned with the sorted active set; None =
    # uniform).  A slow-but-alive peer owns a smaller contiguous slice of
    # the bucket and fast peers absorb the remainder; a uniform tuple
    # normalizes to None so the full-participation trace stays bitwise
    # identical.  Rounds-scheduled TAR and kind='ring' topologies only.
    shard_weights: tuple[int, ...] | None = None
    # link-fault rewiring (DESIGN §10): directed (src, dst) edges declared
    # dead by the control plane's link-health tracker.  Round schedules
    # relay the affected pair through a live intermediate; the ring
    # topology reorders its virtual ring to avoid the edge — neither
    # endpoint is ejected.
    dead_links: tuple[tuple[int, int], ...] = ()
    # loss recovery beyond zero-fill (DESIGN §8, core/recovery.py):
    # none | stale (cross-step stale-value fill) | ef (stale + error-feedback
    # residual carry) | ef+budget (+ the phase-aware LossBudget controller).
    # "none" resolves to the exact seed spec — zero extra ops, bitwise
    # parity pinned by the parity suites.
    recovery: str = "none"


@dataclasses.dataclass
class SyncContext:
    """Per-step dynamic context threaded into the pipeline."""
    cfg: OptiReduceConfig
    key: jax.Array                        # replicated per-step PRNG key
    stats: dict = dataclasses.field(default_factory=dict)
    # previous step's decoded bucket (value space), set by the sync engine
    # when cross-step stale-fill recovery is armed; None otherwise
    stale: jnp.ndarray | None = None

    def data_axes(self) -> tuple[str, ...]:
        if self.cfg.pod_axis is not None:
            return (self.cfg.pod_axis, self.cfg.data_axis)
        return (self.cfg.data_axis,)

    def loss_fraction(self) -> jnp.ndarray:
        """Observed entry-loss fraction this step, pmean'd across receivers
        (what the §3.4 safeguards and the UBT controller monitor)."""
        if "total" not in self.stats:
            return jnp.zeros(())
        frac = self.stats["dropped"] / jnp.maximum(self.stats["total"], 1.0)
        return jax.lax.pmean(frac, self.data_axes())


def active_subset(cfg: OptiReduceConfig, n: int) -> tuple[int, ...] | None:
    """Normalized degraded-participation set for an ``n``-peer axis.

    Returns the sorted proper-subset tuple, or None when everyone
    participates — the full set normalizes to None so a policy naming all
    peers stays on the exact full-participation trace (what pins the
    bitwise-parity acceptance criterion).
    """
    ap = cfg.active_peers
    if ap is None:
        return None
    ap = tuple(sorted({int(p) for p in ap}))
    if not ap:
        raise ValueError("active_peers must name at least one peer")
    if ap[0] < 0 or ap[-1] >= n:
        raise ValueError(f"active_peers {ap} outside the {n}-peer axis")
    return None if len(ap) == n else ap


def weights_subset(cfg: OptiReduceConfig,
                   n_active: int) -> tuple[int, ...] | None:
    """Normalized shard-weight tuple for an ``n_active``-peer schedule.

    Returns the per-active-peer positive integer units, or None when the
    weights are uniform — a uniform tuple normalizes away so a policy
    assigning everyone equal units stays on the exact uniform-shard trace
    (the same discipline as :func:`active_subset`).
    """
    w = cfg.shard_weights
    if w is None:
        return None
    w = tuple(int(u) for u in w)
    if len(w) != n_active:
        raise ValueError(f"shard_weights {w} do not match the "
                         f"{n_active}-peer active set")
    if any(u < 1 for u in w):
        raise ValueError(f"shard_weights must be positive integers, got {w}")
    return None if all(u == w[0] for u in w) else w


def dead_link_set(cfg: OptiReduceConfig,
                  n: int) -> tuple[tuple[int, int], ...]:
    """Normalized (sorted, deduplicated) dead directed edges."""
    dl = cfg.dead_links or ()
    out = tuple(sorted({(int(s), int(d)) for (s, d) in dl}))
    for (s, d) in out:
        if not (0 <= s < n and 0 <= d < n) or s == d:
            raise ValueError(f"dead link {(s, d)} outside the {n}-peer axis")
    return out


def _mask_for(ctx: SyncContext, n: int, s: int, axis: str,
              self_index: jnp.ndarray | None = None) -> jnp.ndarray | None:
    """Receiver-specific (N, S) arrival mask for TAR stage 1.

    ``self_index`` overrides the row that is never dropped (a degraded
    round schedule indexes rows by virtual ring position, not peer id);
    the PRNG stream stays keyed on the absolute receiver id either way.
    """
    cfg = ctx.cfg
    if cfg.drop_rate <= 0.0:
        return None
    me = jax.lax.axis_index(axis)
    key = jax.random.fold_in(ctx.key, me)
    return drops_lib.make_mask(cfg.drop_pattern, key, n, s,
                               rate=cfg.drop_rate,
                               packet_elems=cfg.packet_elems,
                               self_index=me if self_index is None
                               else self_index)


# ------------------------------------------------------------------- codecs
@dataclasses.dataclass
class Encoded:
    """A codec's wire representation of one flat bucket.

    ``data`` is what travels (fp values or uint8 codes, flat); ``lo`` /
    ``step`` are the per-Hadamard-block quantization grids (pmax-shared
    across the whole DP group) a quantizing codec needs on the receive side.
    ``stale`` is the previous step's bucket re-encoded under this step's
    key — the cross-step prediction a StaleFill recovery codec substitutes
    for zero-arrival wire spans (None whenever recovery is off).
    """
    data: jnp.ndarray
    lo: jnp.ndarray | None = None
    step: jnp.ndarray | None = None
    stale: jnp.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class Codec:
    """Identity codec — also the base class defining the codec protocol.

    Hooks, in pipeline order:
      ``encode``          full-bucket encode before the stage-1 exchange
      ``reduce``          decode + drop-compensated mean of the (N, S)
                          received matrix for this node's shard
      ``encode_shard``    re-encode the aggregated shard for stage 2
      ``decode_gathered`` full-bucket decode after the stage-2 broadcast
      ``decode_values``   value-domain decode of one shard (the deferred-
                          stage-2 ``reduce_scatter`` path)
    ``linear`` marks codecs whose decode commutes with averaging (so they
    compose with topologies that reduce internally, e.g. ring).
    """
    linear: bool = dataclasses.field(default=True, init=False)

    def block(self, cfg: OptiReduceConfig) -> int:
        return 1

    def encode(self, x: jnp.ndarray, ctx: SyncContext, axis: str) -> Encoded:
        return Encoded(x)

    def reduce(self, received: jnp.ndarray, mask: jnp.ndarray | None,
               shard_index: jnp.ndarray, enc: Encoded,
               ctx: SyncContext) -> jnp.ndarray:
        return tar_lib.masked_mean(received, mask, ctx.cfg.use_kernels)

    def encode_shard(self, own: jnp.ndarray, shard_index: jnp.ndarray,
                     enc: Encoded, ctx: SyncContext) -> jnp.ndarray:
        return own

    def decode_gathered(self, gathered: jnp.ndarray, enc: Encoded,
                        ctx: SyncContext) -> jnp.ndarray:
        return gathered

    def decode_values(self, vals: jnp.ndarray, enc: Encoded,
                      ctx: SyncContext) -> jnp.ndarray:
        return vals


class Identity(Codec):
    """Raw wire bytes: no rotation, no compression."""


class Hadamard(Codec):
    """Blockwise randomized Hadamard transform (§3.3): linear, so drops
    spread across the block and the decoded mean stays unbiased."""

    def block(self, cfg: OptiReduceConfig) -> int:
        return cfg.hadamard_block

    def encode(self, x, ctx, axis):
        return Encoded(ht_encode(x, ctx.key, block=ctx.cfg.hadamard_block,
                                 use_kernel=ctx.cfg.use_kernels))

    def decode_gathered(self, gathered, enc, ctx):
        return ht_decode(gathered, ctx.key, block=ctx.cfg.hadamard_block,
                         use_kernel=ctx.cfg.use_kernels)

    def decode_values(self, vals, enc, ctx):
        return ht_decode(vals, ctx.key, block=ctx.cfg.hadamard_block,
                         use_kernel=ctx.cfg.use_kernels)


@dataclasses.dataclass(frozen=True)
class HTQuant(Codec):
    """Hadamard rotation + THC-style shared-grid uniform stochastic
    quantization (beyond-paper §Perf).

    Per-block [−amax_b, amax_b] grids are pmax'd across the *whole* DP group
    (the exchange axis plus every other configured data axis), so all nodes
    derive identical grids locally (no scale exchange) and the codes are
    homomorphic — the THC property, made cheap by the rotation (rotated
    blocks are near-Gaussian with comparable scales).

    Under ``cfg.use_kernels`` all three quantization stages run fused
    kernels: rotate+amax (grids), sign+FWHT+quantize (stage-1 codes — the
    rotated fp32 bucket never hits HBM), dequant+compensated-mean (receive),
    and the stage-2 re-quantization of the aggregated shard dispatches to
    the grid-quantize kernel.  The jnp path is the bit-parity oracle.

    ``bits=None`` reads ``cfg.quant_bits``; ``reduce_scatter_axis`` passes
    ``bits=cfg.rs_wire_bits`` and its own ``noise_salt``.  Not ``linear``:
    decode does not commute with topologies that reduce internally.
    """
    bits: int | None = None
    noise_salt: int = 3        # stage-1 stochastic-rounding noise fold_in
    stage2_salt: int = 4       # stage-2 (broadcast) noise fold_in
    linear: bool = dataclasses.field(default=False, init=False)

    def _bits(self, cfg: OptiReduceConfig) -> int:
        return cfg.quant_bits if self.bits is None else self.bits

    def block(self, cfg: OptiReduceConfig) -> int:
        return cfg.hadamard_block

    def _grids(self, enc: Encoded, shard_index, nblk: int):
        lo = jax.lax.dynamic_slice_in_dim(enc.lo, shard_index * nblk, nblk, 0)
        step = jax.lax.dynamic_slice_in_dim(enc.step, shard_index * nblk,
                                            nblk, 0)
        return lo, step

    def local_amax(self, x, ctx):
        """Pre-``pmax`` half of :meth:`encode`: this peer's per-block amax.

        Returns ``(x1, amax)`` where ``x1`` is whatever the second half
        needs (the rotated bucket on the jnp path; the un-rotated bucket on
        the kernel path, which re-rotates in VMEM).  The host wire datapath
        calls this, max-shares ``amax`` over the wire (an elementwise max
        is order-free, so the shared grids are bitwise identical to the
        fabric ``pmax``), then :meth:`encode_given_amax`.
        """
        cfg = ctx.cfg
        block = cfg.hadamard_block
        if cfg.use_kernels:
            amax = ht_encode_amax(x, ctx.key, block=block, use_kernel=True)
            return x, amax                # rotated bucket never materialized
        x = ht_encode(x, ctx.key, block=block, use_kernel=False)
        return x, jnp.max(jnp.abs(x.reshape(-1, block)), axis=1)

    def encode_given_amax(self, x1, amax, ctx) -> Encoded:
        """Post-``pmax`` half of :meth:`encode`: quantize onto the grids
        derived from the group-shared ``amax``."""
        cfg = ctx.cfg
        block = cfg.hadamard_block
        bits = self._bits(cfg)
        levels = (1 << bits) - 1
        amax = jnp.maximum(amax, 1e-12)
        step = 2.0 * amax / levels                      # (nblocks,)
        lo = -amax
        noise = jax.random.uniform(
            jax.random.fold_in(ctx.key, self.noise_salt),
            (x1.shape[0] // block, block))
        if cfg.use_kernels:
            codes = ht_encode_quant(x1, ctx.key, noise, lo, step, block=block,
                                    bits=bits, use_kernel=True).reshape(-1)
        else:
            xb = x1.reshape(-1, block)
            q = jnp.floor((xb - lo[:, None]) / step[:, None] + noise)
            codes = jnp.clip(q, 0, levels).astype(jnp.uint8).reshape(-1)
        return Encoded(codes, lo=lo, step=step)

    def encode(self, x, ctx, axis):
        x1, amax = self.local_amax(x, ctx)
        amax = jax.lax.pmax(amax, axis)
        for extra in ctx.data_axes():     # grids shared by the full DP group
            if extra != axis:
                amax = jax.lax.pmax(amax, extra)
        return self.encode_given_amax(x1, amax, ctx)

    def reduce(self, received, mask, shard_index, enc, ctx):
        cfg = ctx.cfg
        block = cfg.hadamard_block
        n, s = received.shape
        nblk = s // block
        my_lo, my_step = self._grids(enc, shard_index, nblk)
        if cfg.use_kernels:
            return dequant_masked_mean(received, my_lo, my_step, mask,
                                       block=block, use_kernel=True)
        vals = (received.reshape(n, nblk, block).astype(jnp.float32)
                * my_step[None, :, None] + my_lo[None, :, None]
                ).reshape(n, s)
        return tar_lib.masked_mean(vals, mask, cfg.use_kernels)

    def encode_shard(self, own, shard_index, enc, ctx):
        cfg = ctx.cfg
        block = cfg.hadamard_block
        nblk = own.shape[0] // block
        my_lo, my_step = self._grids(enc, shard_index, nblk)
        noise = jax.random.uniform(
            jax.random.fold_in(ctx.key, self.stage2_salt), (nblk, block))
        codes = grid_quant(own.reshape(nblk, block), noise, my_lo, my_step,
                           bits=self._bits(cfg), use_kernel=cfg.use_kernels)
        return codes.reshape(-1)

    def decode_gathered(self, gathered, enc, ctx):
        cfg = ctx.cfg
        block = cfg.hadamard_block
        out = (gathered.reshape(-1, block).astype(jnp.float32)
               * enc.step[:, None] + enc.lo[:, None]).reshape(-1)
        return ht_decode(out, ctx.key, block=block,
                         use_kernel=cfg.use_kernels)

    def decode_values(self, vals, enc, ctx):
        return ht_decode(vals, ctx.key, block=ctx.cfg.hadamard_block,
                         use_kernel=ctx.cfg.use_kernels)


# --------------------------------------------------------------- transports
class Reliable:
    """Everything arrives (TCP-class transports): no mask, no loss stats.

    ``payload``, when the topology can offer it, is the stage-1 wire state
    (the (n_shards, S) shard matrix about to be exchanged) — the synthetic
    transports ignore it; :class:`WireTransport` really sends those bytes
    over a host wire backend and masks by what arrived.
    """

    def arrival_mask(self, ctx: SyncContext, n: int, s: int, axis: str,
                     self_index: jnp.ndarray | None = None,
                     payload: jnp.ndarray | None = None
                     ) -> jnp.ndarray | None:
        return None

    def incast(self, ctx: SyncContext) -> int:
        return ctx.cfg.incast


class Lossy(Reliable):
    """UBT best-effort delivery: the drop-mask model (core/drops.py) decides
    per-receiver arrivals and the loss stats feed ``ctx.loss_fraction``."""

    def arrival_mask(self, ctx, n, s, axis, self_index=None, payload=None):
        mask = _mask_for(ctx, n, s, axis, self_index=self_index)
        if mask is not None:
            ctx.stats["dropped"] = ctx.stats.get("dropped", 0.0) + \
                jnp.sum(1.0 - mask)
            ctx.stats["total"] = ctx.stats.get("total", 0.0) + mask.size
        return mask


class AdaptiveTransport(Lossy):
    """§3.2 control plane in the sync loop: a :class:`Lossy` transport whose
    next-step recommendations come from the runtime :class:`ControlPlane`
    (the UBT controllers plus the straggler detector; see repro/runtime/).

    This is now a thin adapter — the controllers are host state (an XLA
    fabric cannot drop or time out), so the loop is: run a step, call
    ``observe(loss_frac, stage_time=...)``, and when it returns True (the
    policy moved) rebuild or cache-switch the step with ``apply(cfg)`` —
    Hadamard toggles on above the §3.2.1 2% threshold, ``DynamicIncast``
    advertises the next incast, and per-peer stage times (when the caller
    can measure them) feed persistent-straggler ejection.
    ``launch/train.py --adaptive`` wires the ControlPlane in directly.
    """

    def __init__(self, control=None, use_hadamard: bool | None = None, *,
                 state: UbtState | None = None):
        from repro.runtime import ControlPlane, StragglerDetector
        if control is None:
            if state is None:
                raise ValueError("AdaptiveTransport needs a ControlPlane "
                                 "(or a UbtState via state=)")
            control = ControlPlane(
                state=state,
                detector=StragglerDetector(state.incast.n_nodes))
        self.control = control
        # only an explicit argument overrides the controller's current
        # codec recommendation (a wrapped ControlPlane may already have
        # crossed the activation threshold)
        if use_hadamard is not None:
            self.control.use_hadamard = bool(use_hadamard)

    @classmethod
    def create(cls, n_nodes: int, **kw) -> "AdaptiveTransport":
        from repro.runtime import ControlPlane
        return cls(control=ControlPlane.create(n_nodes=n_nodes, **kw))

    @property
    def state(self) -> UbtState:
        return self.control.state

    @property
    def use_hadamard(self) -> bool:
        return self.control.use_hadamard

    @use_hadamard.setter
    def use_hadamard(self, value: bool) -> None:
        self.control.use_hadamard = bool(value)

    def incast(self, ctx: SyncContext | None = None) -> int:
        return self.control.policy().incast

    def observe(self, loss_frac: float, *, stage_time: float | None = None,
                timed_out: bool = False,
                peer_stage_times=None) -> bool:
        """Feed one step's observations; True if the recommendation moved."""
        from repro.runtime import StepTelemetry
        return self.control.observe(StepTelemetry(
            step=self.control.steps, loss_frac=float(loss_frac),
            timed_out=timed_out, step_time=stage_time,
            peer_stage_times=(None if peer_stage_times is None
                              else tuple(peer_stage_times))))

    def apply(self, cfg: OptiReduceConfig) -> OptiReduceConfig:
        """Fold the current recommendation into a sync config."""
        return self.control.apply(cfg)


class WireTransport(Lossy):
    """Arrival masks observed from a *real* host wire exchange (DESIGN §7).

    The in-JAX datapath keeps its XLA collectives (a TPU fabric cannot drop
    packets), but the stage-1 shard matrix is also really packetized and
    exchanged between host peers over a :mod:`repro.net` backend (in-memory
    loopback or localhost UDP).  The bridge is an ``io_callback``: each
    device hands its ``(n_shards, S)`` wire state plus its rank out to the
    host ring as a rendezvous-free *deposit*; the ring's worker thread
    runs each exchange off the XLA pool and the callback returns the
    observed arrival mask of the **previous** exchange (all-ones on the
    priming call; bitwise that bucket's own mask when the loss schedule
    ignores the exchange counter, an equal-distribution sample otherwise)
    — the same next-round-from-last-round structure as the
    §3.2 controllers, and deadlock-free under any XLA thunk scheduling
    (see ``HostRing.bridge_exchange`` for why both a callback barrier and
    an in-callback operand read can deadlock an oversubscribed host).
    The bytes cross the wire under the adaptive per-round deadline, and
    the mask — missing, late, duplicated, out-of-order packets already
    resolved — is bit-compatible with a ``core/drops.py`` mask; per
    peer/round stage times, timeout flags, and received fractions
    accumulate on the ring for the launcher to drain into
    :class:`StepTelemetry`.

    Caveats (see DESIGN §7): only stage-1 exchanges on full-participation
    TAR schedules offer the payload hook (degraded round schedules exchange
    over a virtual ring the host bridge does not model), and the callback
    must stay un-vmapped (``sync_packed`` modes scan/pipelined are fine —
    one exchange per bucket per step; ``mode="vmap"`` would batch the
    callback).  ``bridge`` is ``HostRing.bridge_exchange`` or any
    ``(rank, shards) -> mask`` callable.
    """

    def __init__(self, bridge):
        self._bridge = bridge

    def _host_mask(self, me, payload):
        # NOTE: the payload is deliberately NOT materialized here — this
        # runs on an XLA worker thread, and reading the operand can wait on
        # a ready-event whose producer is queued on that same (possibly
        # saturated) pool.  The ring's worker thread materializes it.
        import numpy as np
        return np.asarray(self._bridge(int(me), payload), np.float32)

    def arrival_mask(self, ctx, n, s, axis, self_index=None, payload=None):
        if payload is None or self_index is not None:
            raise NotImplementedError(
                "WireTransport needs the stage-1 payload hook of a "
                "full-participation TAR schedule (degraded virtual-ring "
                "rounds are not bridged to the host wire)")
        from jax.experimental import io_callback
        me = jax.lax.axis_index(axis)
        # The ring pairs deposits by a per-rank call counter, so each
        # rank's callbacks must execute in program order.  ordered=False is
        # sound here because the sync engine emits exactly ONE exchange
        # stage (one callback) per lax.scan iteration in both the scan and
        # pipelined schedules, and iterations are serialized by the loop
        # carry — there is never a second same-rank callback in flight to
        # reorder against.  (ordered=True would express this directly but
        # its token parameter breaks shard_map sharding propagation on this
        # XLA.)  Running several wire-bridged sync calls concurrently in
        # one program WOULD break the pairing; the launcher's fsdp/vmap/tp
        # guards rule those out.
        mask = io_callback(self._host_mask,
                           jax.ShapeDtypeStruct((n, s), jnp.float32),
                           me, payload, ordered=False)
        ctx.stats["dropped"] = ctx.stats.get("dropped", 0.0) + \
            jnp.sum(1.0 - mask)
        ctx.stats["total"] = ctx.stats.get("total", 0.0) + mask.size
        return mask


# --------------------------------------------------------------- topologies
class Topology:
    """Exchange-schedule protocol: owns padding, the collectives, and the
    placement of the codec/transport hooks between them.

    Execution is split into three stage callables so schedules can be
    software-pipelined across buckets (``sync_pytree(mode="pipelined")``):

      ``encode_stage``    bucket -> wire state (pad + codec encode; the
                          Pallas-kernel-heavy producer side, no collectives)
      ``exchange_stage``  wire state -> gathered state (every collective of
                          the schedule, plus the small per-shard reduce
                          between TAR's two stages)
      ``decode_stage``    gathered state -> flat synced bucket (codec decode
                          + the unpad slice; kernel-heavy consumer side)

    Stage state is a flat tuple of arrays-or-None with a bucket-independent
    structure, so it can ride in a ``lax.scan`` carry.  ``all_reduce`` is
    exactly the three stages composed — every mode (scan / vmap / pipelined /
    the unfused oracle) runs the identical per-bucket computation, which is
    what keeps them bitwise-interchangeable.
    """

    def validate(self, transport: Reliable, codec: Codec) -> None:
        pass

    def encode_stage(self, bucket: jnp.ndarray, transport: Reliable,
                     codec: Codec, ctx: SyncContext) -> tuple:
        raise NotImplementedError

    def exchange_stage(self, state: tuple, transport: Reliable,
                       codec: Codec, ctx: SyncContext) -> tuple:
        raise NotImplementedError

    def decode_stage(self, state: tuple, length: int, transport: Reliable,
                     codec: Codec, ctx: SyncContext) -> jnp.ndarray:
        raise NotImplementedError

    def all_reduce(self, bucket: jnp.ndarray, transport: Reliable,
                   codec: Codec, ctx: SyncContext) -> jnp.ndarray:
        state = self.encode_stage(bucket, transport, codec, ctx)
        state = self.exchange_stage(state, transport, codec, ctx)
        return self.decode_stage(state, bucket.shape[-1], transport, codec,
                                 ctx)

    def reduce_scatter(self, g, axis, dim, transport, codec, ctx):
        raise NotImplementedError(
            f"{type(self).__name__} has no reduce_scatter form")


class PsumTopology(Topology):
    """XLA's native all-reduce (what a stock JAX program does)."""

    def validate(self, transport, codec):
        if not isinstance(codec, Identity) or isinstance(transport, Lossy):
            raise ValueError("psum is XLA-native: it bypasses the codec and "
                             "cannot model drops (use a TAR topology)")

    def encode_stage(self, bucket, transport, codec, ctx):
        cfg = ctx.cfg
        n = compat.axis_size(cfg.data_axis)
        if active_subset(cfg, n) is not None:
            raise ValueError(
                "psum is XLA-native: it cannot exclude peers — degraded "
                "participation needs a TAR or ring topology")
        if weights_subset(cfg, n) is not None or dead_link_set(cfg, n):
            raise ValueError(
                "psum is XLA-native: it cannot rebalance shards or route "
                "around links — use a rounds-scheduled TAR or ring topology")
        return (bucket,)

    def exchange_stage(self, state, transport, codec, ctx):
        (bucket,) = state
        return (jax.lax.pmean(bucket, ctx.data_axes()),)

    def decode_stage(self, state, length, transport, codec, ctx):
        return state[0]


@dataclasses.dataclass(frozen=True)
class RingTopology(Topology):
    """Baseline schedules that reduce internally: Gloo Ring, recursive
    halving-doubling ("NCCL Tree"), Gloo BCube.  Compose with any *linear*
    codec (decode commutes with the internal averaging) and a reliable
    transport; a ``pod`` axis is folded in with a pmean."""
    kind: str = "ring"                   # ring | tree | bcube

    def __post_init__(self):
        if self.kind not in ("ring", "tree", "bcube"):
            raise ValueError(f"unknown ring topology kind {self.kind!r}")

    def validate(self, transport, codec):
        if isinstance(transport, Lossy):
            raise ValueError(
                f"{self.kind} reduces in-flight partial sums; the UBT drop "
                "model needs TAR's receive structure (Lossy -> TarTopology)")
        if not codec.linear:
            raise ValueError(
                f"codec {type(codec).__name__} does not commute with "
                f"{self.kind}'s internal reduction")

    def _active(self, cfg: OptiReduceConfig, n: int):
        active = active_subset(cfg, n)
        if active is not None and self.kind != "ring":
            raise ValueError(
                f"{self.kind} exchanges over a rigid power-of-base "
                "structure; degraded participation supports kind='ring' "
                "(or a TAR topology)")
        return active

    def _geometry(self, cfg: OptiReduceConfig, n: int):
        """(active, order, weights): the degraded set, the (possibly
        link-rewired) virtual ring order, and the per-position shard
        weights — None/None/None on the exact uniform full-participation
        trace (the bitwise-parity fast path).

        A failed (i -> j) edge reroutes the virtual ring around the edge
        (ring hops are all distance-1, so a ``tar.ring_order``-ed tuple
        avoids it completely) rather than ejecting j; weights follow their
        peer through the reordering.
        """
        active = self._active(cfg, n)
        part = active if active is not None else tuple(range(n))
        weights = weights_subset(cfg, len(part))
        dead = dead_link_set(cfg, n)
        if (weights is not None or dead) and self.kind != "ring":
            raise ValueError(
                f"{self.kind} exchanges over a rigid power-of-base "
                "structure; shard weights / dead links support kind='ring' "
                "(or a rounds-scheduled TAR topology)")
        order = tar_lib.ring_order(part, dead) if dead else part
        if weights is not None and order != part:
            weights = tuple(weights[part.index(p)] for p in order)
        if active is None and order == part and weights is None:
            return None, None, None
        return active, order, weights

    def encode_stage(self, bucket, transport, codec, ctx):
        cfg = ctx.cfg
        n = compat.axis_size(cfg.data_axis)
        active, order, weights = self._geometry(cfg, n)
        if weights is not None:
            pad_n = sum(weights)
        elif order is not None:
            pad_n = len(order)
        else:
            pad_n = n
        x, _ = tar_lib.pad_for_tar(bucket, pad_n, codec.block(cfg))
        enc = codec.encode(x, ctx, cfg.data_axis)
        return (enc.data, enc.lo, enc.step)

    def exchange_stage(self, state, transport, codec, ctx):
        data, lo, step = state
        cfg = ctx.cfg
        n = compat.axis_size(cfg.data_axis)
        active, order, weights = self._geometry(cfg, n)
        if order is not None:
            # virtual ring of active peers in link-avoiding order; ejected
            # peers' garbage output is replaced by the graft before it can
            # reach the pod reduction
            out = ring_lib.ring_allreduce(data, cfg.data_axis, active=order,
                                          weights=weights)
            if active is not None:
                out = tar_lib.graft_inactive(out, cfg.data_axis, active)
        elif self.kind == "ring":
            out = ring_lib.ring_allreduce(data, cfg.data_axis)
        elif self.kind == "tree":
            out = ring_lib.tree_allreduce(data, cfg.data_axis)
        else:
            base = 4 if n % 4 == 0 else 2
            out = ring_lib.bcube_allreduce(data, cfg.data_axis, base=base)
        if cfg.pod_axis is not None:
            out = jax.lax.pmean(out, cfg.pod_axis)
        return (out, lo, step)

    def decode_stage(self, state, length, transport, codec, ctx):
        data, lo, step = state
        # the stage-1 encode output is gone by now — only the grids survive
        # the exchange, so the Encoded carries data=None rather than lying
        out = codec.decode_values(data, Encoded(None, lo=lo, step=step), ctx)
        return out[:length]


@dataclasses.dataclass(frozen=True)
class TarTopology(Topology):
    """Transpose AllReduce (§3.1): stage-1 shard exchange → codec reduce →
    stage-2 broadcast, with the codec/transport hooks between the stages.

    ``schedule``: ``'a2a'`` lowers the stages as tiled all_to_all/all_gather
    (the production path); ``'rounds'`` lowers the paper's explicit
    2*ceil((N-1)/I) ppermute round schedule, taking I from the transport
    (so :class:`AdaptiveTransport` drives it).
    ``outer``: how a configured ``pod`` axis joins — ``'tar'`` nests a TAR
    over the pods between the stages (§3.1.2 hierarchical 2D), ``'pmean'``
    folds them with a plain pmean (what a quantizing codec needs: values,
    not codes, cross the pod boundary).

    Degraded participation (``cfg.active_peers`` a proper subset, DESIGN
    §5): the ``'rounds'`` schedule is regenerated over the *virtual ring of
    active peers* — A = |active| shards, 2(A-1) rounds, ejected peers
    self-loop, plus ceil(E/A) graft rounds routing the result to ejected
    peers.  The ``'a2a'`` schedule keeps its N-shard collectives (an
    all_to_all cannot subset the axis) and instead zeroes ejected senders'
    rows in the arrival mask at *every* receiver — their contributions are
    excluded from the compensated mean, bitwise-identically on all
    replicas.  Either way the synced gradient is the mean over active
    contributions, and ejected peers still receive it.
    """
    schedule: str = "a2a"                # a2a | rounds
    outer: str = "tar"                   # tar | pmean

    def __post_init__(self):
        if self.schedule not in ("a2a", "rounds"):
            raise ValueError(f"unknown TAR schedule {self.schedule!r}")
        if self.outer not in ("tar", "pmean"):
            raise ValueError(f"unknown TAR outer mode {self.outer!r}")

    def _outer_reduce(self, own, codec, ctx):
        cfg = ctx.cfg
        g = compat.axis_size(cfg.pod_axis)
        if g <= 1:
            return own
        if self.outer == "tar" and own.shape[0] % g == 0:
            return tar_lib.tar_allreduce(own, cfg.pod_axis,
                                         use_kernel=cfg.use_kernels)
        return jax.lax.pmean(own, cfg.pod_axis)

    def _participation(self, cfg: OptiReduceConfig, n: int):
        """(active, n_shards, weights, dead): the rounds schedule shards
        over the active set — straggler-proportionally when ``weights`` is
        set — and relays around ``dead`` links; a2a keeps N uniform shards
        and excludes by mask."""
        active = active_subset(cfg, n)
        part = active if active is not None else tuple(range(n))
        weights = weights_subset(cfg, len(part))
        dead = dead_link_set(cfg, n)
        if (weights is not None or dead) and self.schedule != "rounds":
            raise ValueError(
                "the a2a TAR schedule lowers to all_to_all/all_gather, "
                "which can neither resize its tiles nor avoid an edge — "
                "use schedule='rounds' for shard_weights / dead_links")
        if active is not None and self.schedule == "rounds":
            return active, len(active), weights, dead
        return active, n, weights, dead

    @staticmethod
    def _check_weighted(cfg: OptiReduceConfig, codec) -> None:
        if not codec.linear:
            raise ValueError(
                "shard_weights require a linear codec: a quantizing codec "
                "grids the bucket by uniform shard geometry")
        if cfg.recovery != "none":
            raise ValueError(
                "shard_weights are incompatible with gradient recovery: "
                "stale-fill indexes the bucket by uniform shard geometry")

    def encode_stage(self, bucket, transport, codec, ctx):
        cfg = ctx.cfg
        n = compat.axis_size(cfg.data_axis)
        _, n_shards, weights, _ = self._participation(cfg, n)
        if weights is not None:
            self._check_weighted(cfg, codec)
            # pad so the bucket cuts into sum(weights) block-aligned units
            n_shards = sum(weights)
        x, _ = tar_lib.pad_for_tar(bucket, n_shards, codec.block(cfg))
        if hasattr(codec, "local_amax"):
            # split encode (quantizing codec): emit only the pre-collective
            # half here; the grid pmax and the quantize ride the exchange
            # stage, so in the pipelined schedule bucket k's amax collective
            # overlaps bucket k-1's shard exchange instead of serializing
            # after this bucket's rotation.  (StaleFill never wraps a
            # non-linear codec, so local_amax is a safe discriminator.)
            x1, amax = codec.local_amax(x, ctx)
            return (x1, None, None, None, amax)
        enc = codec.encode(x, ctx, cfg.data_axis)
        # 4th slot: the re-encoded stale bucket a recovery codec may attach
        # (None otherwise — an empty pytree leaf, so the disabled path's
        # scan carries and HLO are unchanged); 5th slot: the pre-pmax amax
        # of a split (quantizing) encode
        return (enc.data, enc.lo, enc.step, enc.stale, None)

    def exchange_stage(self, state, transport, codec, ctx):
        data, lo, step, stale, amax = state
        cfg = ctx.cfg
        axis = cfg.data_axis
        if amax is not None:
            # deferred half of the split encode: share the grids across the
            # whole DP group (same collective order as Codec.encode keeps
            # the math bitwise-identical), then quantize
            amax = jax.lax.pmax(amax, axis)
            for extra in ctx.data_axes():
                if extra != axis:
                    amax = jax.lax.pmax(amax, extra)
            enc_q = codec.encode_given_amax(data, amax, ctx)
            data, lo, step = enc_q.data, enc_q.lo, enc_q.step
        n = compat.axis_size(axis)
        active, n_shards, weights, dead = self._participation(cfg, n)
        enc = Encoded(data, lo=lo, step=step, stale=stale)
        if weights is not None:
            self._check_weighted(cfg, codec)
            plan = tar_lib.shard_plan(data.shape[0], weights,
                                      codec.block(cfg))
            if plan.padded != data.shape[0]:
                raise ValueError(
                    f"bucket length {data.shape[0]} not a multiple of "
                    f"sum(shard_weights)={sum(weights)} units")
            shards = tar_lib.weighted_rows(data, plan)
            s = plan.s_max
        else:
            plan = None
            s = data.shape[0] // n_shards
            shards = data.reshape(n_shards, s)
        if self.schedule == "rounds":
            received = tar_lib.tar_exchange_rounds(
                shards, axis, incast=transport.incast(ctx), active=active,
                dead_links=dead)
        else:
            received = jax.lax.all_to_all(shards, axis, split_axis=0,
                                          concat_axis=0, tiled=True)
        i = jax.lax.axis_index(axis)
        if self.schedule == "rounds" and (active is not None
                                          or plan is not None):
            # rows are in virtual-ring order; so are shard ownership and the
            # self row of the drop mask
            if active is not None:
                vpos, _ = tar_lib.peer_lookup(active, n)
                shard_index = jnp.take(vpos, i)
            else:
                shard_index = i        # weighted, full participation
            mask = transport.arrival_mask(ctx, n_shards, s, axis,
                                          self_index=shard_index)
        else:
            shard_index = i
            mask = transport.arrival_mask(ctx, n, s, axis, payload=shards)
            if active is not None:
                # a2a: exclude ejected senders' rows at EVERY receiver (the
                # ejected peer's own row included, so replicas agree) — the
                # masked-mean machinery compensates exactly like a drop
                _, is_active = tar_lib.peer_lookup(active, n)
                rows = is_active[:, None]
                mask = jnp.broadcast_to(rows, (n, s)) if mask is None \
                    else mask * rows
        own = codec.reduce(received, mask, shard_index, enc, ctx)
        if cfg.pod_axis is not None:
            own = self._outer_reduce(own, codec, ctx)
        wire = codec.encode_shard(own, shard_index, enc, ctx)
        if self.schedule == "rounds":
            gathered = tar_lib.tar_broadcast_rounds(
                wire, axis, incast=transport.incast(ctx), active=active,
                dead_links=dead, plan=plan)
            if active is not None:
                gathered = tar_lib.graft_inactive(gathered, axis, active)
        else:
            gathered = jax.lax.all_gather(wire, axis, axis=0, tiled=True)
        return (gathered, lo, step, None, None)  # stale consumed in reduce

    def decode_stage(self, state, length, transport, codec, ctx):
        data, lo, step, _, _ = state
        # only the quantization grids survive the exchange; data=None marks
        # the stage-1 encode output as unavailable at decode time
        out = codec.decode_gathered(data, Encoded(None, lo=lo, step=step),
                                    ctx)
        return out[:length]

    def reduce_scatter(self, g, axis, dim, transport, codec, ctx):
        """TAR stage 1 + compensated reduce on an arbitrary tensor,
        scattering ``dim`` over ``axis`` — the FSDP/ZeRO grad reduction;
        the all_gather at next use is the deferred stage 2."""
        cfg = ctx.cfg
        n = compat.axis_size(axis)
        _active = active_subset(cfg, n)
        part_n = n if _active is None else len(_active)
        if weights_subset(cfg, part_n) is not None or dead_link_set(cfg, n):
            raise ValueError(
                "reduce_scatter lowers to all_to_all (the FSDP a2a form): "
                "shard_weights / dead_links need the rounds schedule")
        g2 = jnp.moveaxis(g, dim, 0)
        lead = g2.shape[0]
        rest = g2.shape[1:]
        assert lead % n == 0, (lead, n)
        # keep the wire dtype (bf16 grads stay bf16): halves collective
        # bytes and the per-layer transients; reductions accumulate in fp32
        rows = g2.reshape(n, -1)                       # row j -> shard j
        row_len = rows.shape[1]
        pad = (-row_len) % codec.block(cfg)
        if pad:
            rows = jnp.pad(rows, ((0, 0), (0, pad)))
        enc = codec.encode(rows.reshape(-1), ctx, axis)
        shards = enc.data.reshape(n, -1)
        received = jax.lax.all_to_all(shards, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
        mask = transport.arrival_mask(ctx, n, received.shape[1], axis,
                                      payload=shards)
        active = active_subset(cfg, n)
        if active is not None:           # FSDP reduction: same a2a exclusion
            _, is_active = tar_lib.peer_lookup(active, n)
            rows = is_active[:, None]
            mask = jnp.broadcast_to(rows, received.shape) if mask is None \
                else mask * rows
        i = jax.lax.axis_index(axis)
        own = codec.reduce(received, mask, i, enc, ctx)
        own = codec.decode_values(own, enc, ctx)
        if pad:
            own = own[:row_len]
        out = own.reshape((lead // n,) + rest)
        return jnp.moveaxis(out, 0, dim)


# ------------------------------------------------------------ spec + registry
@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """One gradient-sync strategy = Topology × Transport × Codec."""
    topology: Topology
    transport: Reliable
    codec: Codec

    def __post_init__(self):
        self.topology.validate(self.transport, self.codec)

    def all_reduce(self, bucket: jnp.ndarray, ctx: SyncContext) -> jnp.ndarray:
        """Reduce one flat bucket to its (approximate) DP mean."""
        return self.topology.all_reduce(bucket, self.transport, self.codec,
                                        ctx)

    # stage callables for software-pipelined schedules (sync engine's
    # mode="pipelined"): encode -> exchange -> decode composes to all_reduce
    def encode_stage(self, bucket: jnp.ndarray, ctx: SyncContext) -> tuple:
        return self.topology.encode_stage(bucket, self.transport, self.codec,
                                          ctx)

    def exchange_stage(self, state: tuple, ctx: SyncContext) -> tuple:
        return self.topology.exchange_stage(state, self.transport,
                                            self.codec, ctx)

    def decode_stage(self, state: tuple, length: int,
                     ctx: SyncContext) -> jnp.ndarray:
        return self.topology.decode_stage(state, length, self.transport,
                                          self.codec, ctx)

    def reduce_scatter(self, g: jnp.ndarray, axis: str, dim: int,
                       ctx: SyncContext) -> jnp.ndarray:
        """Scatter ``dim`` over ``axis``, returning this node's reduced
        shard (the deferred-stage-2 / FSDP form)."""
        return self.topology.reduce_scatter(g, axis, dim, self.transport,
                                            self.codec, ctx)


_REGISTRY: dict[str, Callable[[OptiReduceConfig], CollectiveSpec]] = {}


def register_strategy(name: str, spec: CollectiveSpec | None = None):
    """Register a named strategy: either a spec instance
    (``register_strategy("x", spec)``) or, as a decorator, a factory
    ``cfg -> CollectiveSpec`` for cfg-dependent composition."""
    if spec is not None:
        _REGISTRY[name] = lambda cfg: spec
        return spec

    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def strategy_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_spec(cfg: OptiReduceConfig) -> CollectiveSpec:
    try:
        factory = _REGISTRY[cfg.strategy]
    except KeyError:
        raise ValueError(f"unknown strategy {cfg.strategy!r}; "
                         f"one of {strategy_names()}") from None
    return factory(cfg)


def _recovered(codec: Codec, cfg: OptiReduceConfig) -> Codec:
    """Registry wiring for ``cfg.recovery`` (DESIGN §8): fold the loss-
    recovery knob into a lossy strategy's codec. ``"none"`` returns the
    codec untouched without even importing the recovery module — the
    resolved spec, and the traced program, stay bitwise the seed ones."""
    if cfg.recovery == "none":
        return codec
    from . import recovery as recovery_lib
    return recovery_lib.wrap_codec(codec, cfg)


# ------------------------------------------------- the named strategy table
register_strategy("psum",
                  CollectiveSpec(PsumTopology(), Reliable(), Identity()))
register_strategy("gloo_ring",
                  CollectiveSpec(RingTopology("ring"), Reliable(), Identity()))
register_strategy("nccl_tree",
                  CollectiveSpec(RingTopology("tree"), Reliable(), Identity()))
register_strategy("bcube",
                  CollectiveSpec(RingTopology("bcube"), Reliable(),
                                 Identity()))
register_strategy("tar_tcp",
                  CollectiveSpec(TarTopology(), Reliable(), Identity()))
register_strategy("tar_rounds",
                  CollectiveSpec(TarTopology(schedule="rounds", outer="pmean"),
                                 Reliable(), Identity()))


@register_strategy("optireduce")
@register_strategy("optireduce_2d")   # pod_axis in cfg drives the 2D path
def _optireduce_spec(cfg: OptiReduceConfig) -> CollectiveSpec:
    codec = Hadamard() if cfg.use_hadamard else Identity()
    return CollectiveSpec(TarTopology(), Lossy(), _recovered(codec, cfg))


@register_strategy("optireduce_q")    # quantized exchange (beyond-paper)
def _optireduce_q_spec(cfg: OptiReduceConfig) -> CollectiveSpec:
    # _recovered rejects recovery over quantized codes (not linearly
    # decodable) instead of silently ignoring the knob
    return CollectiveSpec(TarTopology(outer="pmean"), Lossy(),
                          _recovered(HTQuant(), cfg))


# new cross-product compositions the layering opens (one-liners):
@register_strategy("optireduce_rounds")  # paper round schedule + drops + HT
def _optireduce_rounds_spec(cfg: OptiReduceConfig) -> CollectiveSpec:
    return CollectiveSpec(TarTopology(schedule="rounds", outer="pmean"),
                          Lossy(), _recovered(Hadamard(), cfg))


@register_strategy("tar_rounds_q")       # round schedule + THC quantization
def _tar_rounds_q_spec(cfg: OptiReduceConfig) -> CollectiveSpec:
    return CollectiveSpec(TarTopology(schedule="rounds", outer="pmean"),
                          Lossy(), _recovered(HTQuant(), cfg))
register_strategy("ring_ht",             # Gloo ring over rotated buckets
                  CollectiveSpec(RingTopology("ring"), Reliable(), Hadamard()))
