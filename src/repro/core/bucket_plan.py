"""Static bucketization plan for the gradient-sync engine.

The seed ``sync_pytree`` Python-looped over buckets — every bucket traced its
own copy of the strategy pipeline (O(#buckets) HLO growth) after
materializing a second full-size gradient copy via concatenate-then-slice.
``BucketPlan`` replaces that with trace-time-static layout bookkeeping:

* built once from the pytree treedef + leaf shapes (hashable, so it can ride
  in jit static args or be cached by the trainer),
* ``pack`` lays the flat gradient stream into ONE ``(B, bucket_elems)``
  batch (a single full-size buffer; the last bucket zero-padded),
* the engine then runs the strategy body once under ``lax.scan`` over the
  leading bucket axis (or vectorized via ``vmap``, or stage-skewed across
  buckets via ``mode="pipelined"`` — see ``allreduce.sync_packed``) — one
  traced pipeline regardless of B,
* ``unpack`` restores leaf shapes/dtypes from the synced batch.

Zero-padding the tail bucket is sound for every strategy: the pipelines are
elementwise across peers (pad positions sync to 0 and are sliced away), and
it is what makes the batched layout possible at all.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Hashable leaf->bucket layout, computed once from treedef/shapes."""
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[str, ...]
    bucket_elems: int
    num_buckets: int

    @classmethod
    def for_tree(cls, tree, bucket_elems: int) -> "BucketPlan":
        """Plan from a pytree of arrays (or ShapeDtypeStructs)."""
        leaves, treedef = jax.tree.flatten(tree)
        shapes = tuple(tuple(leaf.shape) for leaf in leaves)
        dtypes = tuple(jnp.dtype(leaf.dtype).name for leaf in leaves)
        total = sum(math.prod(s) for s in shapes)
        num_buckets = max(1, -(-total // bucket_elems))
        if num_buckets == 1:
            bucket_elems = total        # single bucket: no tail padding
        return cls(treedef=treedef, shapes=shapes, dtypes=dtypes,
                   bucket_elems=bucket_elems, num_buckets=num_buckets)

    @property
    def sizes(self) -> tuple[int, ...]:
        return tuple(math.prod(s) for s in self.shapes)

    @property
    def total(self) -> int:
        return sum(self.sizes)

    @property
    def padded(self) -> int:
        return self.num_buckets * self.bucket_elems

    @property
    def offsets(self) -> tuple[int, ...]:
        """Flat-stream start offset of each leaf (pytree order) — the arena
        coordinates consumers like ``packed_global_norm`` reduce over."""
        offs = []
        off = 0
        for size in self.sizes:
            offs.append(off)
            off += size
        return tuple(offs)

    def pack(self, tree, dtype=jnp.float32) -> jnp.ndarray:
        """Flatten leaves (pytree order) into one (B, bucket_elems) batch —
        the engine's only full-size buffer.  ``dtype`` defaults to fp32 (the
        sync engine's wire dtype); the trainer's packed gradient arena packs
        micro-batch grads in ``accum_dtype`` and accumulates in packed space
        (the per-leaf cast-then-concatenate is elementwise identical to the
        seed per-leaf accumulator)."""
        leaves = jax.tree.leaves(tree)
        parts = [leaf.reshape(-1).astype(dtype) for leaf in leaves]
        pad = self.padded - self.total
        if pad:
            parts.append(jnp.zeros((pad,), dtype))
        flat = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
        return flat.reshape(self.num_buckets, self.bucket_elems)

    def unpack(self, batch: jnp.ndarray):
        """Inverse of ``pack``: (B, bucket_elems) -> original pytree."""
        flat = batch.reshape(-1)
        leaves = []
        off = 0
        for shape, dtype, size in zip(self.shapes, self.dtypes, self.sizes):
            leaves.append(flat[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(self.treedef, leaves)

    def bucket_keys(self, key: jax.Array) -> jax.Array:
        """Stacked per-bucket PRNG keys (see :func:`bucket_keys`)."""
        return bucket_keys(key, self.num_buckets)


def bucket_keys(key: jax.Array, num_buckets: int) -> jax.Array:
    """Stacked per-bucket PRNG keys: fold_in(key, bucket_index), the same
    derivation as the seed's Python loop — the single source of truth the
    bitwise parity of every sync engine schedule rests on."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(num_buckets, dtype=jnp.uint32))
