"""OptiReduce core: the paper's contribution as composable JAX modules."""
from .allreduce import (OptiReduceConfig, SyncContext, strategies,
                        sync_bucket, sync_pytree, sync_pytree_unfused)
from .bucket_plan import BucketPlan
from .hadamard import ht_decode, ht_encode, rademacher_sign
from .safeguards import LossMonitor, guard_update
from .ubt import AdaptiveTimeout, DynamicIncast, TimelyRateControl, UbtState

__all__ = [
    "OptiReduceConfig", "SyncContext", "strategies", "sync_bucket",
    "sync_pytree", "sync_pytree_unfused", "BucketPlan",
    "ht_decode", "ht_encode", "rademacher_sign",
    "LossMonitor", "guard_update", "AdaptiveTimeout", "DynamicIncast",
    "TimelyRateControl", "UbtState",
]
