"""OptiReduce core: the paper's contribution as composable JAX modules."""
from .allreduce import (OptiReduceConfig, SyncContext, reduce_scatter_axis,
                        strategies, sync_bucket, sync_packed, sync_pytree,
                        sync_pytree_unfused)
from .bucket_plan import BucketPlan
from .hadamard import ht_decode, ht_encode, rademacher_sign
from .pipeline import (AdaptiveTransport, Codec, CollectiveSpec, Hadamard,
                       HTQuant, Identity, Lossy, PsumTopology, Reliable,
                       RingTopology, TarTopology, Topology, register_strategy,
                       resolve_spec, strategy_names)
from .safeguards import LossMonitor, guard_update
from .ubt import AdaptiveTimeout, DynamicIncast, TimelyRateControl, UbtState

__all__ = [
    "OptiReduceConfig", "SyncContext", "strategies", "sync_bucket",
    "sync_packed", "sync_pytree", "sync_pytree_unfused",
    "reduce_scatter_axis", "BucketPlan",
    "CollectiveSpec", "register_strategy", "resolve_spec", "strategy_names",
    "Topology", "PsumTopology", "RingTopology", "TarTopology",
    "Reliable", "Lossy", "AdaptiveTransport",
    "Codec", "Identity", "Hadamard", "HTQuant",
    "ht_decode", "ht_encode", "rademacher_sign",
    "LossMonitor", "guard_update", "AdaptiveTimeout", "DynamicIncast",
    "TimelyRateControl", "UbtState",
]
