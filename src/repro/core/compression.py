"""Lossy/compression baselines the paper compares against (Fig 16).

  * Top-K sparsification (Stich et al.) with error feedback memory.
  * TernGrad (Wen et al.): stochastic ternarization onto {-s, 0, +s}.
  * THC (Li et al.): Hadamard rotation + shared-grid uniform stochastic
    quantization; codes are *homomorphic* — they are summed across workers
    and dequantized once (reuses the FWHT and quant kernels).

These all decide statically how much to send before transmission; the paper's
point (reproduced in bench_compression) is that this does not remove tails.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.fwht import randomized_fwht
from repro.kernels.quant import uniform_dequant, uniform_quant
from .hadamard import rademacher_sign


# --------------------------------------------------------------------- Top-K
class TopKState(NamedTuple):
    error: jnp.ndarray  # error-feedback memory, same shape as the bucket


def topk_init(length: int) -> TopKState:
    return TopKState(error=jnp.zeros((length,), jnp.float32))


@functools.partial(jax.jit, static_argnames=("k",))
def topk_compress(x: jnp.ndarray, state: TopKState, *, k: int):
    """Keep the k largest-|.| entries of (x + error); the rest feed back."""
    corrected = x + state.error
    _, idx = jax.lax.top_k(jnp.abs(corrected), k)
    vals = corrected[idx]
    sparse = jnp.zeros_like(corrected).at[idx].set(vals)
    new_state = TopKState(error=corrected - sparse)
    return sparse, new_state


# ------------------------------------------------------------------ TernGrad
@jax.jit
def terngrad_compress(x: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    """Unbiased stochastic ternarization: E[out] == x (scale s = max|x|)."""
    s = jnp.max(jnp.abs(x))
    p = jnp.where(s > 0, jnp.abs(x) / s, 0.0)
    b = jax.random.bernoulli(key, p, x.shape)
    return s * jnp.sign(x) * b.astype(x.dtype)


# ----------------------------------------------------------------------- THC
class THCCompressed(NamedTuple):
    codes: jnp.ndarray   # uint8 (rows, block)
    lohi: jnp.ndarray    # shared (2,) quantization range


@functools.partial(jax.jit, static_argnames=("bits", "block", "use_kernel"))
def thc_compress(x: jnp.ndarray, key: jax.Array, lohi: jnp.ndarray, *,
                 bits: int = 4, block: int = 4096,
                 use_kernel: bool = False) -> THCCompressed:
    """Rotate (randomized HT) then quantize onto the shared [lo, hi] grid.

    ``lohi`` must be agreed across workers (THC pre-negotiates the range;
    we compute it from a profiling step). x: flat, length % block == 0.
    """
    sign = rademacher_sign(key, block)
    rot = randomized_fwht(x.reshape(-1, block), sign, mode="encode",
                          use_kernel=use_kernel)
    noise = jax.random.uniform(jax.random.fold_in(key, 1), rot.shape)
    codes = uniform_quant(rot, noise, lohi, bits=bits, use_kernel=use_kernel)
    return THCCompressed(codes=codes, lohi=lohi)


@functools.partial(jax.jit, static_argnames=("bits", "block", "nsum", "use_kernel"))
def thc_decompress_sum(code_sum: jnp.ndarray, key: jax.Array,
                       lohi: jnp.ndarray, *, bits: int = 4, block: int = 4096,
                       nsum: int = 1, use_kernel: bool = False) -> jnp.ndarray:
    """Dequantize a *sum* of nsum workers' codes, un-rotate, divide by nsum."""
    sign = rademacher_sign(key, block)
    rot_sum = uniform_dequant(code_sum, lohi, bits=bits, nsum=nsum)
    mean_rot = rot_sum / nsum
    out = randomized_fwht(mean_rot, sign, mode="decode", use_kernel=use_kernel)
    return out.reshape(-1)
