"""Safeguards against excessive gradient loss (paper §3.4).

Two layers:
  * In-graph: ``guard_update`` scales an update to zero when the observed
    loss fraction exceeds the skip threshold — jit-safe (lax-free ``where``),
    so a pathological round is skipped without a host round-trip.
  * Host-side: ``LossMonitor`` tracks the loss series, escalates to HALT
    after too many consecutive skips, and manages a ring of parameter
    snapshots for rollback (the paper's "snapshots and selective skipping").
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def guard_scale(loss_frac: jnp.ndarray, *,
                skip_threshold: float = 0.10) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The §3.4 skip decision as a multiplicative scale.

    Returns ``(scale, skipped?)`` with scale 0.0 when loss_frac exceeds the
    threshold, else 1.0 — the packed-arena trainer folds this scale into its
    single fused guard+clip multiply instead of a per-leaf tree pass.
    """
    skipped = loss_frac > skip_threshold
    return jnp.where(skipped, 0.0, 1.0), skipped


def guard_update(update: Any, loss_frac: jnp.ndarray, *,
                 skip_threshold: float = 0.10) -> tuple[Any, jnp.ndarray]:
    """Zero the pytree ``update`` when loss_frac > skip_threshold.

    Returns (guarded_update, skipped?). All replicas see the same
    loss_frac (it is computed from the aggregated result), so replicas
    stay consistent.
    """
    scale, skipped = guard_scale(loss_frac, skip_threshold=skip_threshold)
    return jax.tree.map(lambda u: u * scale.astype(u.dtype), update), skipped


@dataclasses.dataclass
class LossMonitor:
    """Host-side monitor: skip accounting, halt escalation, snapshot ring."""
    skip_threshold: float = 0.10
    halt_after_consecutive_skips: int = 10
    snapshot_every: int = 100
    snapshot_keep: int = 3

    consecutive_skips: int = 0
    total_skips: int = 0
    halted: bool = False
    history: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=1000))
    _snapshots: collections.deque = dataclasses.field(
        default_factory=collections.deque)

    def observe(self, step: int, loss_frac: float, skipped: bool) -> None:
        self.history.append((step, float(loss_frac)))
        if skipped:
            self.consecutive_skips += 1
            self.total_skips += 1
            if self.consecutive_skips >= self.halt_after_consecutive_skips:
                self.halted = True  # prompt user intervention (§3.4)
        else:
            self.consecutive_skips = 0

    def maybe_snapshot(self, step: int, params: Any) -> None:
        if step % self.snapshot_every == 0:
            self._snapshots.append((step, jax.tree.map(jnp.copy, params)))
            while len(self._snapshots) > self.snapshot_keep:
                self._snapshots.popleft()

    def rollback(self) -> tuple[int, Any] | None:
        """Most recent snapshot (step, params), or None."""
        if not self._snapshots:
            return None
        step, params = self._snapshots[-1]
        self.consecutive_skips = 0
        self.halted = False
        return step, params
