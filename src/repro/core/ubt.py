"""Unreliable Bounded Transport (UBT) control plane (paper §3.2).

XLA collectives on a TPU fabric cannot drop packets or time out, so these
controllers do not sit in the datapath; they are the *decision logic* the
paper specifies, reproduced exactly, and they drive (a) the cloud-network
simulator (sim/netsim.py) and (b) the drop-mask generator used in training
(core/drops.py). All state machines are plain Python over floats so they are
unit-testable against the paper's update rules.

Components:
  * AdaptiveTimeout — t_B = P95 of 20 profiled stage times (§3.2.1);
    early-timeout t_C moving average (alpha=0.95) with the x%-wait rule:
    start 10%, double while loss > 0.1%, decrement while loss < 0.01%,
    cap 50%; t_C sources: on-time -> observed, timeout -> t_B,
    last-percentile-seen -> extrapolated; median across nodes then EMA.
  * DynamicIncast — raise I on loss-free rounds, halve on loss (§3.2.2);
    senders use the min advertised I.
  * TimelyRateControl — §3.2.3: additive increase below T_low, multiplicative
    decrease above T_high (paper constants: 25us/250us/50Mbps/beta=0.5).
  * LossBudget — phase-aware acceptable-drop-fraction controller (DESIGN
    §8): the budget tightens geometrically as the LR schedule / loss curve
    approaches convergence, and when the observed loss EMA overruns it the
    round deadlines stretch (accept-or-extend) to recover late packets.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class AdaptiveTimeout:
    """Per-stage timeout controller. Times are in arbitrary units (seconds)."""
    warmup_iters: int = 20
    percentile: float = 95.0
    alpha: float = 0.95           # EMA weight on the *new* t_C sample
    x_init: float = 0.10
    x_min: float = 0.01
    x_max: float = 0.50
    loss_hi: float = 1e-3         # 0.1 %
    loss_lo: float = 1e-4         # 0.01 %
    ht_threshold: float = 0.02    # > 2% loss activates Hadamard (§3.2.1 fn.6)

    t_b: float | None = None
    t_c: float | None = None
    x: float = dataclasses.field(default=0.10)
    _warmup: list = dataclasses.field(default_factory=list)

    def observe_warmup(self, stage_time: float) -> None:
        self._warmup.append(float(stage_time))
        if len(self._warmup) >= self.warmup_iters:
            self.t_b = float(np.percentile(self._warmup, self.percentile))
            if self.t_c is None:
                self.t_c = float(np.median(self._warmup))

    @property
    def ready(self) -> bool:
        return self.t_b is not None

    def round_deadline(self, last_pctile_seen: bool) -> float:
        """Time budget for the current receive stage."""
        assert self.t_b is not None
        if last_pctile_seen and self.t_c is not None:
            return min(self.t_b, (1.0 + self.x) * self.t_c)
        return self.t_b

    def round_deadline_or(self, default: float,
                          last_pctile_seen: bool = False) -> float:
        """:meth:`round_deadline` once profiled; ``default`` during warmup
        (a wire receive loop needs a budget from step 0, before t_B
        exists)."""
        if self.t_b is None:
            return default
        return self.round_deadline(last_pctile_seen)

    def update(self, *, stage_times: Sequence[float], timed_out: Sequence[bool],
               frac_received: Sequence[float], loss_frac: float) -> None:
        """End-of-round update of t_C and x% (paper §3.2.1).

        stage_times[i]: node i's observed completion (or expiry) time;
        timed_out[i]: hit t_B; frac_received[i]: fraction of data received
        (for last-percentile extrapolation); loss_frac: entry loss this round.
        """
        assert self.t_b is not None and self.t_c is not None
        samples = []
        for t, to, fr in zip(stage_times, timed_out, frac_received):
            if to:
                samples.append(self.t_b)                       # (2) timed out
            elif fr >= 1.0:
                samples.append(t)                              # (1) on time
            else:
                samples.append(t * (1.0 / max(fr, 1e-6)))      # (3) extrapolate
        t_c_round = float(np.median(samples))                  # median across PS nodes
        self.t_c = self.alpha * t_c_round + (1.0 - self.alpha) * self.t_c

        if loss_frac > self.loss_hi:
            self.x = min(self.x_max, self.x * 2.0)
        elif loss_frac < self.loss_lo:
            self.x = max(self.x_min, self.x - 0.01)

    def hadamard_active(self, loss_frac: float) -> bool:
        return loss_frac > self.ht_threshold


@dataclasses.dataclass
class DynamicIncast:
    """Receiver-advertised incast factor I (§3.2.2)."""
    n_nodes: int = 8
    i_init: int = 1
    loss_tolerance: float = 1e-4

    value: int = 1

    def __post_init__(self) -> None:
        self.value = max(1, int(self.i_init))

    def update(self, *, loss_frac: float, timed_out: bool) -> int:
        if loss_frac > self.loss_tolerance or timed_out:
            self.value = max(1, self.value // 2)
        else:
            self.value = min(self.n_nodes - 1, self.value + 1)
        return self.value

    @staticmethod
    def effective(advertised: Sequence[int]) -> int:
        """Senders use the smallest advertised I for the round."""
        return max(1, min(int(v) for v in advertised))


@dataclasses.dataclass
class TimelyRateControl:
    """Minimal TIMELY-like rate control (§3.2.3). Units: seconds, bits/s."""
    t_low: float = 25e-6
    t_high: float = 250e-6
    add_step: float = 50e6        # alpha = 50 Mbps
    beta: float = 0.5
    rate: float = 10e9            # starting rate
    max_rate: float = 100e9
    min_rate: float = 100e6

    def update(self, rtt: float) -> float:
        if rtt < self.t_low:
            self.rate = min(self.max_rate, self.rate + self.add_step)
        elif rtt > self.t_high:
            self.rate = max(self.min_rate,
                            self.rate * (1.0 - self.beta * (1.0 - self.t_high / rtt)))
        # in the [t_low, t_high] band the paper's minimal scheme holds rate
        return self.rate


@dataclasses.dataclass
class LossBudget:
    """Phase-aware acceptable-drop-fraction controller (DESIGN §8).

    Early in training large gradient losses are tolerable (SGD noise
    dominates); near convergence the same loss stalls progress. The budget
    interpolates geometrically from ``budget_init`` at phase 0 to
    ``budget_final`` at phase 1, where *phase* is fed from the LR schedule
    (``update_phase(progress=...)``) and/or a loss-curve plateau detector
    (``update_phase(train_loss=...)``) and never decreases.

    The transport consumes it as an accept-or-extend rule: while the
    observed loss EMA overruns the current budget, :meth:`deadline_factor`
    stretches the AdaptiveTimeout round deadline (up to ``max_stretch``×)
    so late packets are waited for instead of charged as drops — tail
    latency is spent exactly where convergence needs the data.
    """
    budget_init: float = 0.02     # acceptable drop fraction at phase 0
    budget_final: float = 1e-4    # at phase 1 (converged)
    ema_alpha: float = 0.3        # weight on the newest loss sample
    gain: float = 0.5             # stretch = (loss/budget)**gain, capped
    max_stretch: float = 4.0
    plateau_patience: int = 20    # non-improving evals to reach phase 1

    phase: float = 0.0
    loss_ema: float = 0.0
    _best_loss: float | None = None
    _stalls: int = 0

    def budget(self) -> float:
        """Acceptable drop fraction at the current phase (monotone in it)."""
        f = min(max(self.phase, 0.0), 1.0)
        return float(self.budget_init ** (1.0 - f) * self.budget_final ** f)

    def update_phase(self, *, progress: float | None = None,
                     train_loss: float | None = None) -> float:
        """Advance the training phase; returns the new value in [0, 1].

        ``progress``: LR-schedule fraction elapsed (e.g. step/total_steps or
        1 - lr/lr0). ``train_loss``: the loss curve — phase rises as the
        relative improvement stalls (``plateau_patience`` flat evals ⇒ 1).
        The phase is the max of all signals seen and never moves backward.
        """
        f = self.phase
        if progress is not None:
            f = max(f, min(max(float(progress), 0.0), 1.0))
        if train_loss is not None:
            t = float(train_loss)
            if self._best_loss is None or t < self._best_loss * 0.99:
                self._best_loss = t if self._best_loss is None \
                    else min(self._best_loss, t)
                self._stalls = 0
            else:
                self._stalls += 1
            f = max(f, min(1.0, self._stalls / float(self.plateau_patience)))
        self.phase = f
        return f

    def observe(self, loss_frac: float) -> None:
        """Feed one round/step's observed drop fraction into the EMA."""
        self.loss_ema = (self.ema_alpha * float(loss_frac)
                         + (1.0 - self.ema_alpha) * self.loss_ema)

    def over_budget(self) -> bool:
        return self.loss_ema > self.budget()

    def deadline_factor(self) -> float:
        """Multiplicative round-deadline stretch in [1, max_stretch]."""
        over = self.loss_ema / max(self.budget(), 1e-9)
        if over <= 1.0:
            return 1.0
        return float(min(self.max_stretch, over ** self.gain))

    def stretch(self, deadline: float, hard: float | None = None) -> float:
        """Accept-or-extend: the deadline after the budget's say.

        ``hard`` optionally caps the stretched deadline (a wire receive
        loop's absolute bound); ``max_stretch`` always does.
        """
        d = deadline * self.deadline_factor()
        return d if hard is None else min(hard, d)


@dataclasses.dataclass
class UbtState:
    """Bundle of the UBT controllers for one training job. ``budget`` is
    the optional phase-aware loss budget (recovery='ef+budget')."""
    timeout: AdaptiveTimeout
    incast: DynamicIncast
    rate: TimelyRateControl
    budget: LossBudget | None = None

    @classmethod
    def create(cls, n_nodes: int, **kw) -> "UbtState":
        budget = kw.get("budget", None)
        return cls(timeout=AdaptiveTimeout(**kw.get("timeout", {})),
                   incast=DynamicIncast(n_nodes=n_nodes, **kw.get("incast", {})),
                   rate=TimelyRateControl(**kw.get("rate", {})),
                   budget=None if budget is None else LossBudget(**budget))
