"""Unreliable Bounded Transport (UBT) control plane (paper §3.2).

XLA collectives on a TPU fabric cannot drop packets or time out, so these
controllers do not sit in the datapath; they are the *decision logic* the
paper specifies, reproduced exactly, and they drive (a) the cloud-network
simulator (sim/netsim.py) and (b) the drop-mask generator used in training
(core/drops.py). All state machines are plain Python over floats so they are
unit-testable against the paper's update rules.

Components:
  * AdaptiveTimeout — t_B = P95 of 20 profiled stage times (§3.2.1);
    early-timeout t_C moving average (alpha=0.95) with the x%-wait rule:
    start 10%, double while loss > 0.1%, decrement while loss < 0.01%,
    cap 50%; t_C sources: on-time -> observed, timeout -> t_B,
    last-percentile-seen -> extrapolated; median across nodes then EMA.
  * DynamicIncast — raise I on loss-free rounds, halve on loss (§3.2.2);
    senders use the min advertised I.
  * TimelyRateControl — §3.2.3: additive increase below T_low, multiplicative
    decrease above T_high (paper constants: 25us/250us/50Mbps/beta=0.5).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class AdaptiveTimeout:
    """Per-stage timeout controller. Times are in arbitrary units (seconds)."""
    warmup_iters: int = 20
    percentile: float = 95.0
    alpha: float = 0.95           # EMA weight on the *new* t_C sample
    x_init: float = 0.10
    x_min: float = 0.01
    x_max: float = 0.50
    loss_hi: float = 1e-3         # 0.1 %
    loss_lo: float = 1e-4         # 0.01 %
    ht_threshold: float = 0.02    # > 2% loss activates Hadamard (§3.2.1 fn.6)

    t_b: float | None = None
    t_c: float | None = None
    x: float = dataclasses.field(default=0.10)
    _warmup: list = dataclasses.field(default_factory=list)

    def observe_warmup(self, stage_time: float) -> None:
        self._warmup.append(float(stage_time))
        if len(self._warmup) >= self.warmup_iters:
            self.t_b = float(np.percentile(self._warmup, self.percentile))
            if self.t_c is None:
                self.t_c = float(np.median(self._warmup))

    @property
    def ready(self) -> bool:
        return self.t_b is not None

    def round_deadline(self, last_pctile_seen: bool) -> float:
        """Time budget for the current receive stage."""
        assert self.t_b is not None
        if last_pctile_seen and self.t_c is not None:
            return min(self.t_b, (1.0 + self.x) * self.t_c)
        return self.t_b

    def round_deadline_or(self, default: float,
                          last_pctile_seen: bool = False) -> float:
        """:meth:`round_deadline` once profiled; ``default`` during warmup
        (a wire receive loop needs a budget from step 0, before t_B
        exists)."""
        if self.t_b is None:
            return default
        return self.round_deadline(last_pctile_seen)

    def update(self, *, stage_times: Sequence[float], timed_out: Sequence[bool],
               frac_received: Sequence[float], loss_frac: float) -> None:
        """End-of-round update of t_C and x% (paper §3.2.1).

        stage_times[i]: node i's observed completion (or expiry) time;
        timed_out[i]: hit t_B; frac_received[i]: fraction of data received
        (for last-percentile extrapolation); loss_frac: entry loss this round.
        """
        assert self.t_b is not None and self.t_c is not None
        samples = []
        for t, to, fr in zip(stage_times, timed_out, frac_received):
            if to:
                samples.append(self.t_b)                       # (2) timed out
            elif fr >= 1.0:
                samples.append(t)                              # (1) on time
            else:
                samples.append(t * (1.0 / max(fr, 1e-6)))      # (3) extrapolate
        t_c_round = float(np.median(samples))                  # median across PS nodes
        self.t_c = self.alpha * t_c_round + (1.0 - self.alpha) * self.t_c

        if loss_frac > self.loss_hi:
            self.x = min(self.x_max, self.x * 2.0)
        elif loss_frac < self.loss_lo:
            self.x = max(self.x_min, self.x - 0.01)

    def hadamard_active(self, loss_frac: float) -> bool:
        return loss_frac > self.ht_threshold


@dataclasses.dataclass
class DynamicIncast:
    """Receiver-advertised incast factor I (§3.2.2)."""
    n_nodes: int = 8
    i_init: int = 1
    loss_tolerance: float = 1e-4

    value: int = 1

    def __post_init__(self) -> None:
        self.value = max(1, int(self.i_init))

    def update(self, *, loss_frac: float, timed_out: bool) -> int:
        if loss_frac > self.loss_tolerance or timed_out:
            self.value = max(1, self.value // 2)
        else:
            self.value = min(self.n_nodes - 1, self.value + 1)
        return self.value

    @staticmethod
    def effective(advertised: Sequence[int]) -> int:
        """Senders use the smallest advertised I for the round."""
        return max(1, min(int(v) for v in advertised))


@dataclasses.dataclass
class TimelyRateControl:
    """Minimal TIMELY-like rate control (§3.2.3). Units: seconds, bits/s."""
    t_low: float = 25e-6
    t_high: float = 250e-6
    add_step: float = 50e6        # alpha = 50 Mbps
    beta: float = 0.5
    rate: float = 10e9            # starting rate
    max_rate: float = 100e9
    min_rate: float = 100e6

    def update(self, rtt: float) -> float:
        if rtt < self.t_low:
            self.rate = min(self.max_rate, self.rate + self.add_step)
        elif rtt > self.t_high:
            self.rate = max(self.min_rate,
                            self.rate * (1.0 - self.beta * (1.0 - self.t_high / rtt)))
        # in the [t_low, t_high] band the paper's minimal scheme holds rate
        return self.rate


@dataclasses.dataclass
class UbtState:
    """Bundle of the three controllers for one training job."""
    timeout: AdaptiveTimeout
    incast: DynamicIncast
    rate: TimelyRateControl

    @classmethod
    def create(cls, n_nodes: int, **kw) -> "UbtState":
        return cls(timeout=AdaptiveTimeout(**kw.get("timeout", {})),
                   incast=DynamicIncast(n_nodes=n_nodes, **kw.get("incast", {})),
                   rate=TimelyRateControl(**kw.get("rate", {})))
