"""Layered gradient-loss recovery beyond zero-fill (DESIGN §8).

The compensated masked mean (core/tar.py) renormalizes over the peers that
*did* arrive, but a wire span no sender delivered is zero-filled — and under
bursty loss (core/drops.py ``pattern="burst"``) whole runs of packets share
that fate. Three escalating mechanisms recover the lost mass, each a
composable option over the CollectiveSpec registry and each collapsing to
the exact seed trace when disabled (``cfg.recovery == "none"`` adds no ops):

  1. **Cross-step prediction** (:class:`StaleFill`, ``recovery="stale"``) —
     a per-bucket stale-value cache: every lost (sender, span) wire entry
     is filled with the *previous step's decoded bucket*, re-encoded under
     the current step's key, and the reduce takes the plain mean over all N
     (instead of renormalizing over survivors). Pure datapath — the cache
     rides the BucketPlan arena as extra scan carry state through the sync
     engine (``sync_packed(..., stale=...)``) and the codec's
     ``Encoded.stale`` slot through the stage pipeline.
  2. **Error feedback** (``recovery="ef"``; implies stale) — each rank
     accumulates the residual between its true contribution and what the
     stale fill applied in its stead (:func:`ef_residual`), and adds it to
     the next step's encode, so dropped gradient mass is eventually applied
     exactly once. State is threaded through ``train/trainer.py`` and
     checkpointed
     with params/optimizer state (``train/checkpoint.py``). Sound because
     the synthetic UBT masks are pure functions of (key, receiver) — every
     rank recomputes exactly which of its wire entries arrived.
  3. **Phase-aware loss budget** (``recovery="ef+budget"``) — a transport-
     layer controller (:class:`repro.core.ubt.LossBudget`) that tightens
     the acceptable drop fraction as training approaches convergence,
     stretching ``AdaptiveTimeout`` deadlines (and the wire peers'
     accept-or-extend decisions) when the observed loss overruns the
     phase's budget.

Scope: mechanisms 1–2 need a full-participation TAR schedule with a linear
codec (Identity/Hadamard) and the synthetic ``Lossy`` transport — the same
preconditions the wire bridge documents. Quantized codecs are rejected
(codes are not linearly decodable, so neither the stale re-encode nor the
residual split applies); so is ``active_peers`` degradation (the residual
reconstruction assumes the full sender set).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import drops as drops_lib
from . import tar as tar_lib
from .hadamard import ht_decode, ht_encode

MODES = ("none", "stale", "ef", "ef+budget")


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Parsed ``cfg.recovery`` knob: which mechanisms are armed."""
    mode: str = "none"

    @property
    def stale(self) -> bool:          # mechanisms are layered: ef ⇒ stale
        return self.mode in ("stale", "ef", "ef+budget")

    @property
    def ef(self) -> bool:
        return self.mode in ("ef", "ef+budget")

    @property
    def budget(self) -> bool:
        return self.mode == "ef+budget"

    @property
    def any(self) -> bool:
        return self.mode != "none"


def parse(mode: str) -> RecoveryPolicy:
    if mode not in MODES:
        raise ValueError(f"unknown recovery mode {mode!r}; one of {MODES}")
    return RecoveryPolicy(mode)


# ------------------------------------------- mechanism 1: stale-value fill
@dataclasses.dataclass(frozen=True)
class StaleFill:
    """Codec wrapper: lost wire spans are *predicted* from the previous
    step's decoded bucket (``ctx.stale``), re-encoded under this step's key.

    Where the compensated mean renormalizes over the senders that arrived
    (high variance when a burst takes out most of a span, zero when it takes
    all), this substitutes the stale value for every lost (sender, span)
    entry and takes the plain mean over all N — cross-step prediction:
    temporally-correlated gradients make last step's mean the best available
    estimate of a lost contribution.  Every entry — arrived or filled —
    then carries weight exactly 1/N, which is what makes the error-feedback
    residual split exact (``decode(m*w + (1-m)*w_stale)/N`` applied now +
    ``decode((1-m)*(w - w_stale))/N`` carried = the full ``bucket/N``
    contribution).

    Delegates every codec hook to ``inner``; only ``reduce`` changes, and
    with no stale cache or no mask the output is bitwise the inner codec's.
    ``inner`` must be linear: the stale bucket is re-encoded with the same
    key as the live data, so wire-space fill equals value-space fill rotated
    — the prediction stays meaningful under HT.
    """
    inner: object

    @property
    def linear(self) -> bool:
        return self.inner.linear

    def block(self, cfg) -> int:
        return self.inner.block(cfg)

    def encode(self, x, ctx, axis):
        enc = self.inner.encode(x, ctx, axis)
        if ctx.stale is None:
            return enc
        stale = ctx.stale.astype(x.dtype)
        pad = x.shape[0] - stale.shape[0]
        if pad < 0:
            raise ValueError(f"stale cache ({stale.shape[0]}) longer than "
                             f"the padded bucket ({x.shape[0]})")
        if pad:
            stale = jnp.pad(stale, (0, pad))
        enc_st = self.inner.encode(stale, ctx, axis)
        return dataclasses.replace(enc, stale=enc_st.data)

    def reduce(self, received, mask, shard_index, enc, ctx):
        if mask is None or enc.stale is None:
            return self.inner.reduce(received, mask, shard_index, enc, ctx)
        s = received.shape[1]
        stale_shard = jax.lax.dynamic_slice_in_dim(
            enc.stale, shard_index * s, s, 0).astype(received.dtype)
        filled = mask * received + (1.0 - mask) * stale_shard[None, :]
        ctx.stats["filled"] = ctx.stats.get("filled", 0.0) + \
            jnp.sum(1.0 - mask)
        # plain mean over all N: arrived entries weigh exactly 1/N (the EF
        # residual split relies on this), lost entries carry the prediction
        return self.inner.reduce(filled, None, shard_index, enc, ctx)

    def encode_shard(self, own, shard_index, enc, ctx):
        return self.inner.encode_shard(own, shard_index, enc, ctx)

    def decode_gathered(self, gathered, enc, ctx):
        return self.inner.decode_gathered(gathered, enc, ctx)

    def decode_values(self, vals, enc, ctx):
        return self.inner.decode_values(vals, enc, ctx)


def wrap_codec(codec, cfg):
    """Fold ``cfg.recovery`` into a strategy's codec (registry wiring).

    Returns the codec unchanged for ``recovery="none"`` — the spec, and
    therefore the traced program, is bitwise the seed one. Otherwise wraps
    it in :class:`StaleFill`, validating the composability preconditions.
    """
    pol = parse(cfg.recovery)
    if not pol.stale:
        return codec
    if not codec.linear:
        raise ValueError(
            f"recovery={cfg.recovery!r} needs a linear codec (Identity/"
            f"Hadamard); {type(codec).__name__} codes are not linearly "
            "decodable")
    if cfg.active_peers is not None:
        raise ValueError(
            "recovery does not compose with degraded participation "
            "(the residual/stale reconstruction assumes the full sender "
            "set); clear active_peers or set recovery='none'")
    return StaleFill(inner=codec)


# --------------------------------------- mechanism 2: error feedback (EF)
def sender_arrival_masks(cfg, key: jax.Array, n: int, s: int) -> jnp.ndarray:
    """(n, n*s) sender-major arrival matrix for one bucket's stage 1.

    Row ``i`` concatenates, over owners ``j = 0..n-1``, sender ``i``'s
    arrival mask for the span it sent to owner ``j`` — reconstructing every
    receiver's ``Lossy`` draw (``fold_in(key, j)``, self row forced) so any
    rank knows exactly which of its wire entries were applied this step.
    """
    def one(j):
        return drops_lib.make_mask(cfg.drop_pattern,
                                   jax.random.fold_in(key, j), n, s,
                                   rate=cfg.drop_rate,
                                   packet_elems=cfg.packet_elems,
                                   self_index=j)
    masks = jax.vmap(one)(jnp.arange(n))               # (owner, sender, s)
    return jnp.transpose(masks, (1, 0, 2)).reshape(n, n * s)


def ef_residual(bucket: jnp.ndarray, key: jax.Array, cfg, n: int,
                me, stale: jnp.ndarray | None = None) -> jnp.ndarray:
    """Rank ``me``'s undelivered gradient mass for one bucket.

    ``bucket`` is the rank's contribution (gradient + carried residual);
    ``stale`` the cross-step prediction the receivers substituted for its
    lost wire entries (the previous step's decoded bucket). Returns
    ``decode((1 - arrival_me) * (encode(bucket) - encode(stale)))`` — the
    gap between what this rank owed and what the stale fill already applied
    in its stead, to be added to the next step's encode. Subtracting the
    fill is what makes the split exact for linear codecs:
    ``decode(m*w + (1-m)*w_stale) + residual == bucket`` (the
    mass-conservation property the hypothesis suite pins) — carrying the
    full lost mass on top of the fill would apply it twice.
    """
    if cfg.drop_rate <= 0.0:
        return jnp.zeros_like(bucket)
    basis = bucket if stale is None else bucket - stale.astype(bucket.dtype)
    block = cfg.hadamard_block if cfg.use_hadamard else 1
    x, length = tar_lib.pad_for_tar(basis, n, block)
    s = x.shape[0] // n
    arrival = sender_arrival_masks(cfg, key, n, s)
    mine = jax.lax.dynamic_slice_in_dim(arrival, me, 1, 0)[0]
    if cfg.use_hadamard:
        w = ht_encode(x, key, block=block, use_kernel=cfg.use_kernels)
        resid = ht_decode((1.0 - mine) * w, key, block=block,
                          use_kernel=cfg.use_kernels)
    else:
        resid = (1.0 - mine) * x
    return resid[:length].astype(bucket.dtype)


def ef_residual_arena(arena: jnp.ndarray, step_key: jax.Array, cfg, n: int,
                      me, stale: jnp.ndarray | None = None) -> jnp.ndarray:
    """Per-bucket :func:`ef_residual` over a packed (B, bucket_elems) arena,
    with the sync engine's per-bucket key derivation (fold_in by index).
    ``stale`` is the (B, bucket_elems) prediction cache the fill consumed
    *this* step (pre-update)."""
    from .bucket_plan import bucket_keys
    keys = bucket_keys(step_key, arena.shape[0])
    basis = arena if stale is None else arena - stale.astype(arena.dtype)
    return jax.vmap(lambda g, k: ef_residual(g, k, cfg, n, me))(basis, keys)


def init_state(policy: RecoveryPolicy, nbuckets: int, bucket_elems: int,
               n_dp: int = 1) -> dict:
    """Zero-initialized recovery state matching the trainer's threading.

    ``stale`` — previous step's decoded arena, shape (B, E), replicated
    (every rank decodes identical buckets); ``ef`` — the carried residual,
    shape (n_dp, B, E), sharded over the data axis (each data rank drops
    different wire spans). A zero stale cache makes step 0 behave exactly
    like zero-fill.
    """
    state = {}
    if policy.stale:
        state["stale"] = jnp.zeros((nbuckets, bucket_elems), jnp.float32)
    if policy.ef:
        state["ef"] = jnp.zeros((n_dp, nbuckets, bucket_elems), jnp.float32)
    return state
