"""Deterministic synthetic LM data pipeline, host-sharded and resumable.

Each (step, host) pair maps to a unique PRNG stream, so:
  * every host loads only its shard (no cross-host I/O),
  * a restarted job regenerates exactly the batches it would have seen
    (checkpoint/restart determinism — fault-tolerance story),
  * elastic rescaling (N -> N') re-partitions the same global stream.

Tokens follow a Zipf-like marginal with short-range Markov structure so a
small LM has actual signal to learn (used by the TTA benchmarks, where real
convergence curves are required).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.3
    markov_weight: float = 0.7     # next-token dependence strength
    n_succ: int = 4                # successors per token (1 = deterministic)


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.marginal = p / p.sum()
        # a sparse deterministic "grammar": each token prefers a few successors
        self.succ = rng.integers(0, v, size=(v, cfg.n_succ))

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=b, p=self.marginal)
        follow = rng.random((b, s)) < cfg.markov_weight
        succ_pick = rng.integers(0, cfg.n_succ, size=(b, s))
        fresh = rng.choice(cfg.vocab_size, size=(b, s), p=self.marginal)
        for t in range(s):
            nxt = self.succ[toks[:, t], succ_pick[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, fresh[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch(self, step: int, host: int, n_hosts: int
                   ) -> dict[str, np.ndarray]:
        """This host's contiguous slice of the global batch."""
        g = self.global_batch(step)
        b = self.cfg.global_batch
        assert b % n_hosts == 0, (b, n_hosts)
        lo = host * (b // n_hosts)
        hi = lo + b // n_hosts
        return {k: v[lo:hi] for k, v in g.items()}
