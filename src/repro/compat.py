"""Version-compat shims for JAX APIs that moved between releases.

The repo targets the modern spelling (``jax.shard_map``,
``jax.sharding.AxisType``); older runtimes (<= 0.4.x) only ship
``jax.experimental.shard_map.shard_map`` (with ``check_rep`` instead of
``check_vma``) and a ``jax.make_mesh`` without ``axis_types``. All mesh /
shard_map construction goes through here so the rest of the codebase can
stay on the new API.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on new JAX, experimental fallback on old JAX."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def _needs_barrier_vjp() -> bool:
    # jax < 0.5 ships optimization_barrier without a differentiation rule
    return jax.__version_info__ < (0, 5, 0)


def _register_barrier_batching() -> None:
    """Old JAX also lacks a vmap rule for optimization_barrier; the barrier
    is shape-oblivious, so batching is a pass-through of the batch dims."""
    try:
        from jax._src.lax import lax as _lax_internal
        from jax.interpreters import batching
        prim = _lax_internal.optimization_barrier_p
    except (ImportError, AttributeError):      # pragma: no cover - new JAX
        return
    if prim in batching.primitive_batchers:
        return

    def _batch(args, dims):
        outs = prim.bind(*args)
        return outs, dims

    batching.primitive_batchers[prim] = _batch


if _needs_barrier_vjp():
    _register_barrier_batching()


@jax.custom_vjp
def _barrier_vjp(xs):
    return jax.lax.optimization_barrier(xs)


def _barrier_fwd(xs):
    return _barrier_vjp(xs), None


def _barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_barrier_vjp.defvjp(_barrier_fwd, _barrier_bwd)


def optimization_barrier(xs):
    """``jax.lax.optimization_barrier`` that is differentiable on old JAX
    (identity VJP with a matching barrier on the cotangents)."""
    if _needs_barrier_vjp():
        return _barrier_vjp(xs)
    return jax.lax.optimization_barrier(xs)


def axis_size(axis) -> int:
    """Static size of a named mesh axis, inside a shard_map body.

    Old JAX has no ``jax.lax.axis_size``; ``psum(1, axis)`` constant-folds
    to the same static int there.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where supported."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)
