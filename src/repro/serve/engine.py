"""Serving engine: batched decode (and prefill) under the production mesh.

Sharding policy (chosen per shape):
  * batch >= dp_total           -> KV/state batch dim over ('pod','data'),
                                   heads over 'model' (decode_32k).
  * batch <  dp_total (B=1 long) -> KV *sequence* dim over the data axes
                                   (flash-decoding split: partial softmax
                                   merged with psum'd statistics), heads
                                   over 'model' (long_500k).

The engine builds serve_step = shard_map(decode_step) and exposes
``abstract_state`` for the dry-run (ShapeDtypeStructs only).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decode_step, init_decode_state, param_specs
from repro.models.layers import KVCache
from repro.models.parallel import ParallelCtx
from repro.models.ssm import SSMState


@dataclasses.dataclass(frozen=True)
class ServePlan:
    batch_axes: tuple[str, ...]      # mesh axes carrying the batch dim
    seq_axes: tuple[str, ...]        # mesh axes carrying the KV seq dim
    tp: int
    dp_total: int

    @property
    def seq_shards(self) -> int:
        return self.dp_total if self.seq_axes else 1


def plan_serving(mesh, global_batch: int) -> ServePlan:
    names = tuple(mesh.axis_names)
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]
    tp = mesh.shape["model"] if "model" in names else 1
    if global_batch >= dp_total and global_batch % dp_total == 0:
        return ServePlan(batch_axes=dp_axes, seq_axes=(), tp=tp,
                         dp_total=dp_total)
    # tiny batch: shard the cache's sequence dim instead (split-K decode)
    return ServePlan(batch_axes=(), seq_axes=dp_axes, tp=tp,
                     dp_total=dp_total)


def state_specs(cfg: ModelConfig, plan: ServePlan):
    """PartitionSpecs for the decode-state pytree from init_decode_state:
    KV (stack, B, S, H_kv, dh); SSM conv (stack, B, K-1, d_inner),
    ssm (stack, B, H, P, N)."""
    from repro.models.transformer import TpLayout
    lay = TpLayout.build(cfg, plan.tp)
    b_ax = (plan.batch_axes if len(plan.batch_axes) > 1
            else (plan.batch_axes[0] if plan.batch_axes else None))
    s_ax = (plan.seq_axes if len(plan.seq_axes) > 1
            else (plan.seq_axes[0] if plan.seq_axes else None))
    kv_sharded = plan.tp > 1 and (not lay.kv_replicated or lay.kv_single)
    kv_tp = "model" if kv_sharded else None

    def kv_spec(_):
        return P(None, b_ax, s_ax, kv_tp, None)

    def conv_spec(_):
        return P(None, b_ax, None, "model" if plan.tp > 1 else None)

    def ssm_spec(_):
        return P(None, b_ax, "model" if plan.tp > 1 else None, None, None)

    def build(state):
        out = []
        for st in state:
            if isinstance(st, KVCache):
                out.append(KVCache(k=kv_spec(st), v=kv_spec(st)))
            elif isinstance(st, SSMState):
                out.append(SSMState(conv=conv_spec(st), ssm=ssm_spec(st)))
            else:
                raise TypeError(type(st))
        return out

    return build


def abstract_state(cfg: ModelConfig, shape: ShapeConfig, plan: ServePlan):
    """ShapeDtypeStructs for the decode state at GLOBAL (tp-padded) shapes."""
    return jax.eval_shape(
        functools.partial(_global_state, cfg=cfg, batch=shape.global_batch,
                          max_seq=shape.seq_len, tp=plan.tp))


def _global_state(cfg: ModelConfig, batch: int, max_seq: int, tp: int):
    """Global decode state with tp-padded head counts (local x tp)."""
    local = init_decode_state(None, cfg, batch=batch, max_seq=max_seq, tp=tp,
                              seq_shards=1)
    out = []
    for st in local:
        if isinstance(st, KVCache):
            k = st.k
            # local kv heads x tp when kv is sharded or sliced; replicated
            # (multi-head) kv stays at its local count
            from repro.models.transformer import TpLayout
            lay = TpLayout.build(cfg, tp)
            mult = tp if (not lay.kv_replicated or lay.kv_single) else 1
            shape = (k.shape[0], k.shape[1], k.shape[2],
                     k.shape[3] * mult, k.shape[4])
            out.append(KVCache(k=jnp.zeros(shape, k.dtype),
                               v=jnp.zeros(shape, k.dtype)))
        else:
            conv = st.conv
            ssm = st.ssm
            out.append(SSMState(
                conv=jnp.zeros((conv.shape[0], conv.shape[1], conv.shape[2],
                                conv.shape[3] * tp), conv.dtype),
                ssm=jnp.zeros((ssm.shape[0], ssm.shape[1],
                               ssm.shape[2] * tp, ssm.shape[3],
                               ssm.shape[4]), ssm.dtype)))
    return out


def build_serve_step(cfg: ModelConfig, mesh, plan: ServePlan, *,
                     unroll: bool = False, weight_fsdp: bool = False,
                     moe_stationary: bool = False):
    """serve_step(params, state, tokens, pos, key) -> (next_tok, new_state).

    ``weight_fsdp``: additionally shard weights over the data axes and
    gather them just-in-time per layer (ZeRO-inference). Required for the
    archs whose weights exceed HBM at tp=16 (arctic 477B: 60 GB/chip at
    tp-only vs 3.7 GB fsdp'd over 256); costs an all-gather per layer —
    the roofline flags these cells collective-bound, and §Perf explores
    the 2-axis expert-parallel alternative.
    """
    names = tuple(mesh.axis_names)
    tp_axis = "model" if "model" in names else None
    dp_axes = tuple(a for a in ("pod", "data") if a in names)

    gather = None
    if weight_fsdp:
        def gather(w, dim, key):
            del key
            for ax in reversed(dp_axes):
                w = jax.lax.all_gather(w, ax, axis=dim, tiled=True)
            return w

    pctx = ParallelCtx(tp_axis=tp_axis,
                       dp_axis="data" if "data" in names else None,
                       pod_axis="pod" if "pod" in names else None,
                       fsdp=weight_fsdp, gather=gather,
                       moe_stationary=moe_stationary)
    seq_axes = plan.seq_axes if plan.seq_axes else None

    def body(params, state, tokens, pos, key):
        return decode_step(params, state, tokens, pos, cfg, pctx, key=key,
                           seq_shard_axis=seq_axes, unroll=unroll)

    p_specs = param_specs(cfg, tp=plan.tp,
                          fsdp_axes=dp_axes if weight_fsdp else None)
    s_specs = state_specs(cfg, plan)
    b_ax = (plan.batch_axes if len(plan.batch_axes) > 1
            else (plan.batch_axes[0] if plan.batch_axes else None))
    tok_spec = P(b_ax, None)

    def make(abstract_st):
        st_specs = s_specs(abstract_st)
        fn = compat.shard_map(
            body, mesh=mesh,
            in_specs=(p_specs, st_specs, tok_spec, P(), P()),
            out_specs=(tok_spec, st_specs),
            check_vma=False)
        shardings = {
            "params": jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                                   is_leaf=lambda x: isinstance(x, P)),
            "state": jax.tree.map(lambda s: NamedSharding(mesh, s), st_specs,
                                  is_leaf=lambda x: isinstance(x, P)),
            "tokens": NamedSharding(mesh, tok_spec),
        }
        return fn, shardings

    return make


def generate(params, cfg: ModelConfig, prompts: jnp.ndarray, *,
             max_new: int = 16, key=None, pctx: ParallelCtx | None = None
             ) -> jnp.ndarray:
    """Single-host convenience loop (examples/tests): prefill the prompt
    token-by-token, then greedy-decode ``max_new`` tokens."""
    from repro.models.parallel import SINGLE
    pctx = pctx or SINGLE
    key = key if key is not None else jax.random.PRNGKey(0)
    b, s0 = prompts.shape
    state = init_decode_state(params, cfg, batch=b, max_seq=s0 + max_new,
                              dtype=cfg.param_dtype)
    tok = prompts[:, :1]
    out = [prompts]
    for t in range(s0 + max_new - 1):
        nxt, state = decode_step(params, state, tok, jnp.asarray(t, jnp.int32),
                                 cfg, pctx, key=key)
        if t + 1 < s0:
            tok = prompts[:, t + 1:t + 2]      # teacher-force the prompt
        else:
            tok = nxt
            out.append(nxt)
    return jnp.concatenate(out, axis=1)
