"""Decoder-only transformer family covering all assigned architectures:
dense (GQA), MoE (top-k, shared experts, dense-residual), SSM (Mamba-2),
hybrid interleave (Jamba), and frontend-stubbed VLM/audio variants.

Structure
---------
Layers are organized into a repeating *period* (1 for homogeneous stacks;
8 for Jamba's 1:7 attn:mamba interleave). Parameters are stacked over the
repeat count and the stack is driven by ``jax.lax.scan`` — compact HLO,
which matters for the 512-device dry-run compiles.

Parallelism (manual SPMD inside shard_map; see models/parallel.py)
  * tp ('model'): q heads / d_ff / experts / vocab column-sharded; row-
    parallel projections psum. KV heads are replicated (and q heads padded)
    when they don't divide tp.
  * fsdp ('data' [,'pod']): every large weight additionally sharded on its
    non-tp dim; gathered just-in-time in the scan body via ``pctx.gather``
    — whose custom VJP is where OptiReduce runs as the ZeRO reduce-scatter
    (see train/trainer.py).
  * loss: vocab-sharded cross-entropy, chunked over sequence, rematerialized
    — full logits are never alive (256k vocab would not fit otherwise).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .layers import (KVCache, attention_decode, attention_train, gated_mlp,
                     rms_norm)
from .moe import moe_block
from .parallel import ParallelCtx
from .ssm import SSMState, mamba2_forward


# --------------------------------------------------------------------- layout
def _ceil_to(x: int, q: int) -> int:
    return -(-x // q) * q


@dataclasses.dataclass(frozen=True)
class TpLayout:
    """Padded dimensions for a given tensor-parallel degree."""
    tp: int
    heads_pad: int
    kv_pad: int          # padded KV heads (== n_kv when replicated/sliced)
    kv_replicated: bool  # n_kv < tp: KV projection weights replicated
    kv_single: bool      # ...and each shard's q heads share ONE kv head, so
                         # each shard keeps exactly one KV head (cache 1/tp)
    vocab_pad: int
    experts_pad: int
    ssm_heads_pad: int

    @staticmethod
    def build(cfg: ModelConfig, tp: int) -> "TpLayout":
        heads_pad = _ceil_to(cfg.n_heads, tp) if cfg.n_heads else 0
        kv_single = False
        if cfg.n_kv_heads and cfg.n_kv_heads >= tp:
            kv_pad, kv_repl = _ceil_to(cfg.n_kv_heads, tp), False
        else:
            kv_pad, kv_repl = cfg.n_kv_heads, True
            if kv_pad and tp > 1:
                hq_l = heads_pad // tp
                kv_single = all(
                    len({(q * kv_pad) // heads_pad
                         for q in range(s * hq_l, (s + 1) * hq_l)}) == 1
                    for s in range(tp))
        return TpLayout(
            tp=tp,
            heads_pad=heads_pad,
            kv_pad=kv_pad,
            kv_replicated=kv_repl,
            kv_single=kv_single,
            vocab_pad=_ceil_to(cfg.vocab_size, tp),
            experts_pad=_ceil_to(cfg.n_experts, tp) if cfg.n_experts else 0,
            ssm_heads_pad=_ceil_to(cfg.ssm_heads, tp) if cfg.ssm_heads else 0,
        )

    @property
    def kv_local(self) -> int:
        """KV heads held per shard (cache head dim)."""
        if self.kv_single:
            return 1
        if self.kv_replicated:
            return self.kv_pad
        return self.kv_pad // self.tp

    def kv_select(self, shard: jnp.ndarray) -> jnp.ndarray | None:
        """Global KV head this shard keeps (kv_single only)."""
        if not self.kv_single:
            return None
        hq_l = self.heads_pad // self.tp
        return (shard * hq_l * self.kv_pad) // self.heads_pad

    def kv_map(self, cfg: ModelConfig, shard: jnp.ndarray) -> jnp.ndarray | None:
        """Local q head -> local KV-cache head index (None = default GQA)."""
        hq_l = self.heads_pad // self.tp
        if self.kv_single:
            return None          # one local head; default repeat covers it
        if self.kv_replicated:
            # cache holds all n_kv heads; global q head h uses h*kv//heads
            gq = shard * hq_l + jnp.arange(hq_l)
            return jnp.clip((gq * self.kv_pad) // max(self.heads_pad, 1),
                            0, self.kv_pad - 1)
        kv_l = self.kv_pad // self.tp
        if hq_l % kv_l == 0 and (self.heads_pad // self.kv_pad) * kv_l == hq_l:
            return None  # contiguous GQA grouping holds shard-locally
        return jnp.arange(hq_l) * kv_l // hq_l


# ------------------------------------------------------------- param building
class Leaf(NamedTuple):
    shape: tuple
    spec: P              # global PartitionSpec (stack dim first where present)
    fsdp_dim: int | None # dim sharded over the fsdp axes (None = replicated)
    init: str            # 'normal' | 'zeros' | 'ones' | 'alog' | 'conv'


def _period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.attn_every:
        p = cfg.attn_every
    if cfg.n_experts and cfg.moe_every > 1:
        p = int(np.lcm(p, cfg.moe_every))
    return p


def _layer_leaves(cfg: ModelConfig, lay: TpLayout, layer_in_period: int,
                  n_repeat: int, fsdp_axes) -> dict[str, Leaf]:
    """Leaf table for one period position; all shapes carry the stack dim."""
    d = cfg.d_model
    dh = cfg.dh
    fx = fsdp_axes  # e.g. ('data',) or ('pod','data') or None
    L = n_repeat

    def w(shape, tp_dim=None, fsdp_dim=None, init="normal"):
        spec = [None] * (len(shape))
        if tp_dim is not None and lay.tp > 1:
            spec[tp_dim] = "model"
        if fx is not None and fsdp_dim is not None:
            spec[fsdp_dim] = fx if len(fx) > 1 else fx[0]
        return Leaf(tuple(shape), P(*spec), fsdp_dim if fx else None, init)

    leaves: dict[str, Leaf] = {}
    is_attn = cfg.is_attn_layer(layer_in_period)
    is_moe = cfg.is_moe_layer(layer_in_period)

    leaves["ln1"] = w((L, d), init="ones")
    if is_attn:
        leaves["wq"] = w((L, d, lay.heads_pad * dh), tp_dim=2, fsdp_dim=1)
        kv_tp = None if lay.kv_replicated else 2
        leaves["wk"] = w((L, d, lay.kv_pad * dh), tp_dim=kv_tp, fsdp_dim=1)
        leaves["wv"] = w((L, d, lay.kv_pad * dh), tp_dim=kv_tp, fsdp_dim=1)
        leaves["wo"] = w((L, lay.heads_pad * dh, d), tp_dim=1, fsdp_dim=2)
    else:
        di = cfg.d_inner
        h = lay.ssm_heads_pad or cfg.ssm_heads
        gn = 1 * cfg.ssm_state
        leaves["wx"] = w((L, d, di), tp_dim=2, fsdp_dim=1)
        leaves["wz"] = w((L, d, di), tp_dim=2, fsdp_dim=1)
        leaves["wB"] = w((L, d, gn), fsdp_dim=1)
        leaves["wC"] = w((L, d, gn), fsdp_dim=1)
        leaves["wdt"] = w((L, d, h), tp_dim=2, fsdp_dim=1)
        leaves["dt_bias"] = w((L, h), tp_dim=1, init="zeros")
        leaves["conv_w"] = w((L, cfg.ssm_conv_k, di), tp_dim=2, init="conv")
        leaves["a_log"] = w((L, h), tp_dim=1, init="alog")
        leaves["d_skip"] = w((L, h), tp_dim=1, init="ones")
        leaves["out_proj"] = w((L, di, d), tp_dim=1, fsdp_dim=2)

    # FFN position: MLP or MoE (or both for arctic's dense residual);
    # pure-SSM layers (d_ff == 0, no MoE) have no FFN sublayer at all.
    needs_dense = (not is_moe) or cfg.dense_residual
    if (needs_dense and cfg.d_ff) or is_moe:
        leaves["ln2"] = w((L, d), init="ones")
    if needs_dense and cfg.d_ff:
        leaves["w_gate"] = w((L, d, cfg.d_ff), tp_dim=2, fsdp_dim=1)
        leaves["w_up"] = w((L, d, cfg.d_ff), tp_dim=2, fsdp_dim=1)
        leaves["w_down"] = w((L, cfg.d_ff, d), tp_dim=1, fsdp_dim=2)
    if is_moe:
        e = lay.experts_pad
        f = cfg.d_ff
        leaves["router"] = w((L, d, e), fsdp_dim=1)
        leaves["we_gate"] = w((L, e, d, f), tp_dim=1, fsdp_dim=2)
        leaves["we_up"] = w((L, e, d, f), tp_dim=1, fsdp_dim=2)
        leaves["we_down"] = w((L, e, f, d), tp_dim=1, fsdp_dim=3)
        if cfg.n_shared_experts:
            fs = cfg.n_shared_experts * cfg.d_ff
            leaves["ws_gate"] = w((L, d, fs), tp_dim=2, fsdp_dim=1)
            leaves["ws_up"] = w((L, d, fs), tp_dim=2, fsdp_dim=1)
            leaves["ws_down"] = w((L, fs, d), tp_dim=1, fsdp_dim=2)
    return leaves


def param_table(cfg: ModelConfig, *, tp: int = 1,
                fsdp_axes: tuple[str, ...] | None = None
                ) -> dict[str, Any]:
    """The complete leaf table: {'embed': ..., 'stages': [pos0, pos1, ...],
    'final_ln': ...}. Shapes are global (padded); specs are PartitionSpecs."""
    lay = TpLayout.build(cfg, tp)
    period = _period(cfg)
    assert cfg.n_layers % period == 0, (cfg.name, cfg.n_layers, period)
    n_repeat = cfg.n_layers // period
    fx = fsdp_axes

    def w(shape, tp_dim=None, fsdp_dim=None, init="normal"):
        spec = [None] * len(shape)
        if tp_dim is not None and tp > 1:
            spec[tp_dim] = "model"
        if fx is not None and fsdp_dim is not None:
            spec[fsdp_dim] = fx if len(fx) > 1 else fx[0]
        return Leaf(tuple(shape), P(*spec), fsdp_dim if fx else None, init)

    table: dict[str, Any] = {
        "embed": w((lay.vocab_pad, cfg.d_model), tp_dim=0, fsdp_dim=1),
        "final_ln": w((cfg.d_model,), init="ones"),
        "stages": [
            _layer_leaves(cfg, lay, pos, n_repeat, fx) for pos in range(period)
        ],
    }
    if not cfg.tie_embeddings:
        table["lm_head"] = w((cfg.d_model, lay.vocab_pad), tp_dim=1,
                             fsdp_dim=0)
    if cfg.frontend:
        table["frontend_proj"] = w((cfg.frontend_dim, cfg.d_model),
                                   fsdp_dim=0)
    return table


def _tree_map_table(fn: Callable[[Leaf], Any], table) -> Any:
    if isinstance(table, Leaf):
        return fn(table)
    if isinstance(table, dict):
        return {k: _tree_map_table(fn, v) for k, v in table.items()}
    if isinstance(table, list):
        return [_tree_map_table(fn, v) for v in table]
    raise TypeError(type(table))


def param_specs(cfg: ModelConfig, *, tp: int = 1, fsdp_axes=None):
    return _tree_map_table(lambda l: l.spec,
                           param_table(cfg, tp=tp, fsdp_axes=fsdp_axes))


def abstract_params(cfg: ModelConfig, *, tp: int = 1, fsdp_axes=None):
    dt = cfg.param_dtype
    return _tree_map_table(lambda l: jax.ShapeDtypeStruct(l.shape, dt),
                           param_table(cfg, tp=tp, fsdp_axes=fsdp_axes))


def init_params(key: jax.Array, cfg: ModelConfig, *, tp: int = 1,
                fsdp_axes=None, scale: float = 0.02):
    """Materialize parameters (single-host; used by smoke tests/examples)."""
    table = param_table(cfg, tp=tp, fsdp_axes=fsdp_axes)
    leaves_flat = jax.tree.leaves(table,
                                  is_leaf=lambda x: isinstance(x, Leaf))
    keys = jax.random.split(key, len(leaves_flat))
    it = iter(range(len(leaves_flat)))

    def mk(leaf: Leaf):
        i = next(it)
        if leaf.init == "zeros":
            return jnp.zeros(leaf.shape, cfg.param_dtype)
        if leaf.init == "ones":
            return jnp.ones(leaf.shape, cfg.param_dtype)
        if leaf.init == "alog":
            # A in [1, 16) -> a_log = log(A), mamba2 default
            u = jax.random.uniform(keys[i], leaf.shape, jnp.float32,
                                   1.0, 16.0)
            return jnp.log(u).astype(cfg.param_dtype)
        if leaf.init == "conv":
            fan = leaf.shape[-2]
            return (jax.random.normal(keys[i], leaf.shape, jnp.float32)
                    / math.sqrt(fan)).astype(cfg.param_dtype)
        fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
        std = min(scale, 1.0 / math.sqrt(fan_in))
        return (jax.random.normal(keys[i], leaf.shape, jnp.float32)
                * std).astype(cfg.param_dtype)

    return _tree_map_table(mk, table)


def count_params(cfg: ModelConfig) -> int:
    table = param_table(cfg, tp=1)
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(
        table, is_leaf=lambda x: isinstance(x, Leaf)))


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k of n_experts)."""
    if not cfg.n_experts:
        return count_params(cfg)
    table = param_table(cfg, tp=1)
    total = 0
    for path, leaf in _walk(table):
        n = int(np.prod(leaf.shape))
        if path.startswith("we_"):
            n = n * cfg.top_k // max(cfg.n_experts, 1)
        total += n
    return total


def _walk(table, prefix=""):
    if isinstance(table, Leaf):
        yield prefix, table
    elif isinstance(table, dict):
        for k, v in table.items():
            yield from _walk(v, k)
    elif isinstance(table, list):
        for v in table:
            yield from _walk(v, prefix)


# ------------------------------------------------------------------- forward
def _maybe_gather(pctx: ParallelCtx, w: jnp.ndarray, dim: int | None,
                  key: jax.Array | None) -> jnp.ndarray:
    if dim is None or not pctx.fsdp or pctx.gather is None:
        return w
    return pctx.gather(w, dim, key)


def _apply_layer(x, lw, cfg: ModelConfig, lay: TpLayout, pctx: ParallelCtx,
                 pos_in_period: int, *, positions, key,
                 cache=None, decode=False, pos=None, seq_shard_axis=None,
                 collect_state=False):
    """One layer (pre-norm residual). Returns (x, new_cache)."""
    table = _layer_leaves(cfg, lay, pos_in_period, 1, ("data",))
    # fsdp_dim in the table counts the stack dim; layer slices have it removed
    fsdp_dim = {k: (v.fsdp_dim - 1 if v.fsdp_dim is not None else None)
                for k, v in table.items()}

    def g(name):
        return _maybe_gather(pctx, lw[name], fsdp_dim.get(name), key)

    is_attn = cfg.is_attn_layer(pos_in_period)
    is_moe = cfg.is_moe_layer(pos_in_period)
    h = rms_norm(x, lw["ln1"])           # per-token: valid on a seq shard
    h = pctx.gather_seq(h)               # SP: (B, S/tp, D) -> (B, S, D)
    if pctx.sp and not decode:
        # sublayers see the full sequence; rebuild absolute positions
        positions = jnp.broadcast_to(
            jnp.arange(h.shape[1], dtype=jnp.int32), h.shape[:2])
    new_cache = cache
    if is_attn:
        wdict = {"wq": g("wq"), "wk": g("wk"), "wv": g("wv"), "wo": g("wo"),
                 "head_dim": cfg.dh, "attn_chunk": cfg.attn_chunk}
        shard = pctx.tp_index()
        kv_map = lay.kv_map(cfg, shard)
        kv_sel = lay.kv_select(shard)
        if decode:
            att, new_cache = attention_decode(
                h, wdict, cache, pctx, pos=pos, rope_theta=cfg.rope_theta,
                seq_shard_axis=seq_shard_axis, kv_map=kv_map,
                kv_select=kv_sel)
        elif collect_state:
            att, new_cache = attention_train(
                h, wdict, pctx, positions=positions,
                rope_theta=cfg.rope_theta, kv_map=kv_map, kv_select=kv_sel,
                collect_kv=True)
        else:
            att = attention_train(h, wdict, pctx, positions=positions,
                                  rope_theta=cfg.rope_theta, kv_map=kv_map,
                                  kv_select=kv_sel)
        x = x + att
    else:
        wdict = {"wx": g("wx"), "wz": g("wz"), "wB": g("wB"), "wC": g("wC"),
                 "wdt": g("wdt"), "dt_bias": lw["dt_bias"],
                 "conv_w": lw["conv_w"], "a_log": lw["a_log"],
                 "d_skip": lw["d_skip"], "out_proj": g("out_proj"),
                 "d_state": cfg.ssm_state, "n_groups": 1}
        y, new_cache = mamba2_forward(h, wdict, pctx, chunk=cfg.ssm_chunk,
                                      state=cache, decode=decode)
        x = x + y

    if "ln2" not in lw:                    # pure-SSM layer: no FFN sublayer
        return x, new_cache
    h2 = rms_norm(x, lw["ln2"])
    h2 = pctx.gather_seq(h2)
    ff = 0.0
    if "w_gate" in lw:
        ff = ff + gated_mlp(h2, {"w_gate": g("w_gate"), "w_up": g("w_up"),
                                 "w_down": g("w_down")}, pctx,
                            activation=cfg.activation)
    if is_moe:
        stationary = pctx.moe_stationary and pctx.fsdp
        if stationary:   # expert weights stay dp-sharded (§Perf H2)
            moe_w = {"router": g("router"), "we_gate": lw["we_gate"],
                     "we_up": lw["we_up"], "we_down": lw["we_down"]}
        else:
            moe_w = {"router": g("router"), "we_gate": g("we_gate"),
                     "we_up": g("we_up"), "we_down": g("we_down")}
        ff = ff + moe_block(h2, moe_w, pctx, top_k=cfg.top_k,
                            n_experts=cfg.n_experts,
                            capacity_factor=cfg.capacity_factor,
                            activation=cfg.activation,
                            weights_stationary=stationary)
        if cfg.n_shared_experts:
            ff = ff + gated_mlp(h2, {"w_gate": g("ws_gate"),
                                     "w_up": g("ws_up"),
                                     "w_down": g("ws_down")}, pctx,
                                activation=cfg.activation)
    return x + ff, new_cache


def embed_tokens(params, tokens, cfg: ModelConfig, lay: TpLayout,
                 pctx: ParallelCtx, key=None) -> jnp.ndarray:
    """Vocab-sharded embedding lookup: local-range gather + psum over tp."""
    emb = _maybe_gather(pctx, params["embed"], 1, key)   # (V_local, D)
    v_local = emb.shape[0]
    shard = pctx.tp_index()
    lo = shard * v_local
    local = tokens - lo
    valid = (local >= 0) & (local < v_local)
    safe = jnp.clip(local, 0, v_local - 1)
    out = jnp.take(emb, safe, axis=0)
    out = jnp.where(valid[..., None], out, 0).astype(cfg.param_dtype)
    return pctx.psum_tp(out)


def forward_hidden(params, batch, cfg: ModelConfig, pctx: ParallelCtx, *,
                   key: jax.Array, remat: bool = True,
                   collect_state: bool = False, unroll: bool = False):
    """Token/prefix embeddings -> final hidden states (B, S, D).

    With collect_state=True (prefill), also returns the per-stage decode
    state (KV caches / SSM states), stacked over the scan dim.
    """
    lay = TpLayout.build(cfg, pctx.tp_size())
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg, lay, pctx, key)
    if cfg.frontend and "prefix_embeds" in batch:
        proj = _maybe_gather(pctx, params["frontend_proj"], 0, key)
        pref = jnp.einsum("bpf,fd->bpd", batch["prefix_embeds"].astype(
            cfg.param_dtype), proj.astype(cfg.param_dtype))
        x = jnp.concatenate([pref, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if pctx.sp and pctx.tp_axis:
        # residual stream sharded over tp along seq (Megatron-SP); x is
        # replicated over tp here, so every shard just takes its slice
        tpn = pctx.tp_size()
        s_l = s // tpn
        x = jax.lax.dynamic_slice_in_dim(
            x, pctx.tp_index() * s_l, s_l, axis=1)
    period = _period(cfg)

    def body(carry, stage_params):
        xc, idx = carry
        states = []
        for pos in range(period):
            # serialize layer scheduling: without the barrier (on weights
            # too — their layout copies don't depend on xc and would be
            # hoisted) XLA's latency-oriented scheduler overlaps several
            # layers' temporaries (jamba prefill measured 55 GiB/dev)
            xc, lw = compat.optimization_barrier((xc, stage_params[pos]))
            lkey = jax.random.fold_in(key, idx * period + pos)
            xc, st = _apply_layer(xc, lw, cfg, lay, pctx, pos,
                                  positions=positions, key=lkey,
                                  collect_state=collect_state)
            states.append(st)
        return (xc, idx + 1), (states if collect_state else None)

    if remat and not collect_state:
        body = jax.checkpoint(body)
    stages = params["stages"]  # list over period of stacked leaves
    if unroll:
        # Python-loop form: no while-loop in HLO, so cost_analysis counts
        # every layer (the dry-run cost model compiles shallow unrolled
        # variants; scan undercounts loop bodies — see launch/dryrun.py)
        n_repeat = jax.tree.leaves(stages)[0].shape[0]
        carry = (x, jnp.zeros((), jnp.int32))
        collected = []
        for r in range(n_repeat):
            stage_r = jax.tree.map(lambda a: a[r], stages)
            carry, st = body(carry, stage_r)
            collected.append(st)
        x, _ = carry
        states = (jax.tree.map(lambda *xs: jnp.stack(xs), *collected)
                  if collect_state else None)
    else:
        (x, _), states = jax.lax.scan(body, (x, jnp.zeros((), jnp.int32)),
                                      stages)
    x = rms_norm(x, params["final_ln"])
    if pctx.sp and pctx.tp_axis:
        # restore the full sequence for the (vocab-sharded) head
        x = jax.lax.all_gather(x, pctx.tp_axis, axis=1, tiled=True)
    if collect_state:
        return x, states
    return x


def lm_loss(params, batch, cfg: ModelConfig, pctx: ParallelCtx, *,
            key: jax.Array, seq_chunk: int = 1024,
            remat: bool = True, unroll: bool = False) -> jnp.ndarray:
    """Mean next-token cross-entropy with a vocab-sharded, seq-chunked,
    rematerialized softmax (full logits are never materialized)."""
    x = forward_hidden(params, batch, cfg, pctx, key=key, remat=remat,
                       unroll=unroll)
    labels = batch["labels"]
    p = x.shape[1] - labels.shape[1]
    if p:
        x = x[:, p:]                      # loss only on token positions
    head = params.get("lm_head")
    if head is None:
        emb = _maybe_gather(pctx, params["embed"], 1, key)
        head_l = emb.T                    # (D, V_local)
    else:
        head_l = _maybe_gather(pctx, head, 0, key)
    v_local = head_l.shape[1]
    shard = pctx.tp_index()
    lo = shard * v_local

    b, s, d = x.shape
    chunk = math.gcd(min(seq_chunk, s), s)   # frontend prefixes may leave
    # a token count that is not a multiple of the requested chunk
    xc = x.reshape(b, s // chunk, chunk, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(b, s // chunk, chunk).transpose(1, 0, 2)

    def chunk_loss(x_chunk, y_chunk):
        logits = jnp.einsum("bcd,dv->bcv", x_chunk.astype(jnp.float32),
                            head_l.astype(jnp.float32))
        # max-shift is gradient-neutral; pmax has no VJP, so detach first
        m = pctx.pmax_tp(jnp.max(jax.lax.stop_gradient(logits), axis=-1))
        z = pctx.psum_tp(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
        local_y = y_chunk - lo
        valid = (local_y >= 0) & (local_y < v_local)
        safe = jnp.clip(local_y, 0, v_local - 1)
        picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        correct = pctx.psum_tp(jnp.where(valid, picked, 0.0))
        weight = (y_chunk >= 0).astype(jnp.float32)
        nll = (jnp.log(z) + m - correct) * weight
        return jnp.sum(nll), jnp.sum(weight)

    if remat:
        chunk_loss = jax.checkpoint(chunk_loss)

    if xc.shape[0] == 1 or unroll:
        total = jnp.zeros(())
        count = jnp.zeros(())
        for i in range(xc.shape[0]):
            l, w = chunk_loss(xc[i], yc[i])
            total = total + l
            count = count + w
    else:
        def scan_body(carry, inp):
            tot, cnt = carry
            l, w = chunk_loss(*inp)
            return (tot + l, cnt + w), None

        (total, count), _ = jax.lax.scan(
            scan_body, (jnp.zeros(()), jnp.zeros(())), (xc, yc))
    return total / jnp.maximum(count, 1.0)


def prefill_step(params, batch, cfg: ModelConfig, pctx: ParallelCtx, *,
                 key: jax.Array, unroll: bool = False):
    """Serving prefill: consume the prompt, return (first_token, state).

    State leaves are stacked over the scan dim, matching init_decode_state's
    layout, so decode_step can consume them directly.
    """
    x, states = forward_hidden(params, batch, cfg, pctx, key=key,
                               remat=False, collect_state=True,
                               unroll=unroll)
    head = params.get("lm_head")
    if head is None:
        emb = _maybe_gather(pctx, params["embed"], 1, key)
        head_l = emb.T
    else:
        head_l = _maybe_gather(pctx, head, 0, key)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        head_l.astype(jnp.float32))
    v_local = logits.shape[-1]
    shard = pctx.tp_index()
    best_local = jnp.max(logits, axis=-1)
    best_idx = jnp.argmax(logits, axis=-1) + shard * v_local
    best = pctx.pmax_tp(best_local)
    winner = jnp.where(best_local >= best, best_idx, 0)
    next_tok = pctx.psum_tp(winner).astype(jnp.int32)
    return next_tok[:, None], states


# -------------------------------------------------------------------- decode
def init_decode_state(params_like, cfg: ModelConfig, *, batch: int,
                      max_seq: int, tp: int = 1, seq_shards: int = 1,
                      dtype=jnp.bfloat16):
    """Abstract/zero decode state matching the stage structure.

    KV caches: (n_repeat, B, S_max/seq_shards, kv_local, dh);
    SSM states: conv (n_repeat, B, K-1, d_inner_local) +
    ssm (n_repeat, B, H_local, P, N) fp32. Returned as a list over period
    positions (None-free pytree: attention layers get KVCache, ssm SSMState).
    """
    lay = TpLayout.build(cfg, tp)
    period = _period(cfg)
    n_repeat = cfg.n_layers // period
    s_local = max_seq // seq_shards
    states = []
    for pos in range(period):
        if cfg.is_attn_layer(pos):
            kv_l = lay.kv_local
            shape = (n_repeat, batch, s_local, kv_l, cfg.dh)
            states.append(KVCache(k=jnp.zeros(shape, dtype),
                                  v=jnp.zeros(shape, dtype)))
        else:
            di_l = cfg.d_inner // tp
            h_l = (lay.ssm_heads_pad or cfg.ssm_heads) // tp
            p = cfg.ssm_head_dim
            states.append(SSMState(
                conv=jnp.zeros((n_repeat, batch, cfg.ssm_conv_k - 1, di_l),
                               dtype),
                ssm=jnp.zeros((n_repeat, batch, h_l, p, cfg.ssm_state),
                              jnp.float32)))
    return states


def decode_step(params, state, tokens, pos, cfg: ModelConfig,
                pctx: ParallelCtx, *, key: jax.Array,
                seq_shard_axis=None, unroll: bool = False):
    """One greedy decode step. tokens: (B, 1) -> (next_tokens, new_state)."""
    lay = TpLayout.build(cfg, pctx.tp_size())
    x = embed_tokens(params, tokens, cfg, lay, pctx, key)
    period = _period(cfg)

    def body(carry, inp):
        xc, idx = carry
        stage_params, stage_state = inp
        new_states = []
        for p_ in range(period):
            # see forward_hidden: barrier weights + activations per layer
            xc, lw = compat.optimization_barrier((xc, stage_params[p_]))
            lkey = jax.random.fold_in(key, idx * period + p_)
            xc, ns = _apply_layer(
                xc, lw, cfg, lay, pctx, p_, positions=None,
                key=lkey, cache=stage_state[p_], decode=True, pos=pos,
                seq_shard_axis=seq_shard_axis)
            new_states.append(ns)
        return (xc, idx + 1), new_states

    if unroll:
        n_repeat = jax.tree.leaves(params["stages"])[0].shape[0]
        carry = (x, jnp.zeros((), jnp.int32))
        collected = []
        for r in range(n_repeat):
            inp = jax.tree.map(lambda a: a[r], (params["stages"], state))
            carry, st = body(carry, inp)
            collected.append(st)
        x, _ = carry
        new_state = jax.tree.map(lambda *xs: jnp.stack(xs), *collected)
    else:
        # Cache lives in the CARRY (updated in place with
        # dynamic_update_slice), not in xs/ys: through-scan xs->ys caches
        # would hold TWO full copies live (the decode_32k cells measured
        # +5..11 GiB/device from exactly that; see EXPERIMENTS §Dry-run).
        def carry_body(carry, stage_params):
            xc, idx, cache_all = carry
            stage_state = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0,
                                                       keepdims=False),
                cache_all)
            (xc, idx2), new_st = body((xc, idx), (stage_params, stage_state))
            cache_all = jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), idx, 0),
                cache_all, new_st)
            return (xc, idx2, cache_all), None

        (x, _, new_state), _ = jax.lax.scan(
            carry_body, (x, jnp.zeros((), jnp.int32), state),
            params["stages"])
    x = rms_norm(x, params["final_ln"])

    head = params.get("lm_head")
    if head is None:
        emb = _maybe_gather(pctx, params["embed"], 1, key)
        head_l = emb.T
    else:
        head_l = _maybe_gather(pctx, head, 0, key)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        head_l.astype(jnp.float32))[:, 0]   # (B, V_local)
    v_local = logits.shape[-1]
    shard = pctx.tp_index()
    best_local = jnp.max(logits, axis=-1)
    best_idx = jnp.argmax(logits, axis=-1) + shard * v_local
    best = pctx.pmax_tp(best_local)
    # break ties toward the winning shard; exact for continuous logits
    winner = jnp.where(best_local >= best, best_idx, 0)
    next_tok = pctx.psum_tp(winner).astype(jnp.int32)
    return next_tok[:, None], new_state
