"""Transformer building blocks: norms, RoPE, GQA attention, gated MLP.

All functions are pure and shape-agnostic: head counts and hidden sizes are
read from the (possibly tensor-parallel-local) weight arrays, so the same
code serves the single-device smoke tests and the sharded production mesh.
Compute dtype is bf16 with fp32 accumulation on matmuls/softmax statistics.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import compat

from .parallel import ParallelCtx


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, *,
               eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, *, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, S, H, dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta=theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


class KVCache(NamedTuple):
    """Decode-time cache. k/v: (B, S_max, Hkv, dh) — locally sharded either
    on batch (dp) or on sequence (flash-decoding split for tiny batches)."""
    k: jnp.ndarray
    v: jnp.ndarray


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
        .reshape(b, s, h * n_rep, d)


def _map_kv(k: jnp.ndarray, hq: int, kv_map: jnp.ndarray | None) -> jnp.ndarray:
    """Expand KV heads to match the local query heads.

    ``kv_map`` (Hq_local,) gives each local q head its KV head index in the
    local cache — needed when KV heads are replicated across tp shards or
    padded; defaults to the contiguous-group GQA mapping."""
    if kv_map is None:
        return _repeat_kv(k, hq // k.shape[2])
    return jnp.take(k, kv_map, axis=2)


def _chunked_causal_attention(q, k, v, *, scale: float, chunk: int):
    """Flash-style online-softmax attention over KV blocks: the (S, S)
    score matrix is never materialized (prefill_32k feasibility). q, k, v:
    (B, S, H, dh) with H already expanded to the query head count."""
    b, s, h, dh = q.shape
    nq = s // chunk

    qc = q.reshape(b, nq, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def q_block(_, qi_idx):
        qi, i = qi_idx
        # running (out, max, denom) over kv blocks
        o0 = jnp.zeros((b, chunk, h, dh), jnp.float32)
        m0 = jnp.full((b, h, chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, chunk), jnp.float32)

        def kv_block(carry, j):
            o, m, l = carry
            kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, axis=1)
            sc = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                            preferred_element_type=jnp.float32) * scale
            # causal mask between block i (rows) and block j (cols)
            rows = i * chunk + jnp.arange(chunk)
            cols = j * chunk + jnp.arange(chunk)
            mask = rows[:, None] >= cols[None, :]
            sc = jnp.where(mask[None, None], sc, -1e30)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype),
                            vj).astype(jnp.float32)
            o_new = o * corr.transpose(0, 2, 1)[..., None] + pv
            return (o_new, m_new, l_new), None

        (o, m, l), _ = jax.lax.scan(kv_block, (o0, m0, l0),
                                    jnp.arange(nq))
        # blocks j > i contribute nothing (fully masked); scanning all nq
        # keeps the trip count static — XLA skips masked work poorly but
        # correctness is exact. (§Perf iterates on this.)
        out = o / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
        return None, out

    _, outs = jax.lax.scan(q_block, None, (qc, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return out.astype(q.dtype)


def attention_train(x: jnp.ndarray, w: dict, pctx: ParallelCtx, *,
                    positions: jnp.ndarray, rope_theta: float = 10000.0,
                    causal: bool = True,
                    kv_map: jnp.ndarray | None = None,
                    kv_select: jnp.ndarray | None = None,
                    collect_kv: bool = False):
    """Full (causal) attention for train/prefill. x: (B, S, D) replicated
    over tp; w holds local shards: wq (D, Hq_l*dh), wk/wv (D, Hkv_l*dh),
    wo (Hq_l*dh, D). Output is psum'd over tp (row-parallel wo).
    With collect_kv, also returns the post-RoPE KVCache (prefill path)."""
    b, s, _ = x.shape
    dh = w["head_dim"]
    q = jnp.einsum("bsd,dh->bsh", x, w["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, w["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, w["wv"].astype(x.dtype))
    hq = q.shape[-1] // dh
    hkv = k.shape[-1] // dh
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, theta=rope_theta)
    k = apply_rope(k, positions, theta=rope_theta)
    del hkv
    if kv_select is not None:
        # this tp shard keeps exactly one KV head (the one its q heads use)
        k = jax.lax.dynamic_slice_in_dim(k, kv_select, 1, axis=2)
        v = jax.lax.dynamic_slice_in_dim(v, kv_select, 1, axis=2)
    k_raw, v_raw = k, v                   # (B, S, Hkv_l, dh) pre-expansion
    k = _map_kv(k, hq, kv_map)
    v = _map_kv(v, hq, kv_map)

    scale = 1.0 / math.sqrt(dh)
    attn_chunk = w.get("attn_chunk", 0)
    if attn_chunk and s > attn_chunk:
        ctx = _chunked_causal_attention(q, k, v, scale=scale,
                                        chunk=attn_chunk)
    else:
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * scale
        if causal:
            mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
            logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    ctx = ctx.reshape(b, s, hq * dh)
    out = jnp.einsum("bsh,hd->bsd", ctx, w["wo"].astype(x.dtype))
    out = pctx.reduce_output(out)   # psum, or psum_scatter(seq) under SP
    if collect_kv:
        return out, KVCache(k=k_raw, v=v_raw)
    return out


def attention_decode(x: jnp.ndarray, w: dict, cache: KVCache,
                     pctx: ParallelCtx, *, pos: jnp.ndarray,
                     rope_theta: float = 10000.0,
                     seq_shard_axis=None,
                     kv_map: jnp.ndarray | None = None,
                     kv_select: jnp.ndarray | None = None
                     ) -> tuple[jnp.ndarray, KVCache]:
    """One-token decode against a KV cache. x: (B, 1, D).

    ``seq_shard_axis``: when set (tiny global batch, e.g. long_500k), the
    cache's sequence dim is sharded over that mesh axis and attention is
    merged with flash-decoding-style partial-softmax statistics (psum of
    renormalized numerators / denominators).
    """
    b, _, _ = x.shape
    dh = w["head_dim"]
    q = jnp.einsum("bsd,dh->bsh", x, w["wq"].astype(x.dtype))
    k_new = jnp.einsum("bsd,dh->bsh", x, w["wk"].astype(x.dtype))
    v_new = jnp.einsum("bsd,dh->bsh", x, w["wv"].astype(x.dtype))
    hq = q.shape[-1] // dh
    hkv = k_new.shape[-1] // dh
    q = q.reshape(b, 1, hq, dh)
    k_new = k_new.reshape(b, 1, hkv, dh)
    v_new = v_new.reshape(b, 1, hkv, dh)
    posb = jnp.broadcast_to(pos.reshape(1, 1), (b, 1))
    q = apply_rope(q, posb, theta=rope_theta)
    k_new = apply_rope(k_new, posb, theta=rope_theta)
    if kv_select is not None:
        k_new = jax.lax.dynamic_slice_in_dim(k_new, kv_select, 1, axis=2)
        v_new = jax.lax.dynamic_slice_in_dim(v_new, kv_select, 1, axis=2)

    s_local = cache.k.shape[1]
    if seq_shard_axis is None:
        k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                         (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                         (0, pos, 0, 0))
        valid = jnp.arange(s_local) <= pos
        new_cache = KVCache(k=k, v=v)
    else:
        # sequence-sharded cache: only the owning shard writes the new token.
        # seq_shard_axis may be a tuple of mesh axes (e.g. ('pod', 'data')):
        # linearize with the first axis major, matching P(('pod','data')).
        axes = ((seq_shard_axis,) if isinstance(seq_shard_axis, str)
                else tuple(seq_shard_axis))
        shard = jnp.zeros((), jnp.int32)
        for ax in axes:
            shard = shard * compat.axis_size(ax) + jax.lax.axis_index(ax)
        start = shard * s_local
        local_pos = jnp.clip(pos - start, 0, s_local - 1)
        owns = (pos >= start) & (pos < start + s_local)
        k_upd = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, local_pos, 0, 0))
        v_upd = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, local_pos, 0, 0))
        k = jnp.where(owns, k_upd, cache.k)
        v = jnp.where(owns, v_upd, cache.v)
        valid = (jnp.arange(s_local) + start) <= pos
        new_cache = KVCache(k=k, v=v)

    del hkv
    kk = _map_kv(k, hq, kv_map)
    vv = _map_kv(v, hq, kv_map)
    scale = 1.0 / math.sqrt(dh)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)

    if seq_shard_axis is None:
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    else:
        # partial softmax merge across sequence shards
        m_local = jnp.max(logits, axis=-1, keepdims=True)
        m = jax.lax.pmax(m_local, seq_shard_axis)
        p = jnp.exp(logits - m)
        num = jnp.einsum("bhqk,bkhd->bqhd", p.astype(jnp.float32),
                         vv.astype(jnp.float32))
        den = jnp.sum(p, axis=-1)                     # (b, h, 1)
        num = jax.lax.psum(num, seq_shard_axis)
        den = jax.lax.psum(den, seq_shard_axis)
        ctx = (num / den.transpose(0, 2, 1)[..., None]).astype(x.dtype)
    ctx = ctx.reshape(b, 1, hq * dh)
    out = jnp.einsum("bsh,hd->bsd", ctx, w["wo"].astype(x.dtype))
    return pctx.psum_tp(out), new_cache


def gated_mlp(x: jnp.ndarray, w: dict, pctx: ParallelCtx, *,
              activation: str = "silu") -> jnp.ndarray:
    """SwiGLU (or GELU-gated) MLP. w_gate/w_up column-parallel,
    w_down row-parallel + psum."""
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    g = jnp.einsum("bsd,df->bsf", x, w["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w["w_up"].astype(x.dtype))
    h = act(g) * u
    out = jnp.einsum("bsf,fd->bsd", h, w["w_down"].astype(x.dtype))
    return pctx.reduce_output(out)
