"""Top-k Mixture-of-Experts with expert parallelism over the tp axis.

Dispatch is capacity-based with scatter/gather (no (T, E, C) one-hot — the
position-in-expert comes from a cumulative sum), which keeps the dry-run HLO
static-shaped and the memory bounded by (E_local, C, D).

Expert placement: experts are sharded over the tensor-parallel ('model')
axis (E_local = E_padded / tp). Activations are replicated over tp between
blocks, so each shard routes *all* local tokens but only computes its own
experts; the final psum over tp both sums expert contributions and restores
replication — EP costs exactly one psum, fused with the block's output
reduction. Router weights are replicated (tiny).

Padded experts (when E % tp != 0, e.g. qwen2's 60 -> 64) are masked to
-inf in the router so they are never selected.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .parallel import ParallelCtx


def moe_block(x: jnp.ndarray, w: dict, pctx: ParallelCtx, *,
              top_k: int, n_experts: int, capacity_factor: float = 1.25,
              activation: str = "silu",
              weights_stationary: bool = False) -> jnp.ndarray:
    """x: (B, S, D) replicated over tp. w:
      router (D, E_pad) replicated; we_gate/we_up (E_local, D, F),
      we_down (E_local, F, D) — expert dim sharded over tp.
    Returns (B, S, D), psum'd over tp.
    """
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    e_pad = w["router"].shape[-1]
    e_local = w["we_gate"].shape[0]
    tp = e_pad // e_local
    shard = pctx.tp_index()

    logits = jnp.einsum("td,de->te", xt, w["router"].astype(xt.dtype))
    logits = logits.astype(jnp.float32)
    if e_pad > n_experts:                      # mask padded experts
        pad_mask = jnp.arange(e_pad) >= n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    gate_vals, gate_idx = jax.lax.top_k(logits, top_k)        # (T, K)
    gates = jax.nn.softmax(gate_vals, axis=-1)                # (T, K)

    capacity = max(1, int(capacity_factor * top_k * t / e_pad))

    # position of each (token, slot) within its expert, over all K slots
    onehot = jax.nn.one_hot(gate_idx, e_pad, dtype=jnp.int32)  # (T, K, E)
    flat = onehot.reshape(t * top_k, e_pad)
    pos_flat = jnp.cumsum(flat, axis=0) - 1                    # (T*K, E)
    pos = jnp.take_along_axis(
        pos_flat.reshape(t, top_k, e_pad),
        gate_idx[..., None], axis=-1)[..., 0]                  # (T, K)
    keep = pos < capacity

    # local experts of this shard: e in [shard*e_local, (shard+1)*e_local)
    local_idx = gate_idx - shard * e_local                     # (T, K)
    is_local = (local_idx >= 0) & (local_idx < e_local) & keep
    safe_e = jnp.clip(local_idx, 0, e_local - 1)
    safe_p = jnp.clip(pos, 0, capacity - 1)

    buf = jnp.zeros((e_local, capacity, d), xt.dtype)
    contrib = jnp.where(is_local[..., None], xt[:, None, :], 0.0)
    buf = buf.at[safe_e, safe_p].add(contrib)                  # (E_l, C, D)

    if weights_stationary:
        # expert weights stay fsdp-sharded on D: compute with the local D
        # slice, psum the (E_l, C, F_l) activations over the dp axes —
        # decode moves E_l*C*F_l activation bytes instead of E_l*D*F
        # weight bytes (~1000x less at batch 128; §Perf H2)
        d_l = w["we_gate"].shape[1]
        i = pctx.dp_shard_index()
        buf_slice = jax.lax.dynamic_slice_in_dim(buf, i * d_l, d_l, axis=2)
        g = jnp.einsum("ecd,edf->ecf", buf_slice,
                       w["we_gate"].astype(xt.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf_slice,
                       w["we_up"].astype(xt.dtype))
        g = pctx.psum_dp(g)
        u = pctx.psum_dp(u)
        h = act(g) * u
        # we_down local (E_l, F_l, D/dp): each dp shard produces its D slice
        out_slice = jnp.einsum("ecf,efd->ecd", h,
                               w["we_down"].astype(xt.dtype))
        out_buf = jax.lax.all_gather(out_slice, pctx.dp_axes(), axis=2,
                                     tiled=True)          # (E_l, C, D)
    else:
        g = jnp.einsum("ecd,edf->ecf", buf, w["we_gate"].astype(xt.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, w["we_up"].astype(xt.dtype))
        h = act(g) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, w["we_down"].astype(xt.dtype))

    gathered = out_buf[safe_e, safe_p]                         # (T, K, D)
    gathered = jnp.where(is_local[..., None], gathered, 0.0)
    combined = jnp.sum(gathered * gates[..., None].astype(xt.dtype), axis=1)
    out = combined.reshape(b, s, d)
    return pctx.reduce_output(out)


def moe_aux_loss(logits_f32: jnp.ndarray, gate_idx: jnp.ndarray,
                 n_experts: int) -> jnp.ndarray:
    """Switch-style load-balancing loss (mean gate prob x mean assignment)."""
    probs = jax.nn.softmax(logits_f32, axis=-1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], probs.shape[-1]), axis=0)
    return n_experts * jnp.sum(me * ce)
