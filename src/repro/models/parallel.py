"""Parallelism context for manual-SPMD model code.

All model code runs inside a ``shard_map`` body (or on a single device for
smoke tests). ``ParallelCtx`` carries the mesh axis names; when an axis is
None the corresponding collective is the identity, so the *same* model code
runs single-device (CPU tests) and fully sharded (dry-run / production).

Tensor-parallel layout (manual Megatron-style):
  column-parallel:  W (D, F/tp) local -> local matmul, no collective
  row-parallel:     W (F/tp, D) local -> local matmul + psum over tp
  activations are replicated over tp between blocks (sequence-parallel
  variant available as a perf option — see train/trainer.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    tp_axis: str | None = None      # 'model'
    dp_axis: str | None = None      # 'data'  (fsdp gathers + grad sync)
    pod_axis: str | None = None     # 'pod'   (multi-pod meshes)
    fsdp: bool = False              # params sharded over dp_axis
    # fsdp weight gather: (w_local, dim, key) -> w_full. The trainer installs
    # a custom-VJP version whose backward is the OptiReduce reduce-scatter.
    gather: Callable | None = None
    # serving: keep MoE expert weights sharded over the dp axes and psum the
    # (tiny) expert activations instead of gathering the (huge) weights —
    # decode is weights-dominated, so this removes the collective bottleneck
    # (§Perf H2). Dense/attn weights still gather.
    moe_stationary: bool = False
    # sequence parallelism (Megatron-SP): the residual stream between
    # blocks is sharded over tp along the sequence dim; sublayers gather it
    # and reduce-scatter their output (same wire bytes as the psum it
    # replaces, but the per-layer saved residual shrinks by 1/tp — the
    # §Perf H3 memory lever).
    sp: bool = False

    def gather_seq(self, x):
        """(B, S/tp, D) -> (B, S, D) at a sublayer input."""
        if not (self.sp and self.tp_axis):
            return x
        return jax.lax.all_gather(x, self.tp_axis, axis=1, tiled=True)

    def reduce_output(self, x):
        """Row-parallel output reduction: psum, or psum_scatter over the
        sequence dim under sequence parallelism."""
        if not self.tp_axis:
            return x
        if self.sp:
            return jax.lax.psum_scatter(x, self.tp_axis,
                                        scatter_dimension=1, tiled=True)
        return jax.lax.psum(x, self.tp_axis)

    def dp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in (self.pod_axis, self.dp_axis) if a)

    def dp_shard_index(self) -> jnp.ndarray:
        """Linear index over (pod, data) — matches P(('pod','data'))."""
        idx = jnp.zeros((), jnp.int32)
        for ax in self.dp_axes():
            idx = idx * compat.axis_size(ax) + jax.lax.axis_index(ax)
        return idx

    def psum_dp(self, x):
        axes = self.dp_axes()
        return jax.lax.psum(x, axes) if axes else x

    def tp_size(self) -> int:
        return compat.axis_size(self.tp_axis) if self.tp_axis else 1

    def dp_size(self) -> int:
        n = compat.axis_size(self.dp_axis) if self.dp_axis else 1
        if self.pod_axis:
            n *= compat.axis_size(self.pod_axis)
        return n

    def tp_index(self) -> jnp.ndarray:
        if self.tp_axis is None:
            return jnp.zeros((), jnp.int32)
        return jax.lax.axis_index(self.tp_axis)

    def psum_tp(self, x):
        return jax.lax.psum(x, self.tp_axis) if self.tp_axis else x

    def pmax_tp(self, x):
        return jax.lax.pmax(x, self.tp_axis) if self.tp_axis else x

    def all_gather_dp(self, x, axis: int):
        """FSDP weight gather (identity when not fsdp)."""
        if not (self.fsdp and self.dp_axis):
            return x
        return jax.lax.all_gather(x, self.dp_axis, axis=axis, tiled=True)


# A no-parallelism context for single-device smoke tests / references.
SINGLE = ParallelCtx()
