"""Mamba-2 (SSD, state-space duality) layer — chunked train form + O(1)
recurrent decode form. Follows the minimal SSD listing of Dao & Gu
(arXiv:2405.21060): intra-chunk quadratic term + inter-chunk state scan.

Tensor parallelism: heads (and the d_inner channels they own) are sharded
over the tp axis; B/C projections are per-group (n_groups small) and
replicated; out_proj is row-parallel with a psum. Decode carries
(conv_state, ssm_state) per layer — constant memory in sequence length,
which is what makes the 500k-token decode shape feasible.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .parallel import ParallelCtx


class SSMState(NamedTuple):
    conv: jnp.ndarray   # (B, K-1, d_inner_local)
    ssm: jnp.ndarray    # (B, H_local, P, N) fp32


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i>=j)."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_), 0)
    return jnp.where(mask, out, -jnp.inf)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv. x: (B, S, C), w: (K, C). Returns (y, new_state)
    where state holds the trailing K-1 inputs for decode continuation."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad
    return y, new_state


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 256,
                init_state: jnp.ndarray | None = None):
    """SSD forward. x: (B, S, H, P); dt: (B, S, H) (post-softplus);
    a_log: (H,); b, c: (B, S, G, N) with H % G == 0.
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    bs, s, h, p = x.shape
    g = b.shape[2]
    n = b.shape[3]
    pad = (-s) % chunk
    if pad:
        # zero-pad the tail: dt=0 makes padded steps identity transitions
        # (decay exp(0)=1, zero state contribution), so the final state and
        # the first s outputs are exact
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s_out = s
        s = s + pad
    else:
        s_out = s
    nc = s // chunk
    rep = h // g

    a = -jnp.exp(a_log.astype(jnp.float32))                       # (H,)
    dta = dt.astype(jnp.float32) * a[None, None, :]               # (B, S, H)

    xc = x.reshape(bs, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(bs, nc, chunk, h).astype(jnp.float32)
    dtac = dta.reshape(bs, nc, chunk, h)
    bc = jnp.repeat(b.reshape(bs, nc, chunk, g, n), rep, axis=3)  # (B,NC,C,H,N)
    cc = jnp.repeat(c.reshape(bs, nc, chunk, g, n), rep, axis=3)
    bc = bc.astype(jnp.float32)
    cc = cc.astype(jnp.float32)

    # intra-chunk (quadratic) term
    seg = _segsum(dtac.transpose(0, 1, 3, 2))                     # (B,NC,H,C,C)
    decay = jnp.exp(seg)
    scores = jnp.einsum("zcihn,zcjhn,zchij->zchij", cc, bc, decay)
    y_intra = jnp.einsum("zchij,zcjhp,zcjh->zcihp", scores, xc, dtc)

    # chunk states: contribution of each chunk to the running state
    decay_to_end = jnp.exp(jnp.cumsum(dtac, axis=2)[:, :, -1:, :]
                           - jnp.cumsum(dtac, axis=2))            # (B,NC,C,H)
    states = jnp.einsum("zcjhn,zcjh,zcjhp->zchpn",
                        bc, decay_to_end * dtc, xc)               # (B,NC,H,P,N)

    # inter-chunk scan: carry state with per-chunk total decay
    chunk_decay = jnp.exp(jnp.sum(dtac, axis=2))                  # (B,NC,H)

    def scan_fn(carry, inp):
        st_in = carry                                             # (B,H,P,N)
        st_chunk, dec = inp
        st_out = st_in * dec[:, :, None, None] + st_chunk
        return st_out, st_in

    init = (jnp.zeros((bs, h, p, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, states_in = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)                # (B,NC,H,P,N)

    # inter-chunk output: y_inter[t] = C_t . (decay into t) state_in
    decay_from_start = jnp.exp(jnp.cumsum(dtac, axis=2))          # (B,NC,C,H)
    y_inter = jnp.einsum("zcihn,zcih,zchpn->zcihp",
                         cc, decay_from_start, states_in)

    y = (y_intra + y_inter).reshape(bs, s, h, p)
    if s_out != s:
        y = y[:, :s_out]
    return y.astype(x.dtype), final_state


def ssd_decode_step(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray,
                    state: jnp.ndarray):
    """One-token recurrence. x: (B, 1, H, P); dt: (B, 1, H);
    b, c: (B, 1, G, N); state: (B, H, P, N) fp32. Returns (y, new_state)."""
    bs, _, h, p = x.shape
    g = b.shape[2]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = jnp.exp(dt[:, 0].astype(jnp.float32) * a[None, :])      # (B, H)
    bh = jnp.repeat(b[:, 0], rep, axis=1).astype(jnp.float32)     # (B, H, N)
    ch = jnp.repeat(c[:, 0], rep, axis=1).astype(jnp.float32)
    xf = x[:, 0].astype(jnp.float32)                              # (B, H, P)
    dtf = dt[:, 0].astype(jnp.float32)
    new_state = state * dta[:, :, None, None] + \
        jnp.einsum("zhn,zh,zhp->zhpn", bh, dtf, xf)
    y = jnp.einsum("zhn,zhpn->zhp", ch, new_state)
    return y[:, None].astype(x.dtype), new_state


def mamba2_forward(x: jnp.ndarray, w: dict, pctx: ParallelCtx, *,
                   chunk: int = 256,
                   state: SSMState | None = None,
                   decode: bool = False):
    """Mamba-2 block. x: (B, S, D) replicated over tp.

    w: wx/wz (D, d_inner_l), wB/wC (D, G*N) replicated, wdt (D, H_l),
    conv_w (K, d_inner_l), a_log (H_l,), d_skip (H_l,), dt_bias (H_l,),
    out_proj (d_inner_l, D), norm_scale (d_inner_l,).
    Returns (y, new_state); y psum'd over tp.
    """
    bsz, s, _ = x.shape
    n = w["d_state"]
    g = w["n_groups"]
    xz = jnp.einsum("bsd,di->bsi", x, w["wx"].astype(x.dtype))
    z = jnp.einsum("bsd,di->bsi", x, w["wz"].astype(x.dtype))
    bproj = jnp.einsum("bsd,dk->bsk", x, w["wB"].astype(x.dtype))
    cproj = jnp.einsum("bsd,dk->bsk", x, w["wC"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, w["wdt"].astype(x.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         w["dt_bias"].astype(jnp.float32))

    conv_state = state.conv if state is not None else None
    xz, new_conv = _causal_conv(jax.nn.silu(xz), w["conv_w"].astype(x.dtype),
                                conv_state)

    h_local = w["a_log"].shape[0]
    p = xz.shape[-1] // h_local
    xh = xz.reshape(bsz, s, h_local, p)
    bmat = bproj.reshape(bsz, s, g, n)
    cmat = cproj.reshape(bsz, s, g, n)
    # replicate groups onto local heads (G is global & small; tp shards heads)
    if decode:
        ssm_in = state.ssm if state is not None else \
            jnp.zeros((bsz, h_local, p, n), jnp.float32)
        y, new_ssm = ssd_decode_step(xh, dt, w["a_log"], bmat, cmat, ssm_in)
    else:
        ssm_in = state.ssm if state is not None else None
        y, new_ssm = ssd_chunked(xh, dt, w["a_log"], bmat, cmat, chunk=chunk,
                                 init_state=ssm_in)
    y = y + xh * w["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, h_local * p)
    y = y * jax.nn.silu(z)                          # gated output
    out = jnp.einsum("bsi,id->bsd", y, w["out_proj"].astype(x.dtype))
    out = pctx.reduce_output(out)   # psum, or psum_scatter(seq) under SP
    return out, SSMState(conv=new_conv, ssm=new_ssm)
