"""Model substrate: every assigned architecture family in pure JAX."""
from .parallel import SINGLE, ParallelCtx
from .transformer import (abstract_params, active_params, count_params,
                          decode_step, forward_hidden, init_decode_state,
                          init_params, lm_loss, param_specs, param_table,
                          prefill_step)

__all__ = [
    "SINGLE", "ParallelCtx", "abstract_params", "active_params",
    "count_params", "decode_step", "forward_hidden", "init_decode_state",
    "init_params", "lm_loss", "param_specs", "param_table", "prefill_step",
]
