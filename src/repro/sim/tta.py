"""Time-to-accuracy harness (paper §5.2, Fig 11/14/16, Table 1).

TTA factors exactly as the paper argues: *what* the model learns per step
depends only on the gradient content (drops / compression), while *how
long* a step takes depends only on the collective + network. We therefore:

1. run REAL training of the paper's GPT-2 (reduced same-family config) on
   the synthetic-grammar LM task, with the gradient-aggregation pipeline
   emulated worker-by-worker (N workers, per-worker gradients, drops/HT/
   compression applied through the actual core/ implementations), and
   measure steps-to-accuracy;
2. take per-step wall-clock from the calibrated network simulator
   (sim/netsim.py) for the same collective;
3. TTA = steps x step-time.

Deterministic in the seed; used by bench_tta / bench_hadamard_drops /
bench_compression.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import compression as comp_lib
from repro.core import drops as drops_lib
from repro.core.hadamard import ht_decode, ht_encode
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import SINGLE, init_params, lm_loss
from repro.optim.optimizers import OptimizerConfig, make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainRunConfig:
    arch: str = "gpt2-paper"
    n_workers: int = 8
    per_worker_batch: int = 4
    seq_len: int = 64
    steps: int = 300
    eval_every: int = 10
    lr: float = 3e-3
    optimizer: str = "momentum"
    drop_rate: float = 0.0
    drop_pattern: str = "tail"
    # loss recovery (DESIGN §8): 'stale' fills lost stage-1 entries from
    # the previous step's mean bucket (plain mean over N); 'ef' adds per-
    # worker error-feedback residuals of the undelivered wire mass.
    # Emulated with the same wire-space layout the trainer's recovery
    # module uses.
    recovery: str = "none"            # none | stale | ef
    use_hadamard: bool = True
    # per-coordinate compensation of missing contributions is exactly what
    # the HT pipeline provides (§3.3 "unbiased estimate"); the naive no-HT
    # path sums received entries and divides by N (biased toward 0 at the
    # dropped coordinates) — which is why Fig 14's no-HT runs degrade.
    compensate: bool | None = None    # default: == use_hadamard
    hadamard_block: int = 1024
    compressor: str | None = None     # None | topk | terngrad | thc
    topk_frac: float = 0.01
    thc_bits: int = 4
    markov_weight: float = 0.85
    n_succ: int = 1
    seed: int = 0


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    return flat, (treedef, shapes, sizes)


def _unflatten(flat, meta):
    treedef, shapes, sizes = meta
    out, off = [], 0
    for sh, sz in zip(shapes, sizes):
        out.append(flat[off:off + sz].reshape(sh))
        off += sz
    return jax.tree.unflatten(treedef, out)


def _aggregate_per_receiver(worker_flats: jnp.ndarray, key,
                            rc: TrainRunConfig, stale: jnp.ndarray | None
                            = None, want_resid: bool = False
                            ) -> tuple[jnp.ndarray, float, dict]:
    """Full two-stage TAR emulation with per-receiver outcomes.

    Stage 1: owner r reduces peers' shard-r contributions under its arrival
    mask. Stage 2: each receiver gets every owner's aggregate under its own
    (tail-drop) mask — so receivers end up with *different* buckets, which
    is the replica-divergence pathology HT exists to tame (Fig 6/14).

    ``stale`` (recovery='stale'/'ef'): previous step's mean bucket (L,) —
    every lost stage-1 entry is filled from it (re-encoded under this
    step's key) and the owner takes the plain mean over N instead of
    renormalizing. ``want_resid`` (recovery='ef'): also return, in value
    space, the gap between each worker's contribution and the stale fill
    applied in its stead (lost entries only).
    Returns (per-receiver buckets (N, L), drop fraction, extras) with
    extras = {'stale': next step's (L,) cache, 'resid': (N, L) or None}.
    """
    n, length = worker_flats.shape
    block = rc.hadamard_block
    pad = (-length) % (n * block)
    g = jnp.pad(worker_flats, ((0, 0), (0, pad)))
    lp = g.shape[1]
    chunk = lp // n
    compensate = rc.use_hadamard if rc.compensate is None else rc.compensate

    if rc.drop_rate <= 0.0:
        mean = jnp.mean(g, 0)
        out = jnp.broadcast_to(mean[None], (n, lp))[:, :length]
        return out, 0.0, {"stale": mean[:length],
                          "resid": jnp.zeros_like(worker_flats)
                          if want_resid else None}

    if rc.use_hadamard:
        g = jax.vmap(lambda r: ht_encode(r, key, block=block))(g)
    st_shards = None
    if stale is not None:
        st = jnp.pad(stale.astype(g.dtype), (0, pad))
        if rc.use_hadamard:
            st = ht_encode(st, key, block=block)
        st_shards = st.reshape(n, chunk)         # [owner, chunk]

    shards = g.reshape(n, n, chunk)              # [worker, owner, chunk]
    dropped = 0.0
    total = 0.0
    aggs, stage1_masks = [], []
    for r in range(n):                           # stage 1, per owner
        m = drops_lib.make_mask(rc.drop_pattern,
                                jax.random.fold_in(key, r), n, chunk,
                                rate=rc.drop_rate, self_index=jnp.int32(r))
        contrib = shards[:, r, :]
        if st_shards is not None:
            # cross-step prediction (DESIGN §8): lost entries filled from
            # the previous step's mean, plain mean over all N (arrived
            # entries weigh exactly 1/N — the EF split relies on this)
            agg = jnp.mean(contrib * m + (1.0 - m) * st_shards[r][None], 0)
        elif compensate:
            cnt = jnp.sum(m, 0)
            agg = jnp.where(cnt > 0, jnp.sum(contrib * m, 0)
                            / jnp.maximum(cnt, 1), 0.0)
        else:
            agg = jnp.sum(contrib * m, 0) / n
        dropped += jnp.sum(1.0 - m)
        total += m.size
        aggs.append(agg)
        stage1_masks.append(m)
    agg_all = jnp.stack(aggs)                    # (owner, chunk)

    resid = None
    if want_resid:
        # worker i's stage-1 arrival across owners, in its wire layout;
        # residual vs the stale fill applied in its stead — carrying the
        # full lost mass on top of the fill would apply it twice
        arrival = jnp.stack(stage1_masks, axis=1).reshape(n, lp)
        resid = (1.0 - arrival) * (g if st_shards is None
                                   else g - st_shards.reshape(lp)[None])
        if rc.use_hadamard:
            resid = jax.vmap(lambda r_: ht_decode(r_, key, block=block))(
                resid)
        resid = resid[:, :length]

    buckets = []
    for i in range(n):                           # stage 2, per receiver
        m2 = drops_lib.make_mask(rc.drop_pattern,
                                 jax.random.fold_in(key, 100 + i), n, chunk,
                                 rate=rc.drop_rate, self_index=jnp.int32(i))
        if compensate:
            # §3.3: receiver rescales by its known received fraction
            frac = jnp.mean(m2, axis=1, keepdims=True)
            recv = agg_all * m2 / jnp.maximum(frac, 1e-3)
        else:
            recv = agg_all * m2
        dropped += jnp.sum(1.0 - m2)
        total += m2.size
        bucket = recv.reshape(lp)
        if rc.use_hadamard:
            bucket = ht_decode(bucket, key, block=block)
        buckets.append(bucket)
    out = jnp.stack(buckets)
    drop_frac = float(dropped / total)
    return out[:, :length], drop_frac, \
        {"stale": jnp.mean(out, 0)[:length], "resid": resid}


def _aggregate(worker_flats: jnp.ndarray, key, rc: TrainRunConfig,
               state: dict) -> tuple[jnp.ndarray, float]:
    """Emulate the collective on N per-worker flat gradients -> (mean,
    observed drop fraction). Uses the real core/ implementations."""
    n, length = worker_flats.shape
    block = rc.hadamard_block
    pad = (-length) % (n * block)
    g = jnp.pad(worker_flats, ((0, 0), (0, pad)))

    if rc.compressor == "topk":
        k = max(1, int(rc.topk_frac * g.shape[1]))
        outs = []
        for i in range(n):
            sparse, state["topk"][i] = comp_lib.topk_compress(
                g[i], state["topk"][i], k=k)
            outs.append(sparse)
        return jnp.mean(jnp.stack(outs), 0)[:length], 0.0
    if rc.compressor == "terngrad":
        outs = [comp_lib.terngrad_compress(g[i], jax.random.fold_in(key, i))
                for i in range(n)]
        return jnp.mean(jnp.stack(outs), 0)[:length], 0.0
    if rc.compressor == "thc":
        lo = jnp.min(g) * 1.2 - 1e-3
        hi = jnp.max(g) * 1.2 + 1e-3
        lohi = jnp.stack([lo, hi])
        codes = [comp_lib.thc_compress(g[i], key, lohi, bits=rc.thc_bits,
                                       block=block).codes.astype(jnp.int32)
                 for i in range(n)]
        code_sum = functools.reduce(lambda a, b: a + b, codes)
        out = comp_lib.thc_decompress_sum(code_sum, key, lohi,
                                          bits=rc.thc_bits, block=block,
                                          nsum=n)
        return out[:length], 0.0

    # --- OptiReduce path (or reliable mean when drop_rate == 0) ----------
    if rc.drop_rate <= 0.0:
        return jnp.mean(g, 0)[:length], 0.0
    compensate = rc.use_hadamard if rc.compensate is None else rc.compensate
    if rc.use_hadamard:
        g = jax.vmap(lambda r: ht_encode(r, key, block=block))(g)
    mask = drops_lib.make_mask(rc.drop_pattern, key, n, g.shape[1],
                               rate=rc.drop_rate)
    if compensate:
        cnt = jnp.sum(mask, 0)
        mean = jnp.where(cnt > 0,
                         jnp.sum(g * mask, 0) / jnp.maximum(cnt, 1), 0.0)
    else:
        mean = jnp.sum(g * mask, 0) / n
    if rc.use_hadamard:
        mean = ht_decode(mean, key, block=block)
    drop_frac = float(1.0 - jnp.mean(mask))
    return mean[:length], drop_frac


def run_training(rc: TrainRunConfig) -> dict:
    """Per-worker replica training (the real DDP topology): each of the N
    workers holds a model copy, computes gradients on its batch shard, and
    updates with *its own received bucket* — so stage-2 drops produce real
    replica divergence, the pathology Fig 14 measures.

    Returns {'steps', 'acc', 'drops', 'divergence', 'mean_drop'}."""
    cfg = get_smoke(rc.arch)
    key = jax.random.PRNGKey(rc.seed)
    params0 = init_params(key, cfg)
    n = rc.n_workers
    params = jax.tree.map(lambda p: jnp.stack([p] * n), params0)
    opt = make_optimizer(OptimizerConfig(name=rc.optimizer, lr=rc.lr,
                                         weight_decay=0.0))
    opt_state = jax.vmap(opt.init)(params)
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=rc.seq_len,
        global_batch=rc.n_workers * rc.per_worker_batch, seed=rc.seed,
        markov_weight=rc.markov_weight, n_succ=rc.n_succ))
    eval_batch = data.global_batch(10**6)

    @jax.jit
    def worker_grads(ps, batch):
        def per_worker(p, tok, lab):
            return jax.grad(lambda pp: lm_loss(
                pp, {"tokens": tok, "labels": lab}, cfg, SINGLE,
                key=jax.random.PRNGKey(0), seq_chunk=rc.seq_len))(p)
        tok = batch["tokens"].reshape(n, rc.per_worker_batch, -1)
        lab = batch["labels"].reshape(n, rc.per_worker_batch, -1)
        return jax.vmap(per_worker)(ps, tok, lab)

    @jax.jit
    def eval_acc(ps):
        from repro.models import forward_hidden
        p = jax.tree.map(lambda x: x[0], ps)     # worker-0 replica
        x = forward_hidden(p, {"tokens": jnp.asarray(eval_batch["tokens"])},
                           cfg, SINGLE, key=jax.random.PRNGKey(0),
                           remat=False)
        emb = p["embed"]
        logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                            emb.astype(jnp.float32))
        pred = jnp.argmax(logits, -1)
        return jnp.mean(pred == jnp.asarray(eval_batch["labels"]))

    @jax.jit
    def divergence(ps):
        return sum(jnp.mean(jnp.std(x.astype(jnp.float32), axis=0))
                   for x in jax.tree.leaves(ps))

    @jax.jit
    def apply_updates(ps, os, gflats, step):
        def one(p, o, gflat):
            g = _unflatten(gflat, meta)
            g = jax.tree.map(lambda gg, pp: gg.astype(pp.dtype), g, p)
            return opt.update(g, o, p, jnp.float32(rc.lr), step)
        return jax.vmap(one)(ps, os, gflats)

    flat0, meta = _flatten(params0)
    state = {"topk": [comp_lib.topk_init(
        flat0.shape[0] + ((-flat0.shape[0]) %
                          (rc.n_workers * rc.hadamard_block)))
        for _ in range(rc.n_workers)]}

    if rc.recovery not in ("none", "stale", "ef"):
        raise ValueError(f"unknown recovery mode {rc.recovery!r} "
                         "(none | stale | ef)")
    if rc.recovery != "none" and rc.compressor is not None:
        raise ValueError("recovery emulation rides the TAR path; "
                         "clear compressor or set recovery='none'")
    use_stale = rc.recovery in ("stale", "ef")
    use_ef = rc.recovery == "ef"
    stale_flat = None
    ef_state = jnp.zeros((n, flat0.shape[0])) if use_ef else None

    hist = {"steps": [], "acc": [], "drops": [], "divergence": []}
    for step in range(rc.steps):
        batch = jax.tree.map(jnp.asarray, data.global_batch(step))
        gtree = worker_grads(params, batch)
        flats = jax.vmap(lambda t: _flatten(t)[0])(gtree)
        skey = jax.random.fold_in(key, step)
        if ef_state is not None:
            flats = flats + ef_state
        if rc.compressor is not None:
            mean_flat, drop = _aggregate(flats, skey, rc, state)
            buckets = jnp.broadcast_to(mean_flat[None], (n,) + mean_flat.shape)
        else:
            buckets, drop, extras = _aggregate_per_receiver(
                flats, skey, rc, stale=stale_flat if use_stale else None,
                want_resid=use_ef)
            if use_stale:
                stale_flat = extras["stale"]
            if use_ef:
                ef_state = extras["resid"]
        params, opt_state = apply_updates(params, opt_state, buckets,
                                          jnp.asarray(step))
        hist["drops"].append(drop)
        if step % rc.eval_every == 0 or step == rc.steps - 1:
            hist["steps"].append(step)
            hist["acc"].append(float(eval_acc(params)))
            hist["divergence"].append(float(divergence(params)))
    hist["mean_drop"] = float(np.mean(hist["drops"]))
    return hist


def steps_to_accuracy(hist: dict, target: float) -> int | None:
    for s, a in zip(hist["steps"], hist["acc"]):
        if a >= target:
            return s + 1
    return None
