"""Cloud-network simulator: tail-calibrated gradient-aggregation timing.

Models the paper's evaluation environments (§5.1):

* Base per-transfer latency is lognormal, calibrated so P99/P50 matches the
  target environment (local cluster 1.5 / 3.0, CloudLab ~1.45; Fig 3/10),
  plus a bandwidth serialization term.
* **TCP stalls** — the mechanism §3.2 identifies: with probability
  ``stall_prob`` a flow loses its tail packets and blocks for an RTO before
  retransmitting. Reliable transports (Gloo/NCCL/TAR+TCP) eat the stall;
  UBT *drops* those bytes instead and progresses (bounded by the adaptive
  timeout). This single loss process therefore produces both the baselines'
  tail inflation and OptiReduce's (small) gradient-drop rate — matching the
  paper's Table 1 shape (drops 0.05–0.18% while TTA stays flat).

Round structures per collective:
  ring      2(N-1) synchronized rounds, chunk B/N, round = max over pairs
  bcube     2*log_b(N) stages; each node sends (b-1) chunks serialized on
            its link per stage
  tree      2*log2(N) rounds, halving/doubling chunk sizes
  ps        gather with N-fold incast serialization at the server + bcast
  tar_tcp   2*ceil((N-1)/I) rounds, chunk B/N, reliable
  optireduce  TAR rounds bounded by UBT: t_B = P95 of profiled stage times,
            early timeout at (all-senders' last-percentile time) + x%*t_C,
            x adapted by the §3.2.1 rule; late tails are dropped; dynamic
            incast adapts I.

``library_factor`` models the Gloo-vs-NCCL implementation gap (the paper
benchmarks both; NCCL's GPU-direct transport is faster at equal topology).
All draws are deterministic in the seed.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.ubt import AdaptiveTimeout, DynamicIncast


@dataclasses.dataclass
class NetworkModel:
    median_ms: float = 0.35          # per-transfer base latency median
    p99_over_p50: float = 1.5        # tail-to-median calibration (Fig 10)
    bandwidth_GBps: float = 3.0      # per-link (25 Gbps, §5.1a)
    stall_prob: float = 0.01         # per-flow TCP tail-loss/RTO episodes
    rto_ms: float = 40.0             # datacenter min-RTO-ish stall length
    drop_frac_per_stall: float = 0.01  # UBT: bytes lost when a flow stalls
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        # lognormal: P99/P50 = exp(2.3263 * sigma)
        self.sigma = math.log(max(self.p99_over_p50, 1.0 + 1e-9)) / 2.3263
        self.mu = math.log(self.median_ms)

    @classmethod
    def environment(cls, name: str, seed: int = 0) -> "NetworkModel":
        """The paper's three environments (§5.1/§5.2). The tail-to-median
        calibration applies to the whole transfer (the paper's background
        workloads congest links, so for MB-sized gradient chunks the tail
        is bandwidth variability, not just latency)."""
        if name == "local_1.5":
            return cls(p99_over_p50=1.5, stall_prob=0.004, seed=seed)
        if name == "local_3.0":
            return cls(p99_over_p50=3.0, stall_prob=0.010, seed=seed)
        if name == "cloudlab":
            return cls(p99_over_p50=1.45, stall_prob=0.006,
                       bandwidth_GBps=1.2, seed=seed)  # 10 Gbps
        raise ValueError(name)

    def base_ms(self, nbytes: float, n: int = 1) -> np.ndarray:
        lat = self.rng.lognormal(self.mu, self.sigma, size=n)
        # congestion: effective bandwidth shares the same tail distribution
        bw_factor = self.rng.lognormal(0.0, self.sigma, size=n)
        return lat + nbytes / (self.bandwidth_GBps * 1e9) * 1e3 * bw_factor

    def tcp_ms(self, nbytes: float, n: int = 1,
               factor: float = 1.0) -> np.ndarray:
        """Reliable-transport transfer times (stalls add an RTO)."""
        t = self.base_ms(nbytes, n)
        stalls = self.rng.random(n) < self.stall_prob
        return (t + stalls * self.rto_ms) * factor

    def ubt_ms(self, nbytes: float, n: int = 1,
               factor: float = 1.0) -> tuple[np.ndarray, np.ndarray]:
        """Best-effort transfer: (completion time of delivered bytes,
        fraction lost). A stalled flow delivers (1 - drop_frac) on time."""
        t = self.base_ms(nbytes, n) * factor
        stalls = self.rng.random(n) < self.stall_prob
        lost = np.where(stalls,
                        self.rng.uniform(0.2, 1.8, n)
                        * self.drop_frac_per_stall, 0.0)
        return t, np.clip(lost, 0.0, 0.2)


@dataclasses.dataclass
class GAResult:
    time_ms: float
    drop_frac: float = 0.0
    rounds: int = 0


class GASimulator:
    """Per-step gradient-aggregation time for each collective topology."""

    def __init__(self, net: NetworkModel, n_nodes: int,
                 library_factor: float = 1.0):
        self.net = net
        self.n = n_nodes
        self.f = library_factor

    # ------------------------------------------------------------ baselines
    def ring(self, nbytes: float) -> GAResult:
        n = self.n
        chunk = nbytes / n
        rounds = 2 * (n - 1)
        t = sum(float(np.max(self.net.tcp_ms(chunk, n, self.f)))
                for _ in range(rounds))
        return GAResult(t, 0.0, rounds)

    def tree(self, nbytes: float) -> GAResult:
        n = self.n
        k = int(math.log2(n))
        t = 0.0
        for stage in range(k):
            t += float(np.max(self.net.tcp_ms(nbytes / 2 ** (stage + 1), n,
                                              self.f)))
        for stage in reversed(range(k)):
            t += float(np.max(self.net.tcp_ms(nbytes / 2 ** (stage + 1), n,
                                              self.f)))
        return GAResult(t, 0.0, 2 * k)

    def bcube(self, nbytes: float, base: int = 2) -> GAResult:
        """Gloo BCube: 2*log_b(N) stages exchanging B/b per stage (total
        wire bytes ~ 2B*log_b(N)/b > ring's 2B — why the paper finds it
        the slowest baseline)."""
        n = self.n
        k = max(1, round(math.log(n, base)))
        t = 0.0
        for _ in range(2 * k):
            t += float(np.max(self.net.tcp_ms(
                (nbytes / base) * (base - 1), n, self.f)))
        return GAResult(t, 0.0, 2 * k)

    def ps(self, nbytes: float) -> GAResult:
        n = self.n
        # all workers push B; the server link serializes N*B (incast)
        serialization = (n * nbytes) / (self.net.bandwidth_GBps * 1e9) * 1e3
        t = float(np.max(self.net.tcp_ms(nbytes, n, self.f))) + serialization
        t += float(np.max(self.net.tcp_ms(nbytes, n, self.f))) + serialization
        return GAResult(t, 0.0, 2)

    def tar_tcp(self, nbytes: float, incast: int = 1) -> GAResult:
        n = self.n
        chunk = nbytes / n
        i = max(incast, 1)
        rounds = 2 * math.ceil((n - 1) / i)
        t = 0.0
        for _ in range(rounds):
            t += float(np.max(self.net.tcp_ms(chunk * i, n, self.f)))
        return GAResult(t, 0.0, rounds)

    # ----------------------------------------------------------- optireduce
    def warmup(self, nbytes: float, *, iters: int = 20) -> AdaptiveTimeout:
        """§3.2.1: profile TAR+TCP stage times; t_B = their P95."""
        at = AdaptiveTimeout(warmup_iters=iters)
        chunk = nbytes / self.n
        for _ in range(iters):
            at.observe_warmup(float(np.max(self.net.tcp_ms(chunk, self.n,
                                                           self.f))))
        return at

    def optireduce_2d(self, nbytes: float, timeout: AdaptiveTimeout,
                      groups: int) -> GAResult:
        """Hierarchical 2D TAR (paper §3.1.2 / App. A): groups of N/G nodes.
        Rounds: (N/G - 1) intra-group exchange + (G - 1) inter-group
        same-rank aggregation + (N/G - 1) intra-group broadcast =
        2(N/G - 1) + (G - 1), vs flat TAR's 2(N - 1)."""
        n = self.n
        nl = max(1, n // max(groups, 1))
        total_t, lost_bytes, total_bytes = 0.0, 0.0, 0.0
        stage_times, to_flags, frac_recv = [], [], []

        def rounds(count, chunk, fanin):
            nonlocal total_t, lost_bytes, total_bytes
            for _ in range(count):
                times, lost = self.net.ubt_ms(chunk, fanin, self.f)
                t99 = float(np.max(times)) * 0.99
                deadline = min(timeout.round_deadline(False),
                               t99 + timeout.x * (timeout.t_c or t99))
                arrived = np.where(times <= deadline, 1.0 - lost,
                                   np.minimum(1.0 - lost, deadline / times))
                total_t += float(min(np.max(times), deadline))
                lost_bytes += float(np.sum(1 - arrived)) * chunk
                total_bytes += fanin * chunk
                stage_times.append(float(min(np.max(times), deadline)))
                to_flags.append(bool(np.any(times > deadline)))
                frac_recv.append(float(np.mean(arrived)))

        rounds(nl - 1, nbytes / nl, nl)              # intra-group exchange
        rounds(max(groups - 1, 0), nbytes / n, groups)  # inter-group
        rounds(nl - 1, nbytes / nl, nl)              # intra-group broadcast
        drop_frac = lost_bytes / max(total_bytes, 1.0)
        timeout.update(stage_times=stage_times, timed_out=to_flags,
                       frac_received=frac_recv, loss_frac=drop_frac)
        return GAResult(total_t, drop_frac, len(stage_times))

    def optireduce(self, nbytes: float, timeout: AdaptiveTimeout,
                   incast: DynamicIncast | None = None) -> GAResult:
        n = self.n
        chunk = nbytes / n
        i = incast.value if incast is not None else 1
        rounds = 2 * math.ceil((n - 1) / max(i, 1))
        total_t = 0.0
        lost_bytes = 0.0
        stage_times, to_flags, frac_recv = [], [], []
        for _ in range(rounds):
            times, lost = self.net.ubt_ms(chunk * max(i, 1), n, self.f)
            # early timeout (Fig 8): once every sender's last-percentile
            # markers are in (~99% of each stream delivered), wait x%*t_C
            # and expire — shaving stall-recovery waits, not live streams;
            # the hard bound t_B caps pathological rounds. Drops stay at
            # the 0.01-0.1% the controller targets.
            t99_all = float(np.max(times)) * 0.99
            deadline = min(timeout.round_deadline(last_pctile_seen=False),
                           t99_all + timeout.x * (timeout.t_c or t99_all))
            arrived_frac = np.where(times <= deadline, 1.0 - lost,
                                    np.minimum(1.0 - lost,
                                               deadline / times))
            t_round = float(min(np.max(times), deadline))
            total_t += t_round
            lost_bytes += float(np.sum(1.0 - arrived_frac)) * chunk
            stage_times.append(t_round)
            to_flags.append(bool(np.any(times > deadline)))
            frac_recv.append(float(np.mean(arrived_frac)))
        drop_frac = lost_bytes / (rounds * n * chunk)
        timeout.update(stage_times=stage_times, timed_out=to_flags,
                       frac_received=frac_recv, loss_frac=drop_frac)
        if incast is not None:
            incast.update(loss_frac=drop_frac, timed_out=any(to_flags))
        return GAResult(total_t, drop_frac, rounds)

    def step(self, strategy: str, nbytes: float, **kw) -> GAResult:
        fn = {"gloo_ring": self.ring, "ring": self.ring,
              "nccl_tree": self.tree, "tree": self.tree,
              "nccl_ring": self.ring,
              "bcube": self.bcube, "ps": self.ps,
              "tar_tcp": self.tar_tcp}[strategy]
        return fn(nbytes, **kw)


# Names this module times natively (the paper's comparison set).
_NATIVE_TIMING = ("optireduce", "tar_tcp", "gloo_ring", "ring", "nccl_ring",
                  "nccl_tree", "tree", "bcube", "ps")


def timing_family(strategy: str) -> str:
    """Map a strategy name to this simulator's timing family.

    Names outside the native table are resolved through the collective-
    pipeline spec registry and classified by their (topology, transport)
    composition — a ``register_strategy``'d one-liner simulates without
    editing this module: ring-kind topologies time as their baseline, a
    lossy transport over TAR times as UBT/OptiReduce, a reliable one as
    TAR+TCP.  (Codecs shift wire *bytes*, not the round structure; callers
    scale ``nbytes`` for that.)
    """
    if strategy in _NATIVE_TIMING:
        return strategy
    try:                                 # lazy: keeps numpy-only imports fast
        from repro.core import pipeline as pl
        spec = pl.resolve_spec(pl.OptiReduceConfig(strategy=strategy))
    except Exception:
        return strategy                  # unknown: let the caller's table err
    topo = spec.topology
    if isinstance(topo, pl.RingTopology):
        return {"ring": "gloo_ring", "tree": "nccl_tree",
                "bcube": "bcube"}[topo.kind]
    if isinstance(topo, pl.PsumTopology):
        return "nccl_ring"               # XLA-native ~ NCCL ring transport
    return "optireduce" if isinstance(spec.transport, pl.Lossy) else "tar_tcp"


# Library speed factors: Gloo's kernel TCP stack = 1.0; NCCL's GPU-direct
# transport ~0.62 (calibrated from Table 1: (118-60)/(154-60));
# OptiReduce's UBT is a DPDK kernel-bypass userspace transport with NIC
# flow steering (§4) — same efficiency class as NCCL's bypass path.
LIBRARY_FACTOR = {
    "gloo_ring": 1.0, "bcube": 1.0, "tar_tcp": 1.0, "ps": 1.0,
    "nccl_ring": 0.62, "nccl_tree": 0.62,
    "optireduce": 0.62,
}


def simulate_job(strategy: str, *, n_nodes: int, bucket_bytes: float,
                 n_steps: int, env: NetworkModel,
                 compute_ms: float = 50.0, overlap: float = 0.5,
                 incast_dynamic: bool = False, incast: int = 1) -> dict:
    """Wall-clock of a training job: per step, compute plus the exposed
    (non-overlapped) fraction of GA time (Fig 1 communication hiding)."""
    strategy = timing_family(strategy)
    sim = GASimulator(env, n_nodes, LIBRARY_FACTOR.get(strategy, 1.0))
    timeout = None
    dyn_incast = None
    if strategy == "optireduce":
        timeout = sim.warmup(bucket_bytes)
        dyn_incast = (DynamicIncast(n_nodes=n_nodes, i_init=incast)
                      if incast_dynamic else None)
    total = 0.0
    drops, ga_times = [], []
    for _ in range(n_steps):
        if strategy == "optireduce":
            r = sim.optireduce(bucket_bytes, timeout, dyn_incast)
        elif strategy == "tar_tcp":
            r = sim.step(strategy, bucket_bytes, incast=incast)
        else:
            r = sim.step(strategy, bucket_bytes)
        total += compute_ms + max(0.0, r.time_ms * (1 - overlap))
        drops.append(r.drop_frac)
        ga_times.append(r.time_ms)
    return {"total_ms": total, "mean_ga_ms": float(np.mean(ga_times)),
            "p50_ga_ms": float(np.percentile(ga_times, 50)),
            "p99_ga_ms": float(np.percentile(ga_times, 99)),
            "mean_drop": float(np.mean(drops)), "drops": drops}
