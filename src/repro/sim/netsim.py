"""Cloud-network simulator: tail-calibrated gradient-aggregation timing.

Models the paper's evaluation environments (§5.1):

* Base per-transfer latency is lognormal, calibrated so P99/P50 matches the
  target environment (local cluster 1.5 / 3.0, CloudLab ~1.45; Fig 3/10),
  plus a bandwidth serialization term.
* **TCP stalls** — the mechanism §3.2 identifies: with probability
  ``stall_prob`` a flow loses its tail packets and blocks for an RTO before
  retransmitting. Reliable transports (Gloo/NCCL/TAR+TCP) eat the stall;
  UBT *drops* those bytes instead and progresses (bounded by the adaptive
  timeout). This single loss process therefore produces both the baselines'
  tail inflation and OptiReduce's (small) gradient-drop rate — matching the
  paper's Table 1 shape (drops 0.05–0.18% while TTA stays flat).

Round structures per collective:
  ring      2(N-1) synchronized rounds, chunk B/N, round = max over pairs
  bcube     2*log_b(N) stages; each node sends (b-1) chunks serialized on
            its link per stage
  tree      2*log2(N) rounds, halving/doubling chunk sizes
  ps        gather with N-fold incast serialization at the server + bcast
  tar_tcp   2*ceil((N-1)/I) rounds, chunk B/N, reliable
  optireduce  TAR rounds bounded by UBT: t_B = P95 of profiled stage times,
            early timeout at (all-senders' last-percentile time) + x%*t_C,
            x adapted by the §3.2.1 rule; late tails are dropped; dynamic
            incast adapts I.

``library_factor`` models the Gloo-vs-NCCL implementation gap (the paper
benchmarks both; NCCL's GPU-direct transport is faster at equal topology).
All draws are deterministic in the seed.

The OptiReduce paths consume the runtime :class:`ControlPlane` (DESIGN §5)
— the same controller bundle the trainer uses — instead of private copies
of the §3.2 state machines: the simulator produces :class:`StepTelemetry`
(per-peer transfer times, per-round stage times/timeouts, loss fraction)
and obeys the returned :class:`SyncPolicy` (incast, timeout x%, and the
degraded-participation active-peer set).  ``NetworkModel.peer_factors``
adds the persistent-straggler latency model: a per-peer multiplier on
every transfer that peer sends, so ``bench_timeout``/``bench_tta`` can
price ejection against wait-for-all.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.ubt import TimelyRateControl
from repro.obs import trace as obs_trace
from repro.runtime import ControlPlane, StepTelemetry


@dataclasses.dataclass
class NetworkModel:
    median_ms: float = 0.35          # per-transfer base latency median
    p99_over_p50: float = 1.5        # tail-to-median calibration (Fig 10)
    bandwidth_GBps: float = 3.0      # per-link (25 Gbps, §5.1a)
    stall_prob: float = 0.01         # per-flow TCP tail-loss/RTO episodes
    rto_ms: float = 40.0             # datacenter min-RTO-ish stall length
    drop_frac_per_stall: float = 0.01  # UBT: bytes lost when a flow stalls
    seed: int = 0
    # persistent-straggler model: multiplier on every transfer peer p sends
    # (None = homogeneous). Mutable mid-run (a peer degrading / healing).
    peer_factors: tuple[float, ...] | None = None
    # Gilbert–Elliott burst-loss parameters fitted from wire-observed mask
    # run-lengths (from_drop_trace(masks=...)): p = P(Good->Bad) and
    # r = P(Bad->Good) per packet. None = the i.i.d.-round process above is
    # the whole loss model (seed behavior).
    burst_p: float | None = None
    burst_r: float | None = None

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        # lognormal: P99/P50 = exp(2.3263 * sigma)
        self.sigma = math.log(max(self.p99_over_p50, 1.0 + 1e-9)) / 2.3263
        self.mu = math.log(self.median_ms)

    def _per_peer(self, n: int):
        """Per-peer latency multipliers when the draw is one-per-peer."""
        if self.peer_factors is not None and len(self.peer_factors) == n:
            return np.asarray(self.peer_factors, dtype=np.float64)
        return 1.0

    @classmethod
    def from_drop_trace(cls, trace, *, masks=None, seed: int = 0,
                        **kw) -> "NetworkModel":
        """Calibrate the UBT loss process from a *wire-observed* per-round
        loss-fraction trace (``1 - round_frac_received`` from the host
        transport's :class:`~repro.runtime.StepTelemetry`).

        The simulator's loss process is two-parameter — a round is lossy
        with ``stall_prob`` and a lossy flow sheds ``drop_frac_per_stall``
        of its bytes in expectation (``ubt_ms`` draws uniform(0.2, 1.8) ×
        that) — so the moment match is direct: ``stall_prob`` = the
        fraction of observed rounds with any loss, ``drop_frac_per_stall``
        = the mean loss among those rounds.  The calibration test in
        tests/test_sim.py pins that a model built this way predicts the
        observed ``loss_frac``.

        ``masks`` (optional): packet-granular 0/1 arrival masks as observed
        on the wire (rows = per-sender packet streams; any iterable of 1-D
        or 2-D arrays). When given, the *burstiness* of the loss is fitted
        too: zero-run lengths across the streams give the Gilbert–Elliott
        ``burst_r`` (1 / mean burst length) and, with the stationary loss
        rate, ``burst_p`` — the exact parameterization
        ``core.drops.gilbert_elliott_params`` uses to synthesize burst
        masks, so wire-fitted and synthetic burst processes agree.
        """
        t = np.asarray(list(trace), dtype=np.float64)
        if t.size == 0:
            raise ValueError("empty drop trace")
        if not np.all((t >= 0) & (t <= 1)):    # NaN fails both comparisons
            raise ValueError("trace entries must be loss fractions in [0,1]")
        lossy = t > 0.0
        stall_prob = float(np.mean(lossy))
        per_stall = float(np.mean(t[lossy])) if lossy.any() else 0.0
        ge = {}
        if masks is not None:
            p, r = fit_gilbert_elliott(masks)
            if p is not None:
                ge = {"burst_p": p, "burst_r": r}
        return cls(stall_prob=stall_prob, drop_frac_per_stall=per_stall,
                   seed=seed, **ge, **kw)

    def burst_loss_seq(self, n_pkts: int) -> np.ndarray:
        """Synthesize a 0/1 packet-loss sequence (1 = lost) from the fitted
        Gilbert–Elliott parameters — the cross-validation generator: its
        run-length statistics must match the wire masks the fit consumed.
        Draws from the model's own rng (deterministic in ``seed``)."""
        if self.burst_p is None or self.burst_r is None:
            raise ValueError("no fitted burst parameters; calibrate with "
                             "from_drop_trace(masks=...)")
        p, r = self.burst_p, self.burst_r
        stationary = p / max(p + r, 1e-12)
        u = self.rng.random(n_pkts + 1)
        lost = np.zeros(n_pkts, dtype=np.float64)
        bad = u[0] < stationary
        for k in range(n_pkts):
            bad = (u[k + 1] >= r) if bad else (u[k + 1] < p)
            lost[k] = 1.0 if bad else 0.0
        return lost

    @classmethod
    def environment(cls, name: str, seed: int = 0) -> "NetworkModel":
        """The paper's three environments (§5.1/§5.2). The tail-to-median
        calibration applies to the whole transfer (the paper's background
        workloads congest links, so for MB-sized gradient chunks the tail
        is bandwidth variability, not just latency)."""
        if name == "local_1.5":
            return cls(p99_over_p50=1.5, stall_prob=0.004, seed=seed)
        if name == "local_3.0":
            return cls(p99_over_p50=3.0, stall_prob=0.010, seed=seed)
        if name == "cloudlab":
            return cls(p99_over_p50=1.45, stall_prob=0.006,
                       bandwidth_GBps=1.2, seed=seed)  # 10 Gbps
        raise ValueError(name)

    def base_ms(self, nbytes: float, n: int = 1) -> np.ndarray:
        lat = self.rng.lognormal(self.mu, self.sigma, size=n)
        # congestion: effective bandwidth shares the same tail distribution
        bw_factor = self.rng.lognormal(0.0, self.sigma, size=n)
        t = lat + nbytes / (self.bandwidth_GBps * 1e9) * 1e3 * bw_factor
        return t * self._per_peer(n)

    def tcp_ms(self, nbytes: float, n: int = 1,
               factor: float = 1.0) -> np.ndarray:
        """Reliable-transport transfer times (stalls add an RTO)."""
        t = self.base_ms(nbytes, n)
        stalls = self.rng.random(n) < self.stall_prob
        return (t + stalls * self.rto_ms) * factor

    def ubt_ms(self, nbytes: float, n: int = 1,
               factor: float = 1.0) -> tuple[np.ndarray, np.ndarray]:
        """Best-effort transfer: (completion time of delivered bytes,
        fraction lost). A stalled flow delivers (1 - drop_frac) on time."""
        t = self.base_ms(nbytes, n) * factor
        stalls = self.rng.random(n) < self.stall_prob
        lost = np.where(stalls,
                        self.rng.uniform(0.2, 1.8, n)
                        * self.drop_frac_per_stall, 0.0)
        return t, np.clip(lost, 0.0, 0.2)

    def ubt_ms_vec(self, nbytes: np.ndarray,
                   factor: float = 1.0) -> tuple[np.ndarray, np.ndarray]:
        """:meth:`ubt_ms` with *per-flow* byte counts — the weighted-shard
        schedule puts a different payload on each sender's link (a reduced-
        weight peer ships a smaller slice; a relayed dead-link flow ships
        its bytes twice).  Index i of ``nbytes`` is peer i's flow."""
        nb = np.asarray(nbytes, dtype=np.float64)
        n = nb.size
        lat = self.rng.lognormal(self.mu, self.sigma, size=n)
        bw_factor = self.rng.lognormal(0.0, self.sigma, size=n)
        t = lat + nb / (self.bandwidth_GBps * 1e9) * 1e3 * bw_factor
        t = t * self._per_peer(n) * factor
        stalls = self.rng.random(n) < self.stall_prob
        lost = np.where(stalls,
                        self.rng.uniform(0.2, 1.8, n)
                        * self.drop_frac_per_stall, 0.0)
        return t, np.clip(lost, 0.0, 0.2)


def fit_gilbert_elliott(masks) -> tuple[float | None, float | None]:
    """Fit Gilbert–Elliott (p, r) from packet-granular 0/1 arrival masks.

    ``masks``: iterable of arrays, each a per-stream arrival mask (1 =
    arrived); 2-D arrays are treated as one stream per row. Zero runs are
    measured *within* streams (a burst never spans two senders' streams).
    The estimators are the run-length moment matches: ``r`` = 1 / mean
    zero-run length (each bad run ends with one Bad->Good transition), and
    ``p`` from the stationary loss rate pi = p/(p+r). Returns (None, None)
    when no stream contains a loss (nothing to fit).
    """
    run_lengths: list[int] = []
    lost_total = 0
    pkt_total = 0
    for m in masks:
        arr = np.asarray(m, dtype=np.float64)
        rows = arr.reshape(1, -1) if arr.ndim == 1 else arr.reshape(
            arr.shape[0], -1)
        for row in rows:
            lost = row <= 0.0
            pkt_total += lost.size
            lost_total += int(np.sum(lost))
            # run-length encode the loss indicator
            padded = np.concatenate([[0], lost.astype(np.int8), [0]])
            edges = np.flatnonzero(np.diff(padded))
            starts, ends = edges[::2], edges[1::2]
            run_lengths.extend((ends - starts).tolist())
    if not run_lengths or pkt_total == 0:
        return None, None
    mean_burst = float(np.mean(run_lengths))
    rate = lost_total / pkt_total
    r = 1.0 / max(mean_burst, 1.0)
    p = min(1.0, r * rate / max(1.0 - rate, 1e-6))
    return p, r


@dataclasses.dataclass
class GAResult:
    time_ms: float
    drop_frac: float = 0.0
    rounds: int = 0
    # fraction of each peer's gradient data that made it into the aggregate
    # (index = peer id; weighted/rebalance path only, None elsewhere) — a
    # rebalanced straggler must show a NONZERO entry here, unlike ejection
    peer_contrib: tuple[float, ...] | None = None


class GASimulator:
    """Per-step gradient-aggregation time for each collective topology.

    ``pace=True`` puts the §3.2.3 Timely rate controller into the UBT flow
    path: each round's flows are paced at the controller's rate against a
    shared bottleneck of ``capacity_GBps`` (default the link rate), the
    resulting queueing delay feeds the controller's RTT signal, and the
    delay rides on the round's transfer times — sustained congestion drives
    the rate to the bottleneck's fair share instead of collapsing the tail.
    """

    def __init__(self, net: NetworkModel, n_nodes: int,
                 library_factor: float = 1.0, *, pace: bool = False,
                 capacity_GBps: float | None = None):
        self.net = net
        self.n = n_nodes
        self.f = library_factor
        self.pace = pace
        self.capacity_GBps = capacity_GBps
        self.pacer = TimelyRateControl(rate=net.bandwidth_GBps * 8e9,
                                       max_rate=net.bandwidth_GBps * 8e9)
        self.base_rtt_s = 20e-6          # propagation floor (below T_low)
        self._queue_s = 0.0              # bottleneck backlog (seconds)
        # virtual-clock cursor for trace export: advances by each simulated
        # round's duration (ms), so a whole simulated run lays out on one
        # continuous cat="sim" timeline with the same span schema as the
        # wire peers (DESIGN §12) — diffable against a wire trace in one
        # Perfetto window
        self._trace_t = 0.0

    def _trace_round(self, tr, dur_ms: float, *, rnd: int, timed_out: bool,
                     frac: float, deadline: float, stage: str) -> None:
        """One simulated round as a ``"round"`` span on the virtual clock
        (same name/args as the wire peers' spans)."""
        tr.complete("round", "sim", ts=self._trace_t, dur=dur_ms,
                    args={"round": rnd, "timed_out": timed_out,
                          "frac_received": frac, "deadline": deadline,
                          "stage": stage})
        if timed_out:
            tr.event("timeout", "sim", ts=self._trace_t + dur_ms,
                     args={"round": rnd, "frac_received": frac})
        self._trace_t += dur_ms

    def paced_round_delay_s(self, nbytes_flow: float, flows: int) -> float:
        """One Timely-paced round: update the bottleneck queue from the
        offered load (``flows`` concurrent senders at the pacer's rate vs
        the shared capacity), feed the controller the observed RTT, and
        return the queueing delay this round's transfers see (seconds)."""
        cap = (self.capacity_GBps or self.net.bandwidth_GBps) * 8e9
        rate = self.pacer.rate
        # this flow's serialization at its paced rate (the round duration)
        dur = nbytes_flow * 8.0 / max(min(rate, cap), 1.0)
        # backlog grows when the aggregate offered load exceeds capacity,
        # drains at the spare capacity otherwise
        self._queue_s = max(0.0,
                            self._queue_s + (flows * rate - cap) / cap * dur)
        delay = self._queue_s
        self.pacer.update(self.base_rtt_s + delay)
        return delay

    # ------------------------------------------------------------ baselines
    def ring(self, nbytes: float) -> GAResult:
        n = self.n
        chunk = nbytes / n
        rounds = 2 * (n - 1)
        t = sum(float(np.max(self.net.tcp_ms(chunk, n, self.f)))
                for _ in range(rounds))
        return GAResult(t, 0.0, rounds)

    def tree(self, nbytes: float) -> GAResult:
        n = self.n
        k = int(math.log2(n))
        t = 0.0
        for stage in range(k):
            t += float(np.max(self.net.tcp_ms(nbytes / 2 ** (stage + 1), n,
                                              self.f)))
        for stage in reversed(range(k)):
            t += float(np.max(self.net.tcp_ms(nbytes / 2 ** (stage + 1), n,
                                              self.f)))
        return GAResult(t, 0.0, 2 * k)

    def bcube(self, nbytes: float, base: int = 2) -> GAResult:
        """Gloo BCube: 2*log_b(N) stages exchanging B/b per stage (total
        wire bytes ~ 2B*log_b(N)/b > ring's 2B — why the paper finds it
        the slowest baseline)."""
        n = self.n
        k = max(1, round(math.log(n, base)))
        t = 0.0
        for _ in range(2 * k):
            t += float(np.max(self.net.tcp_ms(
                (nbytes / base) * (base - 1), n, self.f)))
        return GAResult(t, 0.0, 2 * k)

    def ps(self, nbytes: float) -> GAResult:
        n = self.n
        # all workers push B; the server link serializes N*B (incast)
        serialization = (n * nbytes) / (self.net.bandwidth_GBps * 1e9) * 1e3
        t = float(np.max(self.net.tcp_ms(nbytes, n, self.f))) + serialization
        t += float(np.max(self.net.tcp_ms(nbytes, n, self.f))) + serialization
        return GAResult(t, 0.0, 2)

    def tar_tcp(self, nbytes: float, incast: int = 1) -> GAResult:
        n = self.n
        chunk = nbytes / n
        i = max(incast, 1)
        rounds = 2 * math.ceil((n - 1) / i)
        t = 0.0
        for _ in range(rounds):
            t += float(np.max(self.net.tcp_ms(chunk * i, n, self.f)))
        return GAResult(t, 0.0, rounds)

    # ----------------------------------------------------------- optireduce
    def warmup(self, nbytes: float, *, iters: int = 20,
               control: ControlPlane | None = None,
               detect_stragglers: bool = True, **kw) -> ControlPlane:
        """§3.2.1 profiling: TAR+TCP stage times feed t_B = their P95.

        Returns the job's :class:`ControlPlane` (built here unless passed
        in) — the single owner of the timeout/incast/detector state the
        subsequent :meth:`optireduce` steps consume and update.
        """
        if control is None:
            control = ControlPlane.create(
                n_nodes=self.n, detect_stragglers=detect_stragglers,
                timeout={"warmup_iters": iters}, **kw)
        chunk = nbytes / self.n
        for _ in range(iters):
            control.state.timeout.observe_warmup(
                float(np.max(self.net.tcp_ms(chunk, self.n, self.f))))
        return control

    def optireduce_2d(self, nbytes: float, control: ControlPlane,
                      groups: int) -> GAResult:
        """Hierarchical 2D TAR (paper §3.1.2 / App. A): groups of N/G nodes.
        Rounds: (N/G - 1) intra-group exchange + (G - 1) inter-group
        same-rank aggregation + (N/G - 1) intra-group broadcast =
        2(N/G - 1) + (G - 1), vs flat TAR's 2(N - 1)."""
        timeout = control.state.timeout
        n = self.n
        nl = max(1, n // max(groups, 1))
        total_t, lost_bytes, total_bytes = 0.0, 0.0, 0.0
        stage_times, to_flags, frac_recv = [], [], []
        tr = obs_trace.get_tracer()

        def rounds(count, chunk, fanin):
            nonlocal total_t, lost_bytes, total_bytes
            for _ in range(count):
                times, lost = self.net.ubt_ms(chunk, fanin, self.f)
                t99 = float(np.max(times)) * 0.99
                deadline = min(timeout.round_deadline(False),
                               t99 + timeout.x * (timeout.t_c or t99))
                if control.state.budget is not None:
                    # accept-or-extend (DESIGN §8): stretch while the loss
                    # EMA overruns the phase-tightening budget — beyond t_B
                    # if that is what the data needs (max_stretch bounds
                    # the round at max_stretch x the t_B-capped deadline,
                    # matching the wire peers' uncapped stretch)
                    deadline = control.state.budget.stretch(deadline)
                arrived = np.where(times <= deadline, 1.0 - lost,
                                   np.minimum(1.0 - lost, deadline / times))
                total_t += float(min(np.max(times), deadline))
                lost_bytes += float(np.sum(1 - arrived)) * chunk
                total_bytes += fanin * chunk
                stage_times.append(float(min(np.max(times), deadline)))
                to_flags.append(bool(np.any(times > deadline)))
                frac_recv.append(float(np.mean(arrived)))
                if tr is not None:
                    self._trace_round(tr, stage_times[-1],
                                      rnd=len(stage_times) - 1,
                                      timed_out=to_flags[-1],
                                      frac=frac_recv[-1],
                                      deadline=float(deadline), stage="2d")

        rounds(nl - 1, nbytes / nl, nl)              # intra-group exchange
        rounds(max(groups - 1, 0), nbytes / n, groups)  # inter-group
        rounds(nl - 1, nbytes / nl, nl)              # intra-group broadcast
        drop_frac = lost_bytes / max(total_bytes, 1.0)
        control.observe(StepTelemetry(
            step=control.steps, loss_frac=drop_frac,
            timed_out=any(to_flags), round_times=tuple(stage_times),
            round_timed_out=tuple(to_flags),
            round_frac_received=tuple(frac_recv)))
        return GAResult(total_t, drop_frac, len(stage_times))

    def _optireduce_weighted(self, nbytes: float, control: ControlPlane, *,
                             fixed_incast: int | None = None) -> GAResult:
        """Weighted / link-rewired UBT aggregation (DESIGN §10).

        The round schedule is the same 2*ceil((A-1)/I) groups, but each
        flow's bytes follow the policy's shard weights: in a stage-1 round,
        position k sends its contribution to the *receiver's* shard
        (``sizes[(k+r) % A]`` bytes); in stage 2 it broadcasts its own
        (``sizes[k]``).  A flow crossing a dead directed edge rides the
        two-hop relay, so its bytes double.  The deadline is keyed on the
        FULL-WEIGHT cohort's last-percentile marker plus a small fixed
        slack — NOT on x%*t_C: a reduced-weight straggler exceeding the
        deadline is *scheduled shedding*, and charging it to the §3.2.1
        rule would double x until the timeout collapses the incast.  For
        the same reason the telemetry (loss fraction, timeout flags,
        received fractions) is keyed on the full-weight cohort only, while
        the returned ``drop_frac``/``peer_contrib`` account every byte.
        """
        n = self.n
        policy = control.policy()
        timeout = control.state.timeout
        active = list(policy.active_peers) if policy.active_peers is not None \
            else list(range(n))
        a = len(active)
        w = list(policy.shard_weights) if policy.shard_weights is not None \
            else [1] * a
        dead = set(policy.dead_links)
        i = max(fixed_incast if fixed_incast is not None else policy.incast, 1)
        unit = nbytes / max(sum(w), 1)
        sizes = [wk * unit for wk in w]
        w_max = max(w)
        full = [k for k in range(a) if w[k] == w_max]
        half_rounds = math.ceil(max(a - 1, 1) / i)
        x_reb = 0.05            # fixed slack over the full cohort's marker
        total_t = 0.0
        lost_bytes = total_bytes = 0.0          # every scheduled byte
        full_lost = full_total = 0.0            # full-weight cohort only
        contrib = np.array(sizes, dtype=np.float64)   # own shard: always in
        peer_times = np.zeros(n)
        stage_times, to_flags, frac_recv = [], [], []
        tr = obs_trace.get_tracer()
        for stage in range(2):
            for g in range(half_rounds):
                group = range(g * i + 1, min((g + 1) * i, a - 1) + 1)
                wire = np.zeros(a)      # bytes on each position's link
                data = np.zeros(a)      # gradient bytes each position ships
                for r in group:
                    for k in range(a):
                        dst = (k + r) % a
                        b = sizes[dst] if stage == 0 else sizes[k]
                        data[k] += b
                        wire[k] += 2.0 * b if (active[k], active[dst]) in dead \
                            else b
                nb = np.zeros(n)
                nb[active] = wire
                times, lost = self.net.ubt_ms_vec(nb, self.f)
                if self.pace:
                    times = times + self.paced_round_delay_s(
                        float(np.mean(wire)), a) * 1e3
                peer_times += times
                act_times = times[active]
                act_lost = lost[active]
                t99_full = float(np.max(act_times[full])) * 0.99
                deadline = min(timeout.round_deadline(last_pctile_seen=False),
                               t99_full * (1.0 + x_reb))
                if control.state.budget is not None:
                    deadline = control.state.budget.stretch(deadline)
                arrived = np.where(
                    act_times <= deadline, 1.0 - act_lost,
                    np.minimum(1.0 - act_lost,
                               deadline / np.maximum(act_times, 1e-9)))
                total_t += float(min(np.max(act_times[full]), deadline))
                lost_bytes += float(np.sum((1.0 - arrived) * data))
                total_bytes += float(np.sum(data))
                full_lost += float(np.sum((1.0 - arrived[full])
                                          * data[full]))
                full_total += float(np.sum(data[full]))
                if stage == 0:
                    contrib += arrived * data
                stage_times.append(float(min(np.max(act_times[full]),
                                             deadline)))
                to_flags.append(bool(np.any(act_times[full] > deadline)))
                frac_recv.append(float(np.mean(arrived[full])))
                if tr is not None:
                    self._trace_round(tr, stage_times[-1],
                                      rnd=len(stage_times) - 1,
                                      timed_out=to_flags[-1],
                                      frac=frac_recv[-1],
                                      deadline=float(deadline),
                                      stage="weighted")
        by_peer = np.zeros(n)
        by_peer[active] = contrib / max(nbytes, 1e-12)
        control.observe(StepTelemetry(
            step=control.steps,
            loss_frac=full_lost / max(full_total, 1e-12),
            timed_out=any(to_flags), peer_stage_times=tuple(peer_times),
            round_times=tuple(stage_times), round_timed_out=tuple(to_flags),
            round_frac_received=tuple(frac_recv)))
        return GAResult(total_t, lost_bytes / max(total_bytes, 1e-12),
                        len(stage_times),
                        peer_contrib=tuple(float(c) for c in by_peer))

    def optireduce(self, nbytes: float, control: ControlPlane, *,
                   fixed_incast: int | None = None) -> GAResult:
        """One UBT gradient aggregation under the control plane's policy:
        the round schedule runs over the policy's *active-peer set* (an
        ejected straggler is neither sent to nor waited on — its share of
        the gradient is excluded, not late), the deadline rule uses the
        policy's x%, and the step's telemetry (per-peer times for the
        detector, per-round stage times for the timeout) feeds back in.
        A policy carrying shard weights or dead links routes to the
        weighted schedule (:meth:`_optireduce_weighted`); the uniform path
        below is byte-for-byte the seed behavior."""
        n = self.n
        policy = control.policy()
        if policy.shard_weights is not None or policy.dead_links:
            return self._optireduce_weighted(nbytes, control,
                                             fixed_incast=fixed_incast)
        timeout = control.state.timeout
        active = list(policy.active_peers) if policy.active_peers is not None \
            else list(range(n))
        a = len(active)
        i = fixed_incast if fixed_incast is not None else policy.incast
        chunk = nbytes / max(a, 1)
        rounds = 2 * math.ceil(max(a - 1, 1) / max(i, 1))
        total_t = 0.0
        lost_bytes = 0.0
        peer_times = np.zeros(n)
        stage_times, to_flags, frac_recv = [], [], []
        tr = obs_trace.get_tracer()
        for _ in range(rounds):
            times, lost = self.net.ubt_ms(chunk * max(i, 1), n, self.f)
            if self.pace:
                times = times + self.paced_round_delay_s(
                    chunk * max(i, 1), a) * 1e3
            # every peer's (hypothetical) completion is still observed —
            # the detector needs the straggler's pace to keep scoring it
            peer_times += times
            act_times = times[active]
            act_lost = lost[active]
            # early timeout (Fig 8): once every sender's last-percentile
            # markers are in (~99% of each stream delivered), wait x%*t_C
            # and expire — shaving stall-recovery waits, not live streams;
            # the hard bound t_B caps pathological rounds. Drops stay at
            # the 0.01-0.1% the controller targets.
            t99_all = float(np.max(act_times)) * 0.99
            deadline = min(timeout.round_deadline(last_pctile_seen=False),
                           t99_all + timeout.x * (timeout.t_c or t99_all))
            if control.state.budget is not None:
                # accept-or-extend (DESIGN §8): while the observed loss EMA
                # overruns the tightening budget, wait longer for late
                # packets instead of charging them as drops — beyond t_B if
                # that is what the data needs (max_stretch bounds the round
                # at max_stretch x the t_B-capped deadline, matching the
                # wire peers' uncapped stretch)
                deadline = control.state.budget.stretch(deadline)
            arrived_frac = np.where(act_times <= deadline, 1.0 - act_lost,
                                    np.minimum(1.0 - act_lost,
                                               deadline / act_times))
            t_round = float(min(np.max(act_times), deadline))
            total_t += t_round
            lost_bytes += float(np.sum(1.0 - arrived_frac)) * chunk
            stage_times.append(t_round)
            to_flags.append(bool(np.any(act_times > deadline)))
            frac_recv.append(float(np.mean(arrived_frac)))
            if tr is not None:
                self._trace_round(tr, t_round, rnd=len(stage_times) - 1,
                                  timed_out=to_flags[-1], frac=frac_recv[-1],
                                  deadline=float(deadline), stage="uniform")
        drop_frac = lost_bytes / (rounds * a * chunk)
        control.observe(StepTelemetry(
            step=control.steps, loss_frac=drop_frac, timed_out=any(to_flags),
            peer_stage_times=tuple(peer_times),
            round_times=tuple(stage_times), round_timed_out=tuple(to_flags),
            round_frac_received=tuple(frac_recv)))
        return GAResult(total_t, drop_frac, rounds)

    def step(self, strategy: str, nbytes: float, **kw) -> GAResult:
        fn = {"gloo_ring": self.ring, "ring": self.ring,
              "nccl_tree": self.tree, "tree": self.tree,
              "nccl_ring": self.ring,
              "bcube": self.bcube, "ps": self.ps,
              "tar_tcp": self.tar_tcp}[strategy]
        return fn(nbytes, **kw)


# Names this module times natively (the paper's comparison set).
_NATIVE_TIMING = ("optireduce", "tar_tcp", "gloo_ring", "ring", "nccl_ring",
                  "nccl_tree", "tree", "bcube", "ps")


def timing_family(strategy: str) -> str:
    """Map a strategy name to this simulator's timing family.

    Names outside the native table are resolved through the collective-
    pipeline spec registry and classified by their (topology, transport)
    composition — a ``register_strategy``'d one-liner simulates without
    editing this module: ring-kind topologies time as their baseline, a
    lossy transport over TAR times as UBT/OptiReduce, a reliable one as
    TAR+TCP.  (Codecs shift wire *bytes*, not the round structure; callers
    scale ``nbytes`` for that.)
    """
    if strategy in _NATIVE_TIMING:
        return strategy
    try:                                 # lazy: keeps numpy-only imports fast
        from repro.core import pipeline as pl
        spec = pl.resolve_spec(pl.OptiReduceConfig(strategy=strategy))
    except Exception:
        return strategy                  # unknown: let the caller's table err
    topo = spec.topology
    if isinstance(topo, pl.RingTopology):
        return {"ring": "gloo_ring", "tree": "nccl_tree",
                "bcube": "bcube"}[topo.kind]
    if isinstance(topo, pl.PsumTopology):
        return "nccl_ring"               # XLA-native ~ NCCL ring transport
    return "optireduce" if isinstance(spec.transport, pl.Lossy) else "tar_tcp"


# Library speed factors: Gloo's kernel TCP stack = 1.0; NCCL's GPU-direct
# transport ~0.62 (calibrated from Table 1: (118-60)/(154-60));
# OptiReduce's UBT is a DPDK kernel-bypass userspace transport with NIC
# flow steering (§4) — same efficiency class as NCCL's bypass path.
LIBRARY_FACTOR = {
    "gloo_ring": 1.0, "bcube": 1.0, "tar_tcp": 1.0, "ps": 1.0,
    "nccl_ring": 0.62, "nccl_tree": 0.62,
    "optireduce": 0.62,
}


def simulate_job(strategy: str, *, n_nodes: int, bucket_bytes: float,
                 n_steps: int, env: NetworkModel,
                 compute_ms: float = 50.0, overlap: float = 0.5,
                 incast_dynamic: bool = False, incast: int = 1,
                 eject_stragglers: bool = False, rebalance: bool = False,
                 pace: bool = False,
                 control: ControlPlane | None = None) -> dict:
    """Wall-clock of a training job: per step, compute plus the exposed
    (non-overlapped) fraction of GA time (Fig 1 communication hiding).

    ``eject_stragglers`` arms the control plane's straggler detector (the
    degraded-participation loop); ``rebalance`` arms straggler-proportional
    shard weights instead (a slow peer keeps a smaller slice — combine
    with ``eject_stragglers=False`` to never eject); ``pace`` puts the
    Timely controller into the UBT flow path.  Pass ``control`` to
    share/inspect the controller state (e.g. the detector's ejection
    history) after the run.
    """
    strategy = timing_family(strategy)
    sim = GASimulator(env, n_nodes, LIBRARY_FACTOR.get(strategy, 1.0),
                      pace=pace)
    if strategy == "optireduce":
        control = sim.warmup(bucket_bytes, control=control,
                             detect_stragglers=eject_stragglers,
                             rebalance=rebalance,
                             incast={"i_init": incast})
    total = 0.0
    drops, ga_times = [], []
    contribs = []
    for _ in range(n_steps):
        if strategy == "optireduce":
            r = sim.optireduce(bucket_bytes, control,
                               fixed_incast=None if incast_dynamic
                               else incast)
        elif strategy == "tar_tcp":
            r = sim.step(strategy, bucket_bytes, incast=incast)
        else:
            r = sim.step(strategy, bucket_bytes)
        total += compute_ms + max(0.0, r.time_ms * (1 - overlap))
        drops.append(r.drop_frac)
        ga_times.append(r.time_ms)
        if r.peer_contrib is not None:
            contribs.append(r.peer_contrib)
    out = {"total_ms": total, "mean_ga_ms": float(np.mean(ga_times)),
           "p50_ga_ms": float(np.percentile(ga_times, 50)),
           "p99_ga_ms": float(np.percentile(ga_times, 99)),
           "mean_drop": float(np.mean(drops)), "drops": drops}
    if strategy == "optireduce" and control is not None:
        active = control.policy().active_peers
        out["active_peers"] = list(active if active is not None
                                   else range(n_nodes))
        out["ejected_peers"] = list(control.detector.ejected_peers())
        if rebalance:
            out["shard_weights"] = list(control.detector.weights())
        if contribs:
            out["mean_contrib"] = [float(c) for c in
                                   np.mean(np.asarray(contribs), axis=0)]
    return out
