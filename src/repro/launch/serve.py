"""Serving launcher: batched greedy decoding on a host mesh (smoke scale)
or the production mesh (dry-run scale).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-paper --smoke \\
      --batch 4 --prompt-len 16 --max-new 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.serve.engine import generate


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = generate(params, cfg, prompts, max_new=args.max_new, key=key)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    print("sample:", out[0, -args.max_new:].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
