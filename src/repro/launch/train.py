"""Training launcher: end-to-end training with OptiReduce gradient sync.

On this CPU container it drives reduced (smoke) configs over a host-device
mesh; on a real cluster the same entrypoint runs the full configs over the
production mesh (jax.distributed handles multi-host initialization — the
launcher is host-count agnostic).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch gpt2-paper --smoke \\
      --steps 50 --dp 4 --tp 2 --strategy optireduce --drop-rate 0.01
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, get_smoke
from repro.core.allreduce import OptiReduceConfig, strategies
from repro.core.safeguards import LossMonitor
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params
from repro.optim.optimizers import OptimizerConfig
from repro.train import checkpoint as ckpt_lib
from repro.train.trainer import TrainConfig, build_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--dp", type=int, default=0, help="0 = all devices")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--strategy", default="optireduce",
                    help=f"one of {', '.join(strategies())} or any "
                         "register_strategy'd composition")
    ap.add_argument("--drop-rate", type=float, default=0.0)
    ap.add_argument("--drop-pattern", default="tail")
    ap.add_argument("--recovery", default="none",
                    choices=("none", "stale", "ef", "ef+budget"),
                    help="gradient-loss recovery beyond zero-fill (DESIGN "
                         "§8): 'stale' fills zero-arrival wire spans from "
                         "the previous step's decoded bucket; 'ef' adds "
                         "per-rank error-feedback residuals; 'ef+budget' "
                         "adds the phase-aware loss budget (deadlines "
                         "stretch while observed loss overruns the "
                         "convergence-tightening budget)")
    ap.add_argument("--transport", default="lossy",
                    choices=("lossy", "inproc", "udp"),
                    help="stage-1 arrival masks: 'lossy' = the synthetic "
                         "drop model (core/drops.py); 'inproc'/'udp' really "
                         "exchange the shard bytes between host peers over "
                         "the wire backend (repro/net) and mask by what "
                         "arrived — per-peer stage times, timeout flags and "
                         "received fractions feed the ControlPlane")
    ap.add_argument("--wire-deadline", type=float, default=None,
                    help="receive deadline before the AdaptiveTimeout is "
                         "profiled (backend clock units)")
    ap.add_argument("--rendezvous", default=None,
                    help="with --transport=udp: coordinate the ring's peers "
                         "through the socket rendezvous (repro/net/"
                         "rendezvous.py) — 'auto' starts an in-process "
                         "coordinator, host:port joins an external one; the "
                         "peers consume the live membership view (a rank "
                         "that leaves or dies is skipped, not waited on)")
    ap.add_argument("--incast", type=int, default=1,
                    help="round-schedule incast I (rounds topologies)")
    ap.add_argument("--adaptive", action="store_true",
                    help="drive next-step Hadamard/incast/participation "
                         "from the runtime ControlPlane (paper §3.2 + the "
                         "straggler detector) fed by observed telemetry")
    ap.add_argument("--rebalance", action="store_true",
                    help="with --adaptive: emit straggler-proportional "
                         "shard weights (a slow-but-alive peer owns a "
                         "smaller contiguous slice of each bucket) and "
                         "link-avoiding schedules (a failed directed edge "
                         "is relayed/rerouted) instead of relying on "
                         "ejection alone")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write a per-step JSON report (per-peer straggler "
                         "scores, shard weights, dead-link events) for "
                         "offline analysis")
    ap.add_argument("--trace", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="record a structured trace (DESIGN §12) — step "
                         "spans, wire round/phase spans, every ControlPlane "
                         "transition — and write Perfetto trace_event JSON "
                         "into DIR (default '.') at exit; merge/inspect "
                         "with python -m repro.obs.report")
    ap.add_argument("--trace-capacity", type=int, default=None,
                    help="trace ring-buffer capacity in records (default "
                         "65536; oldest records drop on wraparound)")
    ap.add_argument("--policy-cache", type=int, default=4,
                    help="compiled train steps kept per SyncPolicy (LRU), "
                         "so an eject -> readmit cycle never recompiles")
    ap.add_argument("--dp-mode", default="replicated")
    ap.add_argument("--sync-mode", default="pipelined",
                    choices=("pipelined", "scan", "vmap"),
                    help="bucket schedule: stage-skewed software pipeline "
                         "(overlap encode/exchange/decode across buckets), "
                         "strict scan, or batched vmap — bitwise-identical")
    ap.add_argument("--kernel-mode", default=None,
                    choices=("auto", "interpret", "compile"),
                    help="Pallas kernel dispatch: Mosaic-compile, interpret, "
                         "or auto (compile iff on a TPU backend); default "
                         "defers to REPRO_KERNEL_MODE / 'auto'")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    tracer = None
    if args.trace is not None:
        from repro import obs
        tracer = obs.configure(
            True, capacity=args.trace_capacity or obs.trace.DEFAULT_CAPACITY)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        dp = args.dp or (len(jax.devices()) // args.tp)
        mesh = make_host_mesh(dp=dp, tp=args.tp)
    tp = mesh.shape.get("model", 1)
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} strategy={args.strategy} "
          f"drop_rate={args.drop_rate} transport={args.transport}")

    # host wire transport (DESIGN §7): a HostRing of per-rank peers really
    # exchanges the stage-1 shard bytes (in-memory loopback or localhost
    # UDP); --drop-rate becomes injected *wire* loss instead of the
    # synthetic mask model, and the ring's telemetry finally feeds the
    # ControlPlane per-peer stage times (not just step wall-clock).
    control = ring = rdv_server = None
    rdv_clients = []
    with_budget = args.recovery == "ef+budget"
    need_control = (args.adaptive or args.transport != "lossy" or with_budget
                    or bool(args.report))
    if need_control:
        from repro.runtime import ControlPlane, StepTelemetry
        control = ControlPlane.create(n_nodes=mesh.shape.get("data", 1),
                                      rebalance=args.rebalance,
                                      **({"budget": {}} if with_budget
                                         else {}))
    if args.transport != "lossy":
        if args.dp_mode != "replicated":
            ap.error("--transport needs --dp-mode=replicated (fsdp grads "
                     "reduce through rs_spec, which has no wire bridge)")
        if args.sync_mode == "vmap":
            ap.error("--transport bridges per-bucket io_callbacks; vmap "
                     "would batch them (use --sync-mode pipelined or scan)")
        if args.recovery in ("ef", "ef+budget"):
            ap.error("--recovery=ef/ef+budget reconstructs sender-arrival "
                     "masks from the synthetic drop model; with wire "
                     "transports use --recovery=stale")
        if mesh.shape.get("model", 1) != 1:
            ap.error("--transport needs --tp=1: with model parallelism "
                     "every tp sibling of a data rank would run the "
                     "io_callback, advancing the ring's per-rank exchange "
                     "counter tp times per bucket and pairing deposits "
                     "from different buckets into one wire exchange")
        if args.rendezvous and args.transport != "udp":
            ap.error("--rendezvous coordinates real socket peers; it needs "
                     "--transport=udp")
        from repro.core.pipeline import WireTransport
        from repro.net import HostRing, bernoulli_drops
        n_wire = mesh.shape.get("data", 1)
        membership = None
        if args.rendezvous:
            from repro.net import RendezvousClient, RendezvousServer
            if args.rendezvous == "auto":
                rdv_server = RendezvousServer(n_wire)
                rdv_addr = rdv_server.addr
            else:
                host, _, port = args.rendezvous.rpartition(":")
                rdv_addr = (host or "127.0.0.1", int(port))
            # one client per ring peer; joins are sequential so rank i is
            # peer i, and every client heartbeats — the shared membership
            # view is live, not a snapshot
            for uid in range(n_wire):
                c = RendezvousClient(rdv_addr, uid=uid)
                c.join()
                rdv_clients.append(c)
            membership = rdv_clients[0]
            print(f"rendezvous: {n_wire} peers joined at "
                  f"{rdv_addr[0]}:{rdv_addr[1]} "
                  f"generation={membership.generation}")
        ring = HostRing(
            n_wire,
            OptiReduceConfig(strategy=args.strategy, incast=args.incast,
                             hadamard_block=1024),
            backend=args.transport,
            timeout=control.state.timeout,
            default_deadline=args.wire_deadline,
            budget=control.state.budget,
            drop_fn=(bernoulli_drops(args.drop_rate, seed=args.seed)
                     if args.drop_rate > 0 else None),
            membership=membership)

    tc = TrainConfig(
        sync=OptiReduceConfig(strategy=args.strategy,
                              # wire mode: drops are observed, not synthetic
                              drop_rate=0.0 if ring else args.drop_rate,
                              drop_pattern=args.drop_pattern,
                              incast=args.incast,
                              recovery=args.recovery,
                              hadamard_block=1024),
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr),
        dp_mode=args.dp_mode, microbatch=args.microbatch,
        sync_mode=args.sync_mode,
        transport_override=(WireTransport(ring.bridge_exchange)
                            if ring else None),
        kernel_mode=args.kernel_mode,
        seq_chunk=min(512, args.seq_len))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq_len,
                                  global_batch=args.global_batch,
                                  seed=args.seed))
    key = jax.random.PRNGKey(args.seed)
    fsdp_axes = ("data",) if args.dp_mode == "fsdp" else None
    params = init_params(key, cfg, tp=tp, fsdp_axes=fsdp_axes)

    make_step, opt, _ = build_train_step(cfg, tc, mesh)
    batch0 = data.host_batch(0, 0, 1)
    step_fn, shardings = make_step(jax.eval_shape(opt.init, params), batch0)
    params = jax.device_put(params, shardings["params"])
    opt_state = jax.jit(opt.init, out_shardings=shardings["opt"])(params)
    donate = (0, 1, 2) if args.recovery != "none" else (0, 1)
    jf = jax.jit(step_fn, donate_argnums=donate)

    rec_state = None
    if args.recovery != "none":
        from repro.core import recovery as recovery_lib
        from repro.core.bucket_plan import BucketPlan
        plan = BucketPlan.for_tree(params, tc.bucket_elems)
        rec_state = recovery_lib.init_state(
            recovery_lib.parse(args.recovery), plan.num_buckets,
            plan.bucket_elems, n_dp=mesh.shape.get("data", 1))
        rec_state = jax.device_put(rec_state, shardings["rec"])

    start_step = 0
    ckpt = ckpt_lib.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    # recovery state checkpoints alongside params/optimizer: a resume under
    # ef continues from the carried residual instead of silently dropping
    # the undelivered mass (the manifest's leaf-count guard catches a
    # resume with a different --recovery setting)
    ckpt_tree = ((params, opt_state) if rec_state is None
                 else (params, opt_state, rec_state))
    if args.resume and args.ckpt_dir:
        try:
            start_step, restored, _ = ckpt_lib.restore(
                args.ckpt_dir, ckpt_tree)
            if rec_state is None:
                params, opt_state = restored
            else:
                params, opt_state, rec_state = restored
                rec_state = jax.device_put(rec_state, shardings["rec"])
            params = jax.device_put(params, shardings["params"])
            opt_state = jax.device_put(opt_state, shardings["opt"])
            print(f"resumed from step {start_step}")
        except FileNotFoundError:
            pass

    monitor = LossMonitor(skip_threshold=tc.sync.skip_threshold)
    # §3.2 control plane (DESIGN §5): telemetry (observed loss + step wall
    # clock) feeds the runtime ControlPlane; when its SyncPolicy (Hadamard
    # on/off, advertised incast, active-peer set) moves, the step switches
    # to the policy's compiled step — from the bounded LRU cache when the
    # policy was seen before (eject -> readmit never recompiles), rebuilt
    # and cached otherwise (host-side — XLA itself cannot drop packets).
    if args.adaptive:
        from repro.core.pipeline import (RingTopology, TarTopology,
                                         resolve_spec)
        from repro.runtime import PolicyStepCache, SyncPolicy
        # start from the configured codec so step 0 never rebuilds, and
        # learn which knobs this spec can even observe: incast only lowers
        # rounds schedules; use_hadamard only matters if toggling it
        # resolves to a different spec (cfg-dependent factories); degraded
        # participation needs a mask-capable or reschedulable topology
        control.use_hadamard = tc.sync.use_hadamard
        topo = resolve_spec(tc.sync).topology
        incast_matters = (isinstance(topo, TarTopology)
                          and topo.schedule == "rounds")
        ht_matters = (resolve_spec(dataclasses.replace(
            tc.sync, use_hadamard=True)) is not resolve_spec(
                dataclasses.replace(tc.sync, use_hadamard=False)))
        participation_matters = (isinstance(topo, TarTopology) or
                                 (isinstance(topo, RingTopology)
                                  and topo.kind == "ring"))
        if ring is not None and isinstance(topo, TarTopology) \
                and topo.schedule == "rounds":
            # degraded rounds schedules exchange over a virtual ring the
            # wire bridge does not model (WireTransport raises); keep the
            # detector observing but hold full participation
            participation_matters = False
        # weighted shards / dead-link rewiring need a resizable schedule
        # (rounds TAR or a true ring) and the in-JAX transport — the wire
        # bridge's deposit geometry is fixed per compile, so the launcher
        # holds those knobs at default there (the detector still observes)
        reschedulable = ((isinstance(topo, TarTopology)
                          and topo.schedule == "rounds") or
                         (isinstance(topo, RingTopology)
                          and topo.kind == "ring"))
        rebalance_matters = args.rebalance and reschedulable and ring is None
        deadlink_matters = reschedulable and ring is None

        def policy_of(sync: OptiReduceConfig) -> SyncPolicy:
            return SyncPolicy(use_hadamard=sync.use_hadamard,
                              incast=sync.incast,
                              active_peers=sync.active_peers,
                              shard_weights=sync.shard_weights,
                              dead_links=sync.dead_links)

        step_cache = PolicyStepCache(maxsize=max(1, args.policy_cache))
        step_cache.put(policy_of(tc.sync), (jf, shardings))
        stable_rec, stable_for = None, 0
    report_rows: list[dict] = []
    t0 = time.time()
    try:
        for step in range(start_step, args.steps):
            batch = data.host_batch(step, 0, 1)
            batch = jax.device_put(batch, shardings["batch"])
            st0 = tracer.now() if tracer is not None else 0.0
            t_step = time.time()
            if rec_state is not None:
                params, opt_state, rec_state, metrics = jf(
                    params, opt_state, rec_state, batch,
                    jnp.asarray(step, jnp.int32), key)
            else:
                params, opt_state, metrics = jf(
                    params, opt_state, batch, jnp.asarray(step, jnp.int32),
                    key)
            loss_frac = float(metrics["loss_frac"])
            if with_budget:
                # phase-aware budget (DESIGN §8): the phase follows the LR
                # schedule's progress and the observed loss curve; the EMA
                # itself is fed through control.observe below
                control.state.budget.update_phase(
                    progress=(step + 1) / max(args.steps, 1),
                    train_loss=float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.tree.map(float, metrics)
                rate = (step - start_step + 1) / (time.time() - t0)
                print(f"step {step:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} loss_frac {m['loss_frac']:.5f}"
                      f" skipped {int(m['skipped'])} ({rate:.2f} it/s)",
                      flush=True)
            if control is not None:
                wire_t = None
                if ring is not None:
                    # let in-flight exchanges land; a wedged or dead wire layer
                    # must be loud, not silently degrade to all-ones masks
                    if not ring.flush():
                        print(f"wire[{args.transport}] WARNING: exchanges still "
                              f"in flight at step {step} (flush timed out)",
                              flush=True)
                    if ring.bridge_error is not None:
                        print(f"wire[{args.transport}] ERROR: bridge worker "
                              f"died: {ring.bridge_error!r} — masks degrade to "
                              "all-ones and telemetry stops", flush=True)
                        ring.bridge_error = None
                    wire_t = ring.drain_telemetry(step)
                if wire_t is not None:
                    # the real thing the ROADMAP asked for: per-peer stage
                    # times, per-round deadlines/timeouts/received fractions —
                    # observed on the wire, consumed by detector + controllers
                    control.observe(wire_t)
                    if step % args.log_every == 0 or step == args.steps - 1:
                        pst = ", ".join(f"{t:.3g}" for t
                                        in (wire_t.peer_stage_times or ()))
                        print(f"wire[{args.transport}] peers="
                              f"{len(wire_t.peer_stage_times or ())} "
                              f"stage_times=[{pst}] "
                              f"loss_frac={wire_t.loss_frac:.5f} "
                              f"deadline="
                              f"{ring.peers[0].round_deadline():.4g}"
                              + (f" misses={ring.bridge_misses}"
                                 if ring.bridge_misses else ""),
                              flush=True)
                elif ring is None:
                    # wall-clock only makes sense for the synthetic transport:
                    # a wire-fed AdaptiveTimeout is profiled in the backend's
                    # clock units, and one wall-clock sample during warmup
                    # would inflate t_B/t_C by orders of magnitude
                    control.observe(StepTelemetry(
                        step=step, loss_frac=loss_frac,
                        step_time=time.time() - t_step))
                if args.report:
                    det = control.detector
                    report_rows.append({
                        "step": step,
                        "scores": [float(s) for s in det.scores()],
                        "weights": [int(w) for w in det.weights()],
                        "active": [int(p) for p in det.active_peers()],
                        "dead_links": [list(l)
                                       for l in control.dead_links()],
                        "dead_link_events": [
                            list(l) for l in
                            ((wire_t.dead_link_events or ())
                             if wire_t is not None else ())],
                    })
            if args.adaptive:
                new_sync = control.apply(tc.sync)
                if not incast_matters:       # incast only lowers rounds forms
                    new_sync = dataclasses.replace(new_sync,
                                                   incast=tc.sync.incast)
                if not ht_matters:
                    new_sync = dataclasses.replace(
                        new_sync, use_hadamard=tc.sync.use_hadamard)
                if not participation_matters:
                    new_sync = dataclasses.replace(
                        new_sync, active_peers=tc.sync.active_peers)
                if not rebalance_matters:
                    new_sync = dataclasses.replace(new_sync,
                                                   shard_weights=None)
                if not deadlink_matters:
                    new_sync = dataclasses.replace(new_sync, dead_links=())
                # debounce: a growing incast ramps one step at a time, and each
                # rebuild recompiles the whole step — wait for the controller to
                # settle. A Hadamard toggle is an accuracy decision and an
                # ejection stops the straggler wait: both immediate.
                stable_for = stable_for + 1 if new_sync == stable_rec else 1
                stable_rec = new_sync
                # a link failure (or recovery probe) reroutes immediately —
                # waiting three steps on a dead edge loses three deadlines
                urgent = (new_sync.use_hadamard != tc.sync.use_hadamard or
                          new_sync.active_peers != tc.sync.active_peers or
                          new_sync.dead_links != tc.sync.dead_links)
                if new_sync != tc.sync and (urgent or stable_for >= 3):
                    tc = dataclasses.replace(tc, sync=new_sync)
                    cached = step_cache.get(policy_of(new_sync))
                    if cached is not None:
                        jf, shardings = cached
                        how = "cached step reused"
                    else:
                        make_step, opt, _ = build_train_step(cfg, tc, mesh)
                        step_fn, shardings = make_step(
                            jax.eval_shape(opt.init, params), batch0)
                        jf = jax.jit(step_fn, donate_argnums=donate)
                        step_cache.put(policy_of(new_sync), (jf, shardings))
                        how = "step rebuilt"
                    print(f"adaptive: use_hadamard={new_sync.use_hadamard} "
                          f"incast={new_sync.incast} "
                          f"active={new_sync.active_peers} "
                          f"weights={new_sync.shard_weights} "
                          f"dead={new_sync.dead_links} ({how})", flush=True)
            if tracer is not None:
                tracer.complete("step", "trainer", ts=st0,
                                dur=tracer.now() - st0,
                                args={"step": step,
                                      "loss_frac": round(loss_frac, 6)})
                tracer.counter("loss_frac", loss_frac)
            monitor.observe(step, loss_frac, bool(metrics["skipped"] > 0))
            if monitor.halted:
                print("HALT: excessive gradient loss (§3.4); rolling back")
                rb = monitor.rollback()
                if rb is not None:
                    _, params = rb
            if ckpt and step > 0 and step % args.ckpt_every == 0:
                ckpt.save(step, (params, opt_state) if rec_state is None
                          else (params, opt_state, rec_state))
        if ckpt:
            ckpt.save(args.steps, (params, opt_state) if rec_state is None
                      else (params, opt_state, rec_state))
            ckpt.wait()
    finally:
        if ring is not None:
            ring.close()          # UDP sockets + the bridge worker
        for c in rdv_clients:
            c.leave()
        if rdv_server is not None:
            rdv_server.close()
    if args.report and control is not None:
        import json
        with open(args.report, "w") as f:
            json.dump({"n_peers": control.detector.n_peers,
                       "rebalance": bool(args.rebalance),
                       "steps": report_rows}, f, indent=1)
        print(f"report: {len(report_rows)} steps -> {args.report}",
              flush=True)
    if tracer is not None:
        from repro.obs import export as obs_export
        path = obs_export.write_trace(args.trace, tracer,
                                      meta={"transport": args.transport,
                                            "strategy": args.strategy})
        print(f"trace: {len(tracer)} records ({tracer.dropped} dropped) "
              f"-> {path}", flush=True)
    print("done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
