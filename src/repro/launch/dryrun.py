import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell (EXPERIMENTS.md §Dry-run / §Roofline inputs):
  * FULL compile (production depth, scan-over-layers):
      - compiled.memory_analysis()  — proves the cell fits 16 GB/chip
      - wall-clock lower/compile times
  * COST MODEL (four shallow *unrolled* compiles): XLA's cost_analysis
    counts while-loop bodies once, so scanned layers/microbatches would be
    undercounted. We therefore compile unrolled variants at two depths
    (1 and 2 stage-repeats) x two per-device batch sizes (1 and 2) and fit
    the exact linear form
        M(L, B) = fix_base + B*tok_base + L*fix_layer + L*B*tok_layer
    per metric (FLOPs, bytes, per-collective bytes), then evaluate at the
    production (L, B). The model is exact because every metric is affine in
    depth and batch by construction of the program.

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.json
  python -m repro.launch.dryrun --all --multi-pod --out results/dryrun_mp.json
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs import ARCHS, SHAPES, get_config, input_specs, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.allreduce import OptiReduceConfig
from repro.core.pipeline import resolve_spec
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_params, active_params, count_params
from repro.optim.optimizers import OptimizerConfig
from repro.serve.engine import (abstract_state, build_serve_step,
                                plan_serving)
from repro.train.trainer import TrainConfig, abstract_opt_state, build_train_step

# ---------------------------------------------------------------- cell plans
# Per-arch training plan: dp_mode, microbatch (per-device), moments dtype.
# fsdp whenever params don't comfortably replicate; bf16 moments for the
# giants (16 GB/chip budget — see EXPERIMENTS.md §Dry-run).
PLAN = {
    "arctic-480b":          dict(dp_mode="fsdp", microbatch=1, mom="bf16",
                                 opt="momentum", accum="bf16",
                                 serve_fsdp=True),
    "qwen2-moe-a2.7b":      dict(dp_mode="fsdp", microbatch=4, mom="f32"),
    "mamba2-1.3b":          dict(dp_mode="fsdp", microbatch=4, mom="f32"),
    "command-r-plus-104b":  dict(dp_mode="fsdp", microbatch=1, mom="bf16",
                                 accum="bf16", serve_fsdp=True),
    "stablelm-1.6b":        dict(dp_mode="fsdp", microbatch=4, mom="f32"),
    "smollm-360m":          dict(dp_mode="replicated", microbatch=8, mom="f32"),
    "glm4-9b":              dict(dp_mode="fsdp", microbatch=2, mom="f32"),
    "llava-next-mistral-7b": dict(dp_mode="fsdp", microbatch=2, mom="f32"),
    "musicgen-medium":      dict(dp_mode="fsdp", microbatch=4, mom="f32"),
    "jamba-v0.1-52b":       dict(dp_mode="fsdp", microbatch=1, mom="bf16"),
}

SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        base = 1 if dt.startswith("f8") else DTYPE_BYTES.get(dt, 2)
        total += n * base
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes moved by each collective kind (result-shape proxy;
    '-start' forms counted once, '-done' skipped)."""
    out: dict[str, float] = {}
    pat = re.compile(r"(" + "|".join(COLLECTIVES) + r")(?:-start)?\(")
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done(" in line or "=" not in line:
            continue
        m = pat.search(line)
        if not m:
            continue
        kind = m.group(1)
        nbytes = _shape_bytes(line.split("=", 1)[1].split(m.group(0))[0])
        out[kind] = out.get(kind, 0) + nbytes
        out["count_" + kind] = out.get("count_" + kind, 0) + 1
    return out


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {"available": False}
    if ma is None:
        return {"available": False}
    keys = ["generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes"]
    out = {"available": True}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    peak = (out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    out["peak_bytes_per_device"] = int(peak)
    return out


def dp_total_of(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


# -------------------------------------------------------------- cell builders
def make_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                    strategy: str, drop_rate: float, plan: dict,
                    unroll: bool = False, donate: bool = True):
    mom = jnp.bfloat16 if plan["mom"] == "bf16" else jnp.float32
    dp_tot = dp_total_of(mesh)
    if plan.get("pure_dp"):
        dp_tot *= mesh.shape.get("model", 1)
    b_local = max(1, shape.global_batch // dp_tot)
    # unroll (cost-model) mode: no microbatch scan at all, so every FLOP is
    # visible to cost_analysis; production mode: grad-accumulate
    microbatch = None if unroll else min(plan["microbatch"], b_local)

    tc = TrainConfig(
        sync=OptiReduceConfig(strategy=strategy, drop_rate=drop_rate,
                              rs_wire_bits=plan.get("rs_wire_bits", 0)),
        optimizer=OptimizerConfig(name=plan.get("opt", "adamw"),
                                  state_dtype=mom,
                                  # lax.map over the update breaks donation
                                  # aliasing through the loop (+2x param
                                  # memory) — measured worse; keep it off
                                  scan_update=False),
        dp_mode=plan["dp_mode"], microbatch=microbatch,
        seq_chunk=min(plan.get("seq_chunk", 512), shape.seq_len),
        remat=plan.get("remat", True), unroll=unroll,
        pure_dp=plan.get("pure_dp", False),
        seq_parallel=plan.get("seq_parallel", False),
        accum_dtype=(jnp.bfloat16 if plan.get("accum") == "bf16"
                     else jnp.float32),
        bucket_elems=plan.get("bucket_elems", 6_553_600))

    make_step, opt, _ = build_train_step(cfg, tc, mesh)
    tp = 1 if tc.pure_dp else mesh.shape["model"]
    if tc.dp_mode != "fsdp":
        fsdp_axes = None
    elif tc.pure_dp:
        fsdp_axes = ("model", "data")
    else:
        fsdp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    a_params = abstract_params(cfg, tp=tp, fsdp_axes=fsdp_axes)
    a_opt = abstract_opt_state(opt, a_params)
    a_batch = input_specs(cfg, shape)
    step_fn, _ = make_step(a_opt, a_batch)
    key_arg = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    args = (a_params, a_opt, a_batch, jax.ShapeDtypeStruct((), jnp.int32),
            key_arg)
    jf = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
    return jf, args, tc


def make_serve_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                    unroll: bool = False, donate: bool = True,
                    weight_fsdp: bool = False, moe_stationary: bool = False):
    plan = plan_serving(mesh, shape.global_batch)
    make = build_serve_step(cfg, mesh, plan, unroll=unroll,
                            weight_fsdp=weight_fsdp,
                            moe_stationary=moe_stationary)
    a_state = abstract_state(cfg, shape, plan)
    step_fn, _ = make(a_state)
    tp = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    a_params = abstract_params(cfg, tp=tp,
                               fsdp_axes=dp_axes if weight_fsdp else None)
    a_tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    key_arg = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    args = (a_params, a_state, a_tokens, jax.ShapeDtypeStruct((), jnp.int32),
            key_arg)
    jf = jax.jit(step_fn, donate_argnums=(1,) if donate else ())
    return jf, args, plan


def make_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                      unroll: bool = False, weight_fsdp: bool = False):
    from jax.sharding import PartitionSpec as P

    from repro.models import param_specs, prefill_step
    from repro.models.layers import KVCache
    from repro.models.parallel import ParallelCtx
    from repro.models.ssm import SSMState
    from repro.models.transformer import TpLayout, _period

    names = tuple(mesh.axis_names)
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    gather = None
    if weight_fsdp:
        def gather(w, dim, key):
            del key
            for ax in reversed(dp_axes):
                w = jax.lax.all_gather(w, ax, axis=dim, tiled=True)
            return w
    pctx = ParallelCtx(tp_axis="model", dp_axis="data",
                       pod_axis="pod" if "pod" in names else None,
                       fsdp=weight_fsdp, gather=gather)
    tp = mesh.shape["model"]

    def body(params, batch, key):
        return prefill_step(params, batch, cfg, pctx, key=key, unroll=unroll)

    a_batch = input_specs(cfg, shape)
    b_ax = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    batch_spec = jax.tree.map(lambda _: P(b_ax), a_batch)
    p_specs = param_specs(cfg, tp=tp,
                          fsdp_axes=dp_axes if weight_fsdp else None)
    lay = TpLayout.build(cfg, tp)
    st_specs = []
    for pos in range(_period(cfg)):
        if cfg.is_attn_layer(pos):
            kv_sharded = not lay.kv_replicated or lay.kv_single
            sp = P(None, b_ax, None, "model" if kv_sharded else None, None)
            st_specs.append(KVCache(k=sp, v=sp))
        else:
            st_specs.append(SSMState(conv=P(None, b_ax, None, "model"),
                                     ssm=P(None, b_ax, "model", None, None)))
    fn = compat.shard_map(body, mesh=mesh,
                       in_specs=(p_specs, batch_spec, P()),
                       out_specs=(P(b_ax, None), st_specs),
                       check_vma=False)
    a_params = abstract_params(cfg, tp=tp,
                               fsdp_axes=dp_axes if weight_fsdp else None)
    key_arg = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return jax.jit(fn), (a_params, a_batch, key_arg), None


def _build(kind: str, cfg, shape, mesh, *, strategy, drop_rate, plan,
           unroll=False, donate=True):
    if kind == "train":
        return make_train_cell(cfg, shape, mesh, strategy=strategy,
                               drop_rate=drop_rate, plan=plan, unroll=unroll,
                               donate=donate)
    if kind == "prefill":
        return make_prefill_cell(cfg, shape, mesh, unroll=unroll,
                                 weight_fsdp=plan.get("serve_fsdp", False))
    return make_serve_cell(cfg, shape, mesh, unroll=unroll, donate=donate,
                           weight_fsdp=plan.get("serve_fsdp", False),
                           moe_stationary=plan.get("moe_stationary", False))


# --------------------------------------------------------------- cost model
def _metrics(compiled) -> dict[str, float]:
    out: dict[str, float] = {}
    try:
        ca = compiled.cost_analysis()
        out["flops"] = float(ca.get("flops", 0.0))
        out["bytes"] = float(ca.get("bytes accessed", 0.0))
        out["transcendentals"] = float(ca.get("transcendentals", 0.0))
    except Exception:
        pass
    for k, v in parse_collective_bytes(compiled.as_text()).items():
        out["coll_" + k] = float(v)
    return out


def cost_model(cfg: ModelConfig, shape: ShapeConfig, mesh, kind: str, *,
               strategy: str, drop_rate: float, plan: dict) -> dict:
    """Fit M(L,B) = fix_base + B*tok_base + L*fix_layer + L*B*tok_layer per
    metric from four shallow unrolled compiles; evaluate at production L,B."""
    from repro.models.transformer import _period
    period = _period(cfg)
    d1, d2 = period, 2 * period
    dp = dp_total_of(mesh)
    if kind == "train" and plan.get("pure_dp"):
        dp *= mesh.shape.get("model", 1)     # both axes carry batch
    b1, b2 = dp, 2 * dp
    if shape.global_batch < dp:              # long_500k: B=1 global
        b1, b2 = 1, 2

    meas: dict[tuple[int, int], dict] = {}
    for d in (d1, d2):
        for b in (b1, b2):
            # dense attention (attn_chunk=0): no scan inside the layer, so
            # cost_analysis sees every attention FLOP (compile-only; the
            # S^2 scores are never allocated)
            c = dataclasses.replace(cfg, n_layers=d, attn_chunk=0)
            s = dataclasses.replace(shape, global_batch=b)
            jf, args, _ = _build(kind, c, s, mesh, strategy=strategy,
                                 drop_rate=drop_rate, plan=plan, unroll=True,
                                 donate=False)
            with mesh:
                compiled = jf.lower(*args).compile()
            meas[(d, b)] = _metrics(compiled)

    keys = set()
    for m in meas.values():
        keys.update(m.keys())
    L, B = cfg.n_layers, shape.global_batch
    out = {}
    for k in sorted(keys):
        f = {db: meas[db].get(k, 0.0) for db in meas}
        lay_b1 = (f[(d2, b1)] - f[(d1, b1)]) / (d2 - d1)
        lay_b2 = (f[(d2, b2)] - f[(d1, b2)]) / (d2 - d1)
        tok_layer = (lay_b2 - lay_b1) / (b2 - b1)
        fix_layer = lay_b1 - b1 * tok_layer
        base_b1 = f[(d1, b1)] - d1 * lay_b1
        base_b2 = f[(d1, b2)] - d1 * lay_b2
        tok_base = (base_b2 - base_b1) / (b2 - b1)
        fix_base = base_b1 - b1 * tok_base
        val = fix_base + B * tok_base + L * (fix_layer + B * tok_layer)
        out[k] = max(val, 0.0)
    out["_model"] = {"depths": [d1, d2], "batches": [b1, b2],
                     "eval_at": [L, B]}
    return out


# ------------------------------------------------------------------ run cell
def run_cell(arch: str, shape_name: str, mesh, *, strategy: str = "optireduce",
             drop_rate: float = 0.01, overrides: dict | None = None,
             with_cost_model: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    plan = dict(PLAN[arch])
    plan.update(overrides or {})
    ok, why = shape_applicable(cfg, shape)
    rec: dict = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "params": count_params(cfg), "active_params": active_params(cfg),
        "strategy": strategy, "drop_rate": drop_rate,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    t0 = time.time()
    try:
        jf, args, extra = _build(shape.kind, cfg, shape, mesh,
                                 strategy=strategy, drop_rate=drop_rate,
                                 plan=plan)
        if shape.kind == "train":
            rec["dp_mode"] = extra.dp_mode
            rec["microbatch"] = extra.microbatch
        elif shape.kind == "decode":
            rec["serve_plan"] = dataclasses.asdict(extra)
        with mesh:
            lowered = jf.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 1)
        rec["compile_s"] = round(t2 - t1, 1)
        rec["memory"] = memory_summary(compiled)
        rec["full_hlo_metrics"] = _metrics(compiled)   # body-once caveat
        if with_cost_model:
            rec["cost_model"] = cost_model(cfg, shape, mesh, shape.kind,
                                           strategy=strategy,
                                           drop_rate=drop_rate, plan=plan)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="optireduce")
    ap.add_argument("--drop-rate", type=float, default=0.01)
    ap.add_argument("--dp-mode", default=None)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--seq-chunk", type=int, default=None)
    ap.add_argument("--bucket-elems", type=int, default=None)
    ap.add_argument("--no-cost-model", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    # fail fast (with the registered-name list) before any compile work
    resolve_spec(OptiReduceConfig(strategy=args.strategy))

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    overrides = {}
    for k in ("dp_mode", "microbatch", "seq_chunk", "bucket_elems"):
        v = getattr(args, k)
        if v is not None:
            overrides[k] = v

    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        print(f"=== {arch} x {shape} "
              f"({'2x16x16' if args.multi_pod else '16x16'}) ===", flush=True)
        rec = run_cell(arch, shape, mesh, strategy=args.strategy,
                       drop_rate=args.drop_rate, overrides=overrides,
                       with_cost_model=not args.no_cost_model)
        results.append(rec)
        status = rec["status"]
        mem = rec.get("memory", {}).get("peak_bytes_per_device")
        mem_s = f" peak={mem/2**30:.2f}GiB" if mem else ""
        flops = rec.get("cost_model", {}).get("flops")
        fl_s = f" flops/dev={flops:.3e}" if flops else ""
        print(f"  -> {status}{mem_s}{fl_s} "
              f"(lower {rec.get('lower_s')}s compile {rec.get('compile_s')}s)",
              flush=True)
        if status == "error":
            print("  " + rec["error"], flush=True)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {len(results)} cells, {n_err} errors", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
