"""Multi-process peer launcher: ``python -m repro.launch.multiproc`` (§9).

Spawns ``--nprocs`` genuinely independent OS processes, each running a
single :class:`~repro.net.peer.HostPeer` over its own single-socket UDP
backend, with ranks resolved through the TCP rendezvous coordinator
(``repro.net.rendezvous``) instead of a fixed peer list — the repo's first
launch path where a peer can really crash, be ejected, restart, and
readmit.  ``--backend=inproc`` runs the *same* worker loop as threads over
the in-memory coordinator + loopback fabric, so CI without sockets still
exercises the full launch path (join -> lockstep phase barriers ->
membership events -> telemetry -> checkpoint).

One worker step is four rendezvous-fenced phases (barrier tag = ``step *
PHASES_PER_STEP + phase``)::

    events -> phase1 encode | phase2 send1 | phase3 reduce+send2 | phase4
    decode -> telemetry -> ControlPlane -> checkpoint

Crash lifecycle: ``--kill-rank R --kill-step S`` makes the worker holding
rank R SIGKILL itself after the step-S phase-1 fence (mid-step: the
survivors' receive deadlines expire and the step completes *degraded*);
the coordinator's EOF detection frees the slot, survivors drain the death
event at their next step fence and force-eject R through the ControlPlane.
With ``--restart``, the parent respawns the dead uid once the group has
moved past the crash step; the fresh process restores from ``--ckpt-dir``
(``train/checkpoint.py``), rejoins — claiming the freed slot, required
only from its ``since`` step boundary — and readmits through PROBATION.

Each worker writes a JSON report (per-step output checksums, observed
loss, live set, detector statuses, membership generation); the parent
merges them into ``--report``.  The smoke suite pins a 4-process UDP run
bitwise against the single-process inproc HostRing under a scripted loss
schedule.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import signal
import subprocess
import sys
import tempfile
import threading
import time


def _tag(step: int, phase: int) -> int:
    from repro.net import PHASES_PER_STEP
    return step * PHASES_PER_STEP + phase


class _Killed(Exception):
    """Thread-mode stand-in for SIGKILL (inproc backend)."""


class _StepMembership:
    """Step-boundary snapshot of the rendezvous live set.

    The peer must see *one* membership for all four phases of a step: a
    rank that leaves while a slower rank is still inside its phase 3/4
    (the unfenced tail of the last step) must stay receivable until that
    step completes — its packets are already on the wire — or results
    would depend on which rank finished first.  :meth:`refresh` runs at
    the step fence, right after the membership events drain, so deaths
    still degrade the very next step.

    The snapshot applies the same rule the barriers do: a member with
    ``since > tag(step, 0)`` is not yet required — a rejoiner whose JOIN
    races a survivor's step fence (its ``since`` is rounded up to the
    next step boundary) must not be waited on this step, or whether the
    survivors skip it would depend on restart timing.
    """

    def __init__(self, client):
        self._client = client
        self._live: frozenset | None = None

    def refresh(self, step: int) -> None:
        mem = self._client.membership()
        self._live = None if mem is None else frozenset(
            m.rank for m in mem.members if m.since <= _tag(step, 0))

    def is_live(self, rank: int) -> bool:
        return self._live is None or rank in self._live


# ----------------------------------------------------------------- worker
def _compile_stage_fns(peer, elems: int, key) -> None:
    """Trace + compile every jitted stage fn *before* the first barrier.

    A worker (above all a rejoiner) that compiles inside its first step
    would stall its stage-1 sends for seconds while every survivor's
    receive deadline expires — scoring it as a straggler the moment it
    came back.  Compiling here instead happens while the others wait at
    the entry fence, which costs them a bounded barrier wait, not masked
    gradient entries.  Runs entirely off the backend: dummy zero inputs
    through the same jit entry points the phases call.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core.pipeline import HTQuant

    n = peer.n
    x = jnp.zeros(elems, jnp.float32)
    if isinstance(peer.codec, HTQuant):
        x1, amax = peer._enc_local(x, key)
        data, lo, stp = peer._enc_finish(x1, amax, key)
    else:
        data, _ = peer._enc(x, key, None)
        lo = stp = None
    wire1 = np.asarray(data)
    s = wire1.shape[0] // n
    received = jnp.zeros((n, s), wire1.dtype)
    mask = jnp.ones((n, s), jnp.float32)
    wire2 = peer._red(received, mask, jnp.asarray(peer.rank, jnp.int32),
                      lo, stp, None, key)
    gathered = jnp.zeros(n * np.asarray(wire2).shape[0], np.asarray(
        wire2).dtype)
    peer._dec(gathered, lo, stp, key).block_until_ready()


def _run_peer(client, backend, args, *, uid: int, kill_fn=None) -> dict:
    """One worker's whole life: join -> fenced step loop -> leave.

    ``client`` is a rendezvous client (TCP or Local — same duck type),
    ``backend`` the datagram fabric, ``kill_fn(client)`` the crash
    injection for the scripted kill scenario.
    """
    import jax
    import numpy as np

    from repro.core.pipeline import OptiReduceConfig
    from repro.net import HostPeer, aggregate_reports
    from repro.runtime import ControlPlane
    from repro.train import checkpoint as ckpt_lib

    rank, _, start_step = client.join()
    if hasattr(backend, "attach"):
        backend.attach(rank, client.addr_of)
    # per-rank tracer (DESIGN §12): thread-local so the inproc mode's N
    # rank-threads in one process keep separate rings; in udp mode each
    # worker is its own process and this is simply its tracer
    tracer = None
    if getattr(args, "trace_dir", None):
        from repro.obs import trace as obs_trace
        tracer = obs_trace.configure_thread(True, rank=rank)
    cfg = OptiReduceConfig(strategy=args.strategy, drop_rate=0.0,
                           hadamard_block=args.hadamard_block,
                           packet_elems=args.packet_elems)
    step_mem = _StepMembership(client)
    peer = HostPeer(rank, backend, cfg, default_deadline=args.deadline,
                    membership=step_mem)
    control = ControlPlane.create(
        n_nodes=args.nprocs,
        detector_kw=dict(probation=args.probation,
                         min_active=args.min_active))

    key0 = jax.random.PRNGKey(args.seed)
    model = np.zeros(args.elems, np.float32)
    resumed_from = None
    ckpt_dir = None
    if args.ckpt_dir:
        ckpt_dir = os.path.join(args.ckpt_dir, f"rank{rank:02d}")
        try:
            got_step, tree, _ = ckpt_lib.restore(
                ckpt_dir, {"step": np.zeros((), np.int64), "model": model})
            resumed_from = int(got_step)
            model = np.asarray(tree["model"], np.float32)
        except FileNotFoundError:
            pass

    _compile_stage_fns(peer, args.elems, key0)

    records = []
    for step in range(start_step, args.steps):
        if args.step_sleep > 0:
            time.sleep(args.step_sleep)
        client.barrier(_tag(step, 0), timeout=args.barrier_timeout)
        for kind, r, gen in client.events():
            control.apply_membership(kind, r, gen)
        step_mem.refresh(step)
        # every worker derives the same per-step data matrix from the seed
        # and contributes its own row — what makes cross-run bitwise
        # comparison (multiproc UDP vs single-process inproc) meaningful
        data = np.random.default_rng(args.seed + step).standard_normal(
            (args.nprocs, args.elems)).astype(np.float32)
        key = jax.random.fold_in(key0, step)
        st0 = tracer.now() if tracer is not None else 0.0
        peer.phase1_encode(data[rank], key, step, 0)
        client.barrier(_tag(step, 1), timeout=args.barrier_timeout)
        if kill_fn is not None and rank == args.kill_rank \
                and step == args.kill_step and start_step <= args.kill_step:
            kill_fn(client)
        peer.phase2_send_stage1(step, 0)
        client.barrier(_tag(step, 2), timeout=args.barrier_timeout)
        rep = peer.phase3_reduce_send_stage2(step, 0)
        client.barrier(_tag(step, 3), timeout=args.barrier_timeout)
        out, rep2 = peer.phase4_decode(step, 0)
        rep.merge(rep2)
        tel = aggregate_reports([rep], step)
        control.observe(tel)
        if tracer is not None:
            tracer.complete("step", "trainer", ts=st0,
                            dur=tracer.now() - st0,
                            args={"step": step,
                                  "loss_frac": round(float(tel.loss_frac),
                                                     6),
                                  "timed_out": bool(tel.timed_out)})
        model += out
        records.append({
            "step": step,
            "checksum": hashlib.sha256(
                np.ascontiguousarray(out).tobytes()).hexdigest()[:16],
            "loss_frac": round(float(tel.loss_frac), 6),
            "stage2_dropped": float(rep.stage2_dropped),
            "timed_out": bool(tel.timed_out),
            "live": [int(r) for r in sorted(
                client.membership().live_ranks())]
            if client.membership() is not None else list(range(args.nprocs)),
            "statuses": [control.detector.status(i)
                         for i in range(args.nprocs)],
            "generation": int(client.generation),
            "skipped": sorted(set(int(s) for s in rep.skipped_senders)),
        })
        if ckpt_dir:
            ckpt_lib.save(ckpt_dir, step,
                          {"step": np.asarray(step, np.int64),
                           "model": model},
                          meta={"uid": uid, "rank": rank}, keep=2)
    client.leave()
    trace_path = None
    if tracer is not None:
        from repro.obs import export as obs_export
        trace_path = obs_export.write_trace(
            args.trace_dir, tracer, meta={"uid": uid, "backend":
                                          type(backend).__name__})
    return {"uid": uid, "rank": rank, "start_step": start_step,
            "resumed_from": resumed_from, "exit": "ok", "steps": records,
            "trace": trace_path}


def _sigkill_self(client) -> None:
    os.kill(os.getpid(), signal.SIGKILL)


def _thread_crash(client) -> None:
    client.crash()
    raise _Killed()


def _worker_main(args) -> int:
    """``--worker`` subprocess entry (udp backend only)."""
    from repro.net import RendezvousClient, UdpProcessBackend, \
        bernoulli_drops

    drop_fn = bernoulli_drops(args.drop_rate, seed=args.drop_seed) \
        if args.drop_rate > 0 else None
    backend = UdpProcessBackend(args.nprocs, drop_fn=drop_fn)
    host, _, port = args.rendezvous.rpartition(":")
    client = RendezvousClient((host or "127.0.0.1", int(port)),
                              uid=args.uid, peer_port=backend.port)
    try:
        result = _run_peer(client, backend, args, uid=args.uid,
                           kill_fn=_sigkill_self)
    finally:
        backend.close()
        client.close()
    if args.report_file:
        with open(args.report_file, "w") as f:
            json.dump(result, f)
    return 0


# ----------------------------------------------------------------- parent
def _spawn(args, uid: int, rendezvous: str, report_file: str
           ) -> subprocess.Popen:
    cmd = [sys.executable, "-m", "repro.launch.multiproc", "--worker",
           "--uid", str(uid), "--rendezvous", rendezvous,
           "--report-file", report_file,
           "--nprocs", str(args.nprocs), "--steps", str(args.steps),
           "--elems", str(args.elems), "--strategy", args.strategy,
           "--packet-elems", str(args.packet_elems),
           "--hadamard-block", str(args.hadamard_block),
           "--drop-rate", str(args.drop_rate),
           "--drop-seed", str(args.drop_seed),
           "--seed", str(args.seed), "--deadline", str(args.deadline),
           "--step-sleep", str(args.step_sleep),
           "--barrier-timeout", str(args.barrier_timeout),
           "--kill-rank", str(args.kill_rank),
           "--kill-step", str(args.kill_step),
           "--probation", str(args.probation),
           "--min-active", str(args.min_active)]
    if args.ckpt_dir:
        cmd += ["--ckpt-dir", args.ckpt_dir]
    if args.trace_dir:
        cmd += ["--trace-dir", args.trace_dir]
    env = dict(os.environ)
    # make `python -m repro.launch.multiproc` resolvable in the child even
    # when the parent found `repro` via a sys.path edit (demo scripts)
    pkg_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    paths = env.get("PYTHONPATH", "").split(os.pathsep)
    if pkg_root not in paths:
        env["PYTHONPATH"] = os.pathsep.join(
            [pkg_root] + [p for p in paths if p])
    return subprocess.Popen(cmd, env=env)


def _launch_udp(args) -> dict:
    from repro.net import RendezvousServer

    server = None
    if args.rendezvous == "auto":
        server = RendezvousServer(args.nprocs,
                                  heartbeat_timeout=args.heartbeat_timeout)
        rdv = f"{server.addr[0]}:{server.addr[1]}"
    else:
        rdv = args.rendezvous
    reports_dir = tempfile.mkdtemp(prefix="multiproc_reports_")
    procs: dict[int, tuple[subprocess.Popen, str]] = {}
    report_files: list[str] = []

    def spawn(uid: int, attempt: int) -> None:
        path = os.path.join(reports_dir, f"uid{uid}_a{attempt}.json")
        report_files.append(path)
        procs[uid] = (_spawn(args, uid, rdv, path), path)

    for uid in range(args.nprocs):
        spawn(uid, 0)

    deadline = time.monotonic() + args.timeout
    respawned = False
    want_restart = args.restart and args.kill_rank >= 0
    failures: list[str] = []
    try:
        while procs:
            if time.monotonic() > deadline:
                for p, _ in procs.values():
                    p.kill()
                raise SystemExit(
                    f"multiproc: wall-clock timeout ({args.timeout}s) — "
                    f"{len(procs)} workers still running")
            for uid in list(procs):
                p, path = procs[uid]
                rc = p.poll()
                if rc is None:
                    continue
                del procs[uid]
                if rc == -signal.SIGKILL and want_restart and not respawned:
                    # the scripted victim: respawn once the coordinator has
                    # processed the death (slot freed) and the survivors
                    # have finished the post-crash step — a step-(k+1)
                    # fence arrival means every survivor drained the death
                    # event at its step-(k+1) boundary (the step's phase
                    # barriers cannot all release otherwise), so the
                    # ejection is observed at every rank before the rejoin
                    # can race a fence
                    respawned = True
                    while (server is not None
                           and (len(server.live_ranks()) >= args.nprocs
                                or server.latest_step() <= args.kill_step + 1)
                           and time.monotonic() < deadline):
                        time.sleep(0.05)
                    spawn(uid, 1)
                elif rc != 0:
                    failures.append(f"uid {uid} exited {rc}")
            time.sleep(0.05)
    finally:
        if server is not None:
            server.close()
    if failures:
        raise SystemExit("multiproc: " + "; ".join(failures))

    workers = []
    for path in report_files:
        if os.path.exists(path):
            with open(path) as f:
                workers.append(json.load(f))
        else:
            workers.append({"exit": "killed", "report": path})
    return {"backend": "udp", "nprocs": args.nprocs, "steps": args.steps,
            "strategy": args.strategy,
            "scenario": {"kill_rank": args.kill_rank,
                         "kill_step": args.kill_step,
                         "restart": bool(args.restart)},
            "workers": workers}


def _launch_inproc(args) -> dict:
    """Same worker loop as threads over the in-memory coordinator — the
    socket-free CI path through the full launch machinery."""
    from repro.net import InprocBackend, LocalCoordinator, bernoulli_drops

    coord = LocalCoordinator(args.nprocs)
    drop_fn = bernoulli_drops(args.drop_rate, seed=args.drop_seed) \
        if args.drop_rate > 0 else None
    backend = InprocBackend(args.nprocs, drop_fn=drop_fn)
    results: dict[str, dict] = {}
    errors: list = []
    lock = threading.Lock()

    def run(uid: int, attempt: int) -> None:
        label = f"uid{uid}_a{attempt}"
        client = coord.client(uid)
        try:
            res = _run_peer(client, backend, args, uid=uid,
                            kill_fn=_thread_crash)
            with lock:
                results[label] = res
        except _Killed:
            with lock:
                results[label] = {"uid": uid, "exit": "killed",
                                  "rank": client.rank}
        except Exception as e:            # surface, never hang the join
            with lock:
                errors.append((uid, e))

    threads = {uid: threading.Thread(target=run, args=(uid, 0), daemon=True)
               for uid in range(args.nprocs)}
    for t in threads.values():
        t.start()
    deadline = time.monotonic() + args.timeout
    want_restart = args.restart and args.kill_rank >= 0
    respawned = False
    while any(t.is_alive() for t in threads.values()) or \
            (want_restart and not respawned):
        if time.monotonic() > deadline:
            raise SystemExit(f"multiproc: wall-clock timeout "
                             f"({args.timeout}s)")
        if want_restart and not respawned:
            with lock:
                # threads race their joins, so the victim's uid is whoever
                # ended up holding --kill-rank; detect the death by outcome
                victim = next((w["uid"] for w in results.values()
                               if w.get("exit") == "killed"), None)
            # respawn only after the survivors have FINISHED the
            # post-crash step (a step-(k+2) fence arrival means every
            # survivor's step-(k+1) drain observed the ejection) so the
            # rejoin cannot race the death into one event drain and hide
            # the EJECTED state from the status trail
            if victim is not None and \
                    len(coord.live_ranks()) < args.nprocs and \
                    coord.latest_step() > args.kill_step + 1:
                respawned = True
                t2 = threading.Thread(target=run, args=(victim, 1),
                                      daemon=True)
                threads[f"{victim}r"] = t2
                t2.start()
        time.sleep(0.02)
        with lock:
            if errors:
                raise SystemExit(f"multiproc workers failed: {errors}")
    with lock:
        if errors:
            raise SystemExit(f"multiproc workers failed: {errors}")
    return {"backend": "inproc", "nprocs": args.nprocs, "steps": args.steps,
            "strategy": args.strategy,
            "scenario": {"kill_rank": args.kill_rank,
                         "kill_step": args.kill_step,
                         "restart": bool(args.restart)},
            "workers": [results[k] for k in sorted(results)]}


# ------------------------------------------------------------------- CLI
def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.multiproc",
        description="N-process HostPeer runtime over UDP + TCP rendezvous")
    ap.add_argument("--nprocs", type=int, default=4)
    ap.add_argument("--backend", default="udp", choices=("udp", "inproc"),
                    help="udp: N OS processes, single-socket backends, TCP "
                         "rendezvous; inproc: N threads over the in-memory "
                         "coordinator (socket-free CI fallback)")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--elems", type=int, default=4096,
                    help="fp32 gradient elements per peer per step")
    ap.add_argument("--strategy", default="optireduce")
    ap.add_argument("--packet-elems", type=int, default=256)
    ap.add_argument("--hadamard-block", type=int, default=256)
    ap.add_argument("--drop-rate", type=float, default=0.0)
    ap.add_argument("--drop-seed", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=0.25,
                    help="per-round receive deadline (seconds)")
    ap.add_argument("--step-sleep", type=float, default=0.0,
                    help="pause before each step's entry fence — paces the "
                         "run so a restarted worker (process spawn + jit "
                         "warmup) can rejoin mid-run in demos and tests")
    ap.add_argument("--barrier-timeout", type=float, default=120.0)
    ap.add_argument("--heartbeat-timeout", type=float, default=6.0)
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="parent wall-clock bound for the whole run")
    ap.add_argument("--rendezvous", default="auto",
                    help="'auto' starts an in-parent coordinator; or "
                         "host:port of an external one")
    ap.add_argument("--report", default=None,
                    help="write the merged JSON report here")
    ap.add_argument("--ckpt-dir", default=None,
                    help="per-rank checkpoint root (crash resume)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="record per-rank structured traces (DESIGN §12) "
                         "and write trace_rankNN.json Perfetto files into "
                         "DIR; paths land in the merged report, and "
                         "python -m repro.obs.report DIR renders the "
                         "cross-rank tail tables + control timeline")
    ap.add_argument("--kill-rank", type=int, default=-1,
                    help="scripted crash: this rank SIGKILLs itself")
    ap.add_argument("--kill-step", type=int, default=-1,
                    help="...after this step's phase-1 fence")
    ap.add_argument("--restart", action="store_true",
                    help="respawn the killed worker once the group moved on")
    ap.add_argument("--probation", type=int, default=2,
                    help="clean steps a readmitted peer needs to go ACTIVE")
    ap.add_argument("--min-active", type=int, default=1)
    # internal (worker subprocess) flags
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--uid", type=int, default=-1, help=argparse.SUPPRESS)
    ap.add_argument("--report-file", default=None, help=argparse.SUPPRESS)
    return ap


def main(argv=None) -> dict | int:
    args = build_parser().parse_args(argv)
    if args.worker:
        return _worker_main(args)
    if args.kill_rank >= 0 and args.restart and not args.ckpt_dir:
        args.ckpt_dir = tempfile.mkdtemp(prefix="multiproc_ckpt_")
    report = _launch_udp(args) if args.backend == "udp" \
        else _launch_inproc(args)
    if args.trace_dir:
        report["trace_dir"] = args.trace_dir
        report["traces"] = sorted(w["trace"] for w in report["workers"]
                                  if w.get("trace"))
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1)
    ok = [w for w in report["workers"] if w.get("exit") == "ok"]
    print(f"multiproc[{args.backend}] nprocs={args.nprocs} "
          f"steps={args.steps} ok_workers={len(ok)} "
          f"killed={sum(1 for w in report['workers'] if w.get('exit') == 'killed')}")
    return report


if __name__ == "__main__":
    out = main()
    sys.exit(out if isinstance(out, int) else 0)
