"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips (TPU v5e pod);
multi-pod: 2 pods = 512 chips with a leading 'pod' axis (the hierarchical-
TAR group axis, DESIGN §2).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(dp: int = 1, tp: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert dp * tp <= n, (dp, tp, n)
    return make_mesh((dp, tp), ("data", "model"))
