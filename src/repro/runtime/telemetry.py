"""Per-step observations flowing into the control plane.

A :class:`StepTelemetry` is a plain host-side record of what one training
(or simulated) step observed.  Producers fill in what they can measure:

* the trainer / launcher knows the whole-step wall clock and the observed
  entry-loss fraction (``ctx.stats`` from the Lossy transport);
* the cloud-network simulator additionally knows per-peer transfer times
  (the straggler signal) and the per-round stage times / timeout flags /
  received fractions the §3.2.1 ``AdaptiveTimeout.update`` rule consumes.

Every field is optional beyond ``loss_frac``; the :class:`ControlPlane`
uses whatever is present (a controller whose inputs are missing simply
holds its state).  Times are in whichever unit the producer profiles in —
the controllers only ever compare them against each other.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class StepTelemetry:
    """One step's observations, as seen by this (logical) receiver."""
    step: int = 0
    # entry-loss fraction this step (dropped / total mask entries, pmean'd
    # across receivers — what ``SyncContext.loss_fraction`` reports)
    loss_frac: float = 0.0
    # did any receive stage hit its deadline this step
    timed_out: bool = False
    # per-peer completion times for the step's receive stages (index = peer
    # id on the data axis) — the StragglerDetector's input; NaN/None entries
    # mean "peer unobserved this step"
    peer_stage_times: tuple[float, ...] | None = None
    # whole-step wall clock: the warmup profiling sample when per-round
    # stage times are not separately measurable (the real-trainer case)
    step_time: float | None = None
    # per-round detail (the simulator measures these): stage completion
    # time, t_B-expiry flag, and fraction of data received per round —
    # exactly the inputs of AdaptiveTimeout.update (§3.2.1)
    round_times: tuple[float, ...] | None = None
    round_timed_out: tuple[bool, ...] | None = None
    round_frac_received: tuple[float, ...] | None = None
    # raw drop-stat counters, when the producer has them
    dropped: float = 0.0
    total: float = 0.0
    # directed (src, dst) links observed *fully* lossy this step: receiver
    # dst saw zero packets from src while other senders delivered — a link
    # fault suspect, not a straggler signal.  The ControlPlane turns
    # consecutive suspicions into SyncPolicy.dead_links (ring rewiring)
    dead_link_events: tuple[tuple[int, int], ...] = ()

    @classmethod
    def from_stats(cls, step: int, stats: dict, *,
                   step_time: float | None = None,
                   peer_stage_times: Sequence[float] | None = None,
                   timed_out: bool = False) -> "StepTelemetry":
        """Build from a ``SyncContext.stats`` dict (trainer-side producer)."""
        dropped = float(stats.get("dropped", 0.0))
        total = float(stats.get("total", 0.0))
        loss = dropped / total if total > 0 else 0.0
        return cls(step=step, loss_frac=loss, dropped=dropped, total=total,
                   step_time=step_time, timed_out=timed_out,
                   peer_stage_times=(None if peer_stage_times is None
                                     else tuple(float(t)
                                                for t in peer_stage_times)))

    @classmethod
    def from_wire(cls, step: int, *, round_times: Sequence[float],
                  round_timed_out: Sequence[bool],
                  round_frac_received: Sequence[float],
                  peer_stage_times: Sequence[float] | None,
                  dropped: float, total: float,
                  step_time: float | None = None,
                  dead_link_events: Sequence[tuple[int, int]] = ()
                  ) -> "StepTelemetry":
        """Build from a host wire transport's observations (repro/net/):
        every field the simulator used to be the only producer of —
        per-round stage times / t_B-expiry flags / received fractions and
        per-peer last-arrival times — now measured on a real exchange.
        NaN entries in ``peer_stage_times`` mean "peer unobserved"; None
        means no receiver observed arrivals at all this step (e.g. every
        round empty) — the StragglerDetector holds its state either way."""
        loss = dropped / total if total > 0 else 0.0
        return cls(step=step, loss_frac=loss, dropped=float(dropped),
                   total=float(total), step_time=step_time,
                   timed_out=any(bool(b) for b in round_timed_out),
                   peer_stage_times=(None if peer_stage_times is None else
                                     tuple(float(t)
                                           for t in peer_stage_times)),
                   round_times=tuple(float(t) for t in round_times),
                   round_timed_out=tuple(bool(b) for b in round_timed_out),
                   round_frac_received=tuple(float(f)
                                             for f in round_frac_received),
                   dead_link_events=tuple((int(s), int(d))
                                          for (s, d) in dead_link_events))
