"""Closed-loop runtime control plane (paper §3.2, DESIGN §5).

One subsystem owns every adaptive decision the sync layer makes:

    StepTelemetry  --observe-->  ControlPlane  --policy-->  SyncPolicy
    (per-peer stage times,       (UbtState controllers      (hadamard on/off,
     loss fraction, drop          + StragglerDetector)       incast I, timeout
     stats, round times)                                     x%, active peers)

The :class:`ControlPlane` is host state (an XLA fabric cannot drop or time
out; see ``core/ubt.py``): the trainer, the launcher's ``--adaptive`` loop,
and the cloud-network simulator all feed it :class:`StepTelemetry` and read
back a small hashable :class:`SyncPolicy`.  The policy's ``active_peers``
drives the degraded-participation topologies (``OptiReduceConfig
.active_peers``), and :class:`PolicyStepCache` keeps one compiled train step
per policy so an eject -> readmit cycle never recompiles.
"""
from .control import ControlPlane, PolicyStepCache, SyncPolicy
from .straggler import (ACTIVE, EJECTED, PROBATION, PeerState,
                        StragglerDetector)
from .telemetry import StepTelemetry

__all__ = [
    "StepTelemetry", "SyncPolicy", "ControlPlane", "PolicyStepCache",
    "StragglerDetector", "PeerState", "ACTIVE", "EJECTED", "PROBATION",
]
