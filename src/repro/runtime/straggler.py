"""Persistent-straggler detection with probationary readmission.

The §3.2 controllers bound how long a round *waits*; they cannot help when
one peer is slow on every round — the adaptive timeout just converges to
the straggler's pace (its warmup P95 includes the straggler) and every step
pays the tail.  Following the degraded-participation line of work ("don't
wait for a persistently slow peer, exclude its contribution and keep the
collective tight"), the detector keeps a per-peer EWMA *slowness score* —
stage time relative to the median of the currently-participating peers —
and drives a three-state machine:

    ACTIVE --(score > eject_score for `patience` steps)--> EJECTED
    EJECTED --(`cooldown` steps elapsed)--> PROBATION (tentatively back in)
    PROBATION --(score <= readmit_score for `probation` steps)--> ACTIVE
    PROBATION --(score > eject_score once)--> EJECTED (cooldown restarts)

PROBATION peers count as participating (they are being *watched*, not
excluded), so the active set is ACTIVE + PROBATION.  Ejection never shrinks
the set below ``min_active`` and the hysteresis band
(``readmit_score`` < ``eject_score``) keeps a borderline peer from flapping
the membership — every membership change recompiles a train step (the
policy cache bounds, but does not eliminate, that cost).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

ACTIVE = "active"
EJECTED = "ejected"
PROBATION = "probation"


@dataclasses.dataclass
class PeerState:
    """Detector state for one peer."""
    status: str = ACTIVE
    score: float = 1.0      # EWMA of stage time / median-of-participants
    strikes: int = 0        # consecutive over-threshold steps while ACTIVE
    clean: int = 0          # consecutive under-threshold steps in PROBATION
    countdown: int = 0      # steps remaining in the EJECTED cooldown
    ejections: int = 0      # lifetime ejection count (telemetry/reporting)
    held: bool = False      # membership-ejected (crashed/left): the cooldown
    #                         never auto-promotes it to PROBATION — only an
    #                         explicit readmit() (a rendezvous rejoin) does


class StragglerDetector:
    """EWMA-scored persistent-straggler ejection (see module docstring)."""

    def __init__(self, n_peers: int, *, alpha: float = 0.25,
                 eject_score: float = 1.75, readmit_score: float = 1.25,
                 patience: int = 4, cooldown: int = 12, probation: int = 6,
                 min_active: int = 2, enabled: bool = True,
                 weight_resolution: int = 4, weight_floor: float = 0.25,
                 weight_band: float = 0.35):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha {alpha} outside (0, 1]")
        if readmit_score >= eject_score:
            raise ValueError("readmit_score must sit below eject_score "
                             "(the hysteresis band)")
        if weight_resolution < 1:
            raise ValueError(f"weight_resolution {weight_resolution} < 1")
        if not 0.0 < weight_floor <= 1.0:
            raise ValueError(f"weight_floor {weight_floor} outside (0, 1]")
        self.n_peers = int(n_peers)
        self.alpha = float(alpha)
        self.eject_score = float(eject_score)
        self.readmit_score = float(readmit_score)
        self.patience = int(patience)
        self.cooldown = int(cooldown)
        self.probation = int(probation)
        self.min_active = max(1, int(min_active))
        self.enabled = bool(enabled)
        self.weight_resolution = int(weight_resolution)
        self.weight_floor = float(weight_floor)
        self.weight_band = float(weight_band)
        self.peers = [PeerState() for _ in range(self.n_peers)]
        self._weight_units = [self.weight_resolution] * self.n_peers

    # ------------------------------------------------------------- queries
    def active_peers(self) -> tuple[int, ...]:
        """Participating peers (ACTIVE + PROBATION), sorted."""
        return tuple(i for i, p in enumerate(self.peers)
                     if p.status != EJECTED)

    def ejected_peers(self) -> tuple[int, ...]:
        return tuple(i for i, p in enumerate(self.peers)
                     if p.status == EJECTED)

    def status(self, peer: int) -> str:
        return self.peers[peer].status

    def scores(self) -> tuple[float, ...]:
        return tuple(p.score for p in self.peers)

    def weights(self) -> tuple[int, ...]:
        """Straggler-proportional shard units per peer (0 for EJECTED).

        Normalized inverse-score: a peer's raw target is
        ``resolution * median_active_score / score`` — uniform
        (== ``weight_resolution``) for a median-pace peer, proportionally
        fewer units for a slow one — clamped to
        ``[weight_floor * resolution, resolution]`` so a slow-but-alive
        peer always keeps a nonzero contiguous slice, then rounded to
        integer units.  PROBATION peers are additionally capped at half
        weight: they are being *watched*, not yet trusted with a full
        shard (reduced, never zero).  Hysteresis banding: the stored unit
        moves only when the raw target strays ``weight_band`` beyond the
        rounding midpoint, so score dithering around a unit boundary does
        not thrash SyncPolicy compile keys (every distinct weight tuple is
        a recompile).
        """
        res = self.weight_resolution
        floor_units = max(1, int(round(self.weight_floor * res)))
        active = self.active_peers()
        scores = [self.peers[p].score for p in active]
        med = max(float(np.median(scores)), 1e-12) if scores else 1.0
        out = []
        for i, p in enumerate(self.peers):
            if p.status == EJECTED:
                # park at the floor so a readmitted peer re-enters small
                # and earns its weight back through the hysteresis band
                self._weight_units[i] = floor_units
                out.append(0)
                continue
            cap = res if p.status == ACTIVE else max(floor_units, res // 2)
            target = res * med / max(p.score, 1e-12)
            target = min(float(cap), max(float(floor_units), target))
            cur = self._weight_units[i]
            if abs(target - cur) > 0.5 + self.weight_band:
                cur = int(round(target))
            cur = min(cap, max(floor_units, cur))
            self._weight_units[i] = cur
            out.append(cur)
        return tuple(out)

    # ------------------------------------------------------------- updates
    def _score(self, times: Sequence[float | None]) -> None:
        vals = np.array([math.nan if t is None else float(t) for t in times],
                        dtype=np.float64)
        # the baseline is the median over *participating* observed peers, so
        # an ejected straggler cannot drag the reference pace it is judged by
        part = [i for i in self.active_peers() if np.isfinite(vals[i])]
        ref = vals[part] if part else vals[np.isfinite(vals)]
        if ref.size == 0:
            return
        med = max(float(np.median(ref)), 1e-12)
        for i, t in enumerate(vals):
            if np.isfinite(t):
                rel = t / med
                p = self.peers[i]
                p.score = (1.0 - self.alpha) * p.score + self.alpha * rel

    def observe(self, peer_times: Sequence[float | None]) -> bool:
        """Feed one step's per-peer stage times; True if the *membership*
        (the active-peer set) changed."""
        if len(peer_times) != self.n_peers:
            raise ValueError(f"expected {self.n_peers} peer times, "
                             f"got {len(peer_times)}")
        before = self.active_peers()
        self._score(peer_times)
        for peer in self.peers:
            if peer.status == EJECTED:
                if peer.held:
                    continue            # a corpse never cools back in
                peer.countdown -= 1
                if peer.countdown <= 0:
                    peer.status = PROBATION
                    peer.clean = 0
                    peer.strikes = 0
            elif peer.status == PROBATION:
                if peer.score > self.eject_score:
                    # still slow: one strike re-ejects (floor permitting —
                    # another peer may have been ejected while this one
                    # cooled down), cooldown restarts
                    if self._can_eject():
                        self._eject(peer)
                elif peer.score <= self.readmit_score:
                    peer.clean += 1
                    if peer.clean >= self.probation:
                        peer.status = ACTIVE
                        peer.clean = 0
                else:
                    # hysteresis middle band: not clean — the readmission
                    # counter requires *consecutive* under-threshold steps
                    peer.clean = 0
            else:  # ACTIVE
                if self.enabled and peer.score > self.eject_score:
                    peer.strikes += 1
                    if peer.strikes >= self.patience and self._can_eject():
                        self._eject(peer)
                else:
                    peer.strikes = 0
        return self.active_peers() != before

    # -------------------------------------------- membership-driven events
    def force_eject(self, peer_index: int) -> bool:
        """Rendezvous-driven ejection (crash / leave): immediate, bypasses
        both ``patience`` and the ``min_active`` floor — a dead peer cannot
        participate regardless of what the schedule would prefer — and is
        *held* out of the cooldown -> PROBATION path until an explicit
        :meth:`readmit` (its rejoin).  Returns True if the status moved."""
        p = self.peers[peer_index]
        changed = p.status != EJECTED
        if changed:
            p.ejections += 1
        p.status = EJECTED
        p.held = True
        p.strikes = 0
        p.clean = 0
        p.countdown = 0
        return changed

    def readmit(self, peer_index: int) -> bool:
        """Rendezvous-driven probationary readmission (a peer re-joined).

        EJECTED -> PROBATION with a *fresh* score: a restarted process does
        not inherit its corpse's EWMA (the crash step charged the corpse
        the full deadline, and one PROBATION strike would re-eject it on
        arrival).  No-op unless currently EJECTED.  Returns True if moved.
        """
        p = self.peers[peer_index]
        if p.status != EJECTED:
            return False
        p.status = PROBATION
        p.held = False
        p.score = 1.0
        p.strikes = 0
        p.clean = 0
        p.countdown = 0
        return True

    def _can_eject(self) -> bool:
        return len(self.active_peers()) - 1 >= self.min_active

    def _eject(self, peer: PeerState) -> None:
        peer.status = EJECTED
        # exponential backoff for repeat offenders: each re-ejection doubles
        # the cooldown (capped), so a persistently slow peer costs one slow
        # probation step per ~doubling window instead of flapping every
        # `cooldown` steps — while a healed peer still gets readmitted
        peer.countdown = self.cooldown * min(2 ** peer.ejections, 16)
        peer.strikes = 0
        peer.clean = 0
        peer.ejections += 1
