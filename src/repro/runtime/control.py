"""The closed loop: telemetry in, a hashable SyncPolicy out.

The :class:`ControlPlane` owns every adaptive controller of the sync layer —
the §3.2 :class:`~repro.core.ubt.UbtState` bundle (adaptive timeout, dynamic
incast, Timely rate control) plus the :class:`StragglerDetector` — and
exposes exactly one output, a :class:`SyncPolicy`:

* ``use_hadamard`` — the §3.2.1 codec recommendation, with a hysteresis
  band [threshold/2, threshold) so loss hovering at the 2% activation
  threshold cannot flap the codec (each flip retraces the step);
* ``incast``       — the advertised round-schedule fan-in I, clamped to the
  active-set size;
* ``active_peers`` — the degraded-participation set (None = everyone), fed
  straight into ``OptiReduceConfig.active_peers``;
* ``shard_weights`` — straggler-proportional shard units per *active* peer
  (None = uniform), from ``StragglerDetector.weights()`` when rebalancing
  is enabled: a slow-but-alive peer owns a smaller contiguous slice of the
  bucket instead of being ejected;
* ``dead_links``   — directed (src, dst) edges the link-health tracker has
  declared failed; the round schedules reroute around them (relay / ring
  reordering) instead of ejecting either endpoint;
* ``timeout_x``    — the x%-wait knob the simulator's deadline rule uses
  (host-only: it never changes the compiled program, so it is excluded
  from policy equality/hash and the compile key).

Equality (and hash) of two policies therefore answers "would these compile
to the same step?", which is what :class:`PolicyStepCache` keys on — an
eject -> readmit cycle returns to a previously-compiled step instead of
recompiling.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Hashable

from repro.core.ubt import UbtState
from repro.obs import trace as obs_trace

from .straggler import StragglerDetector
from .telemetry import StepTelemetry


@dataclasses.dataclass(frozen=True)
class SyncPolicy:
    """The control plane's recommendation for the next step (hashable;
    ``timeout_x`` is carried but excluded from equality/hash — it is
    continuous host-side state that never changes the compiled program)."""
    use_hadamard: bool = False
    incast: int = 1
    active_peers: tuple[int, ...] | None = None     # None = full set
    # shard units per active peer, aligned with the (sorted) active set;
    # None = uniform — a uniform tuple is normalized away before it gets
    # here so the full-participation trace stays bitwise-identical
    shard_weights: tuple[int, ...] | None = None
    # directed (src, dst) edges declared failed by the link-health tracker
    dead_links: tuple[tuple[int, int], ...] = ()
    timeout_x: float = dataclasses.field(default=0.10, compare=False)
    # membership generation this policy was computed under (rendezvous-fed;
    # 0 = no rendezvous).  Stamped so a launcher can order policies against
    # membership snapshots; excluded from equality/hash — the generation
    # number itself never changes the compiled program
    generation: int = dataclasses.field(default=0, compare=False)

    @property
    def compile_key(self) -> Hashable:
        """What a compiled train step depends on."""
        return (self.use_hadamard, self.incast, self.active_peers,
                self.shard_weights, self.dead_links)

    def apply(self, cfg):
        """Fold this policy into a sync config (any dataclass carrying
        ``use_hadamard`` / ``incast`` / ``active_peers`` /
        ``shard_weights`` / ``dead_links`` fields)."""
        return dataclasses.replace(cfg, use_hadamard=self.use_hadamard,
                                   incast=self.incast,
                                   active_peers=self.active_peers,
                                   shard_weights=self.shard_weights,
                                   dead_links=self.dead_links)


class ControlPlane:
    """Telemetry-driven owner of the UBT controllers + straggler detector."""

    def __init__(self, state: UbtState, detector: StragglerDetector, *,
                 use_hadamard: bool = False, rebalance: bool = False,
                 link_patience: int = 2, link_recover: int = 50):
        self.state = state
        self.detector = detector
        self.use_hadamard = use_hadamard
        # rebalance: emit straggler-proportional shard weights instead of
        # relying on ejection alone — a slow peer keeps a (smaller) slice
        self.rebalance = bool(rebalance)
        # link-health tracker: ``link_patience`` consecutive fully-lossy
        # observations declare a directed edge dead; once dead the schedule
        # relays around it, so the edge goes unobserved — after
        # ``link_recover`` quiet steps it is revived (probed) and re-killed
        # within ``link_patience`` steps if still down.  Both transitions
        # are recompiles; the long recover period bounds the probe cost
        self.link_patience = max(1, int(link_patience))
        self.link_recover = max(1, int(link_recover))
        self._link_strikes: dict[tuple[int, int], int] = {}
        self._link_quiet: dict[tuple[int, int], int] = {}
        self._dead_links: set[tuple[int, int]] = set()
        self.steps = 0                      # observed (post-warmup) steps
        self.generation = 0                 # latest membership generation

    @classmethod
    def create(cls, n_nodes: int, *, use_hadamard: bool = False,
               detector: StragglerDetector | None = None,
               detect_stragglers: bool = True,
               rebalance: bool = False,
               link_patience: int = 2, link_recover: int = 50,
               detector_kw: dict | None = None, **kw) -> "ControlPlane":
        """Build the full controller bundle for an ``n_nodes`` job.  ``kw``
        forwards to :meth:`UbtState.create` (``timeout=``/``incast=``/
        ``rate=`` sub-dicts); ``detector_kw`` to :class:`StragglerDetector`.
        """
        if detector is None:
            detector = StragglerDetector(n_nodes,
                                         enabled=detect_stragglers,
                                         **(detector_kw or {}))
        return cls(state=UbtState.create(n_nodes=n_nodes, **kw),
                   detector=detector, use_hadamard=use_hadamard,
                   rebalance=rebalance, link_patience=link_patience,
                   link_recover=link_recover)

    # ------------------------------------------------------------ the loop
    def observe(self, t: StepTelemetry) -> bool:
        """Feed one step's telemetry; True if the policy moved (the caller
        should re-resolve its sync config / compiled step).

        With tracing on, every state transition this observation causes —
        peer eject/probation/readmit, link death/revival, codec flips,
        incast moves, LossBudget phase steps — lands as a ``cat="policy"``
        instant event with its cause (DESIGN §12)."""
        before = self.policy()
        tr = obs_trace.get_tracer()
        if tr is not None:
            statuses0 = tuple(p.status for p in self.detector.peers)
            dead0 = set(self._dead_links)
            budget0 = (None if self.state.budget is None
                       else int(min(max(self.state.budget.phase, 0.0), 1.0)
                                * 10))
        at = self.state.timeout
        sample = t.step_time
        if sample is None and t.peer_stage_times is not None:
            observed = [x for x in t.peer_stage_times
                        if x is not None and x == x]
            sample = max(observed) if observed else None
        if sample is not None and not at.ready:
            at.observe_warmup(float(sample))
        if at.ready and at.t_c is not None and t.round_times:
            at.update(stage_times=list(t.round_times),
                      timed_out=list(t.round_timed_out or
                                     (False,) * len(t.round_times)),
                      frac_received=list(t.round_frac_received or
                                         (1.0,) * len(t.round_times)),
                      loss_frac=t.loss_frac)
        self.state.incast.update(loss_frac=t.loss_frac, timed_out=t.timed_out)
        if self.state.budget is not None:
            # phase-aware loss budget (DESIGN §8): the observed loss EMA is
            # what the accept-or-extend deadline rule compares to the
            # tightening budget; the *phase* advances out-of-band via
            # update_phase (LR progress / loss curve, launcher-fed)
            self.state.budget.observe(t.loss_frac)
        if at.hadamard_active(t.loss_frac):
            self.use_hadamard = True
        elif t.loss_frac < at.ht_threshold / 2.0:
            # hysteresis band [thr/2, thr): loss hovering at the threshold
            # must not flap the codec (each flip retraces the step)
            self.use_hadamard = False
        if t.peer_stage_times is not None:
            self.detector.observe(t.peer_stage_times)
        self._observe_links(t.dead_link_events or ())
        self.steps += 1
        after = self.policy()
        if tr is not None:
            self._trace_transitions(tr, t, before, after, statuses0, dead0,
                                    budget0)
        return after != before

    # status -> event name for the per-peer transition timeline
    _STATUS_EVENT = {"ejected": "eject", "probation": "probation",
                     "active": "readmit"}

    def _trace_transitions(self, tr, t: StepTelemetry, before: SyncPolicy,
                           after: SyncPolicy, statuses0, dead0,
                           budget0) -> None:
        """Emit one instant event per state transition this step caused."""
        step = int(t.step)
        for p, (s0, peer) in enumerate(zip(statuses0, self.detector.peers)):
            if peer.status != s0:
                tr.event(self._STATUS_EVENT[peer.status], "policy", tid=p,
                         args={"step": step, "peer": p, "from": s0,
                               "score": round(float(peer.score), 4),
                               "cause": "score"})
        dead1 = set(self._dead_links)
        for link in sorted(dead1 - dead0):
            tr.event("dead_link", "policy",
                     args={"step": step, "src": link[0], "dst": link[1],
                           "cause": "fully_lossy"})
        for link in sorted(dead0 - dead1):
            tr.event("link_revived", "policy",
                     args={"step": step, "src": link[0], "dst": link[1],
                           "cause": "quiet_probe"})
        if after.use_hadamard != before.use_hadamard:
            tr.event("hadamard", "policy",
                     args={"step": step, "on": after.use_hadamard,
                           "loss_frac": round(float(t.loss_frac), 5),
                           "cause": "loss_threshold"})
        if after.incast != before.incast:
            tr.event("incast", "policy",
                     args={"step": step, "from": before.incast,
                           "to": after.incast, "cause": "loss_controller"})
        if self.state.budget is not None:
            b1 = int(min(max(self.state.budget.phase, 0.0), 1.0) * 10)
            if b1 != budget0:
                tr.event("budget_phase", "policy",
                         args={"step": step,
                               "phase": round(self.state.budget.phase, 3),
                               "cause": "loss_budget"})
        if after != before:
            tr.event("policy_change", "policy",
                     args={"step": step,
                           "active": len(after.active_peers
                                         or range(self.detector.n_peers)),
                           "incast": after.incast,
                           "hadamard": after.use_hadamard,
                           "dead_links": len(after.dead_links),
                           "rebalanced": after.shard_weights is not None})

    def _observe_links(self, events) -> None:
        """Fold one step's fully-lossy link observations into the tracker."""
        seen = {(int(s), int(d)) for (s, d) in events}
        for link in seen:
            self._link_strikes[link] = self._link_strikes.get(link, 0) + 1
            self._link_quiet.pop(link, None)
            if self._link_strikes[link] >= self.link_patience:
                self._dead_links.add(link)
        for link in list(self._link_strikes):
            if link in seen:
                continue
            if link in self._dead_links:
                # dead + unobserved: the schedule is relaying around it, so
                # silence is expected — count quiet steps toward a probe
                self._link_quiet[link] = self._link_quiet.get(link, 0) + 1
                if self._link_quiet[link] >= self.link_recover:
                    self._dead_links.discard(link)
                    self._link_strikes.pop(link, None)
                    self._link_quiet.pop(link, None)
            else:
                # a clean observation clears accumulated strikes
                self._link_strikes.pop(link, None)

    def dead_links(self) -> tuple[tuple[int, int], ...]:
        """Currently-dead directed edges, sorted (telemetry/reporting)."""
        return tuple(sorted(self._dead_links))

    def apply_membership(self, kind: str, rank: int,
                         generation: int | None = None) -> bool:
        """Fold one rendezvous membership event into the detector's
        lifecycle (DESIGN §9): ``"leave"``/``"death"`` force-eject the rank
        (a dead peer is degradation already decided, not a score to argue
        with); ``"join"`` readmits it through PROBATION.  Takes primitives
        — not a rendezvous event type — so ``runtime`` stays import-free of
        ``net`` (net already imports runtime).  Returns True if the active
        set changed."""
        if generation is not None:
            self.generation = max(self.generation, int(generation))
        if not 0 <= rank < self.detector.n_peers:
            return False
        if kind == "join":
            changed = self.detector.readmit(rank)
        elif kind in ("leave", "death"):
            changed = self.detector.force_eject(rank)
        else:
            raise ValueError(f"unknown membership event kind {kind!r} "
                             "(join | leave | death)")
        if changed:
            tr = obs_trace.get_tracer()
            if tr is not None:
                tr.event("membership", "policy", tid=rank,
                         args={"peer": rank, "kind": kind,
                               "status": self.detector.status(rank),
                               "generation": self.generation,
                               "cause": "rendezvous"})
        return changed

    def policy(self) -> SyncPolicy:
        active = self.detector.active_peers()
        n = self.detector.n_peers
        a = max(1, len(active))
        weights = None
        if self.rebalance:
            units = self.detector.weights()
            w = tuple(units[p] for p in active)
            if w and any(u != w[0] for u in w):
                weights = w          # uniform normalizes to None (parity)
        member = set(active)
        dead = tuple(sorted(link for link in self._dead_links
                            if link[0] in member and link[1] in member))
        return SyncPolicy(
            use_hadamard=self.use_hadamard,
            # senders use the min advertised I, and a degraded schedule has
            # only a-1 distinct peers to fan in from
            incast=max(1, min(self.state.incast.value, max(1, a - 1))),
            active_peers=None if len(active) == n else active,
            shard_weights=weights,
            dead_links=dead,
            timeout_x=self.state.timeout.x,
            generation=self.generation)

    def apply(self, cfg):
        """Fold the current policy into a sync config."""
        return self.policy().apply(cfg)


class PolicyStepCache:
    """Bounded LRU of compiled artifacts keyed by ``SyncPolicy.compile_key``
    — an eject -> probation -> readmit cycle revisits previous policies, and
    each train-step compile is seconds, so the launcher keeps the last few
    compiled steps around instead of rebuilding."""

    def __init__(self, maxsize: int = 4):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = int(maxsize)
        self._entries: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, policy: SyncPolicy) -> bool:
        return policy.compile_key in self._entries

    def get(self, policy: SyncPolicy):
        """Cached artifact for this policy, or None (marks it most-recent)."""
        key = policy.compile_key
        if key not in self._entries:
            self.misses += 1
            return None
        self.hits += 1
        self._entries.move_to_end(key)
        return self._entries[key]

    def put(self, policy: SyncPolicy, value) -> None:
        key = policy.compile_key
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
