"""smollm-360m [dense]: llama-arch small.
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
import jax.numpy as jnp
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab_size=49152, head_dim=64,
    source="hf:HuggingFaceTB/SmolLM-135M",
)

SMOKE = ModelConfig(
    name="smollm-smoke", family="dense",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, d_ff=96,
    vocab_size=512, head_dim=20,
    param_dtype=jnp.float32,
)
