"""The paper's own primary workload: OpenAI GPT-2 (base) — used by the
TTA benchmarks (Fig 11, Table 1) and examples. [Radford et al. 2019]"""
import jax.numpy as jnp
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gpt2-paper", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=50257, head_dim=64, activation="gelu",
    source="Radford et al. 2019 (paper §5.1.2)",
)

SMOKE = ModelConfig(
    name="gpt2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, head_dim=16, activation="gelu",
    param_dtype=jnp.float32,
)
