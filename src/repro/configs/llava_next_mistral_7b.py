"""llava-next-mistral-7b [vlm]: mistral backbone, anyres patch frontend STUB
(input_specs supplies precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
import jax.numpy as jnp
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128,
    frontend="patches", frontend_dim=1024, prefix_len=2048,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16,
    frontend="patches", frontend_dim=48, prefix_len=8,
    param_dtype=jnp.float32,
)
