"""Architecture + run-shape configuration schema.

Every assigned architecture provides a ``CONFIG`` (exact published numbers)
and a ``SMOKE`` (reduced same-family config for CPU tests). Shapes are the
four assignment-wide cells; ``input_specs`` builds ShapeDtypeStruct stand-ins
for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int                    # 0 for attention-free
    vocab_size: int
    head_dim: int = 0            # default d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # MoE replaces MLP in layers l % moe_every == moe_offset
    moe_offset: int = 0
    n_shared_experts: int = 0    # qwen2-moe: shared experts alongside routed
    dense_residual: bool = False # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0           # d_inner // ssm_head_dim
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_k: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0          # hybrid: one attn layer per `attn_every` layers
    attn_offset: int = 0         # position of the attn layer within the period
    # --- misc ---
    norm: str = "rms"
    activation: str = "silu"
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    # --- frontend stub (vlm / audio) ---
    frontend: str | None = None  # 'patches' | 'frames'
    frontend_dim: int = 0        # incoming embedding width
    prefix_len: int = 0          # prefix positions in train/prefill sequences
    # --- numerics ---
    param_dtype: Any = jnp.bfloat16
    # chunked (flash-style) attention block size for train/prefill when
    # seq_len exceeds it; 0 = always dense (cost-model mode)
    attn_chunk: int = 4096
    # --- provenance ---
    source: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def is_attn_layer(self, layer: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every:
            return layer % self.attn_every == self.attn_offset
        return True

    def is_moe_layer(self, layer: int) -> bool:
        if not self.n_experts:
            return False
        return layer % self.moe_every == self.moe_offset

    def sub_quadratic(self) -> bool:
        """True when the arch can decode 500k context (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, ("pure full-attention arch: 500k-token decode needs "
                       "sub-quadratic attention (skip per assignment; see "
                       "DESIGN.md)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                *, batch_override: int | None = None) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: full sequences (tokens + labels for train). The frontend
    stub supplies precomputed patch/frame embeddings as a prefix.
    decode: one new token per sequence (the KV cache / SSM state is part of
    the serve state, built by ``serve.engine.abstract_state``).
    """
    b = batch_override or shape.global_batch
    s = shape.seq_len
    f32 = jnp.float32

    if shape.kind in ("train", "prefill"):
        p = min(cfg.prefix_len, s // 2) if cfg.frontend else 0
        specs: dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s - p), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
        if p:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, p, cfg.frontend_dim), f32)
        return specs

    # decode: one token against existing state
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
