"""jamba-v0.1-52b [hybrid]: Mamba+attn 1:7 interleave, MoE 16e top-2 every
other layer. [arXiv:2403.19887; hf]"""
import jax.numpy as jnp
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, head_dim=128,
    n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4,
    ssm_state=16, ssm_heads=64, ssm_head_dim=128, ssm_expand=2,
    ssm_chunk=256,
    source="arXiv:2403.19887",
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=512, head_dim=16,
    n_experts=4, top_k=2, moe_every=2, moe_offset=1,
    attn_every=4, attn_offset=2,
    ssm_state=16, ssm_heads=8, ssm_head_dim=16, ssm_chunk=8,
    param_dtype=jnp.float32,
)
