"""arctic-480b [moe]: 128 routed experts (top-2) + dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]"""
import jax.numpy as jnp
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab_size=32000, head_dim=128,
    n_experts=128, top_k=2, dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=512, head_dim=16,
    n_experts=8, top_k=2, dense_residual=True,
    param_dtype=jnp.float32,
)
