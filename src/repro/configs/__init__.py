"""Architecture registry: the 10 assigned configs + the paper's GPT-2."""
from . import (arctic_480b, command_r_plus_104b, glm4_9b, gpt2_paper,
               jamba_v0_1_52b, llava_next_mistral_7b, mamba2_1_3b,
               musicgen_medium, qwen2_moe_a2_7b, smollm_360m, stablelm_1_6b)
from .base import SHAPES, ModelConfig, ShapeConfig, input_specs, shape_applicable

_MODULES = {
    "arctic-480b": arctic_480b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "mamba2-1.3b": mamba2_1_3b,
    "command-r-plus-104b": command_r_plus_104b,
    "stablelm-1.6b": stablelm_1_6b,
    "smollm-360m": smollm_360m,
    "glm4-9b": glm4_9b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "musicgen-medium": musicgen_medium,
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "gpt2-paper": gpt2_paper,
}

ARCHS = tuple(k for k in _MODULES if k != "gpt2-paper")


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _MODULES[name].SMOKE


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
           "get_smoke", "input_specs", "shape_applicable"]
