"""mamba2-1.3b [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
import jax.numpy as jnp
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280,
    ssm_state=128, ssm_heads=64, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=256,
    source="arXiv:2405.21060",
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=512,
    ssm_state=16, ssm_heads=8, ssm_head_dim=16, ssm_chunk=8,
    param_dtype=jnp.float32,
)
