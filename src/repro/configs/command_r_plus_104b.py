"""command-r-plus-104b [dense]: GQA kv=8, no-bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
import jax.numpy as jnp
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab_size=256000, head_dim=128,
    source="hf:CohereForAI/c4ai-command-r-v01",
)

SMOKE = ModelConfig(
    name="command-r-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16,
    param_dtype=jnp.float32,
)
