"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
import jax.numpy as jnp
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=151936, head_dim=128,
    n_experts=60, top_k=4, n_shared_experts=4,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=48,
    vocab_size=512, head_dim=16,
    n_experts=6, top_k=2, n_shared_experts=2,
    param_dtype=jnp.float32,
)
