"""musicgen-medium [audio]: decoder-only over EnCodec tokens; conditioning
frontend STUB (precomputed frame embeddings). [arXiv:2306.05284; hf]"""
import jax.numpy as jnp
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, head_dim=64,
    frontend="frames", frontend_dim=768, prefix_len=256,
    source="arXiv:2306.05284",
)

SMOKE = ModelConfig(
    name="musicgen-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16,
    frontend="frames", frontend_dim=48, prefix_len=8,
    param_dtype=jnp.float32,
)
