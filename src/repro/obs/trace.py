"""Structured trace recorder: typed span/event records in a preallocated
host-side ring buffer (DESIGN §12).

The recorder is *host* state (like the ControlPlane — XLA cannot observe
deadlines or policy decisions) and is **off by default**.  The contract at
every hot call site is a single ``None`` check::

    tr = trace.get_tracer()          # None when tracing is disabled
    ...
    if tr is not None:
        tr.complete("round", "wire", ts=t0, dur=t1 - t0, tid=rank,
                    args={"sender": s, "frac": f})

``get_tracer()`` reads one module-global reference, so the disabled path
costs one function call + one identity test per call *site* — and sites
that fire per packet hoist the lookup out of the loop entirely (fetch the
tracer once per exchange, guard each record with ``if tr is not None``,
which is a local-variable ``is`` test: a few nanoseconds).

Record schema (one tuple per record, allocated only when tracing is ON)::

    (ph, ts, dur, name, cat, tid, args)

    ph    "X" complete span | "i" instant event | "C" counter sample
    ts    start time in the producer's clock (seconds; see below)
    dur   span duration in the same clock ("X" only; 0.0 otherwise)
    name  event name ("round", "encode", "eject", ...)
    cat   category: "wire" | "policy" | "trainer" | "sim" — the category
          is also the *clock domain*: wire events carry the backend clock
          (virtual seconds on inproc, monotonic on UDP), trainer/policy
          events the tracer clock, sim events the simulator's virtual ms.
          Cross-category ordering is therefore only meaningful per domain;
          the exporters keep categories on separate Perfetto tracks.
    tid   logical lane inside this process (peer rank for wire events)
    args  small JSON-safe dict or None

The buffer is a fixed ``capacity`` list allocated once at ``configure``;
when it wraps, the oldest records are overwritten and ``Tracer.dropped``
counts what was lost — recording never allocates beyond the record tuple
and never blocks on I/O.  Export is explicit (``repro.obs.export``).

Env activation (for launchers that cannot thread a flag):
``REPRO_TRACE=1`` enables at import, ``REPRO_TRACE_CAPACITY`` sizes the
ring.
"""
from __future__ import annotations

import os
import threading
import time

__all__ = ["TraceConfig", "Tracer", "Span", "configure", "configure_thread",
           "get_tracer", "is_enabled", "reset"]

DEFAULT_CAPACITY = 1 << 16


class TraceConfig:
    """Process-global tracing configuration (see :func:`configure`)."""

    def __init__(self, enabled: bool = False,
                 capacity: int = DEFAULT_CAPACITY, rank: int = 0,
                 clock=time.perf_counter):
        if capacity < 1:
            raise ValueError(f"capacity {capacity} < 1")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.rank = int(rank)
        self.clock = clock


class Span:
    """Context manager emitting one complete ("X") record at exit.

    ``set(key=value)`` attaches args discovered mid-span (e.g. the round's
    observed loss fraction).
    """
    __slots__ = ("_tracer", "name", "cat", "tid", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, tid: int,
                 args: dict | None):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args
        self._t0 = 0.0

    def set(self, **kw) -> "Span":
        if self.args is None:
            self.args = {}
        self.args.update(kw)
        return self

    def __enter__(self) -> "Span":
        self._t0 = self._tracer.now()
        return self

    def __exit__(self, *exc) -> None:
        t1 = self._tracer.now()
        self._tracer.complete(self.name, self.cat, ts=self._t0,
                              dur=t1 - self._t0, tid=self.tid,
                              args=self.args)


class _NopSpan:
    """Shared allocation-free stand-in returned by :func:`span` when
    tracing is disabled — ``with trace.span(...)`` costs one dict lookup
    and two no-op calls."""
    __slots__ = ()

    def set(self, **kw) -> "_NopSpan":
        return self

    def __enter__(self) -> "_NopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOP_SPAN = _NopSpan()


class Tracer:
    """Ring-buffered trace recorder (see module docstring)."""

    def __init__(self, config: TraceConfig):
        self.capacity = config.capacity
        self.rank = config.rank
        self.clock = config.clock
        self._buf: list = [None] * self.capacity   # preallocated ring
        self._n = 0
        self._lock = threading.Lock()
        self.dropped = 0

    # ------------------------------------------------------------ recording
    def now(self) -> float:
        return self.clock()

    def _push(self, rec: tuple) -> None:
        with self._lock:
            i = self._n
            self._n = i + 1
            if i >= self.capacity:
                self.dropped += 1
            self._buf[i % self.capacity] = rec

    def complete(self, name: str, cat: str, *, ts: float, dur: float,
                 tid: int = 0, args: dict | None = None) -> None:
        """One finished span with an explicit start/duration — the raw API
        for producers with their own clock (wire peers, the simulator)."""
        self._push(("X", float(ts), float(max(dur, 0.0)), name, cat,
                    int(tid), args))

    def event(self, name: str, cat: str, *, ts: float | None = None,
              tid: int = 0, args: dict | None = None) -> None:
        """One instant event (policy decision, timeout, phase change)."""
        self._push(("i", self.clock() if ts is None else float(ts), 0.0,
                    name, cat, int(tid), args))

    def counter(self, name: str, value: float, *, ts: float | None = None,
                cat: str = "metrics") -> None:
        """One counter sample (renders as a Perfetto counter track)."""
        self._push(("C", self.clock() if ts is None else float(ts), 0.0,
                    name, cat, 0, {"value": float(value)}))

    def span(self, name: str, cat: str = "trainer", *, tid: int = 0,
             **args) -> Span:
        """Nestable context-manager span on the tracer's own clock."""
        return Span(self, name, cat, tid, args or None)

    # -------------------------------------------------------------- reading
    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def records(self) -> list[tuple]:
        """Records in arrival order (oldest surviving first)."""
        with self._lock:
            n, cap = self._n, self.capacity
            if n <= cap:
                return [r for r in self._buf[:n]]
            i = n % cap
            return self._buf[i:] + self._buf[:i]

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._n = 0
            self.dropped = 0


# ------------------------------------------------------- process-global state
_tracer: Tracer | None = None
_tls = threading.local()
_tls_active = False        # any thread-local tracer installed this process


def configure(enabled: bool = True, *, capacity: int = DEFAULT_CAPACITY,
              rank: int = 0, clock=time.perf_counter) -> Tracer | None:
    """Install (or tear down) the process-global tracer.  Returns it, or
    None when ``enabled=False`` — after which every ``get_tracer()`` site
    is back on the few-ns disabled path."""
    global _tracer
    if not enabled:
        _tracer = None
        return None
    _tracer = Tracer(TraceConfig(enabled=True, capacity=capacity, rank=rank,
                                 clock=clock))
    return _tracer


def configure_thread(enabled: bool = True, *,
                     capacity: int = DEFAULT_CAPACITY, rank: int = 0,
                     clock=time.perf_counter) -> Tracer | None:
    """Install a tracer for the *calling thread* only — ``get_tracer()``
    on this thread prefers it over the process-global one.

    This is how the multiproc launcher's inproc mode (N rank-threads in
    one process) keeps per-rank traces separate: each worker thread gets
    its own ring, written to its own ``trace_rankNN.json``.  Threads
    without a thread-local tracer still see the global one, so a fully
    disabled process pays only one extra (False) branch per call site.
    """
    global _tls_active
    if not enabled:
        _tls.tracer = None
        return None
    _tls_active = True
    t = Tracer(TraceConfig(enabled=True, capacity=capacity, rank=rank,
                           clock=clock))
    _tls.tracer = t
    return t


def get_tracer() -> Tracer | None:
    """THE hot-path gate: this thread's tracer (if one was installed via
    :func:`configure_thread`), else the process tracer, else None."""
    if _tls_active:
        t = getattr(_tls, "tracer", None)
        if t is not None:
            return t
    return _tracer


def is_enabled() -> bool:
    return get_tracer() is not None


def span(name: str, cat: str = "trainer", *, tid: int = 0, **args):
    """Convenience span against the global tracer; allocation-free no-op
    when tracing is disabled (for call sites that are not hot enough to
    hoist the :func:`get_tracer` check)."""
    tr = _tracer
    if tr is None:
        return _NOP_SPAN
    return tr.span(name, cat, tid=tid, **args)


def event(name: str, cat: str = "trainer", *, ts: float | None = None,
          tid: int = 0, args: dict | None = None) -> None:
    """Convenience instant event against the global tracer (no-op when
    disabled)."""
    tr = _tracer
    if tr is not None:
        tr.event(name, cat, ts=ts, tid=tid, args=args)


def reset() -> None:
    """Tear down the global + this thread's tracer (tests)."""
    global _tracer, _tls_active
    _tracer = None
    _tls_active = False
    _tls.tracer = None


# env activation: REPRO_TRACE=1 python -m ... (launchers without a flag)
if os.environ.get("REPRO_TRACE", "").strip() not in ("", "0", "false",
                                                     "False"):
    configure(True, capacity=int(os.environ.get("REPRO_TRACE_CAPACITY",
                                                DEFAULT_CAPACITY)))
