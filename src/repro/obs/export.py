"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON (DESIGN §12).

One :class:`~repro.obs.trace.Tracer` exports to one JSON file per rank —
the `trace_event format <https://docs.google.com/document/d/1CvAClvFfyA5R-
PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_ consumed by ``chrome://tracing`` and
https://ui.perfetto.dev — and :func:`validate_trace` is the schema gate the
CI test and the report loader share, so a malformed trace fails loudly at
export or load, never as a silently-empty timeline.

Mapping from recorder tuples to trace events::

    ("X", ts, dur, name, cat, tid, args)  ->  ph="X" complete slice
    ("i", ts, 0,   name, cat, tid, args)  ->  ph="i" instant (scope "p")
    ("C", ts, 0,   name, cat, 0,  {value})->  ph="C" counter track

``pid`` is the rank (one process track per rank in the merged timeline),
``tid`` the logical lane inside it (peer id for wire events).  Timestamps
are exported in microseconds — ``ts * 1e6`` of whatever clock the producer
recorded in (virtual seconds on the inproc backend, monotonic seconds on
UDP/the trainer), which Perfetto renders fine since only deltas matter.
"""
from __future__ import annotations

import json
import math
import os

from .trace import Tracer

__all__ = ["TraceSchemaError", "to_trace_events", "trace_payload",
           "write_trace", "validate_trace", "trace_path"]

_PH = ("X", "i", "C", "M")


class TraceSchemaError(ValueError):
    """An exported/loaded trace does not satisfy the trace_event schema."""


def to_trace_events(records, pid: int = 0) -> list[dict]:
    """Recorder tuples -> trace_event dicts (seconds -> microseconds)."""
    out = []
    for ph, ts, dur, name, cat, tid, args in records:
        ev = {"name": name, "cat": cat or "default", "ph": ph,
              "ts": ts * 1e6, "pid": int(pid), "tid": int(tid)}
        if ph == "X":
            ev["dur"] = dur * 1e6
        elif ph == "i":
            ev["s"] = "p"                   # process-scoped instant
        if args:
            ev["args"] = args
        out.append(ev)
    return out


def trace_payload(tracer: Tracer, *, pid: int | None = None,
                  meta: dict | None = None) -> dict:
    """The full JSON object for one rank's trace file."""
    pid = tracer.rank if pid is None else int(pid)
    events = to_trace_events(tracer.records(), pid=pid)
    # name the process track after the rank so the merged timeline reads
    # "rank 0", "rank 1", ... instead of bare pids
    events.insert(0, {"name": "process_name", "ph": "M", "pid": pid,
                      "tid": 0, "ts": 0,
                      "args": {"name": f"rank {pid}"}})
    payload = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"rank": pid, "dropped": tracer.dropped,
                             **(meta or {})}}
    validate_trace(payload)
    return payload


def trace_path(trace_dir: str, rank: int) -> str:
    return os.path.join(trace_dir, f"trace_rank{rank:02d}.json")


def write_trace(path_or_dir: str, tracer: Tracer, *, pid: int | None = None,
                meta: dict | None = None) -> str:
    """Write one rank's Perfetto JSON; returns the path written.  A
    directory argument resolves to the conventional per-rank filename
    (``trace_rankNN.json``) the report CLI globs for."""
    path = path_or_dir
    if not path.endswith(".json"):
        os.makedirs(path, exist_ok=True)
        path = trace_path(path, tracer.rank if pid is None else pid)
    payload = trace_payload(tracer, pid=pid, meta=meta)
    with open(path, "w") as fh:
        json.dump(payload, fh)
        fh.write("\n")
    return path


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise TraceSchemaError(msg)


def validate_trace(payload: dict) -> dict:
    """Schema-gate one trace JSON object; returns it for chaining.

    Checks the invariants both Perfetto and ``repro.obs.report`` rely on:
    a ``traceEvents`` list of dicts, each with a string ``name``, a known
    ``ph``, finite numeric ``ts`` and int ``pid``/``tid``; ``X`` events a
    finite non-negative ``dur``; ``C`` events a numeric ``args.value``.
    """
    _check(isinstance(payload, dict), "trace payload is not a JSON object")
    events = payload.get("traceEvents")
    _check(isinstance(events, list), "payload lacks a traceEvents list")
    for k, ev in enumerate(events):
        where = f"traceEvents[{k}]"
        _check(isinstance(ev, dict), f"{where} is not an object")
        _check(isinstance(ev.get("name"), str) and ev["name"],
               f"{where} lacks a name")
        ph = ev.get("ph")
        _check(ph in _PH, f"{where} ph {ph!r} not in {_PH}")
        ts = ev.get("ts")
        _check(isinstance(ts, (int, float)) and math.isfinite(ts),
               f"{where} ts {ts!r} is not a finite number")
        for fld in ("pid", "tid"):
            _check(isinstance(ev.get(fld), int),
                   f"{where} {fld} {ev.get(fld)!r} is not an int")
        if ph == "X":
            dur = ev.get("dur")
            _check(isinstance(dur, (int, float)) and math.isfinite(dur)
                   and dur >= 0,
                   f"{where} dur {dur!r} is not a finite non-negative "
                   "number")
        if ph == "C":
            val = (ev.get("args") or {}).get("value")
            _check(isinstance(val, (int, float)) and math.isfinite(val),
                   f"{where} counter args.value {val!r} is not finite")
        args = ev.get("args")
        if args is not None:
            _check(isinstance(args, dict), f"{where} args is not an object")
    return payload
