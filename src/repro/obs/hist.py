"""Streaming tail histograms and a small metrics registry (DESIGN §12).

:class:`TailHistogram` is a log-bucketed (HDR-style) streaming histogram:
values land in geometrically-spaced buckets — ``bins_per_octave`` buckets
per factor of 2 — so any quantile is *exact to within one log-bucket*
(relative error <= 2**(1/bins_per_octave) - 1; ~2.2% at the default 32)
at O(octaves * bins_per_octave) fixed memory, regardless of how many
samples stream through.  That is the p999 contract the tail tables need:
recording a million round times costs the same memory as recording ten,
and per-rank histograms :meth:`merge` associatively into the cross-rank
aggregate (bucket counts add — order never matters).

:class:`MetricsRegistry` fronts counters / gauges / histograms behind
get-or-create names, so instrumented code never branches on "was this
metric registered"; :func:`metrics` is the process-global instance.
"""
from __future__ import annotations

import math
import threading

import numpy as np

__all__ = ["TailHistogram", "Counter", "Gauge", "MetricsRegistry", "metrics"]


class TailHistogram:
    """Log-bucketed streaming histogram (see module docstring).

    Values are clamped to ``[min_value, max_value]`` — an under-range
    sample counts in the first bucket, an over-range one in the last (the
    clamp counts are kept so a mis-sized range is visible).  Non-finite
    samples are rejected loudly: a NaN round time is a producer bug, not
    a tail.
    """

    def __init__(self, min_value: float = 1e-7, max_value: float = 1e4,
                 bins_per_octave: int = 32):
        if not (0 < min_value < max_value):
            raise ValueError(f"need 0 < min_value < max_value, got "
                             f"({min_value}, {max_value})")
        if bins_per_octave < 1:
            raise ValueError(f"bins_per_octave {bins_per_octave} < 1")
        self.min_value = float(min_value)
        self.max_value = float(max_value)
        self.bins_per_octave = int(bins_per_octave)
        octaves = math.log2(self.max_value / self.min_value)
        self.n_bins = int(math.ceil(octaves * self.bins_per_octave)) + 1
        self.counts = np.zeros(self.n_bins, np.int64)
        # one log2 per record; the /bins scale folds into one multiply
        self._scale = float(self.bins_per_octave)
        self.count = 0
        self.sum = 0.0
        self.observed_min = math.inf
        self.observed_max = -math.inf
        self.clamped = 0

    # ------------------------------------------------------------- geometry
    def _index(self, v: float) -> int:
        i = int(math.log2(v / self.min_value) * self._scale)
        return min(max(i, 0), self.n_bins - 1)

    def _edge(self, i: int) -> float:
        """Lower edge of bucket ``i``."""
        return self.min_value * 2.0 ** (i / self._scale)

    def _mid(self, i: int) -> float:
        """Geometric midpoint of bucket ``i`` (the quantile estimate)."""
        return self.min_value * 2.0 ** ((i + 0.5) / self._scale)

    # ------------------------------------------------------------ recording
    def record(self, value: float, n: int = 1) -> None:
        v = float(value)
        if not math.isfinite(v):
            raise ValueError(f"non-finite sample {value!r}")
        if v < self.min_value or v > self.max_value:
            self.clamped += n
            v = min(max(v, self.min_value), self.max_value)
        self.counts[self._index(v)] += n
        self.count += n
        self.sum += value * n
        self.observed_min = min(self.observed_min, float(value))
        self.observed_max = max(self.observed_max, float(value))

    def record_many(self, values) -> None:
        for v in np.asarray(values, np.float64).ravel():
            self.record(float(v))

    # -------------------------------------------------------------- queries
    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1] — exact to one log-bucket.

        Returns the geometric midpoint of the bucket holding the q-th
        sample; NaN on an empty histogram.  The true sample quantile lies
        within a factor ``2**(1/bins_per_octave)`` of the estimate (modulo
        clamping at the range edges).
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        target = max(1, int(math.ceil(q * self.count)))
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target))
        # clamp the estimate to the observed envelope so tiny histograms
        # never report a midpoint outside what was actually fed
        return float(min(max(self._mid(i), self.observed_min),
                         self.observed_max))

    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def summary(self) -> dict:
        """The tail-table row: count + p50/p99/p999 + envelope."""
        return {"count": int(self.count),
                "mean": self.mean(),
                "p50": self.quantile(0.50),
                "p99": self.quantile(0.99),
                "p999": self.quantile(0.999),
                "min": self.observed_min if self.count else math.nan,
                "max": self.observed_max if self.count else math.nan}

    # -------------------------------------------------------------- merging
    def compatible(self, other: "TailHistogram") -> bool:
        return (self.min_value == other.min_value
                and self.max_value == other.max_value
                and self.bins_per_octave == other.bins_per_octave)

    def merge(self, other: "TailHistogram") -> "TailHistogram":
        """Fold ``other`` in (bucket counts add — associative and
        commutative across ranks).  Returns self."""
        if not self.compatible(other):
            raise ValueError("merging histograms with different geometry: "
                             f"({self.min_value}, {self.max_value}, "
                             f"{self.bins_per_octave}) vs "
                             f"({other.min_value}, {other.max_value}, "
                             f"{other.bins_per_octave})")
        self.counts += other.counts
        self.count += other.count
        self.sum += other.sum
        self.observed_min = min(self.observed_min, other.observed_min)
        self.observed_max = max(self.observed_max, other.observed_max)
        self.clamped += other.clamped
        return self

    def copy(self) -> "TailHistogram":
        out = TailHistogram(self.min_value, self.max_value,
                            self.bins_per_octave)
        out.merge(self)
        return out


class Counter:
    """Monotone accumulator."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins sample."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = math.nan

    def set(self, v: float) -> None:
        self.value = float(v)


class MetricsRegistry:
    """Named counters / gauges / tail histograms, get-or-create."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, TailHistogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str, **kw) -> TailHistogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = TailHistogram(**kw)
            return h

    def snapshot(self) -> dict:
        """JSON-safe dump: counters/gauges by value, histograms by
        :meth:`TailHistogram.summary`."""
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: g.value for k, g in self._gauges.items()},
                "histograms": {k: h.summary()
                               for k, h in self._hists.items()},
            }

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


_metrics = MetricsRegistry()


def metrics() -> MetricsRegistry:
    """The process-global registry."""
    return _metrics
