"""Merge per-rank Perfetto traces into paper-style tail tables.

``python -m repro.obs.report <trace_dir | trace.json ...>`` loads one or
more ``trace_rankNN.json`` files (as written by :func:`repro.obs.export.
write_trace`), validates them against the trace_event schema, and renders:

* **Round-completion tail tables** — per rank and merged across ranks —
  from the ``"round"`` complete spans' durations, folded through
  :class:`~repro.obs.hist.TailHistogram` (p50/p99/p999 to one log-bucket).
* **A control-plane event timeline** — every ``cat="policy"`` instant
  event (timeouts are ``cat="wire"`` instants) in timestamp order with
  rank, name, and cause — the "which decision caused that p999 spike"
  view the bench medians can't give.

``--json`` emits the same content machine-readably (the multiproc
launcher embeds these paths in its report; CI asserts on this output).
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

from .export import TraceSchemaError, validate_trace
from .hist import TailHistogram

__all__ = ["load_trace", "discover", "merge_report", "render", "main"]

# round/stage durations arrive in µs (export scales by 1e6); a µs-domain
# histogram range wide enough for virtual-clock sims and real UDP runs
_HIST_KW = dict(min_value=1e-1, max_value=1e10, bins_per_octave=32)

# instant-event names that constitute the control timeline, by category
_TIMELINE_CATS = ("policy", "wire", "sim")
_SPAN_TABLES = ("round", "step", "encode", "decode", "exchange")


def load_trace(path: str) -> dict:
    """Load + schema-validate one per-rank trace file."""
    with open(path) as fh:
        payload = json.load(fh)
    try:
        validate_trace(payload)
    except TraceSchemaError as e:
        raise TraceSchemaError(f"{path}: {e}") from e
    return payload


def discover(paths: list[str]) -> list[str]:
    """Expand directories into their ``trace_rank*.json`` members."""
    out: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            found = sorted(glob.glob(os.path.join(p, "trace_rank*.json")))
            if not found:
                raise FileNotFoundError(f"no trace_rank*.json under {p}")
            out.extend(found)
        else:
            out.append(p)
    return out


def _rank_of(payload: dict, fallback: int) -> int:
    rank = (payload.get("otherData") or {}).get("rank")
    return int(rank) if isinstance(rank, int) else fallback


def merge_report(payloads: list[dict]) -> dict:
    """Fold validated per-rank payloads into one report dict.

    ``tables[name]`` holds per-rank and merged :meth:`TailHistogram.
    summary` rows for each span family in ``_SPAN_TABLES``; ``timeline``
    is the cross-rank event list sorted by timestamp within each clock
    domain (category), since wire/trainer/sim clocks are not comparable.
    """
    tables: dict[str, dict] = {}
    merged: dict[str, TailHistogram] = {}
    timeline: list[dict] = []
    dropped = 0
    for k, payload in enumerate(payloads):
        rank = _rank_of(payload, k)
        dropped += int((payload.get("otherData") or {}).get("dropped", 0))
        for ev in payload["traceEvents"]:
            ph, name = ev["ph"], ev["name"]
            if ph == "X" and name in _SPAN_TABLES:
                per_rank = tables.setdefault(name, {})
                h = per_rank.get(rank)
                if h is None:
                    h = per_rank[rank] = TailHistogram(**_HIST_KW)
                m = merged.get(name)
                if m is None:
                    m = merged[name] = TailHistogram(**_HIST_KW)
                dur = float(ev.get("dur", 0.0))
                if dur > 0:
                    h.record(dur)
                    m.record(dur)
            elif ph == "i" and ev.get("cat") in _TIMELINE_CATS:
                timeline.append({"ts": float(ev["ts"]), "rank": rank,
                                 "name": name, "cat": ev.get("cat"),
                                 "tid": int(ev.get("tid", 0)),
                                 "args": ev.get("args") or {}})
    timeline.sort(key=lambda e: (e["cat"], e["ts"], e["rank"]))
    report = {"ranks": sorted({_rank_of(p, i)
                               for i, p in enumerate(payloads)}),
              "dropped_records": dropped,
              "tables": {}, "timeline": timeline}
    for name, per_rank in sorted(tables.items()):
        if merged[name].count == 0:
            continue      # e.g. zero-duration spans on a virtual clock
        report["tables"][name] = {
            "per_rank": {str(r): h.summary()
                         for r, h in sorted(per_rank.items())},
            "merged": merged[name].summary(),
        }
    return report


def _fmt_us(v: float) -> str:
    if not math.isfinite(v):
        return "    n/a"
    if v >= 1e6:
        return f"{v / 1e6:7.2f}s"
    if v >= 1e3:
        return f"{v / 1e3:6.2f}ms"
    return f"{v:6.1f}us"


def render(report: dict, *, events: int = 40) -> str:
    """Human-readable tail tables + control timeline."""
    lines: list[str] = []
    for name, tab in report["tables"].items():
        lines.append(f"== {name} completion time "
                     f"(per rank + merged, µs-domain) ==")
        lines.append(f"{'rank':>6} {'count':>8} {'p50':>8} {'p99':>8} "
                     f"{'p999':>8} {'max':>8}")
        rows = list(tab["per_rank"].items()) + [("all", tab["merged"])]
        for rank, s in rows:
            lines.append(f"{rank:>6} {s['count']:>8d} {_fmt_us(s['p50'])} "
                         f"{_fmt_us(s['p99'])} {_fmt_us(s['p999'])} "
                         f"{_fmt_us(s['max'])}")
        lines.append("")
    tl = report["timeline"]
    lines.append(f"== control timeline ({len(tl)} events"
                 + (f", showing last {events}" if len(tl) > events else "")
                 + ") ==")
    for ev in tl[-events:]:
        args = " ".join(f"{k}={v}" for k, v in ev["args"].items())
        lines.append(f"  [{ev['cat']:>6}] t={ev['ts']:14.1f}us "
                     f"rank{ev['rank']} {ev['name']:<14} {args}")
    if report["dropped_records"]:
        lines.append(f"\n!! {report['dropped_records']} records dropped to "
                     "ring-buffer wraparound — raise REPRO_TRACE_CAPACITY")
    return "\n".join(lines)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Merge per-rank Perfetto traces into tail tables and "
                    "a control-plane event timeline.")
    p.add_argument("paths", nargs="+",
                   help="trace JSON files and/or directories holding "
                        "trace_rank*.json")
    p.add_argument("--json", action="store_true",
                   help="emit the merged report as JSON instead of tables")
    p.add_argument("--events", type=int, default=40,
                   help="max timeline events to render (text mode)")
    return p


def main(argv: list[str] | None = None) -> dict:
    args = build_parser().parse_args(argv)
    paths = discover(args.paths)
    report = merge_report([load_trace(p) for p in paths])
    report["sources"] = paths
    if args.json:
        print(json.dumps(report, indent=1))
    else:
        print(render(report, events=args.events))
    return report


if __name__ == "__main__":
    main(sys.argv[1:])
