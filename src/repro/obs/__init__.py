"""Tail-latency observability: tracing, tail histograms, exporters.

See DESIGN.md §12.  The hot-path contract is :func:`get_tracer` — one
module-global read returning ``None`` when tracing is off — so every
instrumented loop in the wire/runtime/sim layers stays a few ns per call
site until ``configure()`` (or ``--trace`` / ``REPRO_TRACE=1``) turns
recording on.
"""
from .trace import (TraceConfig, Tracer, Span, configure, configure_thread,
                    get_tracer, is_enabled, span, event, reset)
from .hist import TailHistogram, Counter, Gauge, MetricsRegistry, metrics
from .export import (TraceSchemaError, to_trace_events, trace_payload,
                     write_trace, validate_trace, trace_path)

__all__ = [
    "TraceConfig", "Tracer", "Span", "configure", "configure_thread",
    "get_tracer", "is_enabled", "span", "event", "reset",
    "TailHistogram", "Counter", "Gauge", "MetricsRegistry", "metrics",
    "TraceSchemaError", "to_trace_events", "trace_payload", "write_trace",
    "validate_trace", "trace_path",
]
