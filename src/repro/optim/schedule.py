"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int = 100,
                  total_steps: int = 10_000, min_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(1.0, s / max(warmup_steps, 1))
    t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                 0.0, 1.0)
    cos = peak_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(s < warmup_steps, warm, cos)


def constant(step, *, peak_lr: float):
    del step
    return jnp.asarray(peak_lr, jnp.float32)
