"""Optimizers (no optax in this environment): SGD(+momentum), AdamW, and a
factored Adafactor-lite. States are pure pytrees mirroring the parameter
tree, so they inherit the parameters' sharding specs (FSDP shards optimizer
state for free — the ZeRO property). ``state_dtype`` trades memory for
precision on the moment buffers (bf16 moments are what lets the 480B arch
fit 16 GB/chip; see EXPERIMENTS §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    momentum: float = 0.9
    state_dtype: Any = jnp.float32   # moments dtype (bf16 for giant models)
    grad_clip: float = 1.0
    # sequence the update over layer-stacked leaves (lax.map over dim 0):
    # bounds the fp32 upcast transients to one layer instead of the whole
    # tree — required to fit the 480B arch's update in 16 GB/chip
    scan_update: bool = True


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params, lr, step) -> (new_p, new_s)
    state_like_params: bool  # True if state leaves mirror param leaves


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)

    def update(grads, state, params, lr, step):
        del step
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                          ).astype(p.dtype), params, grads)
        return new_p, state

    return Optimizer(init, update, state_like_params=False)


def _maybe_scan_leaf(cfg: OptimizerConfig, fn, *leaves):
    """Apply fn across dim 0 of layer-stacked leaves (bounded transients)."""
    if cfg.scan_update and leaves[0].ndim >= 3:
        return jax.lax.map(lambda t: fn(*t), leaves)
    return fn(*leaves)


def momentum_sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)

    def update(grads, state, params, lr, step):
        del step

        def upd(p, g, m):
            mf = (cfg.momentum * m.astype(jnp.float32)
                  + g.astype(jnp.float32))
            pf = p.astype(jnp.float32) - lr * mf
            return pf.astype(p.dtype), mf.astype(cfg.state_dtype)

        out = jax.tree.map(
            lambda p, g, m: _maybe_scan_leaf(cfg, upd, p, g, m),
            params, grads, state)
        leaves, treedef = jax.tree.flatten(
            out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
        ps = treedef.unflatten([l[0] for l in leaves])
        ms = treedef.unflatten([l[1] for l in leaves])
        return ps, ms

    return Optimizer(init, update, state_like_params=True)


class AdamState(NamedTuple):
    m: Any
    v: Any


def adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
        return AdamState(m=jax.tree.map(z, params), v=jax.tree.map(z, params))

    def update(grads, state, params, lr, step):
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - cfg.beta1 ** t
        bc2 = 1.0 - cfg.beta2 ** t

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = cfg.beta1 * m.astype(jnp.float32) + (1 - cfg.beta1) * gf
            vf = cfg.beta2 * v.astype(jnp.float32) + (1 - cfg.beta2) * gf * gf
            mhat = mf / bc1
            vhat = vf / bc2
            pf = p.astype(jnp.float32)
            pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                            + cfg.weight_decay * pf)
            return (pf.astype(p.dtype), mf.astype(cfg.state_dtype),
                    vf.astype(cfg.state_dtype))

        out = jax.tree.map(
            lambda p, g, m, v: _maybe_scan_leaf(cfg, upd, p, g, m, v),
            params, grads, state.m, state.v)
        leaves, treedef = jax.tree.flatten(
            out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
            and all(isinstance(y, jax.Array) for y in x))
        ps = treedef.unflatten([l[0] for l in leaves])
        ms = treedef.unflatten([l[1] for l in leaves])
        vs = treedef.unflatten([l[2] for l in leaves])
        return ps, AdamState(m=ms, v=vs)

    return Optimizer(init, update, state_like_params=True)


class AdafactorState(NamedTuple):
    row: Any   # per-leaf row stats (or full v for <2D leaves)
    col: Any


def adafactor(cfg: OptimizerConfig) -> Optimizer:
    """Factored second moment over the trailing two dims (Shazeer-Stern,
    simplified: no update clipping / relative step)."""
    def init(params):
        def rows(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def cols(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(row=jax.tree.map(rows, params),
                              col=jax.tree.map(cols, params))

    def update(grads, state, params, lr, step):
        b2 = cfg.beta2

        def upd(p, g, r, c):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + 1e-30
            if p.ndim >= 2:
                rn = b2 * r + (1 - b2) * jnp.mean(g2, axis=-1)
                cn = b2 * c + (1 - b2) * jnp.mean(g2, axis=-2)
                rmean = jnp.mean(rn, axis=-1, keepdims=True)
                vhat = (rn[..., None] * cn[..., None, :]
                        / jnp.maximum(rmean[..., None], 1e-30))
            else:
                rn = b2 * r + (1 - b2) * g2
                cn = c
                vhat = rn
            pf = p.astype(jnp.float32)
            pf = pf - lr * (gf / (jnp.sqrt(vhat) + cfg.eps)
                            + cfg.weight_decay * pf)
            return pf.astype(p.dtype), rn, cn

        out = jax.tree.map(upd, params, grads, state.row, state.col)
        leaves, treedef = jax.tree.flatten(
            out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
        ps = treedef.unflatten([l[0] for l in leaves])
        rs = treedef.unflatten([l[1] for l in leaves])
        cs = treedef.unflatten([l[2] for l in leaves])
        return ps, AdafactorState(row=rs, col=cs)

    return Optimizer(init, update, state_like_params=False)


_REGISTRY = {
    "sgd": sgd,
    "momentum": momentum_sgd,
    "adamw": adamw,
    "adafactor": adafactor,
}


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    return _REGISTRY[cfg.name](cfg)
