"""Distributed trainer: OptiReduce integrated as the gradient-sync layer.

Two data-parallel modes (DESIGN §4):

* ``replicated`` — paper-faithful: parameters replicated over the data
  axis/axes; after (micro-batched) backward, the flat gradient stream is
  bucketized (25 MB, like PyTorch DDP) and every bucket runs the selected
  strategy from ``core.allreduce`` (Ring / Tree / BCube / TAR / OptiReduce).

* ``fsdp`` — ZeRO-3 scaling path for the multi-billion-parameter archs:
  every large weight is sharded over the fsdp axes; the scan body gathers it
  just-in-time through a custom-VJP all_gather whose *backward is the
  OptiReduce reduce-scatter* (TAR stage 1 + HT + drop-compensated mean) —
  the paper's collective becomes the ZeRO gradient reduction, and the
  deferred stage-2 broadcast is the next step's weight all_gather.
  Replicated leaves (norms, routers, ...) still sync via bucketed strategy.

Cross-bucket overlap is explicit, not hoped-for: ``TrainConfig.sync_mode``
defaults to ``"pipelined"``, the stage-skewed software schedule in
``core.allreduce.sync_packed`` where iteration k encodes bucket k, exchanges
bucket k-1, and decodes bucket k-2 — the paper's "two in-flight buckets"
(§5) expressed as a depth-2 skew whose in-flight payloads ride in the scan
carry, so the exchange collectives overlap neighboring buckets' codec
kernels by construction (see PERF.md for the skew diagram).

The replicated path also keeps the whole gradient stream in a **packed
arena**: micro-batch accumulation adds each microbatch's grads directly
into the ``(B, bucket_elems)`` batch (no per-leaf zeros tree, one pack
fused into the first add), the arena feeds ``sync_packed`` without a
repack, and the §3.4 guard + global-norm + clip run as one fused reduction
and one multiply over the arena before a single unpack for the optimizer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.core.allreduce import (OptiReduceConfig, SyncContext, rs_spec,
                                  sync_packed, sync_pytree)
from repro.core.bucket_plan import BucketPlan
from repro.core.pipeline import resolve_spec
from repro.core.safeguards import guard_scale, guard_update
from repro.kernels import runtime as kernel_runtime
from repro.models import lm_loss, param_specs, param_table
from repro.models.parallel import ParallelCtx
from repro.models.transformer import _tree_map_table
from repro.optim.optimizers import OptimizerConfig, make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    sync: OptiReduceConfig = OptiReduceConfig()
    optimizer: OptimizerConfig = OptimizerConfig()
    dp_mode: str = "replicated"          # 'replicated' | 'fsdp'
    microbatch: int | None = None        # per-device microbatch (grad accum)
    seq_chunk: int = 1024                # xent sequence chunking
    remat: bool = True
    bucket_elems: int = 6_553_600        # 25 MB fp32 buckets
    # bucket schedule: 'pipelined' (stage-skewed software pipeline, overlaps
    # exchange collectives with neighboring buckets' encode/decode kernels),
    # 'scan' (strictly serial), 'vmap' (batched collectives). All three are
    # bitwise-identical per bucket (pinned by the parity suite).
    sync_mode: str = "pipelined"
    guard: bool = True                   # §3.4 skip-update safeguard
    unroll: bool = False                 # Python-unrolled layers (cost model)
    accum_dtype: Any = jnp.float32       # grad-accumulation dtype (bf16 for
                                         # the 480B arch: 16 GB/chip budget)
    # pure data parallelism on a single-pod mesh: treat the 'model' axis as
    # a second data level (hierarchical 2D TAR over (model, data)) — no TP
    # activation psums at all. The right logical mapping for small archs
    # (§Perf hillclimb H1); single-pod meshes only.
    pure_dp: bool = False
    # sequence parallelism (Megatron-SP): residual stream sharded over tp
    # along seq between blocks; shrinks the per-layer saved residual by
    # 1/tp (§Perf H3 memory lever). Requires seq_len % tp == 0.
    seq_parallel: bool = False
    # host wire transport (DESIGN §7): a transport instance (typically
    # net.WireTransport bridged to a HostRing) that replaces the resolved
    # spec's transport, so stage-1 arrival masks come from a real packet
    # exchange instead of the synthetic drop model. Replicated DP only.
    transport_override: Any = None
    # Pallas kernel dispatch (DESIGN §11): 'interpret' | 'compile' | 'auto'
    # (auto = Mosaic-compile iff running on a TPU backend). None leaves the
    # process-level policy (REPRO_KERNEL_MODE / kernels.runtime) untouched.
    kernel_mode: str | None = None


def mesh_axis_names(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes_of(mesh) -> tuple[str, ...]:
    names = mesh_axis_names(mesh)
    return tuple(a for a in ("pod", "data") if a in names)


def make_fsdp_gather(sync_cfg: OptiReduceConfig, fsdp_axes: tuple[str, ...]):
    """(w_local, dim, key) -> w_full gather with OptiReduce reduce-scatter
    as its VJP. Gathers inner axis first so the layout matches a dim sharded
    by P(('pod','data')) (pod-major)."""
    inner_to_outer = tuple(reversed(fsdp_axes))   # ('data', 'pod')
    # one resolved reduce-scatter spec per axis: drops are modeled only on
    # the data axis (the pod hop is the reliable inter-pod aggregation)
    axis_specs = {ax: rs_spec(sync_cfg, with_drops=ax == sync_cfg.data_axis)
                  for ax in fsdp_axes}

    @functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
    def gather(w, dim, key):
        for ax in inner_to_outer:
            w = jax.lax.all_gather(w, ax, axis=dim, tiled=True)
        return w

    def fwd(w, dim, key):
        return gather(w, dim, key), key

    def bwd(dim, key, g):
        ctx = SyncContext(cfg=sync_cfg, key=key)
        out_dtype = g.dtype
        for ax in fsdp_axes:              # outer (pod) first, mirrors fwd
            g = axis_specs[ax].reduce_scatter(g, ax, dim, ctx)
        return (g.astype(out_dtype), None)

    gather.defvjp(fwd, bwd)
    return gather


def _fsdp_leaf_mask(cfg: ModelConfig, tp: int, fsdp_axes):
    """Pytree of bools: which leaves are fsdp-sharded (grads arrive reduced
    through the gather VJP) vs replicated (need explicit bucket sync)."""
    table = param_table(cfg, tp=tp, fsdp_axes=fsdp_axes)
    return _tree_map_table(lambda l: l.fsdp_dim is not None, table)


def _spec_axes(spec: P) -> tuple[str, ...]:
    axes: list[str] = []
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            axes.extend(entry)
        else:
            axes.append(entry)
    return tuple(axes)


def _summed_groups(pairs) -> dict[tuple[str, ...], jnp.ndarray]:
    """Sum (axes, squared-sum) pairs into one accumulator per distinct
    sharded-axes set (canonicalized by sort, so P('a','b') and P('b','a')
    share a group)."""
    groups: dict[tuple[str, ...], jnp.ndarray] = {}
    for axes, ss in pairs:
        key = tuple(sorted(axes))
        prev = groups.get(key)
        groups[key] = ss if prev is None else prev + ss
    return groups


def _psum_group_total(groups: dict[tuple[str, ...], jnp.ndarray]) -> jnp.ndarray:
    total = jnp.zeros((), jnp.float32)
    for axes, ss in groups.items():
        if axes:
            ss = jax.lax.psum(ss, axes)
        total = total + ss
    return total


def sharded_global_norm(grads, specs) -> jnp.ndarray:
    """Global L2 norm of a gradient tree whose leaves are sharded per
    ``specs`` — per-leaf squared sums are psum'd over exactly the axes each
    leaf is sharded on, so replicated leaves are not double-counted and the
    result is identical on every device.  Leaves are grouped by their
    sharded-axes set and each group issues ONE psum (a model with hundreds
    of leaves pays #distinct-axes-sets collectives, not #leaves)."""
    g_leaves = jax.tree.leaves(grads)
    s_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    groups = _summed_groups(
        (_spec_axes(s), jnp.sum(jnp.square(g.astype(jnp.float32))))
        for g, s in zip(g_leaves, s_leaves))
    return jnp.sqrt(_psum_group_total(groups))


def packed_global_norm(batch: jnp.ndarray, plan: BucketPlan,
                       specs) -> jnp.ndarray:
    """:func:`sharded_global_norm` over the packed gradient arena.

    Adjacent leaves sharing a sharded-axes set coalesce into one contiguous
    arena run, so the common all-replicated case is a single fused
    sum-of-squares over the whole flat stream (one HBM pass, no per-leaf
    Python loop) with no psum at all; mixed-sharding trees pay one reduction
    per contiguous run and one psum per distinct axes set.  The zero-padded
    arena tail is excluded (runs stop at ``plan.total`` — after a quantized
    sync the tail carries codec noise, not zeros)."""
    flat = batch.reshape(-1)
    s_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    runs: list[tuple[tuple[str, ...], int, int]] = []
    for off, size, s in zip(plan.offsets, plan.sizes, s_leaves):
        axes = tuple(sorted(_spec_axes(s)))
        if runs and runs[-1][0] == axes and runs[-1][2] == off:
            runs[-1] = (axes, runs[-1][1], off + size)
        else:
            runs.append((axes, off, off + size))
    groups = _summed_groups(
        (axes, jnp.sum(jnp.square(flat[a:b].astype(jnp.float32))))
        for axes, a, b in runs)
    return jnp.sqrt(_psum_group_total(groups))


def build_train_step(cfg: ModelConfig, tc: TrainConfig, mesh):
    """Returns (step_fn, shardings) where step_fn(params, opt_state, batch,
    step, key) -> (params, opt_state, metrics), jit-able under ``mesh``."""
    if tc.kernel_mode is not None:
        # set before any kernel shim resolves (trace time), so the whole
        # step traces under one dispatch mode
        kernel_runtime.set_kernel_mode(tc.kernel_mode)
    names = mesh_axis_names(mesh)
    if tc.pure_dp:
        assert "pod" not in names, "pure_dp is a single-pod remap"
        tp_axis = None
        data_axis = "data"
        pod_axis = "model"            # second data level (2D TAR hierarchy)
        dp_axes = ("model", "data")
    else:
        tp_axis = "model" if "model" in names else None
        dp_axes = dp_axes_of(mesh)
        data_axis = "data" if "data" in names else None
        pod_axis = "pod" if "pod" in names else None
    tp = mesh.shape["model"] if tp_axis else 1
    fsdp = tc.dp_mode == "fsdp"
    fsdp_axes = dp_axes if fsdp else None

    sync_cfg = dataclasses.replace(
        tc.sync, data_axis=data_axis or "data",
        pod_axis=pod_axis)
    sync_spec = resolve_spec(sync_cfg)   # fail fast on unknown strategies
    rec_policy = None
    if sync_cfg.recovery != "none":
        from repro.core import recovery as recovery_lib
        rec_policy = recovery_lib.parse(sync_cfg.recovery)
        if fsdp:
            raise ValueError(
                "recovery rides the bucketed sync path (stale arena + "
                "EF residuals are arena-shaped); fsdp grads reduce "
                "through rs_spec — use dp_mode='replicated'")
        if pod_axis is not None:
            raise ValueError(
                "recovery does not compose with the 2D (pod, data) "
                "hierarchy yet: the stale/EF wire-space layout assumes "
                "a single flat TAR shard order")
        if data_axis is None:
            raise ValueError("recovery needs a 'data' mesh axis")
        if rec_policy.ef and tc.transport_override is not None:
            raise ValueError(
                "recovery='ef'/'ef+budget' reconstructs sender-arrival "
                "masks from the synthetic drop model; wire-observed masks "
                "(transport_override) are not reproducible at the sender "
                "— use recovery='stale' with wire transports")
    if tc.transport_override is not None:
        if fsdp:
            raise ValueError("transport_override drives the bucketed sync "
                             "path; fsdp grads reduce through rs_spec "
                             "(wire transports are replicated-DP only)")
        sync_spec = dataclasses.replace(sync_spec,
                                        transport=tc.transport_override)
    opt = make_optimizer(tc.optimizer)
    gather = make_fsdp_gather(sync_cfg, dp_axes) if fsdp else None
    pctx = ParallelCtx(tp_axis=tp_axis, dp_axis=data_axis, pod_axis=pod_axis,
                       fsdp=fsdp, gather=gather,
                       sp=tc.seq_parallel and tp_axis is not None)

    p_specs = param_specs(cfg, tp=tp, fsdp_axes=fsdp_axes)
    fsdp_mask = _fsdp_leaf_mask(cfg, tp, fsdp_axes) if fsdp else None
    batch_dim_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0]) \
        if dp_axes else P()

    def _body(params, opt_state, batch, step, key, rec_state):
        skey = jax.random.fold_in(key, step)
        new_rec = None

        def loss_fn(p, mb):
            return lm_loss(p, mb, cfg, pctx, key=skey,
                           seq_chunk=tc.seq_chunk, remat=tc.remat,
                           unroll=tc.unroll)

        b_local = batch["tokens"].shape[0]
        mb = tc.microbatch or b_local
        n_micro = max(1, b_local // mb)
        ctx = SyncContext(cfg=sync_cfg, key=jax.random.fold_in(skey, 7))
        if n_micro > 1:
            mbatches = jax.tree.map(
                lambda x: x.reshape(n_micro, mb, *x.shape[1:]), batch)

        if fsdp:
            # large leaves arrive pre-reduced through the gather VJP, so the
            # packed arena cannot span the whole stream — keep the per-leaf
            # accumulator and bucket-sync only the replicated leaves
            if n_micro > 1:
                def micro(carry, mbatch):
                    gacc, lacc = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                    gacc = jax.tree.map(
                        lambda a, b_: a + b_.astype(tc.accum_dtype), gacc, g)
                    return (gacc, lacc + l), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, tc.accum_dtype), params)
                (grads, loss), _ = jax.lax.scan(
                    micro, (zeros, jnp.zeros(())), mbatches)
                grads = jax.tree.map(lambda g: g / n_micro, grads)
                loss = loss / n_micro
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)

            flat_g, tdef = jax.tree.flatten(grads)
            flat_m = jax.tree.leaves(fsdp_mask)
            small = [g for g, m_ in zip(flat_g, flat_m) if not m_]
            if small:
                synced_small = sync_pytree(small, ctx,
                                           bucket_elems=tc.bucket_elems,
                                           mode=tc.sync_mode, spec=sync_spec)
                it = iter(synced_small)
                flat_g = [next(it) if not m_ else g
                          for g, m_ in zip(flat_g, flat_m)]
            grads = jax.tree.unflatten(tdef, flat_g)
            loss_frac = ctx.loss_fraction()

            # ---- safeguards (§3.4), clip, optimizer ----------------------
            if tc.guard:
                grads, skipped = guard_update(
                    grads, loss_frac, skip_threshold=sync_cfg.skip_threshold)
            else:
                skipped = jnp.zeros((), jnp.bool_)
            gnorm = sharded_global_norm(grads, p_specs)
            clip_scale = jnp.minimum(
                1.0, tc.optimizer.grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * clip_scale.astype(g.dtype),
                                 grads)
        else:
            # ---- packed gradient arena (replicated DP) -------------------
            # the (B, bucket_elems) batch IS the accumulator: micro-batch
            # grads pack straight into it (the pack concat fuses into the
            # add — no per-leaf zeros tree, no second full-gradient copy),
            # the sync engine consumes it without a repack, and guard +
            # global-norm + clip are one fused reduction and one multiply
            # over the arena before the single unpack the optimizer needs
            plan = BucketPlan.for_tree(params, tc.bucket_elems)
            if n_micro > 1:
                def micro(carry, mbatch):
                    acc, lacc = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                    return (acc + plan.pack(g, dtype=tc.accum_dtype),
                            lacc + l), None

                arena0 = jnp.zeros((plan.num_buckets, plan.bucket_elems),
                                   tc.accum_dtype)
                (arena, loss), _ = jax.lax.scan(
                    micro, (arena0, jnp.zeros(())), mbatches)
                # accumulate in accum_dtype (bitwise vs the seed per-leaf
                # accumulator), then take the micro-batch mean in fp32 wire
                # space: identical for fp32 accum, and for bf16 it drops the
                # seed's extra accum-dtype rounding of the mean
                arena = arena.astype(jnp.float32) / n_micro
                loss = loss / n_micro
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                arena = plan.pack(grads)

            if rec_state is not None and "ef" in rec_state:
                # the carried EF residual joins this rank's contribution
                # (per-rank state: each data rank dropped different wire
                # spans last step; the local shard is (1, B, E))
                arena = arena + rec_state["ef"][0]

            synced = sync_packed(arena, ctx, mode=tc.sync_mode,
                                 spec=sync_spec,
                                 stale=None if rec_state is None
                                 else rec_state.get("stale"))
            loss_frac = ctx.loss_fraction()

            if rec_state is not None:
                new_rec = dict(rec_state)
                if "stale" in new_rec:
                    # next step's prediction for lost wire spans: this
                    # step's decoded arena, pre-guard/clip (the sync output
                    # is replicated — every rank caches identical buckets)
                    new_rec["stale"] = synced
                if "ef" in new_rec:
                    n_dp = mesh.shape[data_axis]
                    me = jax.lax.axis_index(data_axis)
                    # residual vs the *pre-update* stale cache: that is
                    # what the fill applied in this rank's stead, so the
                    # carried mass is only the gap (no double counting)
                    new_rec["ef"] = recovery_lib.ef_residual_arena(
                        arena, ctx.key, sync_cfg, n_dp, me,
                        stale=rec_state["stale"])[None]

            # ---- safeguards (§3.4), clip: fused over the arena -----------
            # norm and clip read the fp32 wire values with ONE param-dtype
            # round at unpack; for non-fp32 params the seed instead rounded
            # at unpack and then squared/multiplied in param dtype — same
            # math, one fewer low-bit rounding here (fp32 params: identical)
            if tc.guard:
                gscale, skipped = guard_scale(
                    loss_frac, skip_threshold=sync_cfg.skip_threshold)
            else:
                gscale = jnp.ones(())
                skipped = jnp.zeros((), jnp.bool_)
            # norm-after-guard == guard_scale * norm (the scale is 0 or 1)
            gnorm = gscale * packed_global_norm(synced, plan, p_specs)
            clip_scale = jnp.minimum(
                1.0, tc.optimizer.grad_clip / jnp.maximum(gnorm, 1e-9))
            synced = synced * (gscale * clip_scale)
            grads = plan.unpack(synced)
        lr = jnp.asarray(tc.optimizer.lr, jnp.float32)
        new_params, new_opt = opt.update(grads, opt_state, params, lr, step)

        metrics = {
            "loss": jax.lax.pmean(loss, dp_axes) if dp_axes else loss,
            "grad_norm": gnorm,
            "loss_frac": loss_frac,
            "skipped": skipped.astype(jnp.float32),
        }
        return new_params, new_opt, new_rec, metrics

    if rec_policy is None:
        def body(params, opt_state, batch, step, key):
            p, o, _, m = _body(params, opt_state, batch, step, key, None)
            return p, o, m
    else:
        def body(params, opt_state, rec_state, batch, step, key):
            p, o, r, m = _body(params, opt_state, batch, step, key,
                               rec_state)
            return p, o, r, m

    # optimizer state specs mirror parameter specs leaf-for-leaf
    def opt_specs_like(p_specs_tree, opt_state_tree):
        flat_specs = jax.tree.leaves(p_specs_tree,
                                     is_leaf=lambda x: isinstance(x, P))
        n = len(flat_specs)
        flat_state = jax.tree.leaves(opt_state_tree)
        if len(flat_state) % n == 0 and opt.state_like_params:
            reps = len(flat_state) // n
            specs = flat_specs * reps
            treedef = jax.tree.structure(opt_state_tree)
            return jax.tree.unflatten(treedef, specs)
        return jax.tree.map(lambda _: P(), opt_state_tree)

    def make_step(opt_state_example, batch_example):
        o_specs = opt_specs_like(p_specs, opt_state_example)
        batch_spec = jax.tree.map(lambda _: batch_dim_spec, batch_example)
        metric_specs = {"loss": P(), "grad_norm": P(), "loss_frac": P(),
                        "skipped": P()}
        if rec_policy is None:
            in_specs = (p_specs, o_specs, batch_spec, P(), P())
            out_specs = (p_specs, o_specs, metric_specs)
        else:
            # stale cache is replicated (every rank decodes the same
            # buckets); the EF residual is per-data-rank, leading axis
            rec_specs = {}
            if rec_policy.stale:
                rec_specs["stale"] = P()
            if rec_policy.ef:
                rec_specs["ef"] = P(data_axis)
            in_specs = (p_specs, o_specs, rec_specs, batch_spec, P(), P())
            out_specs = (p_specs, o_specs, rec_specs, metric_specs)
        fn = compat.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False)
        shardings = {
            "params": jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                                   is_leaf=lambda x: isinstance(x, P)),
            "opt": jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                                is_leaf=lambda x: isinstance(x, P)),
            "batch": jax.tree.map(lambda s: NamedSharding(mesh, s),
                                  batch_spec,
                                  is_leaf=lambda x: isinstance(x, P)),
        }
        if rec_policy is not None:
            shardings["rec"] = jax.tree.map(
                lambda s: NamedSharding(mesh, s), rec_specs,
                is_leaf=lambda x: isinstance(x, P))
        return fn, shardings

    return make_step, opt, pctx


def abstract_opt_state(opt, abstract_params_tree):
    """Optimizer state ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(opt.init, abstract_params_tree)
