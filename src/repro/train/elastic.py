"""Elastic scaling: continue a job on a different worker count.

OptiReduce makes this cheap: the collective is defined for any N (TAR shard
count follows the axis size) and the drop machinery already tolerates
departed peers mid-step (a failed node is a 100%-dropped peer until the
controller re-forms the mesh). What remains is state surgery:

* replicated params: nothing to do — every survivor holds the full state.
* fsdp shards: concatenate old shards along each leaf's fsdp dim and
  re-split by the new axis size (``reshard``).
* data pipeline: deterministic (step, host, n_hosts) indexing re-partitions
  the global stream automatically (data/pipeline.py).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import Leaf, _tree_map_table, param_table


def _fsdp_dims(cfg: ModelConfig, tp: int) -> Any:
    table = param_table(cfg, tp=tp, fsdp_axes=("data",))
    return _tree_map_table(lambda l: l.fsdp_dim, table)


def gather_shards(shard_trees: list, cfg: ModelConfig, tp: int = 1) -> Any:
    """Reassemble full params from the per-worker fsdp shards."""
    dims = _fsdp_dims(cfg, tp)
    flat_dims = jax.tree.leaves(dims, is_leaf=lambda x: x is None or
                                isinstance(x, int))
    flats = [jax.tree.leaves(t) for t in shard_trees]
    treedef = jax.tree.structure(shard_trees[0])
    out = []
    for i, dim in enumerate(flat_dims):
        parts = [f[i] for f in flats]
        if dim is None:
            out.append(parts[0])            # replicated leaf
        else:
            out.append(np.concatenate([np.asarray(p) for p in parts],
                                      axis=dim))
    return jax.tree.unflatten(treedef, out)


def reshard(full_params: Any, cfg: ModelConfig, new_n: int, *, tp: int = 1
            ) -> list:
    """Split full params into ``new_n`` fsdp shards (one per new worker)."""
    dims = _fsdp_dims(cfg, tp)
    flat_dims = jax.tree.leaves(dims, is_leaf=lambda x: x is None or
                                isinstance(x, int))
    flat = jax.tree.leaves(full_params)
    treedef = jax.tree.structure(full_params)
    shards = [[] for _ in range(new_n)]
    for leaf, dim in zip(flat, flat_dims):
        if dim is None:
            for s in shards:
                s.append(leaf)
            continue
        arr = np.asarray(leaf)
        assert arr.shape[dim] % new_n == 0, (arr.shape, dim, new_n)
        for w, piece in enumerate(np.split(arr, new_n, axis=dim)):
            shards[w].append(piece)
    return [jax.tree.unflatten(treedef, s) for s in shards]
