"""Fault-tolerant checkpointing: per-leaf .npy shards + JSON manifest,
async background saves, a retained-snapshot ring, and exact restart.

Layout:
  <dir>/step_000100/
      manifest.json        # pytree structure + leaf dtypes/shapes + meta
      leaf_00000.npy ...   # one file per leaf (host-local shard or full)

On a real multi-host cluster each host writes only its addressable shards
(the manifest records the process index); in this single-host container the
full arrays are written. Restore is exact: step counter, params, optimizer
state, and data-pipeline position (derived from step — the pipeline is
deterministic, see data/pipeline.py).

Loss-recovery state (DESIGN §8) checkpoints as ordinary tree leaves: with
``--recovery`` on, the launcher saves ``(params, opt_state, rec_state)``
so a resume under error feedback continues from the carried residual and
stale cache instead of silently dropping the undelivered gradient mass.
The manifest's leaf-count guard in :func:`restore` rejects a resume whose
``--recovery`` setting (and therefore tree shape) changed.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> tuple[list, Any]:
    return jax.tree.flatten(tree)


def save(ckpt_dir: str, step: int, tree: Any, *, meta: dict | None = None,
         keep: int = 3) -> str:
    """Synchronous checkpoint write. Returns the step directory."""
    leaves, treedef = _flatten(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "meta": meta or {},
        "process_index": jax.process_index(),
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp_dir, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append(
            {"dtype": str(arr.dtype), "shape": list(arr.shape)})
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # atomic rename makes partially-written checkpoints invisible to restore
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    _gc(ckpt_dir, keep)
    return step_dir


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


class AsyncCheckpointer:
    """Background-thread saver: the training loop never blocks on I/O.
    (The paper's snapshot safeguard, §3.4, uses the same mechanism.)"""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree: Any, meta: dict | None = None) -> None:
        self.wait()
        # device_get now so the trainer can donate/overwrite buffers
        host_tree = jax.tree.map(np.asarray, tree)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree),
            kwargs={"meta": meta, "keep": self.keep}, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, *, step: int | None = None
            ) -> tuple[int, Any, dict]:
    """Restore into the structure of ``tree_like``. Returns
    (step, tree, meta). Raises FileNotFoundError if nothing to restore."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        (manifest["n_leaves"], len(leaves_like))
    leaves = [np.load(os.path.join(step_dir, f"leaf_{i:05d}.npy"))
              for i in range(manifest["n_leaves"])]
    return step, jax.tree.unflatten(treedef, leaves), manifest["meta"]
