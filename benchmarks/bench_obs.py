"""Tracing overhead (DESIGN §12): the cost of the observability layer.

Measures the contract the obs subsystem makes to every hot path it
instruments:

* ``disabled_gate_us`` — one ``get_tracer()`` + ``is not None`` check with
  tracing OFF: the price every instrumented call site pays all the time.
  Must stay in the low tens of ns.
* ``enabled_complete_us`` / ``enabled_event_us`` — one ring-buffer record
  with tracing ON (span with explicit ts/dur; instant event).
* ``hist_record_us`` — one :class:`TailHistogram` sample.
* ``wire_step_untraced/traced_median_us`` — a real 4-peer inproc HostRing
  allreduce step, tracing off vs on: the end-to-end overhead on the wire
  datapath the acceptance criterion bounds.

All medians carry ``_iqr_us`` dispersion siblings per the run.py schema.
"""
from __future__ import annotations

import time

import numpy as np

from .common import Rows


def _median_iqr(samples_us) -> tuple[float, float]:
    a = np.asarray(samples_us, np.float64)
    q1, med, q3 = np.percentile(a, [25, 50, 75])
    return float(med), float(q3 - q1)


def _per_call_us(fn, calls: int, reps: int) -> tuple[float, float]:
    """Median + IQR of per-call cost over ``reps`` timed batches."""
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(calls)
        samples.append((time.perf_counter() - t0) * 1e6 / calls)
    return _median_iqr(samples)


def _bench_primitives(rows: Rows, *, calls: int, reps: int) -> None:
    from repro.obs import TailHistogram, trace

    trace.reset()
    get_tracer = trace.get_tracer

    def disabled_gate(n):
        for _ in range(n):
            tr = get_tracer()
            if tr is not None:
                tr.event("x", "bench")
    med, iqr = _per_call_us(disabled_gate, calls, reps)
    rows.add("obs/disabled_gate_median_us", med,
             "get_tracer()+None check, tracing off")
    rows.add("obs/disabled_gate_iqr_us", iqr, "")

    tr = trace.configure(True, capacity=1 << 14)

    def enabled_complete(n):
        for i in range(n):
            tr.complete("round", "bench", ts=float(i), dur=1.0, tid=0,
                        args={"round": i})
    med, iqr = _per_call_us(enabled_complete, calls, reps)
    rows.add("obs/enabled_complete_median_us", med,
             "one X record into the ring")
    rows.add("obs/enabled_complete_iqr_us", iqr, "")

    def enabled_event(n):
        for i in range(n):
            tr.event("tick", "bench")
    med, iqr = _per_call_us(enabled_event, calls, reps)
    rows.add("obs/enabled_event_median_us", med,
             "one instant record into the ring")
    rows.add("obs/enabled_event_iqr_us", iqr, "")
    trace.reset()

    h = TailHistogram()
    vals = np.random.default_rng(0).lognormal(0.0, 1.0, calls)

    def hist_record(n):
        for i in range(n):
            h.record(vals[i])
    med, iqr = _per_call_us(hist_record, calls, reps)
    rows.add("obs/hist_record_median_us", med,
             "one TailHistogram sample (log-bucketed)")
    rows.add("obs/hist_record_iqr_us", iqr, "")


def _wire_step_us(ring, buckets, key, steps: int) -> list[float]:
    out = []
    for s in range(steps):
        t0 = time.perf_counter()
        ring.allreduce(buckets, key, step=s)
        out.append((time.perf_counter() - t0) * 1e6)
    return out


def _bench_wire(rows: Rows, *, steps: int) -> None:
    import jax

    from repro.core.pipeline import OptiReduceConfig
    from repro.net import HostRing
    from repro.obs import trace

    n, elems = 4, 4096
    cfg = OptiReduceConfig(strategy="optireduce", hadamard_block=256)
    key = jax.random.PRNGKey(0)
    buckets = np.random.default_rng(1).standard_normal(
        (n, elems)).astype(np.float32)

    trace.reset()
    ring = HostRing(n, cfg, backend="inproc")
    _wire_step_us(ring, buckets, key, 2)          # jit warmup, uncounted
    untraced = _wire_step_us(ring, buckets, key, steps)
    ring.close()
    med_u, iqr_u = _median_iqr(untraced)
    rows.add("obs/wire_step_untraced_median_us", med_u,
             f"4-peer inproc allreduce of {elems} fp32, tracing off")
    rows.add("obs/wire_step_untraced_iqr_us", iqr_u, "")

    trace.configure(True, capacity=1 << 16)
    ring = HostRing(n, cfg, backend="inproc")
    _wire_step_us(ring, buckets, key, 2)
    traced = _wire_step_us(ring, buckets, key, steps)
    ring.close()
    trace.reset()
    med_t, iqr_t = _median_iqr(traced)
    rows.add("obs/wire_step_traced_median_us", med_t,
             "same step, tracing on (round+phase spans recorded)")
    rows.add("obs/wire_step_traced_iqr_us", iqr_t, "")
    rows.add("obs/wire_step_overhead_pct",
             100.0 * (med_t - med_u) / max(med_u, 1e-9),
             "traced vs untraced median")


def run(quick: bool = True) -> Rows:
    rows = Rows()
    _bench_primitives(rows, calls=2000 if quick else 20000,
                      reps=9 if quick else 21)
    _bench_wire(rows, steps=6 if quick else 30)
    return rows
