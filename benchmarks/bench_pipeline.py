"""End-to-end sync_pytree timing: the fused BucketPlan engine in its
``scan`` and stage-skewed ``pipelined`` schedules vs the seed per-bucket
Python loop, swept over bucket counts (``vmap`` is correctness-pinned by
the parity suite but not timed here).

Three costs are reported per (variant, B):

  trace_ms   — trace + lower time (the O(#buckets) HLO-growth tax the
               BucketPlan removes; this is host time paid on EVERY reshape
               of the step function)
  hlo_kb     — lowered module size (proxy for compile time / program cache
               pressure at production scale)
  steady_us  — steady-state wall time per call: the MEDIAN over >= 20 reps
               (single-shot means were noisy enough to invert B1 vs B2
               orderings between runs), with the interquartile range
               emitted as a ``steady_iqr_us`` dispersion row per steady
               row so trajectory diffs can tell signal from scheduler noise
               (run.py's schema rejects a steady row without its dispersion
               sibling)

plus derived per-bucket overhead slopes (d(steady)/dB via the
(B_max, B_min) secant) and the headline
``pipeline/pipelined_vs_scan_steady_pct`` — the steady-state delta of the
software-pipelined schedule vs the serial scan at the largest swept B
(on the single-device CI box the collectives are degenerate, so this mostly
prices the skew bookkeeping; the overlap win needs a real fabric).

Run via ``python -m benchmarks.run --only bench_pipeline``; ``run.py`` also
serializes these rows to BENCH_pipeline.json at the repo root so future PRs
can diff the perf trajectory mechanically.
"""
from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp

from repro.compat import make_mesh, shard_map
from repro.core import (OptiReduceConfig, SyncContext, sync_pytree,
                        sync_pytree_unfused)
from jax.sharding import PartitionSpec as P

from .common import Rows

BUCKET = 4096

VARIANTS = (("fused", "scan"),            # historical row name for scan mode
            ("pipelined", "pipelined"),
            ("unfused", None))


def _build(nbuckets: int, strategy: str = "optireduce",
           mode: str | None = "scan"):
    mesh = make_mesh((1,), ("data",))
    cfg = OptiReduceConfig(strategy=strategy, drop_rate=0.0,
                           hadamard_block=256)
    tree = {"g": jnp.zeros((nbuckets * BUCKET,), jnp.float32)}
    spec = {"g": P()}

    def body(t):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(0))
        if mode is None:
            return sync_pytree_unfused(t, ctx, bucket_elems=BUCKET)
        return sync_pytree(t, ctx, bucket_elems=BUCKET, mode=mode)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                          check_vma=False))
    return f, tree


def _measure(nbuckets: int, reps: int, strategy: str = "optireduce",
             mode: str | None = "scan"):
    """Returns (trace_ms, hlo_kb, steady_med_us, steady_iqr_us)."""
    f, tree = _build(nbuckets, strategy, mode)
    t0 = time.perf_counter()
    lowered = f.lower(tree)
    trace_ms = (time.perf_counter() - t0) * 1e3
    hlo_kb = len(lowered.as_text()) / 1024
    # reuse the lowering (calling f would re-trace the whole pipeline)
    compiled = lowered.compile()
    jax.block_until_ready(compiled(tree))             # warmup
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(tree))
        times.append((time.perf_counter() - t0) * 1e6)
    med = statistics.median(times)
    q = statistics.quantiles(times, n=4)
    return trace_ms, hlo_kb, med, q[2] - q[0]


def run(quick: bool = True) -> Rows:
    rows = Rows()
    counts = [1, 2, 4, 8] if quick else [1, 2, 4, 8, 16, 32]
    reps = 20 if quick else 40          # median needs >= 20 reps either way
    steady = {}
    for name, mode in VARIANTS:
        for b in counts:
            trace_ms, hlo_kb, med_us, iqr_us = _measure(b, reps, mode=mode)
            steady[(name, b)] = med_us
            rows.add(f"pipeline/{name}_B{b}_trace_ms", trace_ms,
                     "trace+lower host time")
            rows.add(f"pipeline/{name}_B{b}_hlo_kb", hlo_kb,
                     "lowered module size")
            rows.add(f"pipeline/{name}_B{b}_steady_us", med_us,
                     f"wall us/call, median of {reps} reps")
            rows.add(f"pipeline/{name}_B{b}_steady_iqr_us", iqr_us,
                     f"interquartile range of the {reps} reps")
    b_lo, b_hi = counts[0], counts[-1]
    slopes = {}
    for name, _ in VARIANTS:
        slopes[name] = ((steady[(name, b_hi)] - steady[(name, b_lo)])
                        / (b_hi - b_lo))
        rows.add(f"pipeline/{name}_per_bucket_us", slopes[name],
                 f"d(steady)/dB secant over B={b_lo}..{b_hi}")
    if slopes["unfused"] > 0:
        rows.add("pipeline/per_bucket_overhead_reduction_pct",
                 100.0 * (1 - slopes["fused"] / slopes["unfused"]),
                 "fused vs seed loop (higher is better)")
    rows.add("pipeline/pipelined_vs_scan_steady_pct",
             100.0 * (1 - steady[("pipelined", b_hi)]
                      / steady[("fused", b_hi)]),
             f"pipelined vs scan steady median at B={b_hi} "
             "(positive = pipelined faster; CI box has degenerate "
             "collectives, so this prices skew bookkeeping only)")
    # composable-pipeline specs: the same fused engine over other registry
    # entries (the quantized exchange and a register_strategy'd composition)
    # — tracks the trace/steady cost of the Topology x Transport x Codec
    # dispatch vs the plain optireduce spec above
    b_spec = 4
    for strat in ("optireduce_q", "optireduce_rounds"):
        trace_ms, hlo_kb, med_us, iqr_us = _measure(b_spec, reps,
                                                    strategy=strat)
        rows.add(f"pipeline/spec_{strat}_B{b_spec}_trace_ms", trace_ms,
                 "trace+lower host time, fused engine")
        rows.add(f"pipeline/spec_{strat}_B{b_spec}_steady_us", med_us,
                 f"wall us/call, median of {reps} reps")
        rows.add(f"pipeline/spec_{strat}_B{b_spec}_steady_iqr_us", iqr_us,
                 f"interquartile range of the {reps} reps")
    return rows


if __name__ == "__main__":
    run(quick=False)
