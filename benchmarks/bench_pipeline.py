"""End-to-end sync_pytree timing: fused BucketPlan engine (one lax.scan'd
strategy body) vs the seed per-bucket Python loop, swept over bucket counts.

Three costs are reported per (variant, B):

  trace_ms   — trace + lower time (the O(#buckets) HLO-growth tax the
               BucketPlan removes; this is host time paid on EVERY reshape
               of the step function)
  hlo_kb     — lowered module size (proxy for compile time / program cache
               pressure at production scale)
  steady_us  — steady-state wall time per call (dispatch + compute)

plus derived per-bucket overhead slopes: d(steady)/dB via the (B_max, B_min)
secant, which is the per-bucket host/dispatch cost the scan amortizes.

Run via ``python -m benchmarks.run --only bench_pipeline``; ``run.py`` also
serializes these rows to BENCH_pipeline.json at the repo root so future PRs
can diff the perf trajectory mechanically.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.compat import make_mesh, shard_map
from repro.core import (OptiReduceConfig, SyncContext, sync_pytree,
                        sync_pytree_unfused)
from jax.sharding import PartitionSpec as P

from .common import Rows

BUCKET = 4096


def _build(fn, nbuckets: int, strategy: str = "optireduce"):
    mesh = make_mesh((1,), ("data",))
    cfg = OptiReduceConfig(strategy=strategy, drop_rate=0.0,
                           hadamard_block=256)
    tree = {"g": jnp.zeros((nbuckets * BUCKET,), jnp.float32)}
    spec = {"g": P()}

    def body(t):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(0))
        return fn(t, ctx, bucket_elems=BUCKET)

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                          check_vma=False))
    return f, tree


def _measure(fn, nbuckets: int, reps: int, strategy: str = "optireduce"):
    f, tree = _build(fn, nbuckets, strategy)
    t0 = time.perf_counter()
    lowered = f.lower(tree)
    trace_ms = (time.perf_counter() - t0) * 1e3
    hlo_kb = len(lowered.as_text()) / 1024
    # reuse the lowering (calling f would re-trace the whole pipeline)
    compiled = lowered.compile()
    jax.block_until_ready(compiled(tree))             # warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(compiled(tree))
    steady_us = (time.perf_counter() - t0) / reps * 1e6
    return trace_ms, hlo_kb, steady_us


def run(quick: bool = True) -> Rows:
    rows = Rows()
    counts = [1, 2, 4, 8] if quick else [1, 2, 4, 8, 16, 32]
    reps = 5 if quick else 20
    steady = {}
    for name, fn in (("fused", sync_pytree),
                     ("unfused", sync_pytree_unfused)):
        for b in counts:
            trace_ms, hlo_kb, steady_us = _measure(fn, b, reps)
            steady[(name, b)] = steady_us
            rows.add(f"pipeline/{name}_B{b}_trace_ms", trace_ms,
                     "trace+lower host time")
            rows.add(f"pipeline/{name}_B{b}_hlo_kb", hlo_kb,
                     "lowered module size")
            rows.add(f"pipeline/{name}_B{b}_steady_us", steady_us,
                     f"wall us/call, {reps} reps")
    b_lo, b_hi = counts[0], counts[-1]
    slopes = {}
    for name in ("fused", "unfused"):
        slopes[name] = ((steady[(name, b_hi)] - steady[(name, b_lo)])
                        / (b_hi - b_lo))
        rows.add(f"pipeline/{name}_per_bucket_us", slopes[name],
                 f"d(steady)/dB secant over B={b_lo}..{b_hi}")
    if slopes["unfused"] > 0:
        rows.add("pipeline/per_bucket_overhead_reduction_pct",
                 100.0 * (1 - slopes["fused"] / slopes["unfused"]),
                 "fused vs seed loop (higher is better)")
    # composable-pipeline specs: the same fused engine over other registry
    # entries (the quantized exchange and a register_strategy'd composition)
    # — tracks the trace/steady cost of the Topology x Transport x Codec
    # dispatch vs the plain optireduce spec above
    b_spec = 4
    for strat in ("optireduce_q", "optireduce_rounds"):
        trace_ms, hlo_kb, steady_us = _measure(sync_pytree, b_spec, reps,
                                               strategy=strat)
        rows.add(f"pipeline/spec_{strat}_B{b_spec}_trace_ms", trace_ms,
                 "trace+lower host time, fused engine")
        rows.add(f"pipeline/spec_{strat}_B{b_spec}_steady_us", steady_us,
                 f"wall us/call, {reps} reps")
    return rows


if __name__ == "__main__":
    run(quick=False)
