"""Loss-recovery ablation (DESIGN §8): BENCH_recovery.json.

One question: how much of the dropped gradient mass does each recovery
mechanism actually get back?  Measured as the MSE between the *cumulative*
applied update and the cumulative true mean over T emulated steps — the
quantity the optimizer integrates, so a mechanism that merely delays mass
(error feedback) scores near-lossless while one that discards it (zero
fill) accumulates a random walk of error.

Emulation (value-space, mirrors core/recovery.py exactly):

  zero   — the seed's compensated masked mean: renormalize over the
           senders that arrived, zero where nobody did.
  stale  — cross-step prediction: every lost (sender, span) entry is
           filled with the previous step's decoded mean, plain mean
           over all N.
  ef     — stale + error feedback: each sender carries the gap between
           its contribution and the stale fill applied in its stead,
           ``(1-m) * (contrib - stale)``, into the next step.

Per-peer gradients follow an AR(1) common signal plus peer noise — the
temporal correlation that makes last step's mean a useful prediction, at a
realistic signal-to-noise ratio.  Masks come from ``core/drops.make_mask``
(the same synthetic-Lossy draw the trainer consumes), swept over the
bernoulli and burst (Gilbert–Elliott) patterns at rates down to 1%.

Keys: ``recovery/{pattern}_r{pct}/{mech}_mse_median`` with the schema's
``_mse_iqr`` dispersion sibling (run.py validates the pairing).

Run via ``python -m benchmarks.run --only bench_recovery``;
``REPRO_BENCH_DIR`` redirects the JSON (the CI smoke test uses a tmpdir).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import drops as drops_lib

from .common import Rows

MECHS = ("zero", "stale", "ef")
PATTERNS = ("bernoulli", "burst")


def _step_masks(pattern: str, rate: float, n: int, length: int,
                steps: int) -> np.ndarray:
    """(T, n, L) arrival masks — one independent draw per emulated step,
    keyed like the sync engine (fold_in by step), receiver's own row (0)
    forced present as in the trainer's ``_mask_for``."""
    key = jax.random.PRNGKey(7)

    def one(t):
        return drops_lib.make_mask(pattern, jax.random.fold_in(key, t),
                                   n, length, rate=rate, packet_elems=64,
                                   self_index=0)
    masks = jax.vmap(one)(np.arange(steps, dtype=np.uint32))
    return np.asarray(masks, np.float32)


def _cumulative_mse(mech: str, grads: np.ndarray,
                    masks: np.ndarray) -> np.ndarray:
    """Per-step MSE between cumulative applied update and cumulative true
    mean. ``grads``/(T, n, L), ``masks``/(T, n, L) -> (T,)."""
    steps, n, length = grads.shape
    stale = np.zeros(length, np.float32)
    ef = np.zeros((n, length), np.float32)
    cum_applied = np.zeros(length, np.float64)
    cum_true = np.zeros(length, np.float64)
    mse = np.empty(steps, np.float64)
    for t in range(steps):
        g, m = grads[t], masks[t]
        contrib = g + ef if mech == "ef" else g
        if mech == "zero":
            cnt = m.sum(0)
            applied = np.where(cnt > 0, (m * contrib).sum(0)
                               / np.maximum(cnt, 1.0), 0.0)
        else:  # fill-then-plain-mean (StaleFill.reduce)
            applied = np.mean(m * contrib + (1.0 - m) * stale[None], 0)
            if mech == "ef":
                # ef_residual, Identity codec: gap vs the pre-update stale
                ef = (1.0 - m) * (contrib - stale[None])
            stale = applied.astype(np.float32)
        cum_applied += applied
        cum_true += g.mean(0)
        mse[t] = np.mean((cum_applied - cum_true) ** 2)
    return mse


def run(quick: bool = True) -> Rows:
    rows = Rows()
    n, length = 8, 4096
    steps = 60 if quick else 200
    rng = np.random.default_rng(11)

    # AR(1) common signal + peer noise: per-peer gradients correlated in
    # time (prediction has something to predict) and across peers (the
    # mean is meaningful), at sigma ratios typical of mid-training
    sig = np.zeros(length, np.float32)
    grads = np.empty((steps, n, length), np.float32)
    for t in range(steps):
        sig = 0.9 * sig + 0.45 * rng.standard_normal(length).astype(
            np.float32)
        grads[t] = sig[None] + 0.3 * rng.standard_normal(
            (n, length)).astype(np.float32)

    for pattern in PATTERNS:
        for rate in (0.01, 0.05):
            masks = _step_masks(pattern, rate, n, length, steps)
            lost = float(1.0 - masks.mean())
            pct = int(round(rate * 100))
            for mech in MECHS:
                mse = _cumulative_mse(mech, grads, masks)
                rows.add(f"recovery/{pattern}_r{pct}/{mech}_mse_median",
                         float(np.median(mse)),
                         f"cumulative-update MSE vs true mean, {n} peers x "
                         f"{steps} steps, {pattern} loss {rate:g} "
                         f"(realized {lost:.3f})")
                rows.add(f"recovery/{pattern}_r{pct}/{mech}_mse_iqr",
                         float(np.percentile(mse, 75)
                               - np.percentile(mse, 25)),
                         "dispersion sibling")
    return rows


if __name__ == "__main__":
    run()
