"""Paper §5.3 microbenchmark: gradient MSE by AllReduce topology under a
best-effort transport. Paper numbers (500M tensor, P99/50=1.5):
Ring 14.55, PS 9.92, TAR 2.47 — Ring ~6x TAR, PS ~4x TAR.

The dataflow pathologies are reproduced exactly:
  * Ring: a dropped hop loses the *accumulated partial sum* (k prior
    contributions), and reduce-scatter losses propagate through the
    all-gather phase to every node.
  * PS: incast at the server inflates the drop probability (x4 here).
  * TAR: a drop costs exactly one (sender, receiver) shard contribution.
"""
from __future__ import annotations

import numpy as np

from .common import Rows


def _packet_mask(rng, n_elems, rate, packet=256):
    n_pkts = -(-n_elems // packet)
    keep = (rng.random(n_pkts) >= rate).astype(np.float32)
    return np.repeat(keep, packet)[:n_elems]


def simulate(n=8, length=1 << 16, rate=0.01, incast_factor=4.0, seed=0,
             trials=4):
    rng = np.random.default_rng(seed)
    out = {"ring": [], "ps": [], "tar": []}
    for _ in range(trials):
        g = rng.standard_normal((n, length)).astype(np.float32)
        true = g.mean(0)
        chunk = length // n

        # ---- Ring: reduce-scatter with per-hop loss of partial sums -----
        acc = g.reshape(n, n, chunk).copy()   # acc[node, chunk_idx]
        for h in range(n - 1):
            sends = np.stack([acc[i, (i - h) % n] for i in range(n)])
            for i in range(n):
                m = _packet_mask(rng, chunk, rate)
                prev = (i - 1) % n
                acc[i, (i - h - 1) % n] += sends[prev] * m
        owned = np.stack([acc[i, (i + 1) % n] for i in range(n)]) / n
        # all-gather ring with losses
        result = np.zeros((n, n, chunk), np.float32)
        cur = owned.copy()
        for i in range(n):
            result[i, (i + 1) % n] = owned[i]
        for h in range(n - 1):
            nxt = np.zeros_like(cur)
            for i in range(n):
                m = _packet_mask(rng, chunk, rate)
                nxt[i] = cur[(i - 1) % n] * m
                result[i, (i - h) % n] = nxt[i]
            cur = nxt
        ring_out = result.reshape(n, length)
        out["ring"].append(np.mean((ring_out - true[None]) ** 2))

        # ---- PS: incast-inflated drops at the server ---------------------
        up = np.stack([g[i] * _packet_mask(rng, length,
                                           min(rate * incast_factor, 0.5))
                       for i in range(n)])
        agg = up.sum(0) / n
        down = np.stack([agg * _packet_mask(rng, length, rate)
                         for _ in range(n)])
        out["ps"].append(np.mean((down - true[None]) ** 2))

        # ---- TAR: direct P2P shard exchange ------------------------------
        tar_out = np.zeros((n, length), np.float32)
        aggs = []
        for r in range(n):  # receiver aggregates its shard
            sh = g[:, r * chunk:(r + 1) * chunk]
            m = np.stack([_packet_mask(rng, chunk, rate) if i != r
                          else np.ones(chunk, np.float32)
                          for i in range(n)])
            aggs.append((sh * m).sum(0) / n)
        for i in range(n):  # broadcast stage
            parts = []
            for r in range(n):
                m = (_packet_mask(rng, chunk, rate) if r != i
                     else np.ones(chunk, np.float32))
                parts.append(aggs[r] * m)
            tar_out[i] = np.concatenate(parts)
        out["tar"].append(np.mean((tar_out - true[None]) ** 2))
    return {k: float(np.mean(v)) for k, v in out.items()}


def run(quick: bool = True) -> Rows:
    rows = Rows()
    res = simulate(length=1 << 15 if quick else 1 << 18,
                   rate=0.01, trials=3 if quick else 8)
    scale = 1e4
    rows.add("mse_topology/ring", res["ring"] * scale,
             "x1e-4; paper 14.55")
    rows.add("mse_topology/ps", res["ps"] * scale, "x1e-4; paper 9.92")
    rows.add("mse_topology/tar", res["tar"] * scale, "x1e-4; paper 2.47")
    rows.add("mse_topology/ring_over_tar", res["ring"] / res["tar"],
             "paper ~5.9x (ring propagates accumulated loss)")
    rows.add("mse_topology/ps_over_tar", res["ps"] / res["tar"],
             "paper ~4.0x (incast)")
    return rows


if __name__ == "__main__":
    run(quick=False)
