"""Paper Fig 11 + Table 1: GPT-2 time-to-accuracy across environments.

TTA = steps-to-accuracy (real training of the reduced GPT-2 family config;
identical gradient content for all reliable collectives, drop-injected for
OptiReduce at the simulator's observed loss) x per-step wall-clock
(calibrated network simulator; GPT-2 base: ~497 MB fp32 grads in 25 MB
buckets, two concurrent GAs overlapping backprop).

Paper reference (minutes, 8 nodes): see derived column.
"""
from __future__ import annotations

import numpy as np

from repro.sim.netsim import NetworkModel, simulate_job
from repro.sim.tta import TrainRunConfig, run_training, steps_to_accuracy

from .common import Rows

PAPER_MIN = {  # Table 1 (OpenAI GPT-2)
    "local_1.5": {"gloo_ring": 154, "bcube": 172, "nccl_ring": 118,
                  "nccl_tree": 105, "tar_tcp": 148, "optireduce": 96},
    "local_3.0": {"gloo_ring": 186, "bcube": 210, "nccl_ring": 159,
                  "nccl_tree": 135, "tar_tcp": 166, "optireduce": 97},
    "cloudlab":  {"gloo_ring": 88, "bcube": 100, "nccl_ring": 71,
                  "nccl_tree": 79, "tar_tcp": 90, "optireduce": 60},
}

GRAD_BYTES = 124e6 * 4          # GPT-2 base fp32 gradients
BUCKET = 25 * 2 ** 20
COMPUTE_MS = 180.0              # fwd+bwd per step (V100-class, batch 32)
CONCURRENT_GA = 2               # paper/PyTorch: two in-flight buckets


def step_time_ms(strategy: str, env: NetworkModel, n_steps: int) -> dict:
    n_buckets = int(np.ceil(GRAD_BYTES / BUCKET))
    r = simulate_job(strategy, n_nodes=8, bucket_bytes=BUCKET,
                     n_steps=n_steps * n_buckets, env=env,
                     compute_ms=0.0, overlap=0.0)
    per_step_ga = r["mean_ga_ms"] * n_buckets / CONCURRENT_GA
    # GA overlaps the backward pass (Fig 1): only the excess is exposed
    exposed = max(0.0, per_step_ga - 0.6 * COMPUTE_MS)
    return {"step_ms": COMPUTE_MS + exposed, "ga_ms": per_step_ga,
            "drop": r["mean_drop"]}


def run(quick: bool = True) -> Rows:
    rows = Rows()
    steps = 150 if quick else 400
    target_frac = 0.95

    base = run_training(TrainRunConfig(steps=steps, eval_every=10))
    target = target_frac * max(base["acc"])
    s_reliable = steps_to_accuracy(base, target) or steps
    # OptiReduce trains under its own (tail-pattern) drops
    opti_hist = run_training(TrainRunConfig(
        steps=steps, eval_every=10, drop_rate=0.002, use_hadamard=True))
    s_opti = steps_to_accuracy(opti_hist, target) or steps
    rows.add("tta/steps_reliable", s_reliable, f"to {target:.3f} top-1")
    rows.add("tta/steps_optireduce", s_opti,
             "same target under ~0.1-0.2% tail drops + HT")

    sim_steps = 40 if quick else 150
    for envname, paper in PAPER_MIN.items():
        res = {}
        for strat in ("gloo_ring", "bcube", "nccl_ring", "nccl_tree",
                      "tar_tcp", "optireduce"):
            env = NetworkModel.environment(envname, seed=11)
            st = step_time_ms(strat, env, sim_steps)
            n_steps = s_opti if strat == "optireduce" else s_reliable
            # scale the measured steps to the paper's training length
            tta_min = st["step_ms"] * n_steps * 250 / 60e3
            res[strat] = tta_min
            rows.add(f"tta/{envname}/{strat}_min", round(tta_min, 1),
                     f"paper {paper[strat]} min; drop={st['drop']:.5f}")
        o = res["optireduce"]
        for strat in ("gloo_ring", "nccl_tree"):
            rows.add(f"tta/{envname}/{strat}_vs_opti", res[strat] / o,
                     f"paper {paper[strat]/paper['optireduce']:.2f}x")
    return rows


if __name__ == "__main__":
    run(quick=False)
