"""Paper Fig 16: lossy/compression baselines (Top-K, TernGrad, THC) vs
OptiReduce. Compression shrinks bytes *statically* but tail/stall events
hit the (fewer) flows just the same, so TTA barely improves — while some
schemes also pay an accuracy cost. OptiReduce adapts at run time."""
from __future__ import annotations

import numpy as np

from repro.sim.netsim import NetworkModel, simulate_job
from repro.sim.tta import TrainRunConfig, run_training, steps_to_accuracy

from .common import Rows

BYTES_FACTOR = {            # wire bytes vs fp32 allreduce
    "optireduce": 1.0,
    "topk": 0.02 * 2.0,     # 1% values + indices
    "terngrad": 2.0 / 32.0,
    "thc": 4.0 / 32.0,      # 4-bit codes
}


def run(quick: bool = True) -> Rows:
    rows = Rows()
    steps = 150 if quick else 400
    base = run_training(TrainRunConfig(steps=steps, eval_every=10))
    target = 0.95 * max(base["acc"])

    runs = {
        "optireduce": TrainRunConfig(steps=steps, eval_every=10,
                                     drop_rate=0.002),
        "topk": TrainRunConfig(steps=steps, eval_every=10,
                               compressor="topk", topk_frac=0.01),
        "terngrad": TrainRunConfig(steps=steps, eval_every=10,
                                   compressor="terngrad"),
        "thc": TrainRunConfig(steps=steps, eval_every=10, compressor="thc"),
    }
    nbytes = 25 * 2 ** 20
    sim_steps = 60 if quick else 200
    for name, rc in runs.items():
        hist = run_training(rc)
        s = steps_to_accuracy(hist, target)
        acc = max(hist["acc"])
        env = NetworkModel.environment("local_3.0", seed=13)
        strat = "optireduce" if name == "optireduce" else "gloo_ring"
        r = simulate_job(strat, n_nodes=8,
                         bucket_bytes=nbytes * BYTES_FACTOR[name],
                         n_steps=sim_steps, env=env, compute_ms=0.0,
                         overlap=0.0)
        tta = (s if s else steps * 2) * r["mean_ga_ms"]
        rows.add(f"compression/{name}_acc", acc,
                 f"target {target:.3f}; steps_to_target="
                 f"{s if s else 'not reached'}")
        rows.add(f"compression/{name}_rel_tta", tta, "ms of GA to target; "
                 "paper Fig16: compression doesn't fix tails")
    return rows


if __name__ == "__main__":
    run(quick=False)
