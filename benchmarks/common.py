"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import time


class Rows:
    """Collects CSV rows: name,value,derived."""

    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, value, derived: str = ""):
        self.rows.append((name, float(value), derived))
        print(f"{name},{value},{derived}", flush=True)

    def extend(self, other: "Rows"):
        self.rows.extend(other.rows)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
