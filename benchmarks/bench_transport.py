"""Host wire transport benchmarks (DESIGN §7): BENCH_transport.json.

Three questions, all answered on the *host* datapath (repro/net):

  round latency   — wall time of one full over-the-wire TAR allreduce
                    (encode -> packetized stage-1 exchange -> compensated
                    reduce -> stage-2 broadcast -> decode) on the inproc
                    loopback and, where the sandbox allows socket binding,
                    on real localhost UDP; medians over >= 15 reps with
                    ``*_iqr_ms`` dispersion siblings (run.py schema).
  loss fidelity   — scripted per-packet loss rate swept against the
                    *observed* ``loss_fraction`` of the reassembled masks
                    (the wire's drop bookkeeping must report what the
                    schedule injected; the mask is what training consumes).
  codec overhead  — packetize + reassemble round-trip per bucket size (the
                    pure wire-format tax, no sockets, no jax).
  fan-in scale    — round latency at 16/32/64 peers (inproc; UDP to 32 —
                    the single-process localhost ceiling) at fixed per-peer
                    payload: the n² cost curve elastic membership pays.

UDP rows are always emitted so the BENCH key set never shrinks between
runs (run.py's shape gate); in a sandbox that forbids sockets they carry
value 0 and derived ``udp-unavailable``.

Run via ``python -m benchmarks.run --only bench_transport``;
``REPRO_BENCH_DIR`` redirects the JSON (the CI smoke test uses a tmpdir).
"""
from __future__ import annotations

import statistics
import time

import jax
import numpy as np

from repro.core.allreduce import OptiReduceConfig
from repro.net import (HostRing, Reassembly, bernoulli_drops, packetize,
                       udp_available)
from repro.net.wire import KIND_DATA1, PacketHeader

from .common import Rows


def _iqr(xs) -> float:
    return float(np.percentile(xs, 75) - np.percentile(xs, 25))


def _cfg(packet_elems: int = 256) -> OptiReduceConfig:
    return OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                            hadamard_block=256, packet_elems=packet_elems)


def _ring_latency(backend: str, n: int, elems: int, reps: int, key,
                  deadline: float | None = None) -> tuple[float, float]:
    if deadline is None:
        deadline = 1.0 if backend == "inproc" else 0.5
    ring = HostRing(n, _cfg(), backend=backend, default_deadline=deadline)
    buckets = np.random.default_rng(0).standard_normal(
        (n, elems)).astype(np.float32)
    try:
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            ring.allreduce(buckets, key)
            times.append((time.perf_counter() - t0) * 1e3)
        # early reps pay per-peer jit tracing; steady is what the wire costs
        steady = times[5:] if len(times) > 8 else times
        return statistics.median(steady), _iqr(steady)
    finally:
        ring.close()


def _loss_sweep(rows: Rows, n: int, elems: int, rates, key,
                steps: int = 8) -> None:
    buckets = np.random.default_rng(1).standard_normal(
        (n, elems)).astype(np.float32)
    for rate in rates:
        ring = HostRing(n, _cfg(), backend="inproc",
                        drop_fn=bernoulli_drops(rate, seed=3))
        dropped = total = 0.0
        try:
            # drop draws are keyed on the packet header, so distinct step
            # ids give independent loss realizations to average over
            for s in range(steps):
                _, tel = ring.allreduce(buckets, key, step=s)
                dropped += tel.dropped
                total += tel.total
        finally:
            ring.close()
        rows.add(f"transport/loss_sweep_rate_{rate:g}_observed",
                 dropped / max(total, 1.0),
                 f"observed stage-1 loss_fraction at scripted per-packet "
                 f"rate {rate:g} ({n} peers x {steps} steps)")


def _reassembly_overhead(elems: int, packet_elems: int,
                         reps: int) -> tuple[float, float]:
    payload = np.random.default_rng(2).standard_normal(elems).astype(
        np.float32)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        pkts = packetize(payload, kind=KIND_DATA1, sender=0, step=0, bucket=0,
                         round=1, packet_elems=packet_elems)
        reas = Reassembly(elems, np.float32, packet_elems)
        for p in pkts:
            hdr, frag = PacketHeader.decode(p)
            reas.add(hdr, frag)
        assert reas.complete
        times.append((time.perf_counter() - t0) * 1e3)
    return statistics.median(times), _iqr(times)


#: peer counts for the fan-in scale rows.  Inproc covers the full ladder;
#: UDP stops at 32 — beyond that a single process multiplexing N sockets,
#: N receive threads and N jit contexts measures host oversubscription,
#: not the wire (the multi-process launcher is the 64+ story).
SCALE_PEERS = (16, 32, 64)
UDP_SCALE_PEERS = (16, 32)
SCALE_ELEMS = 4096


def _scale_rows(rows: Rows, key, reps: int) -> None:
    """Round latency vs peer count at fixed per-peer payload: the TAR
    schedule is all-to-all per stage, so wire work grows ~n² while the
    per-peer bucket stays put — the fan-in cost curve the elastic runtime
    (DESIGN §9) pays per extra member."""
    for n in SCALE_PEERS:
        med, iqr = _ring_latency("inproc", n, SCALE_ELEMS, reps, key)
        rows.add(f"transport/inproc_scale_{n}p_median_ms", med,
                 f"TAR allreduce, {n} peers, {SCALE_ELEMS} fp32/peer, "
                 f"median of {reps} reps")
        rows.add(f"transport/inproc_scale_{n}p_iqr_ms", iqr,
                 "dispersion sibling")
    for n in UDP_SCALE_PEERS:
        if udp_available():
            # a generous deadline keeps scheduler stalls at high fan-in
            # from masking packets (this measures latency, not loss)
            med, iqr = _ring_latency("udp", n, SCALE_ELEMS, reps, key,
                                     deadline=2.0)
            note = (f"localhost UDP sockets, {n} peers, {SCALE_ELEMS} "
                    f"fp32/peer, median of {reps} reps")
        else:
            med, iqr, note = 0.0, 0.0, "udp-unavailable"
        rows.add(f"transport/udp_scale_{n}p_median_ms", med, note)
        rows.add(f"transport/udp_scale_{n}p_iqr_ms", iqr,
                 "dispersion sibling" if note != "udp-unavailable"
                 else note)


def run(quick: bool = True) -> Rows:
    rows = Rows()
    key = jax.random.PRNGKey(0)
    n = 4
    reps = 15 if quick else 30
    sizes = [(16_384, "64KB")] if quick else [(16_384, "64KB"),
                                              (262_144, "1MB")]

    for elems, label in sizes:
        med, iqr = _ring_latency("inproc", n, elems, reps, key)
        rows.add(f"transport/inproc_{label}_roundtrip_median_ms", med,
                 f"full over-the-wire TAR allreduce, {n} peers, "
                 f"{elems} fp32/peer, median of {reps} reps")
        rows.add(f"transport/inproc_{label}_roundtrip_iqr_ms", iqr,
                 "dispersion sibling")
        if udp_available():
            umed, uiqr = _ring_latency("udp", n, elems, reps, key)
            u_note = f"localhost UDP sockets, same schedule ({reps} reps)"
        else:
            umed, uiqr, u_note = 0.0, 0.0, "udp-unavailable"
        rows.add(f"transport/udp_{label}_roundtrip_median_ms", umed, u_note)
        rows.add(f"transport/udp_{label}_roundtrip_iqr_ms", uiqr,
                 "dispersion sibling" if u_note != "udp-unavailable"
                 else u_note)

    _scale_rows(rows, key, reps=5 if quick else 9)

    _loss_sweep(rows, n, 16_384, (0.0, 0.01, 0.05), key)

    for elems, label in sizes:
        med, iqr = _reassembly_overhead(elems, 256, reps)
        rows.add(f"transport/reassembly_{label}_median_ms", med,
                 f"packetize + reassemble {elems} fp32 at 256 elems/packet")
        rows.add(f"transport/reassembly_{label}_iqr_ms", iqr,
                 "dispersion sibling")
    return rows


if __name__ == "__main__":
    run()
