"""Benchmark suite entrypoint — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows (plus section comments).

  python -m benchmarks.run            # quick mode (CI-sized)
  python -m benchmarks.run --full     # paper-sized sweeps
  python -m benchmarks.run --only bench_tta

Every module's rows are validated against a small schema (machine-readable
row keys, finite numeric values, non-empty, a ``_steady_iqr_us`` dispersion
sibling for every ``_steady_us`` timing row) and JSON-serialized modules are
additionally diffed against the previous BENCH_*.json of the same sweep
mode — a key that disappears is a regression-breaking shape change and the
suite exits non-zero (the perf trajectory across PRs is diffed mechanically;
see PERF.md).
"""
from __future__ import annotations

import argparse
import importlib
import json
import math
import os
import re
import sys
import time

MODULES = [
    "bench_mse_topology",     # §5.3 MSE micro (Ring/PS/TAR)
    "bench_hadamard_drops",   # Fig 9 + Fig 14
    "bench_incast",           # Fig 13
    "bench_timeout",          # §5.3 early-timeout ablation
    "bench_scaling",          # Fig 15
    "bench_tta",              # Fig 11 + Table 1
    "bench_compression",      # Fig 16
    "bench_kernels",          # §4 kernel layer parity/perf
    "bench_pipeline",         # fused BucketPlan sync engine vs seed loop
    "bench_transport",        # host wire transport (DESIGN §7)
    "bench_recovery",         # loss-recovery ablation (DESIGN §8)
    "bench_obs",              # tracing overhead (DESIGN §12)
]

# rows from these modules are serialized to BENCH_<name>.json at the repo
# root so the perf trajectory is machine-readable across PRs (see PERF.md)
JSON_MODULES = {"bench_kernels": "BENCH_kernels.json",
                "bench_pipeline": "BENCH_pipeline.json",
                "bench_timeout": "BENCH_timeout.json",
                "bench_transport": "BENCH_transport.json",
                "bench_recovery": "BENCH_recovery.json",
                "bench_obs": "BENCH_obs.json"}

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# machine-readable row keys: a path-like identifier, no spaces/commas (the
# CSV/JSON consumers split on them)
_KEY_RE = re.compile(r"^[A-Za-z0-9_][A-Za-z0-9_/.:+-]*$")


class BenchSchemaError(RuntimeError):
    """A bench emitted rows that downstream tooling cannot consume."""


def _validate_rows(name: str, rows) -> None:
    """Schema gate on a module's emitted rows (see module docstring)."""
    if not getattr(rows, "rows", None):
        raise BenchSchemaError(f"{name}: emitted no rows")
    for key, value, derived in rows.rows:
        if not isinstance(key, str) or not _KEY_RE.match(key):
            raise BenchSchemaError(
                f"{name}: row key {key!r} is not machine-readable "
                f"(must match {_KEY_RE.pattern})")
        if not isinstance(value, (int, float)) or not math.isfinite(value):
            raise BenchSchemaError(
                f"{name}: row {key!r} value {value!r} is not a finite number")
        if not isinstance(derived, str):
            raise BenchSchemaError(
                f"{name}: row {key!r} derived field must be a string")
    # timing summary rows must carry a dispersion sibling: a bare point
    # estimate is not diffable across PRs (single-shot noise once inverted
    # the bench_pipeline B1/B2 ordering). Every `X_steady_us` row needs the
    # matching `X_steady_iqr_us`, every `X_median_ms` row its `X_iqr_ms`
    # (the netsim-driven ablations report medians over steps), every
    # `X_median_us` row its `X_iqr_us` (the obs overhead rows), and every
    # `X_mse_median` row its `X_mse_iqr` (the recovery ablation).
    keys = {r[0] for r in rows.rows}
    for key in keys:
        sibling = None
        if key.endswith("_steady_us"):
            sibling = key[:-len("_steady_us")] + "_steady_iqr_us"
        elif key.endswith("_median_ms"):
            sibling = key[:-len("_median_ms")] + "_iqr_ms"
        elif key.endswith("_median_us"):
            sibling = key[:-len("_median_us")] + "_iqr_us"
        elif key.endswith("_mse_median"):
            sibling = key[:-len("_mse_median")] + "_mse_iqr"
        if sibling is not None and sibling not in keys:
            raise BenchSchemaError(
                f"{name}: summary row {key!r} lacks its dispersion "
                f"sibling {sibling!r}")


def _write_json(name: str, rows, *, full: bool) -> None:
    # REPRO_BENCH_DIR redirects the JSON (and its shape-gate baseline) away
    # from the repo root — the CI smoke test writes to a tmpdir so a test
    # run never rewrites the checked-in trajectory files
    out_dir = os.environ.get("REPRO_BENCH_DIR", _REPO_ROOT)
    path = os.path.join(out_dir, JSON_MODULES[name])
    payload = {r[0]: {"value": r[1], "derived": r[2]} for r in rows.rows}
    # record which sweep produced the file: quick- and full-mode rows have
    # different key sets / rep counts and must not be diffed against each
    # other across PRs
    payload["_meta"] = {"mode": "full" if full else "quick", "bench": name}
    previous = None
    if os.path.exists(path):
        try:
            with open(path) as fh:
                previous = json.load(fh)
        except (OSError, json.JSONDecodeError):
            previous = None
    # shape-regression gate: same-mode reruns may add keys but never lose
    # them (PR-over-PR diffs would silently stop covering the lost rows).
    # On regression the previous file stays the baseline (so a rerun cannot
    # self-accept the shrunken key set) and the offending payload goes to a
    # .rejected.json side file for inspection.
    if previous and previous.get("_meta", {}).get("mode") == \
            payload["_meta"]["mode"]:
        missing = sorted(set(previous) - set(payload) - {"_meta"})
        if missing:
            rejected = path + ".rejected.json"
            with open(rejected, "w") as fh:
                json.dump(payload, fh, indent=2, sort_keys=True)
                fh.write("\n")
            raise BenchSchemaError(
                f"{name}: keys disappeared from {JSON_MODULES[name]} "
                f"vs the previous {payload['_meta']['mode']} sweep: "
                f"{missing[:8]} (payload kept at {rejected})")
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {path}", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    mods = [args.only] if args.only else MODULES
    print("name,value,derived")
    failures = 0
    for name in mods:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run(quick=not args.full)
            if rows is not None:
                _validate_rows(name, rows)
            if name in JSON_MODULES and rows is not None:
                _write_json(name, rows, full=args.full)
        except Exception as e:  # keep the suite going
            failures += 1
            print(f"{name}/FAILED,0,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
