"""Benchmark suite entrypoint — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows (plus section comments).

  python -m benchmarks.run            # quick mode (CI-sized)
  python -m benchmarks.run --full     # paper-sized sweeps
  python -m benchmarks.run --only bench_tta
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    "bench_mse_topology",     # §5.3 MSE micro (Ring/PS/TAR)
    "bench_hadamard_drops",   # Fig 9 + Fig 14
    "bench_incast",           # Fig 13
    "bench_timeout",          # §5.3 early-timeout ablation
    "bench_scaling",          # Fig 15
    "bench_tta",              # Fig 11 + Table 1
    "bench_compression",      # Fig 16
    "bench_kernels",          # §4 kernel layer parity/perf
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    mods = [args.only] if args.only else MODULES
    print("name,value,derived")
    failures = 0
    for name in mods:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run(quick=not args.full)
        except Exception as e:  # keep the suite going
            failures += 1
            print(f"{name}/FAILED,0,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
