"""Benchmark suite entrypoint — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows (plus section comments).

  python -m benchmarks.run            # quick mode (CI-sized)
  python -m benchmarks.run --full     # paper-sized sweeps
  python -m benchmarks.run --only bench_tta
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

MODULES = [
    "bench_mse_topology",     # §5.3 MSE micro (Ring/PS/TAR)
    "bench_hadamard_drops",   # Fig 9 + Fig 14
    "bench_incast",           # Fig 13
    "bench_timeout",          # §5.3 early-timeout ablation
    "bench_scaling",          # Fig 15
    "bench_tta",              # Fig 11 + Table 1
    "bench_compression",      # Fig 16
    "bench_kernels",          # §4 kernel layer parity/perf
    "bench_pipeline",         # fused BucketPlan sync engine vs seed loop
]

# rows from these modules are serialized to BENCH_<name>.json at the repo
# root so the perf trajectory is machine-readable across PRs (see PERF.md)
JSON_MODULES = {"bench_pipeline": "BENCH_pipeline.json"}

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _write_json(name: str, rows, *, full: bool) -> None:
    path = os.path.join(_REPO_ROOT, JSON_MODULES[name])
    payload = {r[0]: {"value": r[1], "derived": r[2]} for r in rows.rows}
    # record which sweep produced the file: quick- and full-mode rows have
    # different key sets / rep counts and must not be diffed against each
    # other across PRs
    payload["_meta"] = {"mode": "full" if full else "quick", "bench": name}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {path}", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    mods = [args.only] if args.only else MODULES
    print("name,value,derived")
    failures = 0
    for name in mods:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run(quick=not args.full)
            if name in JSON_MODULES and rows is not None:
                _write_json(name, rows, full=args.full)
        except Exception as e:  # keep the suite going
            failures += 1
            print(f"{name}/FAILED,0,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
