"""Paper Fig 15 + Appendix A: OptiReduce speedup vs worker count (6..144
nodes) on a synthetic 500M-gradient AllReduce, P99/50 in {1.5, 3} — speedup
over Ring and BCube should hold ~2x in the high-tail environment as N grows;
hierarchical 2D TAR cuts the round count 2(N-1) -> 2(N/G-1)+(G-1) (App. A:
126 -> 21 at N=64, G=16)."""
from __future__ import annotations

import math

from repro.sim.netsim import GASimulator, NetworkModel, simulate_job

from .common import Rows


def _tar2d(n: int, groups: int, nbytes: float, steps: int, envname: str):
    env = NetworkModel.environment(envname, seed=n)
    sim = GASimulator(env, n, 0.62)
    control = sim.warmup(nbytes)
    total, drops, rounds = 0.0, 0.0, 0
    for _ in range(steps):
        r = sim.optireduce_2d(nbytes, control, groups)
        total += r.time_ms
        drops += r.drop_frac
        rounds = r.rounds
    return total / steps, drops / steps, rounds


def run(quick: bool = True) -> Rows:
    rows = Rows()
    # Appendix A round-count claim at N=64, G=16
    rows.add("scaling/appA_rounds_flat_n64", 2 * (64 - 1), "paper: 126")
    rows.add("scaling/appA_rounds_2d_n64_g16", 2 * (64 // 16 - 1) + 15,
             "paper: 21")
    nb = 500e6 * 4 / 20
    steps2 = 40 if quick else 150
    for n, g in ((64, 8), (144, 12)):
        flat, dflat, _ = _tar2d(n, 1, nb, steps2, "local_3.0")
        hier, dhier, r2 = _tar2d(n, g, nb, steps2, "local_3.0")
        rows.add(f"scaling/tar2d_n{n}_g{g}_speedup", flat / hier,
                 f"rounds {2*(n-1)} -> {r2}; drops {dflat:.4f}->{dhier:.4f}")
    nbytes = 500e6 * 4 / 20          # 500M grads, 20 buckets
    steps = 60 if quick else 200
    nodes = [6, 12, 24] if quick else [6, 12, 24, 72, 144]
    for ratio, envname in ((1.5, "local_1.5"), (3.0, "local_3.0")):
        for n in nodes:
            res = {}
            for strat in ("gloo_ring", "bcube", "tar_tcp", "optireduce"):
                env = NetworkModel.environment(envname, seed=n)
                r = simulate_job(strat, n_nodes=n, bucket_bytes=nbytes,
                                 n_steps=steps, env=env, compute_ms=0.0,
                                 overlap=0.0)
                res[strat] = r["mean_ga_ms"]
            o = res["optireduce"]
            rows.add(f"scaling/p{ratio}/n{n}/ring_speedup",
                     res["gloo_ring"] / o, "paper ~2x at p99/50=3")
            rows.add(f"scaling/p{ratio}/n{n}/bcube_speedup",
                     res["bcube"] / o, "")
            rows.add(f"scaling/p{ratio}/n{n}/tar_tcp_speedup",
                     res["tar_tcp"] / o,
                     "UBT's contribution beyond TAR topology")
    return rows


if __name__ == "__main__":
    run(quick=False)
