"""Kernel parity + host-timing sweep: Pallas (interpret mode on CPU) vs
pure-jnp oracle for fwht / masked_sum / quant across shapes and dtypes.
On-TPU timing is out of scope for this container; the roofline for the
kernels' MXU formulation is derived in EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fwht import fwht, fwht_ref
from repro.kernels.fwht.fwht import fwht_pallas
from repro.kernels.masked_sum import masked_mean, masked_mean_ref
from repro.kernels.quant import uniform_quant, uniform_quant_ref

from .common import Rows


def _t(fn, *a, n=3):
    fn(*a)[0].block_until_ready() if isinstance(fn(*a), tuple) else \
        jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*a))
    return (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = True) -> Rows:
    rows = Rows()
    key = jax.random.PRNGKey(0)
    blocks = [256, 1024, 4096] if quick else [256, 1024, 4096, 16384]
    for block in blocks:
        for dtype in (jnp.float32, jnp.bfloat16):
            x = jax.random.normal(key, (32, block)).astype(dtype)
            ref = fwht_ref(x.astype(jnp.float32))
            out = fwht_pallas(x.astype(jnp.float32), interpret=True)
            err = float(jnp.max(jnp.abs(out - ref)))
            us = _t(lambda v=x: fwht(v.astype(jnp.float32)))
            rows.add(f"kernels/fwht_b{block}_{dtype.__name__}", us,
                     f"us/call (jnp MXU form); pallas_vs_oracle_err={err:.2e}")
    n_peers = 8
    for length in ([1 << 14] if quick else [1 << 14, 1 << 18]):
        sh = jax.random.normal(key, (n_peers, length))
        mk = (jax.random.uniform(key, (n_peers, length)) > 0.05).astype(
            jnp.float32)
        err = float(jnp.max(jnp.abs(
            masked_mean(sh, mk, use_kernel=True) - masked_mean_ref(sh, mk))))
        us = _t(lambda: masked_mean(sh, mk))
        rows.add(f"kernels/masked_sum_L{length}", us,
                 f"us/call; pallas_vs_oracle_err={err:.2e}")
    x = jax.random.normal(key, (64, 4096))
    noise = jax.random.uniform(jax.random.fold_in(key, 1), x.shape)
    lohi = jnp.array([float(x.min()), float(x.max())])
    for bits in (4, 8):
        q1 = uniform_quant(x, noise, lohi, bits=bits, use_kernel=True)
        q2 = uniform_quant_ref(x, noise, lohi[0], lohi[1], bits=bits)
        err = int(jnp.max(jnp.abs(q1.astype(jnp.int32) -
                                  q2.astype(jnp.int32))))
        us = _t(lambda b=bits: uniform_quant(x, noise, lohi, bits=b))
        rows.add(f"kernels/quant_b{bits}", us,
                 f"us/call; pallas_vs_oracle_maxdiff={err}")
    return rows


if __name__ == "__main__":
    run(quick=False)
