"""Kernel parity + timing sweep for the Pallas codec kernels.

Two kinds of rows per kernel family:

  parity     — Pallas output vs the jnp oracle (maxdiff / err), plus the
               jnp-form host timing the historical tables tracked.
  device     — ``*_interpret_steady_us`` rows time the Pallas interpreter
               (every box, incl. CPU CI: the interpreter's wall time tracks
               kernel *structure* — grid steps, DMA bookkeeping — not device
               speed), and on a real TPU backend ``*_compiled_steady_us``
               rows time the Mosaic-compiled kernels with
               ``block_until_ready``. Off-TPU the compiled rows are simply
               absent (the JSON schema treats them as optional), so the same
               bench file is the real-hardware mode: run it on a TPU box and
               the compiled columns appear.

Every ``*_steady_us`` row carries a ``*_steady_iqr_us`` dispersion sibling
(median/IQR over reps), per the suite-wide schema in benchmarks/run.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import runtime
from repro.kernels.dequant_reduce import dequant_masked_mean
from repro.kernels.dequant_reduce.dequant_reduce import \
    dequant_masked_mean_pallas
from repro.kernels.fwht import fwht, fwht_ref
from repro.kernels.fwht.fwht import fwht_pallas
from repro.kernels.ht_quant import ht_amax, ht_quant
from repro.kernels.ht_quant.ht_quant import ht_amax_pallas, ht_quant_pallas
from repro.kernels.masked_sum import masked_mean, masked_mean_ref
from repro.kernels.masked_sum.masked_sum import masked_mean_pallas
from repro.kernels.quant import uniform_quant, uniform_quant_ref
from repro.kernels.quant.quant import uniform_quant_pallas

from .common import Rows


def _t(fn, *a, n=3):
    jax.block_until_ready(fn(*a))        # one warmup; handles any pytree
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*a))
    return (time.perf_counter() - t0) / n * 1e6


def _steady(fn, reps=5):
    """(median_us, iqr_us) over ``reps`` timed calls after one warmup."""
    jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return (float(np.median(ts)),
            float(np.percentile(ts, 75) - np.percentile(ts, 25)))


def _device_rows(rows: Rows, name: str, fn, reps=5):
    """interpret-mode rows on every backend; Mosaic-compiled rows when a
    TPU is present (``fn`` must dispatch through the kernel-mode policy)."""
    with runtime.kernel_mode_scope("interpret"):
        med, iqr = _steady(fn, reps)
    rows.add(f"{name}_interpret_steady_us", med,
             "Pallas interpreter host wall-clock (structure, not device)")
    rows.add(f"{name}_interpret_steady_iqr_us", iqr, "")
    if jax.default_backend() == "tpu":
        with runtime.kernel_mode_scope("compile"):
            med_c, iqr_c = _steady(fn, reps)
        rows.add(f"{name}_compiled_steady_us", med_c,
                 "Mosaic-compiled, block_until_ready")
        rows.add(f"{name}_compiled_steady_iqr_us", iqr_c, "")


def run(quick: bool = True) -> Rows:
    rows = Rows()
    key = jax.random.PRNGKey(0)
    blocks = [256, 1024, 4096] if quick else [256, 1024, 4096, 16384]
    for block in blocks:
        for dtype in (jnp.float32, jnp.bfloat16):
            x = jax.random.normal(key, (32, block)).astype(dtype)
            ref = fwht_ref(x.astype(jnp.float32))
            with runtime.kernel_mode_scope("interpret"):
                out = fwht_pallas(x.astype(jnp.float32))
            err = float(jnp.max(jnp.abs(out - ref)))
            us = _t(lambda v=x: fwht(v.astype(jnp.float32)))
            rows.add(f"kernels/fwht_b{block}_{dtype.__name__}", us,
                     f"us/call (jnp MXU form); pallas_vs_oracle_err={err:.2e}")
    xf32 = jax.random.normal(key, (32, 1024))
    _device_rows(rows, "kernels/fwht_b1024",
                 lambda: fwht_pallas(xf32))
    n_peers = 8
    for length in ([1 << 14] if quick else [1 << 14, 1 << 18]):
        sh = jax.random.normal(key, (n_peers, length))
        mk = (jax.random.uniform(key, (n_peers, length)) > 0.05).astype(
            jnp.float32)
        err = float(jnp.max(jnp.abs(
            masked_mean(sh, mk, use_kernel=True) - masked_mean_ref(sh, mk))))
        us = _t(lambda: masked_mean(sh, mk))
        rows.add(f"kernels/masked_sum_L{length}", us,
                 f"us/call; pallas_vs_oracle_err={err:.2e}")
        if length == (1 << 14):
            _device_rows(rows, f"kernels/masked_sum_L{length}",
                         lambda: masked_mean_pallas(sh, mk))
    x = jax.random.normal(key, (64, 4096))
    noise = jax.random.uniform(jax.random.fold_in(key, 1), x.shape)
    lohi = jnp.array([float(x.min()), float(x.max())])
    for bits in (4, 8):
        q1 = uniform_quant(x, noise, lohi, bits=bits, use_kernel=True)
        q2 = uniform_quant_ref(x, noise, lohi[0], lohi[1], bits=bits)
        err = int(jnp.max(jnp.abs(q1.astype(jnp.int32) -
                                  q2.astype(jnp.int32))))
        us = _t(lambda b=bits: uniform_quant(x, noise, lohi, bits=b))
        rows.add(f"kernels/quant_b{bits}", us,
                 f"us/call; pallas_vs_oracle_maxdiff={err}")
    _device_rows(rows, "kernels/quant_b8",
                 lambda: uniform_quant_pallas(x, noise, lohi, bits=8))

    # fused sync-engine kernels: one-pass HT+quant vs the composed pipeline
    for block in ([1024] if quick else [1024, 4096]):
        rws = 32
        xf = jax.random.normal(key, (rws, block))
        sign = jnp.where(jax.random.bernoulli(key, 0.5, (block,)), 1., -1.)
        nz = jax.random.uniform(jax.random.fold_in(key, 2), xf.shape)
        am = jnp.maximum(ht_amax(xf, sign), 1e-12)
        lo, step = -am, 2.0 * am / 255
        qk = ht_quant(xf, sign, nz, lo, step, bits=8, use_kernel=True)
        qr = ht_quant(xf, sign, nz, lo, step, bits=8, use_kernel=False)
        err = int(jnp.max(jnp.abs(qk.astype(jnp.int32) -
                                  qr.astype(jnp.int32))))
        us = _t(lambda: ht_quant(xf, sign, nz, lo, step, bits=8))
        us_composed = (_t(lambda: fwht(xf * sign[None]))
                       + _t(lambda: uniform_quant(xf, nz, lohi, bits=8)))
        # host timing uses the jnp forms (Pallas runs in interpret mode off
        # TPU, so its wall time is meaningless here): one fused jit vs the
        # composed two-pass pipeline. The on-TPU win is the HBM pass count
        # (PERF.md); parity of the actual Pallas kernel is the maxdiff.
        rows.add(f"kernels/ht_quant_b{block}", us,
                 f"us/call one-pass jnp form; composed 2-pass jnp="
                 f"{us_composed:.0f}us; pallas_vs_oracle_maxdiff={err}")
        if block == 1024:
            _device_rows(rows, f"kernels/ht_amax_b{block}",
                         lambda: ht_amax_pallas(xf, sign, block_rows=16))
            _device_rows(
                rows, f"kernels/ht_quant_b{block}",
                lambda: ht_quant_pallas(xf, sign, nz, lo, step,
                                        block_rows=16))
    n_peers, nblk, blk = 8, 8, 1024
    s = nblk * blk
    codes = jax.random.randint(key, (n_peers, s), 0, 256).astype(jnp.uint8)
    lo_b = jax.random.normal(key, (nblk,))
    step_b = jax.random.uniform(key, (nblk,)) * 0.05 + 1e-3
    mk2 = (jax.random.uniform(key, (n_peers, s)) > 0.05).astype(jnp.float32)
    dk = dequant_masked_mean(codes, lo_b, step_b, mk2, block=blk,
                             use_kernel=True)
    dr = dequant_masked_mean(codes, lo_b, step_b, mk2, block=blk,
                             use_kernel=False)
    err = float(jnp.max(jnp.abs(dk - dr)))
    us = _t(lambda: dequant_masked_mean(codes, lo_b, step_b, mk2, block=blk))
    rows.add(f"kernels/dequant_masked_mean_L{s}", us,
             f"us/call one-pass jnp form; pallas_vs_oracle_err={err:.2e}")
    lo_r = jnp.broadcast_to(lo_b.reshape(nblk, 1), (nblk, blk)).reshape(-1)
    step_r = jnp.broadcast_to(step_b.reshape(nblk, 1),
                              (nblk, blk)).reshape(-1)
    _device_rows(rows, f"kernels/dequant_masked_mean_L{s}",
                 lambda: dequant_masked_mean_pallas(codes, lo_r, step_r, mk2))
    return rows


if __name__ == "__main__":
    run(quick=False)
