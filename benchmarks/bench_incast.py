"""Paper Fig 13: static (I=1) vs dynamic incast in UBT — dynamic incast
raises I when loss stays low, halving the round count and cutting mean GA
latency (paper: ~21% on a 500M-gradient AllReduce)."""
from __future__ import annotations

import numpy as np

from repro.sim.netsim import NetworkModel, simulate_job

from .common import Rows


def run(quick: bool = True) -> Rows:
    rows = Rows()
    # Fig 13's regime: per-round latency floors dominate (the incast win is
    # halving the ROUND COUNT); use latency-bound chunk sizes — with
    # bandwidth-bound 25 MB buckets the byte volume is invariant in I and
    # dynamic incast is correctly a no-op.
    nbytes = 2 * 2 ** 20
    steps = 120 if quick else 400
    env_kw = dict(n_nodes=8, bucket_bytes=nbytes, n_steps=steps,
                  compute_ms=0.0, overlap=0.0)
    stat = simulate_job("optireduce",
                        env=NetworkModel.environment("local_1.5", seed=5),
                        incast_dynamic=False, **env_kw)
    dyn = simulate_job("optireduce",
                       env=NetworkModel.environment("local_1.5", seed=5),
                       incast_dynamic=True, **env_kw)
    rows.add("incast/static_I1_mean_ms", stat["mean_ga_ms"], "")
    rows.add("incast/dynamic_mean_ms", dyn["mean_ga_ms"], "")
    rows.add("incast/latency_reduction_pct",
             100 * (1 - dyn["mean_ga_ms"] / stat["mean_ga_ms"]),
             "paper ~21%")
    rows.add("incast/static_p99_ms", stat["p99_ga_ms"], "")
    rows.add("incast/dynamic_p99_ms", dyn["p99_ga_ms"], "")
    rows.add("incast/dynamic_drop", dyn["mean_drop"],
             "must stay < 0.1% while I grows")
    return rows


if __name__ == "__main__":
    run(quick=False)
