"""Paper Fig 13: static (I=1) vs dynamic incast in UBT — dynamic incast
raises I when loss stays low, halving the round count and cutting mean GA
latency (paper: ~21% on a 500M-gradient AllReduce).

Besides the simulator rows, this bench measures the REAL lowered schedule:
``tar_allreduce_rounds(incast=I)`` gates each group of I ppermutes on the
previous group's arrivals (an optimization_barrier chain), so the HLO
barrier count and the wall time on an 8-device host mesh genuinely change
with I (subprocess, same pattern as the collective tests)."""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np

from repro.sim.netsim import NetworkModel, simulate_job

from .common import Rows

_CHILD = r"""
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core.tar import pad_for_tar, tar_allreduce_rounds

mesh = make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1 << 16))
for incast in (1, 4):
    def body(v, incast=incast):
        vv, ln = pad_for_tar(v.reshape(-1), 8)
        return tar_allreduce_rounds(vv, "data", incast=incast)[None, :ln]
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data", None),
                          out_specs=P("data", None), check_vma=False))
    barriers = f.lower(x).as_text().count("optimization_barrier")
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(f(x))
    us = (time.perf_counter() - t0) / 10 * 1e6
    print(f"ROW incast/rounds_I{incast}_us {us:.1f} "
          f"hlo_barriers={barriers}")
"""


def _real_schedule_rows(rows: Rows) -> None:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    try:
        proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                              capture_output=True, text=True, timeout=600)
    except (subprocess.TimeoutExpired, OSError) as e:
        rows.add("incast/rounds_FAILED", 0, type(e).__name__)
        return
    if proc.returncode != 0:
        rows.add("incast/rounds_FAILED", 0, proc.stderr.strip()[-120:])
        return
    for line in proc.stdout.splitlines():
        if line.startswith("ROW "):
            _, name, value, derived = line.split(" ", 3)
            rows.add(name, float(value), derived)


def run(quick: bool = True) -> Rows:
    rows = Rows()
    # Fig 13's regime: per-round latency floors dominate (the incast win is
    # halving the ROUND COUNT); use latency-bound chunk sizes — with
    # bandwidth-bound 25 MB buckets the byte volume is invariant in I and
    # dynamic incast is correctly a no-op.
    nbytes = 2 * 2 ** 20
    steps = 120 if quick else 400
    env_kw = dict(n_nodes=8, bucket_bytes=nbytes, n_steps=steps,
                  compute_ms=0.0, overlap=0.0)
    stat = simulate_job("optireduce",
                        env=NetworkModel.environment("local_1.5", seed=5),
                        incast_dynamic=False, **env_kw)
    dyn = simulate_job("optireduce",
                       env=NetworkModel.environment("local_1.5", seed=5),
                       incast_dynamic=True, **env_kw)
    rows.add("incast/static_I1_mean_ms", stat["mean_ga_ms"], "")
    rows.add("incast/dynamic_mean_ms", dyn["mean_ga_ms"], "")
    rows.add("incast/latency_reduction_pct",
             100 * (1 - dyn["mean_ga_ms"] / stat["mean_ga_ms"]),
             "paper ~21%")
    rows.add("incast/static_p99_ms", stat["p99_ga_ms"], "")
    rows.add("incast/dynamic_p99_ms", dyn["p99_ga_ms"], "")
    rows.add("incast/dynamic_drop", dyn["mean_drop"],
             "must stay < 0.1% while I grows")
    _real_schedule_rows(rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
