"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads results/dryrun_*.json (produced by repro.launch.dryrun) and derives,
per (arch x shape x mesh):

    compute_s    = HLO_FLOPs_per_chip / PEAK_FLOPS
    memory_s     = HLO_bytes_per_chip / HBM_BW
    collective_s = collective_bytes_per_chip / ICI_BW

(cost-model metrics are per-chip already — the HLO is the SPMD per-device
program; dividing the global aggregate by `chips` is the same number).
MODEL_FLOPS = 6*N*D (train; N_active for MoE) or 2*N*D (decode/prefill
forward) is reported against HLO FLOPs to expose remat/dispatch overhead.

  python -m benchmarks.roofline results/dryrun_single_pod.json [--md]
"""
from __future__ import annotations

import argparse
import json
import sys

PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 1 * 128,
    "long_500k": 1 * 1,
}


def analyze(rec: dict, chips: int) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cm = rec.get("cost_model") or {}
    flops = cm.get("flops", 0.0)
    mem_bytes = cm.get("bytes", 0.0)
    coll = sum(v for k, v in cm.items()
               if k.startswith("coll_") and "count" not in k)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": mem_bytes / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    tokens = SHAPE_TOKENS[rec["shape"]]
    if rec["kind"] == "train":
        model_flops = 6 * rec["active_params"] * tokens
    else:
        model_flops = 2 * rec["active_params"] * tokens
    hlo_total = flops * chips
    bound_s = max(terms.values())
    useful = model_flops / hlo_total if hlo_total else 0.0
    # roofline fraction: useful model FLOPs per chip-second at the bound
    mfu = (model_flops / chips / bound_s) / PEAK_FLOPS if bound_s else 0.0
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_per_chip": flops,
        "useful_frac": useful,
        "roofline_frac": mfu,
        "coll_bytes_per_chip": coll,
        "peak_gib": (rec.get("memory", {})
                     .get("peak_bytes_per_device", 0)) / 2 ** 30,
    }


MOVE_HINTS = {
    "compute": "raise MXU utilization: bigger microbatch / fuse small ops "
               "/ drop dead padded-head FLOPs",
    "memory": "cut HBM traffic: better remat policy, bf16 intermediates, "
              "fuse elementwise chains, larger attention blocks",
    "collective": "cut bytes/step: 2D TAR over (pod,data), quantized "
                  "(THC) gradient exchange, overlap with compute, "
                  "sequence-parallel activations",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("json", nargs="+")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    rows = []
    for path in args.json:
        recs = json.load(open(path))
        for rec in recs:
            chips = 1
            for d in rec.get("mesh", "1").split("x"):
                chips *= int(d)
            a = analyze(rec, chips)
            if a is None:
                rows.append((rec, None))
            else:
                rows.append((rec, a))
    if args.md:
        print("| arch | shape | mesh | compute_s | memory_s | collective_s |"
              " dominant | peak GiB | MODEL/HLO | roofline frac |")
        print("|---|---|---|---|---|---|---|---|---|---|")
    for rec, a in rows:
        if a is None:
            if args.md:
                print(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                      f"SKIP/{rec['status']} |||||||")
            continue
        if args.md:
            print(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                  f"| {a['compute_s']:.3e} | {a['memory_s']:.3e} "
                  f"| {a['collective_s']:.3e} | {a['dominant']} "
                  f"| {a['peak_gib']:.1f} | {a['useful_frac']:.2f} "
                  f"| {a['roofline_frac']:.3f} |")
        else:
            print(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
                  f"{a['compute_s']:.4e},{a['memory_s']:.4e},"
                  f"{a['collective_s']:.4e},{a['dominant']},"
                  f"{a['useful_frac']:.3f},{a['roofline_frac']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
