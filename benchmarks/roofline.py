"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads results/dryrun_*.json (produced by repro.launch.dryrun) and derives,
per (arch x shape x mesh):

    compute_s    = HLO_FLOPs_per_chip / PEAK_FLOPS
    memory_s     = HLO_bytes_per_chip / HBM_BW
    collective_s = collective_bytes_per_chip / ICI_BW

(cost-model metrics are per-chip already — the HLO is the SPMD per-device
program; dividing the global aggregate by `chips` is the same number).
MODEL_FLOPS = 6*N*D (train; N_active for MoE) or 2*N*D (decode/prefill
forward) is reported against HLO FLOPs to expose remat/dispatch overhead.

  python -m benchmarks.roofline results/dryrun_single_pod.json [--md]

``--kernels`` adds the compiled-codec-kernel arithmetic-intensity points
(no dry-run JSON needed): FLOPs/HBM-byte of the fused one-pass encode
(ht_quant) and decode (dequant_masked_mean) kernels vs the composed
multi-pass forms, against the HBM ridge point PEAK_FLOPS/HBM_BW. Points
left of the ridge are bandwidth-bound — there the fused kernels' fewer
HBM passes translate directly into wall-clock, which is what the
``*_compiled_steady_us`` rows of BENCH_kernels.json measure on a TPU box.
"""
from __future__ import annotations

import argparse
import json
import sys

PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 1 * 128,
    "long_500k": 1 * 1,
}


def analyze(rec: dict, chips: int) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cm = rec.get("cost_model") or {}
    flops = cm.get("flops", 0.0)
    mem_bytes = cm.get("bytes", 0.0)
    coll = sum(v for k, v in cm.items()
               if k.startswith("coll_") and "count" not in k)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": mem_bytes / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    tokens = SHAPE_TOKENS[rec["shape"]]
    if rec["kind"] == "train":
        model_flops = 6 * rec["active_params"] * tokens
    else:
        model_flops = 2 * rec["active_params"] * tokens
    hlo_total = flops * chips
    bound_s = max(terms.values())
    useful = model_flops / hlo_total if hlo_total else 0.0
    # roofline fraction: useful model FLOPs per chip-second at the bound
    mfu = (model_flops / chips / bound_s) / PEAK_FLOPS if bound_s else 0.0
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_per_chip": flops,
        "useful_frac": useful,
        "roofline_frac": mfu,
        "coll_bytes_per_chip": coll,
        "peak_gib": (rec.get("memory", {})
                     .get("peak_bytes_per_device", 0)) / 2 ** 30,
    }


def codec_kernel_points(rows: int = 4096, n: int = 1024,
                        n_peers: int = 8) -> list[dict]:
    """Arithmetic intensity (FLOPs per HBM byte) of the codec kernels.

    The fused encode kernel (ht_quant) streams x and noise through VMEM
    once and writes uint8 codes; the composed form materializes the
    rotated intermediate and re-reads it for amax and quantization. FLOPs
    are identical either way (the blocked FWHT is two dot_generals over
    the a*b factorization, 2*rows*n*(a+b) with a=b=sqrt(n); the
    elementwise quant is ~6/elt), so the intensity ratio is purely the
    HBM-pass ratio — the quantity PERF.md's pass tables count.
    """
    a = b = int(n ** 0.5)
    f32 = 4
    fwht_flops = 2.0 * rows * n * (a + b)
    quant_flops = 6.0 * rows * n
    enc_flops = fwht_flops + quant_flops
    # fused: read x + noise (+ per-row lo/step, negligible), write codes
    enc_fused_bytes = rows * n * (2 * f32 + 1)
    # composed: fwht r+w, amax re-read, quant reads y + noise, writes codes
    enc_composed_bytes = rows * n * (6 * f32 + 1)
    # decode: dequant is 2 FLOPs/elt, masked mean ~3/elt over n_peers rows
    dec_flops = 5.0 * n_peers * rows * n
    dec_fused_bytes = n_peers * rows * n * (1 + f32) + rows * n * f32
    dec_composed_bytes = (n_peers * rows * n * (1 + 2 * f32 + f32)
                          + rows * n * f32)
    pts = []
    for name, flops, nbytes in (
            ("ht_quant_fused", enc_flops, enc_fused_bytes),
            ("ht_quant_composed", enc_flops, enc_composed_bytes),
            ("dequant_mean_fused", dec_flops, dec_fused_bytes),
            ("dequant_mean_composed", dec_flops, dec_composed_bytes)):
        ai = flops / nbytes
        pts.append({
            "kernel": name,
            "flops_per_byte": ai,
            "ridge_flops_per_byte": PEAK_FLOPS / HBM_BW,
            "bound": "memory" if ai < PEAK_FLOPS / HBM_BW else "compute",
            "hbm_bound_us": nbytes / HBM_BW * 1e6,
        })
    return pts


MOVE_HINTS = {
    "compute": "raise MXU utilization: bigger microbatch / fuse small ops "
               "/ drop dead padded-head FLOPs",
    "memory": "cut HBM traffic: better remat policy, bf16 intermediates, "
              "fuse elementwise chains, larger attention blocks",
    "collective": "cut bytes/step: 2D TAR over (pod,data), quantized "
                  "(THC) gradient exchange, overlap with compute, "
                  "sequence-parallel activations",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("json", nargs="*")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--kernels", action="store_true",
                    help="print codec-kernel arithmetic-intensity points")
    args = ap.parse_args(argv)
    if args.kernels:
        ridge = PEAK_FLOPS / HBM_BW
        if args.md:
            print(f"| kernel | FLOPs/byte | ridge {ridge:.0f} | bound "
                  f"| HBM-bound us |")
            print("|---|---|---|---|---|")
        for p in codec_kernel_points():
            if args.md:
                print(f"| {p['kernel']} | {p['flops_per_byte']:.1f} | "
                      f"{p['ridge_flops_per_byte']:.0f} | {p['bound']} | "
                      f"{p['hbm_bound_us']:.1f} |")
            else:
                print(f"{p['kernel']},{p['flops_per_byte']:.2f},"
                      f"{p['ridge_flops_per_byte']:.1f},{p['bound']},"
                      f"{p['hbm_bound_us']:.2f}")
        if not args.json:
            return 0
    elif not args.json:
        ap.error("need dry-run JSON path(s) or --kernels")
    rows = []
    for path in args.json:
        recs = json.load(open(path))
        for rec in recs:
            chips = 1
            for d in rec.get("mesh", "1").split("x"):
                chips *= int(d)
            a = analyze(rec, chips)
            if a is None:
                rows.append((rec, None))
            else:
                rows.append((rec, a))
    if args.md:
        print("| arch | shape | mesh | compute_s | memory_s | collective_s |"
              " dominant | peak GiB | MODEL/HLO | roofline frac |")
        print("|---|---|---|---|---|---|---|---|---|---|")
    for rec, a in rows:
        if a is None:
            if args.md:
                print(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                      f"SKIP/{rec['status']} |||||||")
            continue
        if args.md:
            print(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                  f"| {a['compute_s']:.3e} | {a['memory_s']:.3e} "
                  f"| {a['collective_s']:.3e} | {a['dominant']} "
                  f"| {a['peak_gib']:.1f} | {a['useful_frac']:.2f} "
                  f"| {a['roofline_frac']:.3f} |")
        else:
            print(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
                  f"{a['compute_s']:.4e},{a['memory_s']:.4e},"
                  f"{a['collective_s']:.4e},{a['dominant']},"
                  f"{a['useful_frac']:.3f},{a['roofline_frac']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
