"""Paper Fig 9 + Fig 14: Hadamard Transform disperses drop error.

(a) Fig 9 micro: encode a gradient bucket, tail-drop entries in transit,
    decode; MSE vs the un-encoded tail-drop (paper example: 0.01 vs 2.53).
(b) Fig 14: real training accuracy under 1/5/10% tail drops with and
    without HT (HT also provides the per-coordinate unbiased estimate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hadamard import ht_decode, ht_encode
from repro.sim.tta import TrainRunConfig, run_training

from .common import Rows


def fig9_micro(block=4096, drop_frac=0.02, seed=0):
    key = jax.random.PRNGKey(seed)
    # heavy-tailed gradient bucket with real mass in the dropped region
    # (Fig 9's scenario: the tail entries a timeout cuts are not zeros)
    g = jax.random.laplace(key, (block,)) * \
        (1.0 + 10.0 * (jax.random.uniform(jax.random.fold_in(key, 1),
                                          (block,)) < 0.02))
    cut = int(block * (1 - drop_frac))
    g = g.at[cut + 3].set(15.0).at[cut + 9].set(-12.0)
    tail_mask = jnp.arange(block) < cut

    raw = jnp.where(tail_mask, g, 0.0)
    mse_raw = float(jnp.mean((raw - g) ** 2))

    enc = ht_encode(g, key, block=block)
    received = jnp.where(tail_mask, enc, 0.0)
    # §3.3: receiver rescales by the inverse keep-rate (unbiased estimate)
    received = received / (cut / block)
    dec = ht_decode(received, key, block=block)
    mse_ht = float(jnp.mean((dec - g) ** 2))
    return mse_raw, mse_ht


def run(quick: bool = True) -> Rows:
    rows = Rows()
    mse_raw, mse_ht = fig9_micro()
    rows.add("hadamard/fig9_mse_no_ht", mse_raw, "paper example 2.53")
    rows.add("hadamard/fig9_mse_ht", mse_ht, "paper example 0.01")
    rows.add("hadamard/fig9_ratio", mse_raw / max(mse_ht, 1e-12),
             "HT dispersal factor")

    # TTA horizon (Fig 14 is a time-to-accuracy claim): measure accuracy at
    # a fixed early-training step budget — the regime where the biased
    # no-HT estimate costs real steps. (At long horizons this small task
    # re-converges either way; the paper's VGG runs plateau instead.)
    steps = 40 if quick else 80
    base = run_training(TrainRunConfig(steps=steps, eval_every=10))
    final = base["acc"][-1]
    rows.add("hadamard/train_acc_lossless", final, f"{steps} steps")
    for rate in ([0.05, 0.10] if quick else [0.01, 0.05, 0.10]):
        for ht in (True, False):
            h = run_training(TrainRunConfig(
                steps=steps, eval_every=10, drop_rate=rate, use_hadamard=ht))
            tag = f"hadamard/train_acc_drop{int(rate*100)}_" + \
                ("ht" if ht else "noht")
            rows.add(tag, h["acc"][-1],
                     f"vs lossless {final:.3f}; paper Fig 14: no-HT "
                     "degrades >=5% drops, HT holds")
    return rows


if __name__ == "__main__":
    run(quick=False)
