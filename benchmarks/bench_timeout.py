"""Paper §5.3 early-timeout ablation + the degraded-participation ablation.

Early timeout: with only the hard bound t_B, every lossy round burns the
full t_B; the early timeout expires at (last-percentile-seen + x%*t_C),
recovering ~16% of training time at equal drop rate (paper: 130 -> 112 min
on VGG-19).

Ejection vs wait-for-all: a *persistent* straggler (one peer 6x slow on
every transfer) defeats the timeout controllers alone — the warmup P95
includes the straggler, so t_B converges to its pace and every step pays
the tail.  The control plane's straggler detector ejects it (degraded
participation, DESIGN §5); the ablation prices ejection against waiting at
equal environment, reporting medians with IQR dispersion siblings.

Rows are emitted in the ``benchmarks/run.py`` schema (machine-readable
keys, ``*_iqr_ms`` sibling for every median row) and serialized to
``BENCH_timeout.json`` (``REPRO_BENCH_DIR`` redirects it, e.g. in CI).
"""
from __future__ import annotations

import numpy as np

from repro.runtime import ControlPlane
from repro.sim.netsim import GASimulator, NetworkModel

from .common import Rows


def _iqr(xs) -> float:
    return float(np.percentile(xs, 75) - np.percentile(xs, 25))


def _run_early(early: bool, steps: int, seed: int = 7):
    # ablation environment with enough stall episodes that the warmup P95
    # (t_B) captures them — the regime where the two policies separate
    # (the paper's VGG-19 testbed ran under sustained background load)
    env = NetworkModel(p99_over_p50=1.5, stall_prob=0.015, seed=seed)
    sim = GASimulator(env, 8)
    nbytes = 25 * 2 ** 20
    timeout = sim.warmup(nbytes).state.timeout
    times, drops = [], []
    n = 8
    chunk = nbytes / n
    rounds = 2 * (n - 1)
    for _ in range(steps):
        total_t, lost = 0.0, 0.0
        st, tf, fr = [], [], []
        for _ in range(rounds):
            t, loss = env.ubt_ms(chunk, n)
            if early:
                t99 = float(np.max(t * 0.99))
                deadline = min(timeout.round_deadline(True),
                               t99 + timeout.x * (timeout.t_c or t99))
            else:
                deadline = timeout.t_b          # hard bound only
            arrived = np.where(t <= deadline, 1.0 - loss,
                               np.minimum(1.0 - loss, deadline / t))
            if early:
                t_round = float(min(np.max(t), deadline))
            else:
                # without the early-expiry signal a receiver waiting on
                # DROPPED bytes cannot distinguish late from lost — it
                # burns the full t_B (§3.2.1 challenge (2))
                lossy = bool(np.any(loss > 0)) or bool(np.any(t > deadline))
                t_round = float(deadline if lossy else np.max(t))
            total_t += t_round
            lost += float(np.sum(1 - arrived)) * chunk
            st.append(t_round)
            tf.append(bool(np.any(t > deadline)))
            fr.append(float(np.mean(arrived)))
        drop = lost / (rounds * n * chunk)
        timeout.update(stage_times=st, timed_out=tf, frac_received=fr,
                       loss_frac=drop)
        times.append(total_t)
        drops.append(drop)
    return np.asarray(times), np.asarray(drops)


def _run_straggler(eject: bool, steps: int, *, factor: float = 6.0,
                   seed: int = 11):
    """Persistent-straggler run: peer N-1 is ``factor``x slow on every
    transfer.  ``eject`` arms the detector; otherwise every round waits."""
    env = NetworkModel(p99_over_p50=1.5, stall_prob=0.01, seed=seed)
    n = 8
    env.peer_factors = (1.0,) * (n - 1) + (float(factor),)
    sim = GASimulator(env, n)
    nbytes = 25 * 2 ** 20
    control = ControlPlane.create(n_nodes=n, detect_stragglers=eject)
    sim.warmup(nbytes, control=control)
    times, drops = [], []
    for _ in range(steps):
        r = sim.optireduce(nbytes, control, fixed_incast=1)
        times.append(r.time_ms)
        drops.append(r.drop_frac)
    return np.asarray(times), np.asarray(drops), control


def _run_rebalance(mode: str, steps: int, *, factor: float = 6.0,
                   seed: int = 7):
    """Three-arm straggler ablation at equal environment: ``wait`` (no
    detector, every round waits), ``eject`` (degraded participation — the
    straggler's gradient share is zero), ``rebalance`` (straggler-
    proportional shard weights — the slow peer keeps a smaller contiguous
    slice, so its contribution survives).  The straggler sits mid-ring
    (peer 3) and the schedule runs at incast 4, where ejection and
    rebalancing execute the same number of gated rounds."""
    env = NetworkModel(p99_over_p50=1.5, stall_prob=0.01, seed=seed)
    n = 8
    env.peer_factors = (1.0,) * 3 + (float(factor),) + (1.0,) * (n - 4)
    sim = GASimulator(env, n)
    nbytes = 25 * 2 ** 20
    control = ControlPlane.create(
        n_nodes=n, detect_stragglers=(mode == "eject"),
        rebalance=(mode == "rebalance"))
    sim.warmup(nbytes, control=control)
    times, contribs = [], []
    for _ in range(steps):
        r = sim.optireduce(nbytes, control, fixed_incast=4)
        times.append(r.time_ms)
        if r.peer_contrib is not None:
            contribs.append(r.peer_contrib[3])
    return np.asarray(times), contribs, control


def run(quick: bool = True) -> Rows:
    rows = Rows()
    steps = 100 if quick else 400

    # ---- §5.3 early-timeout ablation ------------------------------------
    t_off, d_off = _run_early(early=False, steps=steps)
    t_on, d_on = _run_early(early=True, steps=steps)
    rows.add("timeout/tb_only_median_ms", float(np.median(t_off)),
             f"drop={float(np.mean(d_off)):.5f}")
    rows.add("timeout/tb_only_iqr_ms", _iqr(t_off))
    rows.add("timeout/early_tc_median_ms", float(np.median(t_on)),
             f"drop={float(np.mean(d_on)):.5f}")
    rows.add("timeout/early_tc_iqr_ms", _iqr(t_on))
    rows.add("timeout/time_reduction_pct",
             100 * (1 - float(np.median(t_on)) / float(np.median(t_off))),
             "paper ~16% at equal drop rate")

    # ---- ejection vs wait-for-all under a persistent straggler ----------
    t_wait, d_wait, _ = _run_straggler(eject=False, steps=steps)
    t_ej, d_ej, control = _run_straggler(eject=True, steps=steps)
    rows.add("timeout/wait_for_all_median_ms", float(np.median(t_wait)),
             f"drop={float(np.mean(d_wait)):.5f}; 1 peer 6x slow")
    rows.add("timeout/wait_for_all_iqr_ms", _iqr(t_wait))
    rows.add("timeout/ejection_median_ms", float(np.median(t_ej)),
             f"drop={float(np.mean(d_ej)):.5f}; "
             f"ejected={list(control.detector.ejected_peers())}")
    rows.add("timeout/ejection_iqr_ms", _iqr(t_ej))
    rows.add("timeout/ejection_vs_wait_pct",
             100 * (1 - float(np.median(t_ej)) / float(np.median(t_wait))),
             "median step-time saved by degrading participation")
    rows.add("timeout/ejection_drop_frac", float(np.mean(d_ej)),
             "transport loss among active peers stays bounded")

    # ---- rebalance vs eject vs wait (straggler-proportional shards) -----
    # medians over the back half: the weight hysteresis takes a few tens
    # of steps to settle on the straggler's share, and the comparison is
    # about the steady state, not the transient
    t_w, _, _ = _run_rebalance("wait", steps)
    t_e, _, ctl_e = _run_rebalance("eject", steps)
    t_r, contrib, ctl_r = _run_rebalance("rebalance", steps)
    half = len(t_w) // 2
    tail = float(np.mean(contrib[-max(1, len(contrib) // 2):])) \
        if contrib else 0.0
    rows.add("timeout/rebalance_wait_median_ms",
             float(np.median(t_w[half:])), "1 peer 6x slow; wait-for-all")
    rows.add("timeout/rebalance_wait_iqr_ms", _iqr(t_w[half:]))
    rows.add("timeout/rebalance_eject_median_ms",
             float(np.median(t_e[half:])),
             f"ejected={list(ctl_e.detector.ejected_peers())}; "
             "straggler contributes nothing")
    rows.add("timeout/rebalance_eject_iqr_ms", _iqr(t_e[half:]))
    rows.add("timeout/rebalance_median_ms", float(np.median(t_r[half:])),
             f"weights={list(ctl_r.detector.weights())}; "
             f"ejected={list(ctl_r.detector.ejected_peers())}")
    rows.add("timeout/rebalance_iqr_ms", _iqr(t_r[half:]))
    rows.add("timeout/rebalance_vs_eject_pct",
             100 * (float(np.median(t_r[half:]))
                    / float(np.median(t_e[half:])) - 1),
             "acceptance: within +15% of ejection, contribution nonzero")
    rows.add("timeout/rebalance_contrib_frac", tail,
             "straggler's surviving gradient share (ejection: 0)")
    return rows


if __name__ == "__main__":
    run(quick=False)
