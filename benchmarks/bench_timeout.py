"""Paper §5.3 early-timeout ablation: t_C early expiry vs t_B-only.

With only the hard bound t_B, every lossy round burns the full t_B; the
early timeout expires at (last-percentile-seen + x%*t_C), recovering ~16%
of training time at equal drop rate (paper: 130 -> 112 min on VGG-19)."""
from __future__ import annotations

import math

import numpy as np

from repro.core.ubt import AdaptiveTimeout
from repro.sim.netsim import GASimulator, NetworkModel

from .common import Rows


def _run(early: bool, steps: int, seed: int = 7):
    # ablation environment with enough stall episodes that the warmup P95
    # (t_B) captures them — the regime where the two policies separate
    # (the paper's VGG-19 testbed ran under sustained background load)
    env = NetworkModel(p99_over_p50=1.5, stall_prob=0.015, seed=seed)
    sim = GASimulator(env, 8)
    nbytes = 25 * 2 ** 20
    timeout = sim.warmup(nbytes)
    times, drops = [], []
    n = 8
    chunk = nbytes / n
    rounds = 2 * (n - 1)
    for _ in range(steps):
        total_t, lost = 0.0, 0.0
        st, tf, fr = [], [], []
        for _ in range(rounds):
            t, loss = env.ubt_ms(chunk, n)
            if early:
                t99 = float(np.max(t * 0.99))
                deadline = min(timeout.round_deadline(True),
                               t99 + timeout.x * (timeout.t_c or t99))
            else:
                deadline = timeout.t_b          # hard bound only
            arrived = np.where(t <= deadline, 1.0 - loss,
                               np.minimum(1.0 - loss, deadline / t))
            if early:
                t_round = float(min(np.max(t), deadline))
            else:
                # without the early-expiry signal a receiver waiting on
                # DROPPED bytes cannot distinguish late from lost — it
                # burns the full t_B (§3.2.1 challenge (2))
                lossy = bool(np.any(loss > 0)) or bool(np.any(t > deadline))
                t_round = float(deadline if lossy else np.max(t))
            total_t += t_round
            lost += float(np.sum(1 - arrived)) * chunk
            st.append(t_round)
            tf.append(bool(np.any(t > deadline)))
            fr.append(float(np.mean(arrived)))
        drop = lost / (rounds * n * chunk)
        timeout.update(stage_times=st, timed_out=tf, frac_received=fr,
                       loss_frac=drop)
        times.append(total_t)
        drops.append(drop)
    return float(np.mean(times)), float(np.mean(drops))


def run(quick: bool = True) -> Rows:
    rows = Rows()
    steps = 100 if quick else 400
    t_off, d_off = _run(early=False, steps=steps)
    t_on, d_on = _run(early=True, steps=steps)
    rows.add("timeout/tb_only_ms", t_off, f"drop={d_off:.5f}")
    rows.add("timeout/early_tc_ms", t_on, f"drop={d_on:.5f}")
    rows.add("timeout/time_reduction_pct", 100 * (1 - t_on / t_off),
             "paper ~16% at equal drop rate")
    return rows


if __name__ == "__main__":
    run(quick=False)
