"""Fig 14 in miniature: fine-tune under increasing gradient-drop rates,
with and without the randomized Hadamard Transform.

    PYTHONPATH=src python examples/finetune_under_drops.py
    PYTHONPATH=src python examples/finetune_under_drops.py --recovery

Uses the real worker-replica emulation (sim/tta.py): N worker models, TAR
two-stage aggregation with tail drops, per-receiver buckets.

``--recovery`` runs the DESIGN §8 ablation instead: under bursty loss,
compare zero-fill against the stale-value fill and error-feedback recovery
mechanisms (final accuracy + replica divergence per mechanism).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sim.tta import TrainRunConfig, run_training


def sweep_hadamard(steps: int) -> None:
    print("condition,final_acc,mean_drop,replica_divergence")
    base = run_training(TrainRunConfig(steps=steps, eval_every=20))
    print(f"lossless,{base['acc'][-1]:.4f},0.0,0.0")
    for rate in (0.01, 0.05, 0.10):
        for ht in (True, False):
            h = run_training(TrainRunConfig(steps=steps, eval_every=20,
                                            drop_rate=rate, use_hadamard=ht))
            tag = f"drop{int(rate*100)}_{'ht' if ht else 'noht'}"
            print(f"{tag},{h['acc'][-1]:.4f},{h['mean_drop']:.4f},"
                  f"{h['divergence'][-1]:.5f}", flush=True)


def sweep_recovery(steps: int) -> None:
    print("condition,final_acc,mean_drop,replica_divergence")
    base = run_training(TrainRunConfig(steps=steps, eval_every=20))
    print(f"lossless,{base['acc'][-1]:.4f},0.0,0.0")
    for rate in (0.05, 0.10):
        for mech in ("none", "stale", "ef"):
            h = run_training(TrainRunConfig(
                steps=steps, eval_every=20, drop_rate=rate,
                drop_pattern="burst", recovery=mech))
            tag = f"burst{int(rate*100)}_{mech}"
            print(f"{tag},{h['acc'][-1]:.4f},{h['mean_drop']:.4f},"
                  f"{h['divergence'][-1]:.5f}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--recovery", action="store_true",
                    help="run the loss-recovery ablation (zero-fill vs "
                         "stale vs error feedback under bursty drops)")
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("STEPS", 120)))
    args = ap.parse_args()
    if args.recovery:
        sweep_recovery(args.steps)
    else:
        sweep_hadamard(args.steps)


if __name__ == "__main__":
    main()
