"""4-peer gradient allreduce over real localhost UDP sockets (DESIGN §7).

Every byte crosses the wire: each peer HTQuant/Hadamard-encodes its bucket,
packetizes the stage-1 shards into sequenced datagrams, the receivers
reassemble whatever arrives before the adaptive per-round deadline, and the
compensated mean absorbs what didn't.  The demo prints, per step:

  * per-peer stage completion times (the straggler detector's signal —
    peer 2 is scripted 5x slow, watch its column),
  * the adaptive receive deadline converging as AdaptiveTimeout profiles
    real wire stage times (warmup -> t_B -> early-timeout band),
  * the compensated mean's relative error under ~2% injected packet loss.

Falls back to the deterministic in-memory loopback when the sandbox forbids
UDP socket binding (same code path, virtual clock).

    PYTHONPATH=src python examples/udp_allreduce.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.allreduce import OptiReduceConfig
from repro.net import (HostRing, InprocBackend, UdpBackend, bernoulli_drops,
                       peer_factor_delays, udp_available)
from repro.runtime import ControlPlane


def main():
    n = 4
    steps = int(os.environ.get("UDP_DEMO_STEPS", 30))
    elems = 16_384
    drop_rate = float(os.environ.get("UDP_DEMO_DROP", 0.02))
    slow_peer, slow_factor = 2, 5.0

    cfg = OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                           hadamard_block=256, packet_elems=256)
    control = ControlPlane.create(
        n_nodes=n, timeout={"warmup_iters": 8}, detect_stragglers=True,
        # real-socket timing on a loaded host is noisy; only a sustained
        # multiple of the median should read as a straggler
        detector_kw=dict(eject_score=4.0, readmit_score=2.0))
    drops = bernoulli_drops(drop_rate, seed=1)
    if udp_available():
        backend_name = "udp"
        backend = UdpBackend(n, drop_fn=drops)
        default_deadline = 0.5
    else:
        backend_name = "inproc (UDP binding forbidden here)"
        backend = InprocBackend(
            n, drop_fn=drops,
            delay_fn=peer_factor_delays(
                1e-4, tuple(slow_factor if p == slow_peer else 1.0
                            for p in range(n))))
        default_deadline = 1.0
    print(f"backend={backend_name} peers={n} elems={elems} "
          f"injected_loss={drop_rate:.0%} (peer {slow_peer} scripted "
          f"{slow_factor:g}x slow on inproc)")

    ring = HostRing(n, cfg, backend=backend,
                    timeout=control.state.timeout,
                    default_deadline=default_deadline)
    rng = np.random.default_rng(0)
    buckets = rng.standard_normal((n, elems)).astype(np.float32)
    true = buckets.mean(axis=0)
    key = jax.random.PRNGKey(0)
    errs, losses = [], []

    print(f"{'step':>4} {'deadline':>9} "
          + " ".join(f"peer{p}_t" for p in range(n))
          + f" {'loss':>7} {'rel_err':>8}")
    try:
        for step in range(steps):
            deadline = ring.peers[0].round_deadline()
            out, tel = ring.allreduce(buckets, jax.random.fold_in(key, step),
                                      step=step)
            control.observe(tel)
            err = (np.linalg.norm(out[0] - true)
                   / max(np.linalg.norm(true), 1e-9))
            errs.append(err)
            losses.append(tel.loss_frac)
            times = " ".join(f"{t:7.4f}" for t in tel.peer_stage_times)
            print(f"{step:4d} {deadline:9.4f} {times} "
                  f"{tel.loss_frac:7.4f} {err:8.4f}")
        at = control.state.timeout
        print(f"\nAdaptiveTimeout profiled from the wire: "
              f"t_B={at.t_b:.4f} t_C={at.t_c:.4f} x={at.x:.2f} "
              f"-> deadline {at.round_deadline(False):.4f} "
              f"(started at {default_deadline})")
        policy = control.policy()
        print(f"StragglerDetector active set: "
              f"{policy.active_peers or tuple(range(n))} "
              f"(ejected: {control.detector.ejected_peers() or 'none'})")
        print(f"Missing packets became mask entries, never blocks: at mean "
              f"loss {np.mean(losses):.2%} the compensated mean's relative "
              f"error stayed bounded (mean {np.mean(errs):.3f}, "
              f"max {np.max(errs):.3f}) and 0 when nothing dropped.")
    finally:
        ring.close()


if __name__ == "__main__":
    main()
