"""4-peer gradient allreduce over real localhost UDP sockets (DESIGN §7).

Every byte crosses the wire: each peer HTQuant/Hadamard-encodes its bucket,
packetizes the stage-1 shards into sequenced datagrams, the receivers
reassemble whatever arrives before the adaptive per-round deadline, and the
compensated mean absorbs what didn't.  The demo prints, per step:

  * per-peer stage completion times (the straggler detector's signal —
    peer 2 is scripted 5x slow, watch its column),
  * the adaptive receive deadline converging as AdaptiveTimeout profiles
    real wire stage times (warmup -> t_B -> early-timeout band),
  * the compensated mean's relative error under ~2% injected packet loss.

Falls back to the deterministic in-memory loopback when the sandbox forbids
UDP socket binding (same code path, virtual clock).

    PYTHONPATH=src python examples/udp_allreduce.py

``--spawn`` instead routes through the multi-process launcher (DESIGN §9):
one OS process per rank over the TCP rendezvous, a scripted SIGKILL
mid-run, and the relaunch that restores the victim's checkpoint and walks
it back in through the survivors' PROBATION window:

    PYTHONPATH=src python examples/udp_allreduce.py --spawn
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.allreduce import OptiReduceConfig
from repro.net import (HostRing, InprocBackend, UdpBackend, bernoulli_drops,
                       peer_factor_delays, udp_available)
from repro.runtime import ControlPlane


def run_spawn():
    """SIGKILL-and-readmit demo through repro.launch.multiproc: spawn one
    process per rank (threads when the sandbox forbids sockets), SIGKILL
    rank 1 mid-run, relaunch it, and narrate the membership lifecycle the
    survivors observed — ejection, checkpoint restore, probation, active.
    """
    from repro.launch import multiproc as mp

    n, kill_rank, kill_step, steps = 4, 1, 1, 8
    over_udp = udp_available()
    backend = "udp" if over_udp else "inproc"
    argv = ["--backend", backend, "--nprocs", str(n), "--steps", str(steps),
            "--elems", "4096", "--drop-rate", "0.02",
            "--kill-rank", str(kill_rank), "--kill-step", str(kill_step),
            "--restart"]
    if over_udp:
        # a respawned OS process pays interpreter + jit warmup before it can
        # rejoin; pace the survivors so readmission happens mid-run
        argv += ["--step-sleep", "2.0", "--deadline", "1.0"]
    print(f"spawning {n} {'processes' if over_udp else 'threads'} "
          f"({backend}); SIGKILL rank {kill_rank} at step {kill_step}, "
          f"then relaunch it\n")
    report = mp.main(argv)

    killed = [w for w in report["workers"] if w.get("exit") == "killed"]
    finished = {w["rank"]: w for w in report["workers"] if "steps" in w}
    for _ in killed:
        print(f"rank {kill_rank}: SIGKILLed at step {kill_step} — no FIN, "
              f"no atexit; the rendezvous heartbeat is what notices")
    rejoin = finished.get(kill_rank)
    if rejoin is not None:
        print(f"rank {kill_rank} relaunched (uid {rejoin['uid']}): restored "
              f"checkpoint step {rejoin['resumed_from']}, rejoined at step "
              f"{rejoin['start_step']}, finished step "
              f"{rejoin['steps'][-1]['step']}")
    print(f"\n{'step':>4}  " + "  ".join(
        f"rank{r}:sees_rank{kill_rank}" for r in range(n) if r != kill_rank))
    for step in range(steps):
        row = []
        for r in range(n):
            if r == kill_rank:
                continue
            rec = next((s for s in finished[r]["steps"]
                        if s["step"] == step), None)
            row.append("-" if rec is None else rec["statuses"][kill_rank])
        print(f"{step:4d}  " + "  ".join(f"{c:>16}" for c in row))
    checks = {}
    for r, w in sorted(finished.items()):
        for s in w["steps"]:
            checks.setdefault(s["step"], set()).add(s["checksum"])
    agree = [step for step, cs in sorted(checks.items()) if len(cs) == 1]
    print(f"\nbitwise-identical results across participants at steps "
          f"{agree} (membership changes redraw the mean, never corrupt it)")


def main():
    n = 4
    steps = int(os.environ.get("UDP_DEMO_STEPS", 30))
    elems = 16_384
    drop_rate = float(os.environ.get("UDP_DEMO_DROP", 0.02))
    slow_peer, slow_factor = 2, 5.0

    cfg = OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                           hadamard_block=256, packet_elems=256)
    control = ControlPlane.create(
        n_nodes=n, timeout={"warmup_iters": 8}, detect_stragglers=True,
        # real-socket timing on a loaded host is noisy; only a sustained
        # multiple of the median should read as a straggler
        detector_kw=dict(eject_score=4.0, readmit_score=2.0))
    drops = bernoulli_drops(drop_rate, seed=1)
    if udp_available():
        backend_name = "udp"
        backend = UdpBackend(n, drop_fn=drops)
        default_deadline = 0.5
    else:
        backend_name = "inproc (UDP binding forbidden here)"
        backend = InprocBackend(
            n, drop_fn=drops,
            delay_fn=peer_factor_delays(
                1e-4, tuple(slow_factor if p == slow_peer else 1.0
                            for p in range(n))))
        default_deadline = 1.0
    print(f"backend={backend_name} peers={n} elems={elems} "
          f"injected_loss={drop_rate:.0%} (peer {slow_peer} scripted "
          f"{slow_factor:g}x slow on inproc)")

    ring = HostRing(n, cfg, backend=backend,
                    timeout=control.state.timeout,
                    default_deadline=default_deadline)
    rng = np.random.default_rng(0)
    buckets = rng.standard_normal((n, elems)).astype(np.float32)
    true = buckets.mean(axis=0)
    key = jax.random.PRNGKey(0)
    errs, losses = [], []

    print(f"{'step':>4} {'deadline':>9} "
          + " ".join(f"peer{p}_t" for p in range(n))
          + f" {'loss':>7} {'rel_err':>8}")
    try:
        for step in range(steps):
            deadline = ring.peers[0].round_deadline()
            out, tel = ring.allreduce(buckets, jax.random.fold_in(key, step),
                                      step=step)
            control.observe(tel)
            err = (np.linalg.norm(out[0] - true)
                   / max(np.linalg.norm(true), 1e-9))
            errs.append(err)
            losses.append(tel.loss_frac)
            times = " ".join(f"{t:7.4f}" for t in tel.peer_stage_times)
            print(f"{step:4d} {deadline:9.4f} {times} "
                  f"{tel.loss_frac:7.4f} {err:8.4f}")
        at = control.state.timeout
        print(f"\nAdaptiveTimeout profiled from the wire: "
              f"t_B={at.t_b:.4f} t_C={at.t_c:.4f} x={at.x:.2f} "
              f"-> deadline {at.round_deadline(False):.4f} "
              f"(started at {default_deadline})")
        policy = control.policy()
        print(f"StragglerDetector active set: "
              f"{policy.active_peers or tuple(range(n))} "
              f"(ejected: {control.detector.ejected_peers() or 'none'})")
        print(f"Missing packets became mask entries, never blocks: at mean "
              f"loss {np.mean(losses):.2%} the compensated mean's relative "
              f"error stayed bounded (mean {np.mean(errs):.3f}, "
              f"max {np.max(errs):.3f}) and 0 when nothing dropped.")
    finally:
        ring.close()


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spawn", action="store_true",
                    help="multi-process launch with a scripted SIGKILL + "
                         "restart (repro.launch.multiproc)")
    run_spawn() if ap.parse_args().spawn else main()
