"""Batched serving demo: prefill a batch of prompts, then greedy-decode
continuations with the KV-cache/SSM-state engine.

    PYTHONPATH=src python examples/serve_batched.py --arch jamba-v0.1-52b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import (SINGLE, decode_step, init_decode_state,
                          init_params, prefill_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=20)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    # ---- prefill: consume prompts, build decode state -------------------
    t0 = time.time()
    first, prefill_state = prefill_step(params, {"tokens": prompts}, cfg,
                                        SINGLE, key=key)
    print(f"prefill {prompts.shape} in {time.time()-t0:.2f}s")

    # decode state sized for prompt + generation; splice the prefill caches
    state = init_decode_state(params, cfg, batch=args.batch,
                              max_seq=args.prompt_len + args.max_new,
                              dtype=cfg.param_dtype)
    from repro.models.layers import KVCache
    spliced = []
    for st_new, st_pf in zip(state, prefill_state):
        if isinstance(st_new, KVCache):
            spliced.append(KVCache(
                k=st_new.k.at[:, :, :args.prompt_len].set(
                    st_pf.k.astype(st_new.k.dtype)),
                v=st_new.v.at[:, :, :args.prompt_len].set(
                    st_pf.v.astype(st_new.v.dtype))))
        else:
            spliced.append(jax.tree.map(lambda a, b: b.astype(a.dtype),
                                        st_new, st_pf))
    state = spliced

    # ---- decode loop -----------------------------------------------------
    step = jax.jit(lambda p, s, t, pos: decode_step(
        p, s, t, pos, cfg, SINGLE, key=key))
    tok = first
    out = [prompts, tok]
    t0 = time.time()
    for t in range(args.max_new - 1):
        tok, state = step(params, state, tok,
                          jnp.asarray(args.prompt_len + t, jnp.int32))
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.batch}x{args.max_new} in {dt:.2f}s "
          f"({args.batch*args.max_new/dt:.1f} tok/s)")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
