"""Elastic rescaling demo: train on N workers, checkpoint, resume on N'.

    PYTHONPATH=src python examples/elastic_rescale.py

Shows the full fault-tolerance loop: deterministic data re-partitioning,
FSDP shard surgery (gather old shards -> re-split), and loss continuity
across the rescale. OptiReduce itself is N-agnostic (TAR shard count
follows the axis size), so nothing in the collective needs migrating.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import SINGLE, init_params, lm_loss
from repro.optim.optimizers import OptimizerConfig, make_optimizer
from repro.train import checkpoint as ckpt
from repro.train.elastic import gather_shards, reshard


def train_phase(params, opt, opt_state, data, steps, start, n_workers):
    """Emulated N-worker DDP phase (per-worker grads, mean-aggregated)."""
    cfg = get_smoke("gpt2-paper")

    @jax.jit
    def step(p, o, batch, s):
        def loss_fn(pp):
            return lm_loss(pp, batch, cfg, SINGLE, key=jax.random.PRNGKey(0),
                           seq_chunk=32)
        l, g = jax.value_and_grad(loss_fn)(p)
        p2, o2 = opt.update(g, o, p, jnp.float32(3e-3), s)
        return p2, o2, l

    losses = []
    for s in range(start, start + steps):
        # each worker loads only its shard; aggregate == global batch here
        parts = [data.host_batch(s, w, n_workers) for w in range(n_workers)]
        batch = {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt_state, loss = step(params, opt_state, batch,
                                       jnp.asarray(s))
        losses.append(float(loss))
    return params, opt_state, losses


def main():
    cfg = get_smoke("gpt2-paper")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, markov_weight=0.85,
                                  n_succ=1))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = make_optimizer(OptimizerConfig(name="momentum", lr=3e-3,
                                         weight_decay=0.0))
    opt_state = opt.init(params)

    # --- phase 1: 8 workers ------------------------------------------------
    params, opt_state, l1 = train_phase(params, opt, opt_state, data,
                                        steps=40, start=0, n_workers=8)
    print(f"phase1 (N=8):  loss {l1[0]:.3f} -> {l1[-1]:.3f}")

    # checkpoint as 8 FSDP shards (what each worker would hold)
    shards = reshard(params, cfg, 8)
    ckpt.save("/tmp/optireduce_elastic", 40, shards[0],
              meta={"n_workers": 8, "shard": 0})
    print("checkpointed worker-0 shard; simulating rescale 8 -> 4 workers")

    # --- rescale: reassemble from shards, re-split for 4 workers -----------
    full = gather_shards(shards, cfg)
    new_shards = reshard(full, cfg, 4)
    assert len(new_shards) == 4
    restored = gather_shards(new_shards, cfg)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # --- phase 2: 4 workers, same global stream ----------------------------
    params, opt_state, l2 = train_phase(restored, opt, opt_state, data,
                                        steps=40, start=40, n_workers=4)
    print(f"phase2 (N=4):  loss {l2[0]:.3f} -> {l2[-1]:.3f}")
    assert l2[0] <= l1[0], "loss must not regress across the rescale"
    print("elastic rescale OK: training continued seamlessly on N'=4")


if __name__ == "__main__":
    main()
