"""Elastic participation demo: straggler detected -> ejected -> probation
-> readmitted, end to end through the runtime control plane.

    PYTHONPATH=src python examples/elastic_rescale.py

An 8-node job runs under the calibrated cloud-network simulator.  Mid-run
one peer degrades to 7x latency on every transfer (a persistent compute/
network straggler — the case the §3.2 timeout controllers alone cannot fix,
since t_B just converges to the straggler's pace).  The control plane's
EWMA detector ejects it: the SyncPolicy's active-peer set shrinks, the TAR
round schedule regenerates over the remaining peers (the ejected peer's
gradient contribution is excluded and compensated, and it still *receives*
every reduced bucket, so it keeps training).  When the peer heals, the
cooldown expires into probation and clean steps readmit it — a pure policy
flip, served from the compiled-step cache, no checkpoint surgery.

Per-phase step times and drop fractions are printed, plus every policy
transition and the step-cache hit/miss trace (eject -> readmit reuses the
previously compiled steps; only the first sight of each policy "compiles").

Act two narrates the gentler alternative (DESIGN §10): the same straggler
under ``rebalance=True`` — instead of ejecting, the detector's EWMA scores
become shard *weights*, the slow peer's slice of the TAR schedule shrinks
(it keeps contributing gradient, just over fewer elements), step time
recovers to near-ejection pace, and when the peer heals its weight floats
back to uniform — at which point the policy normalizes to the exact
full-participation trace again.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.runtime import ControlPlane, PolicyStepCache
from repro.sim.netsim import GASimulator, NetworkModel

N, SLOW_PEER, SLOW_FACTOR = 8, 5, 7.0
BUCKET = 25 * 2 ** 20


def run_phase(name, sim, control, cache, steps, transitions):
    """Simulate one phase; returns (median step ms, mean drop frac)."""
    times, drops = [], []
    policy = control.policy()
    for _ in range(steps):
        r = sim.optireduce(BUCKET, control, fixed_incast=1)
        times.append(r.time_ms)
        drops.append(r.drop_frac)
        new = control.policy()
        if new != policy:
            if cache.get(new) is None:
                cache.put(new, f"compiled-step-{len(cache)}")
                how = "compiled"
            else:
                how = "cache hit"
            if new.active_peers != policy.active_peers:   # membership moved
                status = control.detector.status(SLOW_PEER)
                transitions.append(
                    f"  step {control.steps:3d}: peer {SLOW_PEER} is "
                    f"{status:9s} active={new.active_peers or 'all'} ({how})")
            policy = new
    med, drop = float(np.median(times)), float(np.mean(drops))
    print(f"{name:28s} median step {med:7.2f} ms   drop {drop:.5f}   "
          f"active={control.policy().active_peers or 'all'}")
    return med, drop, times


def main():
    env = NetworkModel.environment("local_1.5", seed=42)
    sim = GASimulator(env, N)
    # short detector windows so the whole loop fits in a demo run
    control = ControlPlane.create(
        n_nodes=N, detector_kw=dict(alpha=0.4, patience=3, cooldown=15,
                                    probation=4))
    cache = PolicyStepCache(maxsize=4)
    cache.put(control.policy(), "compiled-step-0")
    sim.warmup(BUCKET, control=control)
    transitions: list[str] = []

    print(f"8-node OptiReduce job, 25 MB buckets ({env.p99_over_p50} "
          "tail environment)\n")
    healthy, _, _ = run_phase("phase 1: healthy", sim, control, cache, 40,
                              transitions)

    env.peer_factors = tuple(SLOW_FACTOR if p == SLOW_PEER else 1.0
                             for p in range(N))
    degraded, _, t2 = run_phase(
        f"phase 2: peer {SLOW_PEER} {SLOW_FACTOR:.0f}x slow", sim, control,
        cache, 40, transitions)
    det = control.detector.peers[SLOW_PEER]
    assert det.ejections >= 1, "straggler was never ejected"
    eject_at = next((i for i, t in enumerate(t2) if t < 2 * healthy), None)
    if eject_at is not None:
        waiting = float(np.median(t2[:max(eject_at, 1)]))
        after = float(np.median(t2[eject_at:]))
        print(f"    waiting on the straggler: {waiting:7.2f} ms/step; "
              f"after ejection: {after:7.2f} ms/step")

    env.peer_factors = None                       # the peer heals
    healed, _, _ = run_phase("phase 3: peer healed", sim, control, cache,
                             60, transitions)

    print("\npolicy transitions:")
    print("\n".join(transitions))
    print(f"\nstep cache: {cache.hits} hits, {cache.misses} misses "
          f"({len(cache)} compiled steps held)")

    post_eject = degraded  # median over the phase incl. pre-ejection steps
    assert post_eject < SLOW_FACTOR * healthy, \
        "ejection did not contain the straggler tail"
    final = control.detector.status(SLOW_PEER)
    assert final in ("active", "probation"), \
        f"healed peer was never readmitted (still {final})"
    print(f"\npeer {SLOW_PEER} final state: {final}"
          f"{' (readmitted)' if final == 'active' else ''}")
    print("elastic participation OK: ejected on degradation, readmitted "
          "after probation, no checkpoint surgery")


def rebalance_act():
    """Act two: the same straggler, rebalanced instead of ejected."""
    print("\n--- act two: rebalance instead of eject " + "-" * 28)
    env = NetworkModel.environment("local_1.5", seed=7)
    sim = GASimulator(env, N)
    control = ControlPlane.create(n_nodes=N, detect_stragglers=False,
                                  rebalance=True,
                                  detector_kw=dict(alpha=0.4))
    sim.warmup(BUCKET, control=control)

    def phase(name, steps):
        times, contribs = [], []
        for _ in range(steps):
            r = sim.optireduce(BUCKET, control, fixed_incast=4)
            times.append(r.time_ms)
            if r.peer_contrib is not None:
                contribs.append(r.peer_contrib[SLOW_PEER])
        w = control.detector.weights()
        med = float(np.median(times))
        share = float(np.mean(contribs[-10:])) if contribs \
            else w[SLOW_PEER] / sum(w)
        print(f"{name:28s} median step {med:7.2f} ms   "
              f"weights={list(w)}   peer {SLOW_PEER} contrib {share:.3f}")
        return med, w, share

    healthy, w0, _ = phase("phase 1: healthy", 30)
    env.peer_factors = tuple(SLOW_FACTOR if p == SLOW_PEER else 1.0
                             for p in range(N))
    slowed, w1, share = phase(
        f"phase 2: peer {SLOW_PEER} {SLOW_FACTOR:.0f}x slow", 50)
    env.peer_factors = None
    healed, w2, _ = phase("phase 3: peer healed", 50)

    assert w1[SLOW_PEER] < w1[0], \
        "the straggler's shard weight never shrank"
    assert share > 0.0, "rebalanced straggler lost its gradient share"
    assert slowed < SLOW_FACTOR * healthy, \
        "rebalancing did not contain the straggler tail"
    assert len(set(w2)) == 1, \
        f"healed peer's weight never floated back to uniform: {w2}"
    print(f"\nrebalance OK: weight {w0[SLOW_PEER]} -> {w1[SLOW_PEER]} "
          f"while slow, yet {share:.0%} of the straggler's gradient still "
          f"reached the aggregate (ejection: 0%), back to {w2[SLOW_PEER]} "
          "after healing — no ejection, no lost gradient")


if __name__ == "__main__":
    main()
    rebalance_act()
