"""Quickstart: train the paper's GPT-2 (reduced config) end-to-end with
OptiReduce gradient sync, checkpointing and the §3.4 safeguards.

    PYTHONPATH=src python examples/quickstart.py

Runs a few hundred steps of a ~1M-parameter same-family model on the
synthetic-grammar LM task (CPU-sized; the identical code path drives the
full configs on a real mesh via repro.launch.train).
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import OptiReduceConfig, strategies
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.optim.optimizers import OptimizerConfig
from repro.train import checkpoint as ckpt
from repro.train.trainer import TrainConfig, build_train_step


def main():
    steps = int(os.environ.get("QUICKSTART_STEPS", 200))
    # any registered Topology x Transport x Codec composition works here —
    # see repro.core.pipeline.register_strategy for adding your own
    strategy = os.environ.get("QUICKSTART_STRATEGY", "optireduce")
    print(f"strategy={strategy} (registered: {', '.join(strategies())})")
    cfg = get_smoke("gpt2-paper")
    mesh = make_host_mesh(dp=1, tp=1)
    tc = TrainConfig(
        sync=OptiReduceConfig(strategy=strategy, drop_rate=0.01,
                              drop_pattern="tail", hadamard_block=1024),
        optimizer=OptimizerConfig(name="adamw", lr=3e-3),
        dp_mode="replicated", seq_chunk=64)

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=16, markov_weight=0.85,
                                  n_succ=1))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    make_step, opt, _ = build_train_step(cfg, tc, mesh)
    batch0 = jax.tree.map(jnp.asarray, data.host_batch(0, 0, 1))
    step_fn, sh = make_step(jax.eval_shape(opt.init, params), batch0)
    params = jax.device_put(params, sh["params"])
    opt_state = jax.jit(opt.init, out_shardings=sh["opt"])(params)
    jf = jax.jit(step_fn, donate_argnums=(0, 1))

    saver = ckpt.AsyncCheckpointer("/tmp/optireduce_quickstart")
    t0 = time.time()
    for step in range(steps):
        batch = jax.tree.map(jnp.asarray, data.host_batch(step, 0, 1))
        batch = jax.device_put(batch, sh["batch"])
        params, opt_state, m = jf(params, opt_state, batch,
                                  jnp.asarray(step, jnp.int32), key)
        if step % 25 == 0 or step == steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"loss_frac {float(m['loss_frac']):.5f} "
                  f"({(step+1)/(time.time()-t0):.1f} it/s)", flush=True)
        if step and step % 100 == 0:
            saver.save(step, (params, opt_state))
    saver.wait()
    print("done — checkpoints in /tmp/optireduce_quickstart")


if __name__ == "__main__":
    main()
