"""Trace a tail-latency incident end to end (DESIGN §12).

A 4-peer in-memory allreduce ring where peer 3 is scripted 6x slow and the
wire loses ~1% of packets in Gilbert–Elliott bursts.  With tracing on, the
run records every receive round as a ``"round"`` span (per-sender ``tid``),
every expired deadline as a ``"timeout"`` instant, and every control-plane
decision — the straggler score crossing, the ejection, the codec/incast
moves — as a ``cat="policy"`` event.  The export is a Perfetto JSON you can
drop onto https://ui.perfetto.dev, and ``repro.obs.report`` folds the same
file into the paper-style story:

  * the round-completion tail table (p50 vs p99/p999: the straggler lives
    entirely in the tail percentiles until the ejection removes it),
  * the event timeline showing the causal chain — repeated ``timeout``
    events on peer 3's rounds, then ``eject(peer=3, cause=score)``, then
    the ``policy_change`` that recompiles the schedule without it.

    PYTHONPATH=src python examples/trace_tail_latency.py [--steps N]
                                                         [--out DIR]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.allreduce import OptiReduceConfig
from repro.net import HostRing, InprocBackend, peer_factor_delays
from repro.net.inproc import burst_drops
from repro.obs import report as obs_report
from repro.obs import trace, write_trace
from repro.runtime import ControlPlane

SLOW_PEER, SLOW_FACTOR, BURST_LOSS = 3, 6.0, 0.01


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--out", default=None,
                    help="trace output dir (default: a fresh tempdir)")
    args = ap.parse_args(argv)
    n, elems = 4, 8192
    out_dir = args.out or tempfile.mkdtemp(prefix="repro_trace_")

    tracer = trace.configure(True, capacity=1 << 16)
    cfg = OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                           hadamard_block=256, packet_elems=256)
    control = ControlPlane.create(
        n_nodes=n, timeout={"warmup_iters": 6}, detect_stragglers=True,
        detector_kw=dict(alpha=0.4, patience=3))
    backend = InprocBackend(
        n, drop_fn=burst_drops(BURST_LOSS, seed=2, mean_burst=8.0),
        delay_fn=peer_factor_delays(
            1e-4, tuple(SLOW_FACTOR if p == SLOW_PEER else 1.0
                        for p in range(n))))
    ring = HostRing(n, cfg, backend=backend,
                    timeout=control.state.timeout, default_deadline=1.0)

    print(f"tracing a {n}-peer inproc ring: peer {SLOW_PEER} scripted "
          f"{SLOW_FACTOR:g}x slow, {BURST_LOSS:.0%} bursty loss, "
          f"{args.steps} steps")
    rng = np.random.default_rng(0)
    buckets = rng.standard_normal((n, elems)).astype(np.float32)
    key = jax.random.PRNGKey(0)
    ejected_at = None
    try:
        for step in range(args.steps):
            with tracer.span("step", "trainer", step=step):
                _, tel = ring.allreduce(buckets,
                                        jax.random.fold_in(key, step),
                                        step=step)
                control.observe(tel)
            if ejected_at is None and \
                    control.detector.ejected_peers() == (SLOW_PEER,):
                ejected_at = step
                print(f"  step {step:3d}: control plane ejected peer "
                      f"{SLOW_PEER} (score crossed after patience)")
    finally:
        ring.close()

    path = write_trace(out_dir, tracer,
                       meta={"demo": "trace_tail_latency",
                             "slow_peer": SLOW_PEER})
    print(f"\nwrote {len(tracer)} records ({tracer.dropped} dropped) -> "
          f"{path}\n(open it at https://ui.perfetto.dev, or re-render with "
          f"`python -m repro.obs.report {out_dir}`)\n")

    rep = obs_report.merge_report([obs_report.load_trace(path)])
    print(obs_report.render(rep, events=18))

    # narrate the causal chain the table + timeline encode
    s = rep["tables"]["round"]["merged"]
    timeouts = [e for e in rep["timeline"] if e["name"] == "timeout"]
    slow_tos = [e for e in timeouts
                if e["args"].get("sender") == SLOW_PEER]
    ejects = [e for e in rep["timeline"] if e["name"] == "eject"]
    print(f"\nthe incident, in numbers: p50 round time {s['p50']:.0f}us "
          f"vs p999 {s['p999']:.0f}us — a {s['p999'] / s['p50']:.0f}x tail "
          f"from one {SLOW_FACTOR:g}x straggler.")
    print(f"{len(timeouts)} receive deadlines expired "
          f"({len(slow_tos)} on peer {SLOW_PEER}'s rounds); "
          + (f"the detector ejected peer {ejects[0]['args']['peer']} at "
             f"step {ejects[0]['args']['step']} (cause="
             f"{ejects[0]['args']['cause']}), after which the tail is the "
             "network's, not the straggler's."
             if ejects else "no ejection (raise --steps)."))
    trace.reset()
    return rep


if __name__ == "__main__":
    main()
