"""Property + loop tests for the socket rendezvous (repro/net/rendezvous.py).

Mirrors the wire.py test discipline: the message codec must roundtrip and
be invariant to stream chunking; client view state must be invariant to
duplicate / out-of-order UPDATE delivery; the pure state machine must keep
its generation strictly monotonic under arbitrary join/leave interleavings
and release a barrier tag exactly when every required live member arrived.
The TCP and in-memory shells are exercised end-to-end (join -> barriers ->
leave/death -> degraded release).
"""
import socket
import threading
import time

import pytest

try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.net import (PHASES_PER_STEP, FrameBuffer, LocalCoordinator,
                       Member, Membership, RendezvousClient, RendezvousError,
                       RendezvousFull, RendezvousMessage, RendezvousServer,
                       RendezvousState, tcp_available)
from repro.net.rendezvous import (MSG_BARRIER, MSG_HEADER_BYTES, MSG_JOIN,
                                  MSG_RELEASE, MSG_UPDATE, MSG_WELCOME,
                                  _ClientCore, decode_join, encode_join)

pytestmark = pytest.mark.net

needs_tcp = pytest.mark.skipif(not tcp_available(),
                               reason="sandbox forbids TCP sockets")


# ---------------------------------------------------------- message codec
@given(st.sampled_from([MSG_JOIN, MSG_WELCOME, MSG_UPDATE, MSG_BARRIER,
                        MSG_RELEASE]),
       st.integers(-1, 32767), st.integers(0, 65535),
       st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_message_roundtrip(kind, rank, world, generation, seq):
    msg = RendezvousMessage(kind=kind, rank=rank, world=world,
                            generation=generation, seq=seq,
                            payload=b"\x00\x01payload\xff")
    blob = msg.encode()
    back, used = RendezvousMessage.decode(blob + b"trailing")
    assert back == msg
    assert used == len(blob)
    assert len(blob) == MSG_HEADER_BYTES + len(msg.payload)


def test_message_rejects_garbage():
    msg = RendezvousMessage(kind=MSG_BARRIER, seq=7)
    blob = msg.encode()
    with pytest.raises(RendezvousError):
        RendezvousMessage.decode(bytes([99]) + blob[1:])     # bad version
    with pytest.raises(RendezvousError):
        RendezvousMessage.decode(blob[:1] + bytes([77]) + blob[2:])
    assert RendezvousMessage.decode(blob[:MSG_HEADER_BYTES - 1]) is None
    with pytest.raises(RendezvousError):
        RendezvousMessage(kind=MSG_UPDATE,
                          payload=b"x" * 0x10000).encode()   # length field


@given(st.integers(1, 64), st.integers(0, 6))
def test_framebuffer_chunk_invariance(chunk, seed):
    """Feeding a message stream in arbitrary chunk sizes yields exactly the
    same message sequence (TCP delivers bytes, not datagrams)."""
    msgs = [RendezvousMessage(kind=MSG_BARRIER, rank=r, seq=seed * 10 + r,
                              payload=b"p" * (r * 3))
            for r in range(5)]
    stream = b"".join(m.encode() for m in msgs)
    fb = FrameBuffer()
    got = []
    for i in range(0, len(stream), chunk):
        got.extend(fb.feed(stream[i:i + chunk]))
    assert got == msgs


@given(st.integers(0, 2**32 - 1), st.integers(1, 64))
def test_membership_blob_roundtrip(generation, world):
    mem = Membership(
        generation=generation, world_size=world,
        members=tuple(Member(rank=r, uid=r * 7 + 1, host="127.0.0.1",
                             port=40000 + r, since=r * PHASES_PER_STEP)
                      for r in range(min(world, 5))))
    assert Membership.decode(mem.encode()) == mem


def test_join_payload_roundtrip():
    assert decode_join(encode_join(42, "10.0.0.3", 9999)) == \
        (42, "10.0.0.3", 9999)
    with pytest.raises(RendezvousError):
        decode_join(b"\x00")


# ------------------------------------------------------- client view state
def test_client_core_update_invariance():
    """Duplicate and out-of-order UPDATEs never roll the snapshot back:
    only a strictly newer generation moves it; events always append."""
    core = _ClientCore()
    m1 = Membership(generation=1, world_size=2,
                    members=(Member(rank=0, uid=1), Member(rank=1, uid=2)))
    m3 = Membership(generation=3, world_size=2,
                    members=(Member(rank=0, uid=1),))
    core.apply(m3, ("death", 1, 3))
    core.apply(m1, ("join", 1, 1))              # stale: arrives late
    assert core.membership == m3
    core.apply(m3, ("death", 1, 3))             # duplicate delivery
    assert core.membership == m3
    assert list(core.events) == [("death", 1, 3), ("join", 1, 1),
                                 ("death", 1, 3)]


# ------------------------------------------------------ pure state machine
def _ops_from_seed(seed, world, n_ops):
    """Deterministic join/leave/death op tape for the interleaving test."""
    h = seed
    ops = []
    for i in range(n_ops):
        h = (h * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        ops.append(("join", "leave", "dead")[h % 3])
    return ops


@given(st.integers(0, 2**32 - 1), st.integers(1, 5), st.integers(4, 24))
def test_generation_monotone_under_interleavings(seed, world, n_ops):
    """Every successful membership mutation bumps the generation by exactly
    one; rank slots stay unique and inside the world; a failed op leaves
    the generation untouched."""
    st_ = RendezvousState(world)
    uid = 0
    for op in _ops_from_seed(seed, world, n_ops):
        gen = st_.generation
        live = st_.live_ranks()
        if op == "join":
            try:
                rank, since = st_.join(uid, "h", 1000 + uid, now=0.0)
                uid += 1
                assert rank not in live and 0 <= rank < world
                assert since % PHASES_PER_STEP == 0
                assert st_.generation == gen + 1
            except RendezvousFull:
                assert len(live) == world and st_.generation == gen
        else:
            target = live[0] if live else 0
            removed = (st_.leave(target) if op == "leave"
                       else st_.dead(target))
            assert removed == (target in live)
            assert st_.generation == gen + (1 if removed else 0)
        ranks = st_.live_ranks()
        assert len(set(ranks)) == len(ranks)
        assert all(0 <= r < world for r in ranks)


def test_initial_cohort_since_zero_rejoiner_next_boundary():
    st_ = RendezvousState(2)
    _, since0 = st_.join(10, "h", 1, now=0.0)
    _, since1 = st_.join(11, "h", 2, now=0.0)
    assert since0 == since1 == 0 and st_.started
    for tag in range(6):                     # run into step 1, phase 1
        st_.barrier_arrive(0, tag)
        st_.barrier_arrive(1, tag)
    assert st_.latest_step() == 1
    assert st_.leave(0)
    rank, since = st_.join(12, "h", 3, now=0.0)
    assert rank == 0
    assert since == 2 * PHASES_PER_STEP      # next step boundary: tag 8


def test_release_requires_every_required_member():
    st_ = RendezvousState(2)
    st_.join(1, "h", 1, now=0.0)
    st_.barrier_arrive(0, 0)
    assert st_.release_ready() == {}         # not started: world incomplete
    st_.join(2, "h", 2, now=0.0)
    assert st_.release_ready() == {}         # started, but rank 1 not there
    st_.barrier_arrive(1, 0)
    assert st_.release_ready() == {0: (0, 1)}
    assert st_.release_ready() == {}         # released tags retire


def test_death_releases_held_fence_degraded():
    st_ = RendezvousState(2)
    st_.join(1, "h", 1, now=0.0)
    st_.join(2, "h", 2, now=0.0)
    st_.barrier_arrive(0, 4)
    assert st_.release_ready() == {}
    assert st_.dead(1)                       # the awaited peer crashes
    assert st_.release_ready() == {4: (0,)}  # survivors proceed degraded


def test_rejoiner_not_required_at_inflight_fences():
    st_ = RendezvousState(2)
    st_.join(1, "h", 1, now=0.0)
    st_.join(2, "h", 2, now=0.0)
    for tag in range(5):
        st_.barrier_arrive(0, tag)
        st_.barrier_arrive(1, tag)
        st_.release_ready()
    st_.dead(1)
    st_.join(3, "h", 3, now=0.0)             # rejoiner: since = tag 8
    st_.barrier_arrive(0, 5)
    assert st_.release_ready() == {5: (0,)}  # tag 5 predates its since
    st_.barrier_arrive(0, 8)
    assert st_.release_ready() == {}         # tag 8 requires the rejoiner
    st_.barrier_arrive(1, 8)
    assert st_.release_ready() == {8: (0, 1)}


def test_heartbeat_expiry_is_death():
    st_ = RendezvousState(2, heartbeat_timeout=1.0)
    st_.join(1, "h", 1, now=0.0)
    st_.join(2, "h", 2, now=0.0)
    st_.heartbeat(0, 5.0)
    assert st_.expire(5.5) == [1]
    assert st_.live_ranks() == (0,)


# ------------------------------------------------------- in-memory shell
def test_local_loop_join_barrier_events():
    coord = LocalCoordinator(3)
    clients = [coord.client(u) for u in range(3)]
    ranks = sorted(c.join()[0] for c in clients)
    assert ranks == [0, 1, 2]
    done = []

    def run(c):
        for tag in range(4):
            c.barrier(tag)
        done.append(c.rank)

    ts = [threading.Thread(target=run, args=(c,)) for c in clients]
    for t in ts: t.start()
    for t in ts: t.join()
    assert sorted(done) == [0, 1, 2]
    gen = clients[0].generation
    clients[2].crash()
    assert clients[0].generation == gen + 1
    assert ("death", clients[2].rank, gen + 1) in clients[0].events()
    assert not clients[0].is_live(clients[2].rank)


def test_local_crash_releases_waiters():
    coord = LocalCoordinator(2)
    a, b = coord.client(0), coord.client(1)
    a.join(); b.join()
    released = []

    def wait():
        a.barrier(0, timeout=10.0)
        released.append(True)

    t = threading.Thread(target=wait)
    t.start()
    time.sleep(0.05)
    b.crash()                                # the awaited peer dies
    t.join(timeout=10.0)
    assert released == [True]


# ------------------------------------------------------------- TCP shell
@needs_tcp
def test_tcp_loop_join_barrier_leave():
    server = RendezvousServer(2)
    try:
        a = RendezvousClient(server.addr, uid=1, peer_port=5001)
        b = RendezvousClient(server.addr, uid=2, peer_port=5002)
        ra, mem_a, start_a = a.join()
        rb, _, _ = b.join()
        assert sorted((ra, rb)) == [0, 1] and start_a == 0

        def addr(c, rank):
            # membership UPDATEs are broadcast asynchronously after the
            # joiner's WELCOME — poll briefly instead of assuming a's view
            # already includes b
            deadline = time.monotonic() + 10.0
            while (got := c.addr_of(rank)) is None:
                assert time.monotonic() < deadline, "no membership UPDATE"
                time.sleep(0.01)
            return got

        assert addr(a, rb)[1] == 5002        # b's advertised datagram port
        assert addr(b, ra)[1] == 5001
        done = []

        def run(c):
            for tag in range(4):
                c.barrier(tag, timeout=30.0)
            done.append(c.rank)

        ts = [threading.Thread(target=run, args=(c,)) for c in (a, b)]
        for t in ts: t.start()
        for t in ts: t.join()
        assert sorted(done) == [0, 1]
        b.leave()
        deadline = time.monotonic() + 10.0
        evs = []
        while time.monotonic() < deadline and not evs:
            evs = [e for e in a.events() if e[0] == "leave"]
            time.sleep(0.01)
        assert evs and evs[0][1] == rb
        assert not a.is_live(rb)
        a.leave()
    finally:
        server.close()


@needs_tcp
def test_tcp_eof_death_releases_survivor():
    server = RendezvousServer(2)
    try:
        a = RendezvousClient(server.addr, uid=1)
        b = RendezvousClient(server.addr, uid=2)
        a.join(); b.join()
        released = []

        def wait():
            a.barrier(0, timeout=30.0)
            released.append(True)

        t = threading.Thread(target=wait)
        t.start()
        time.sleep(0.1)
        b._closed = True
        b._sock.close()                      # SIGKILL stand-in: raw EOF
        t.join(timeout=30.0)
        assert released == [True]
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline and a.is_live(b.rank):
            time.sleep(0.01)
        assert not a.is_live(b.rank)
        a.leave()
    finally:
        server.close()


@needs_tcp
def test_tcp_rejoin_gets_freed_slot_and_future_since():
    server = RendezvousServer(2)
    try:
        a = RendezvousClient(server.addr, uid=1)
        b = RendezvousClient(server.addr, uid=2)
        a.join(); b.join()
        for tag in range(2):                 # both at step 0
            ta = threading.Thread(target=a.barrier, args=(tag,))
            ta.start()
            b.barrier(tag, timeout=30.0)
            ta.join(timeout=30.0)
        rb = b.rank
        b.leave()
        c = RendezvousClient(server.addr, uid=3)
        rc, _, start_step = c.join()
        assert rc == rb                      # lowest freed slot reused
        assert start_step == 1               # next step boundary
        c.leave()
        a.leave()
    finally:
        server.close()
