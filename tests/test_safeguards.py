"""§3.4 safeguards: in-graph skip + host-side monitor/rollback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.safeguards import LossMonitor, guard_update


def test_guard_passes_normal():
    upd = {"w": jnp.ones((4,))}
    out, skipped = guard_update(upd, jnp.asarray(0.01), skip_threshold=0.1)
    assert not bool(skipped)
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0)


def test_guard_skips_excessive_loss():
    upd = {"w": jnp.ones((4,))}
    out, skipped = guard_update(upd, jnp.asarray(0.5), skip_threshold=0.1)
    assert bool(skipped)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.0)


def test_guard_is_jittable():
    f = jax.jit(lambda u, l: guard_update(u, l))
    out, skipped = f({"w": jnp.ones(3)}, jnp.asarray(0.5))
    assert bool(skipped)


def test_monitor_halts_after_consecutive_skips():
    mon = LossMonitor(halt_after_consecutive_skips=3)
    for step in range(3):
        mon.observe(step, 0.5, skipped=True)
    assert mon.halted
    assert mon.total_skips == 3


def test_monitor_resets_on_clean_step():
    mon = LossMonitor(halt_after_consecutive_skips=3)
    mon.observe(0, 0.5, True)
    mon.observe(1, 0.0, False)
    mon.observe(2, 0.5, True)
    assert not mon.halted
    assert mon.consecutive_skips == 1


def test_snapshot_rollback():
    mon = LossMonitor(snapshot_every=2, snapshot_keep=2)
    p0 = {"w": jnp.zeros(2)}
    mon.maybe_snapshot(0, p0)
    mon.maybe_snapshot(2, {"w": jnp.ones(2)})
    mon.maybe_snapshot(4, {"w": 2 * jnp.ones(2)})
    step, params = mon.rollback()
    assert step == 4
    np.testing.assert_allclose(np.asarray(params["w"]), 2.0)
    assert not mon.halted
