"""Property tests for the weighted-shard / link-rewiring schedule pieces
(repro/core/tar.py: ``shard_plan`` / ``weighted_rows`` / ``weighted_flat``
/ ``ring_order`` / ``relay_via``).

The load-bearing invariants: a shard plan partitions the padded bucket
into exclusive, contiguous, block-aligned slices that sum to exactly the
bucket (no element owned twice, none orphaned); weighted_rows/weighted_flat
are inverses; a uniform plan degenerates to the ``reshape(n, s)`` geometry
the uniform schedules use (the bitwise-parity precondition); and
``ring_order`` returns a permutation of the active set whose consecutive
hops (wrap included) avoid every dead directed edge — or the *identity*
order when the current hops already do (the parity fast path).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.core import tar as tar_lib


def _weights(seed: int, n: int, lo: int = 1, hi: int = 5) -> tuple:
    rng = np.random.default_rng(seed)
    return tuple(int(w) for w in rng.integers(lo, hi + 1, size=n))


# ------------------------------------------------------------- shard_plan
@given(st.integers(1, 9000), st.integers(2, 8), st.integers(1, 64),
       st.integers(0, 10_000))
def test_shard_plan_partitions_bucket(length, n, block, seed):
    """Sizes sum to the padded length, ownership is exclusive/contiguous,
    every boundary is block-aligned, and padding never exceeds a quantum."""
    w = _weights(seed, n)
    plan = tar_lib.shard_plan(length, w, block)
    total = sum(w)
    assert sum(plan.sizes) == plan.padded
    assert plan.padded >= length
    assert plan.padded - length < total * block       # minimal padding
    assert plan.padded % (total * block) == 0
    assert plan.s_max == max(plan.sizes)
    off = 0
    unit = plan.padded // total
    assert unit % block == 0                          # blocks never straddle
    for k in range(n):
        assert plan.offsets[k] == off                 # contiguous, exclusive
        assert plan.sizes[k] == w[k] * unit           # weight-proportional
        assert plan.sizes[k] % block == 0
        off += plan.sizes[k]
    assert off == plan.padded


@given(st.integers(2, 8), st.integers(1, 4), st.integers(16, 4096))
def test_uniform_plan_is_reshape_geometry(n, block, length):
    """All-equal weights produce exactly the uniform ``reshape(n, s)``
    slicing — the precondition for uniform-weights bitwise parity."""
    plan = tar_lib.shard_plan(length, (3,) * n, block)
    s = plan.padded // n
    assert plan.sizes == (s,) * n
    assert plan.s_max == s
    assert plan.offsets == tuple(k * s for k in range(n))
    x = np.arange(plan.padded, dtype=np.float32)
    rows = np.asarray(tar_lib.weighted_rows(x, plan))
    assert np.array_equal(rows, x.reshape(n, s))


@given(st.integers(1, 5000), st.integers(2, 7), st.integers(0, 10_000))
def test_weighted_rows_flat_roundtrip(length, n, seed):
    w = _weights(seed, n)
    plan = tar_lib.shard_plan(length, w, block=4)
    x = np.random.default_rng(seed).normal(
        size=plan.padded).astype(np.float32)
    rows = tar_lib.weighted_rows(x, plan)
    assert rows.shape == (n, plan.s_max)
    # the zero-pad tail really is zero (a relay/mean can read it safely)
    for k, size in enumerate(plan.sizes):
        assert not np.any(np.asarray(rows)[k, size:])
    back = np.asarray(tar_lib.weighted_flat(rows, plan))
    assert np.array_equal(back, x)


def test_shard_plan_rejects_bad_weights():
    with pytest.raises(ValueError):
        tar_lib.shard_plan(100, ())
    with pytest.raises(ValueError):
        tar_lib.shard_plan(100, (2, 0, 1))


# ------------------------------------------------------------- ring_order
@given(st.integers(3, 8), st.integers(0, 10_000))
def test_ring_order_avoids_dead_edges(n, seed):
    """The rewired ring is a permutation of the active set visiting every
    peer exactly once, and no hop (wrap included) crosses a dead edge."""
    rng = np.random.default_rng(seed)
    active = tuple(range(n))
    # kill one or two of the current ring hops so a rewire is forced
    dead = {(int(i), int((i + 1) % n))
            for i in rng.choice(n, size=min(2, n - 2), replace=False)}
    order = tar_lib.ring_order(active, tuple(dead))
    assert sorted(order) == sorted(active)            # visits each once
    a = len(order)
    for j in range(a):
        hop = (order[j], order[(j + 1) % a])
        assert hop not in dead, hop


@given(st.integers(2, 8))
def test_ring_order_identity_without_dead_hops(n):
    """No dead edge on the current hops -> the exact input order comes
    back (the bitwise-parity fast path), including for dead edges that
    exist but never sit on a ring hop."""
    active = tuple(range(n))
    assert tar_lib.ring_order(active, ()) is not None
    assert tar_lib.ring_order(active, ()) == active
    if n >= 4:
        # (0 -> 2) is never a distance-1 hop of the natural order
        assert tar_lib.ring_order(active, ((0, 2),)) == active


def test_ring_order_subset_and_arbitrary_order():
    active = (1, 3, 4, 6)
    order = tar_lib.ring_order(active, ((3, 4),))
    assert sorted(order) == sorted(active)
    hops = {(order[j], order[(j + 1) % 4]) for j in range(4)}
    assert (3, 4) not in hops


def test_ring_order_raises_when_isolated():
    # every outgoing edge of peer 0 is dead: no Hamiltonian cycle exists
    dead = tuple((0, j) for j in range(1, 4))
    with pytest.raises(ValueError):
        tar_lib.ring_order((0, 1, 2, 3), dead)


# -------------------------------------------------------------- relay_via
@given(st.integers(3, 8), st.integers(0, 10_000))
def test_relay_via_two_live_hops(n, seed):
    rng = np.random.default_rng(seed)
    src, dst = (int(x) for x in rng.choice(n, size=2, replace=False))
    dead = ((src, dst),)
    m = tar_lib.relay_via(src, dst, tuple(range(n)), dead)
    assert m not in (src, dst)
    assert (src, m) not in dead and (m, dst) not in dead


def test_relay_via_raises_when_pair_isolated():
    # 3 peers, and the only candidate relay's inbound hop is dead too
    with pytest.raises(ValueError):
        tar_lib.relay_via(0, 1, (0, 1, 2), ((0, 1), (0, 2)))
