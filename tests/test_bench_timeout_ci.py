"""CI smoke for the timeout/ejection bench: ``python -m benchmarks.run
--only bench_timeout`` in quick mode must keep producing the schema the
PR-over-PR trajectory diffs consume — the early-timeout ablation rows, the
``ejection_vs_wait`` ablation, and an ``_iqr_ms`` dispersion sibling for
every median row — so the harness cannot rot silently between PRs.

Writes to a tmpdir via ``REPRO_BENCH_DIR`` so a test run never rewrites the
checked-in BENCH_timeout.json baseline.
"""
import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_bench_timeout_quick_schema(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(_REPO, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [_REPO, src, env.get("PYTHONPATH", "")])
    env["REPRO_BENCH_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "bench_timeout"],
        env=env, cwd=_REPO, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "FAILED" not in proc.stdout, proc.stdout

    path = tmp_path / "BENCH_timeout.json"
    assert path.exists(), "run.py did not honor REPRO_BENCH_DIR"
    payload = json.loads(path.read_text())
    assert payload["_meta"] == {"mode": "quick", "bench": "bench_timeout"}

    keys = set(payload) - {"_meta"}
    # the early-timeout ablation and the ejection_vs_wait ablation rows
    for key in ("timeout/tb_only_median_ms", "timeout/early_tc_median_ms",
                "timeout/time_reduction_pct",
                "timeout/wait_for_all_median_ms",
                "timeout/ejection_median_ms", "timeout/ejection_vs_wait_pct",
                "timeout/ejection_drop_frac",
                "timeout/rebalance_median_ms",
                "timeout/rebalance_eject_median_ms",
                "timeout/rebalance_wait_median_ms",
                "timeout/rebalance_vs_eject_pct",
                "timeout/rebalance_contrib_frac"):
        assert key in keys, key
    # every median row carries its dispersion sibling (run.py schema)
    for key in keys:
        if key.endswith("_median_ms"):
            assert key[:-len("_median_ms")] + "_iqr_ms" in keys, key
    # values are finite numbers (mirrors run.py's gate end-to-end)
    for key in keys:
        value = payload[key]["value"]
        assert isinstance(value, (int, float)), key

    # the ablation's headline claims hold in the emitted numbers: ejection
    # beats wait-for-all under the persistent straggler, drops stay bounded
    assert payload["timeout/ejection_median_ms"]["value"] < \
        payload["timeout/wait_for_all_median_ms"]["value"]
    assert 0.0 <= payload["timeout/ejection_drop_frac"]["value"] < 0.01

    # rebalance ablation (ISSUE 8 acceptance): straggler-proportional
    # shards land within 15% of ejection's median while the straggler
    # keeps a nonzero gradient contribution (ejection zeroes it)
    reb = payload["timeout/rebalance_median_ms"]["value"]
    ej = payload["timeout/rebalance_eject_median_ms"]["value"]
    wait = payload["timeout/rebalance_wait_median_ms"]["value"]
    assert reb <= 1.15 * ej, (reb, ej)
    assert reb < wait, (reb, wait)
    assert payload["timeout/rebalance_contrib_frac"]["value"] > 0.05

    # the checked-in baseline at the repo root was NOT rewritten
    repo_json = os.path.join(_REPO, "BENCH_timeout.json")
    if os.path.exists(repo_json):
        with open(repo_json) as fh:
            baseline = json.load(fh)
        assert baseline["_meta"]["bench"] == "bench_timeout"
