"""Observability layer (DESIGN §12): trace recorder ring semantics,
disabled-path gating, tail histogram quantile/merge contracts, Perfetto
export schema, report merging, control-plane transition events, and the
end-to-end multiproc-inproc trace -> report flagship.
"""
import json
import math
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.obs import (Counter, Gauge, MetricsRegistry, TailHistogram,
                       TraceSchemaError, metrics, to_trace_events, trace,
                       trace_payload, validate_trace, write_trace)
from repro.obs import report as obs_report


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts and ends with tracing globally off."""
    trace.reset()
    yield
    trace.reset()


# --------------------------------------------------------------------- tracer
class TestTracer:
    def test_disabled_by_default(self):
        assert trace.get_tracer() is None
        assert not trace.is_enabled()

    def test_configure_enable_disable(self):
        tr = trace.configure(True, capacity=8)
        assert trace.get_tracer() is tr
        assert trace.is_enabled()
        assert trace.configure(False) is None
        assert trace.get_tracer() is None

    def test_records_in_arrival_order(self):
        tr = trace.configure(True, capacity=16)
        tr.complete("round", "wire", ts=1.0, dur=0.5, tid=3,
                    args={"sender": 3})
        tr.event("timeout", "wire", ts=2.0, tid=3)
        tr.counter("loss_frac", 0.25, ts=3.0)
        recs = tr.records()
        assert [r[0] for r in recs] == ["X", "i", "C"]
        ph, ts, dur, name, cat, tid, args = recs[0]
        assert (name, cat, tid) == ("round", "wire", 3)
        assert (ts, dur) == (1.0, 0.5)
        assert args == {"sender": 3}
        assert recs[2][6] == {"value": 0.25}

    def test_negative_duration_clamped(self):
        tr = trace.configure(True, capacity=4)
        tr.complete("x", "wire", ts=0.0, dur=-1.0)
        assert tr.records()[0][2] == 0.0

    def test_ring_wraparound_drops_oldest(self):
        tr = trace.configure(True, capacity=4)
        for i in range(10):
            tr.event(f"e{i}", "trainer", ts=float(i))
        assert len(tr) == 4
        assert tr.dropped == 6
        # oldest surviving first
        assert [r[3] for r in tr.records()] == ["e6", "e7", "e8", "e9"]

    def test_clear_resets_ring_and_dropped(self):
        tr = trace.configure(True, capacity=2)
        for i in range(5):
            tr.event("e", "trainer")
        tr.clear()
        assert len(tr) == 0 and tr.dropped == 0
        assert tr.records() == []

    def test_span_nesting_and_set(self):
        tr = trace.configure(True, capacity=8)
        with tr.span("outer", "trainer", tid=1, step=3) as outer:
            with tr.span("inner", "trainer"):
                pass
            outer.set(loss=0.1)
        recs = tr.records()
        # inner exits (and records) first
        assert [r[3] for r in recs] == ["inner", "outer"]
        outer_rec = recs[1]
        assert outer_rec[6] == {"step": 3, "loss": 0.1}
        assert outer_rec[2] >= recs[0][2] >= 0.0  # outer spans inner

    def test_convenience_span_noop_when_disabled(self):
        s = trace.span("x", "trainer")
        # the shared no-op: no allocation, chainable set, context-manages
        assert trace.span("y") is s
        with s.set(a=1) as inner:
            assert inner is s
        trace.event("e")                 # must not raise
        assert trace.get_tracer() is None

    def test_convenience_apis_record_when_enabled(self):
        tr = trace.configure(True, capacity=8)
        with trace.span("step", "trainer", step=1):
            pass
        trace.event("tick", "trainer")
        assert [r[3] for r in tr.records()] == ["step", "tick"]

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            trace.configure(True, capacity=0)

    def test_thread_local_tracer_separates_ranks(self):
        """configure_thread gives each worker thread its own ring — the
        multiproc inproc mode's per-rank separation."""
        global_tr = trace.configure(True, capacity=8)
        seen = {}

        def worker(rank):
            t = trace.configure_thread(True, capacity=8, rank=rank)
            assert trace.get_tracer() is t
            t.event("mine", "trainer", args={"rank": rank})
            seen[rank] = t

        threads = [threading.Thread(target=worker, args=(r,))
                   for r in (1, 2)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        # the main thread never called configure_thread: still global
        assert trace.get_tracer() is global_tr
        assert len(global_tr) == 0
        for rank in (1, 2):
            recs = seen[rank].records()
            assert len(recs) == 1 and recs[0][6] == {"rank": rank}
            assert seen[rank].rank == rank


# ----------------------------------------------------------------- histograms
class TestTailHistogram:
    def test_empty_is_nan(self):
        h = TailHistogram()
        assert math.isnan(h.quantile(0.5))
        assert math.isnan(h.mean())
        assert h.summary()["count"] == 0

    def test_quantile_within_one_log_bucket_of_numpy(self):
        bpo = 32
        h = TailHistogram(min_value=1e-7, max_value=1e4, bins_per_octave=bpo)
        rng = np.random.default_rng(7)
        vals = rng.lognormal(0.0, 2.0, 5000)
        for v in vals:
            h.record(v)
        tol = 2.0 ** (1.0 / bpo)         # one log-bucket of relative error
        for q in (0.5, 0.9, 0.99, 0.999):
            est = h.quantile(q)
            true = float(np.quantile(vals, q))
            assert true / tol <= est <= true * tol, (q, est, true)

    def test_quantile_clamped_to_observed_envelope(self):
        h = TailHistogram()
        h.record(3.0)
        assert h.quantile(0.0) == 3.0
        assert h.quantile(1.0) == 3.0

    def test_non_finite_sample_rejected(self):
        h = TailHistogram()
        with pytest.raises(ValueError):
            h.record(math.nan)
        with pytest.raises(ValueError):
            h.record(math.inf)

    def test_out_of_range_clamps_and_counts(self):
        h = TailHistogram(min_value=1.0, max_value=10.0)
        h.record(0.01)
        h.record(1000.0)
        assert h.clamped == 2
        assert h.count == 2

    def test_merge_associative_and_commutative(self):
        rng = np.random.default_rng(11)
        chunks = [rng.lognormal(0.0, 1.0, 400) for _ in range(3)]

        def hist(vals):
            h = TailHistogram()
            h.record_many(vals)
            return h

        a, b, c = (hist(ch) for ch in chunks)
        left = a.copy().merge(b).merge(c)            # (a+b)+c
        right = a.copy().merge(b.copy().merge(c))    # a+(b+c)
        swapped = c.copy().merge(b).merge(a)         # c+b+a
        direct = hist(np.concatenate(chunks))
        for other in (right, swapped, direct):
            assert np.array_equal(left.counts, other.counts)
            assert left.count == other.count
            assert left.quantile(0.99) == other.quantile(0.99)

    def test_merge_geometry_mismatch_raises(self):
        with pytest.raises(ValueError):
            TailHistogram(bins_per_octave=32).merge(
                TailHistogram(bins_per_octave=16))

    def test_summary_fields(self):
        h = TailHistogram()
        h.record_many([1.0, 2.0, 3.0, 4.0])
        s = h.summary()
        assert s["count"] == 4
        assert s["min"] == 1.0 and s["max"] == 4.0
        assert s["mean"] == pytest.approx(2.5)
        assert 1.0 <= s["p50"] <= 4.0


# property (satellite): a histogram never loses or invents samples —
# whatever streams in is exactly what count/summary report
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 400), st.floats(0.1, 3.0))
def test_hist_recorded_count_equals_fed(n, sigma):
    h = TailHistogram()
    vals = np.random.default_rng(n).lognormal(0.0, sigma, n)
    h.record_many(vals)
    assert h.count == n
    assert int(h.counts.sum()) == n
    assert h.summary()["count"] == n


class TestMetricsRegistry:
    def test_get_or_create_and_snapshot(self):
        reg = MetricsRegistry()
        reg.counter("drops").inc()
        reg.counter("drops").inc(2.0)
        reg.gauge("phase").set(0.4)
        reg.histogram("round_us").record(10.0)
        snap = reg.snapshot()
        assert snap["counters"]["drops"] == 3.0
        assert snap["gauges"]["phase"] == 0.4
        assert snap["histograms"]["round_us"]["count"] == 1
        reg.clear()
        assert reg.snapshot() == {"counters": {}, "gauges": {},
                                  "histograms": {}}

    def test_process_global_singleton(self):
        assert metrics() is metrics()

    def test_counter_gauge_primitives(self):
        c, g = Counter(), Gauge()
        assert c.value == 0.0 and math.isnan(g.value)
        c.inc(5)
        g.set(2)
        assert c.value == 5.0 and g.value == 2.0


# --------------------------------------------------------------------- export
class TestExport:
    def test_tuple_mapping_and_unit_scale(self):
        recs = [("X", 1.5, 0.25, "round", "wire", 3, {"sender": 3}),
                ("i", 2.0, 0.0, "timeout", "wire", 1, None),
                ("C", 3.0, 0.0, "loss", "metrics", 0, {"value": 0.5})]
        evs = to_trace_events(recs, pid=7)
        assert evs[0] == {"name": "round", "cat": "wire", "ph": "X",
                          "ts": 1.5e6, "dur": 0.25e6, "pid": 7, "tid": 3,
                          "args": {"sender": 3}}
        assert evs[1]["s"] == "p" and "dur" not in evs[1]
        assert evs[2]["args"]["value"] == 0.5

    def test_payload_has_process_metadata_and_validates(self):
        tr = trace.configure(True, capacity=8, rank=2)
        tr.event("tick", "policy")
        payload = trace_payload(tr, meta={"transport": "inproc"})
        first = payload["traceEvents"][0]
        assert first["ph"] == "M" and first["args"]["name"] == "rank 2"
        assert payload["otherData"] == {"rank": 2, "dropped": 0,
                                        "transport": "inproc"}
        validate_trace(payload)          # round-trips its own gate

    def test_write_trace_dir_convention(self, tmp_path):
        tr = trace.configure(True, capacity=8, rank=3)
        tr.complete("round", "wire", ts=0.0, dur=0.1)
        path = write_trace(str(tmp_path), tr)
        assert path.endswith("trace_rank03.json")
        with open(path) as fh:
            validate_trace(json.load(fh))

    @pytest.mark.parametrize("mutate,frag", [
        (lambda p: p.pop("traceEvents"), "traceEvents"),
        (lambda p: p["traceEvents"][1].pop("name"), "name"),
        (lambda p: p["traceEvents"][1].update(ph="Z"), "ph"),
        (lambda p: p["traceEvents"][1].update(ts=math.nan), "ts"),
        (lambda p: p["traceEvents"][1].update(pid="0"), "pid"),
        (lambda p: p["traceEvents"][1].update(dur=-1.0), "dur"),
        (lambda p: p["traceEvents"][1].update(args=[1]), "args"),
    ])
    def test_validate_rejects_malformed(self, mutate, frag):
        tr = trace.configure(True, capacity=8)
        tr.complete("round", "wire", ts=0.0, dur=1.0)
        payload = trace_payload(tr)
        mutate(payload)
        with pytest.raises(TraceSchemaError, match=frag):
            validate_trace(payload)

    def test_validate_rejects_nonfinite_counter(self):
        payload = {"traceEvents": [
            {"name": "c", "cat": "m", "ph": "C", "ts": 0.0, "pid": 0,
             "tid": 0, "args": {"value": math.inf}}]}
        with pytest.raises(TraceSchemaError, match="value"):
            validate_trace(payload)


# --------------------------------------------------------------------- report
def _payload_for(rank, round_durs_us, events=()):
    """Hand-build a validated per-rank payload (µs already)."""
    evs = [{"name": "process_name", "ph": "M", "pid": rank, "tid": 0,
            "ts": 0, "args": {"name": f"rank {rank}"}}]
    for i, d in enumerate(round_durs_us):
        evs.append({"name": "round", "cat": "wire", "ph": "X",
                    "ts": float(i), "dur": float(d), "pid": rank, "tid": 0})
    for name, cat, ts, args in events:
        evs.append({"name": name, "cat": cat, "ph": "i", "s": "p",
                    "ts": float(ts), "pid": rank, "tid": 0, "args": args})
    return validate_trace({"traceEvents": evs,
                           "otherData": {"rank": rank, "dropped": 0}})


class TestReport:
    def test_merge_tables_and_timeline(self):
        p0 = _payload_for(0, [100.0] * 99 + [5000.0],
                          events=[("eject", "policy", 7.0, {"peer": 3})])
        p1 = _payload_for(1, [110.0] * 100,
                          events=[("timeout", "wire", 3.0, {"round": 2})])
        rep = obs_report.merge_report([p0, p1])
        assert rep["ranks"] == [0, 1]
        tab = rep["tables"]["round"]
        assert tab["merged"]["count"] == 200
        assert set(tab["per_rank"]) == {"0", "1"}
        # the one 5ms outlier in 200 samples is the p999, not the p50
        assert tab["merged"]["p50"] < 200.0
        assert tab["merged"]["p999"] > 1000.0
        names = [(e["name"], e["rank"]) for e in rep["timeline"]]
        assert ("eject", 0) in names and ("timeout", 1) in names
        # timeline sorted by ts within each category (clock domain)
        for cat in ("policy", "wire"):
            ts = [e["ts"] for e in rep["timeline"] if e["cat"] == cat]
            assert ts == sorted(ts)

    def test_merged_equals_per_rank_merge(self):
        """The cross-rank table is the histogram-merge of the per-rank
        ones (associativity contract end to end)."""
        rng = np.random.default_rng(5)
        durs = [rng.lognormal(5.0, 1.0, 300) for _ in range(3)]
        rep = obs_report.merge_report(
            [_payload_for(r, d) for r, d in enumerate(durs)])
        manual = TailHistogram(**obs_report._HIST_KW)
        manual.record_many(np.concatenate(durs))
        assert rep["tables"]["round"]["merged"] == manual.summary()

    def test_empty_tables_skipped(self):
        # zero-duration spans (virtual clock) contribute nothing
        p = _payload_for(0, [0.0, 0.0])
        rep = obs_report.merge_report([p])
        assert rep["tables"] == {}

    def test_discover_and_cli(self, tmp_path, capsys):
        for rank in range(2):
            tr = trace.configure(True, capacity=32, rank=rank)
            for i in range(5):
                tr.complete("round", "wire", ts=float(i), dur=0.001,
                            tid=0, args={"round": i})
            tr.event("hadamard", "policy", ts=2.5,
                     args={"on": True, "cause": "loss_threshold"})
            write_trace(str(tmp_path), tr)
        trace.reset()
        found = obs_report.discover([str(tmp_path)])
        assert [p[-17:] for p in found] == ["trace_rank00.json",
                                           "trace_rank01.json"]
        rep = obs_report.main([str(tmp_path)])
        out = capsys.readouterr().out
        assert rep["tables"]["round"]["merged"]["count"] == 10
        assert "round completion time" in out
        assert "hadamard" in out
        rep2 = obs_report.main([str(tmp_path), "--json"])
        assert json.loads(capsys.readouterr().out)["ranks"] == [0, 1]
        assert rep2["ranks"] == [0, 1]

    def test_discover_empty_dir_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            obs_report.discover([str(tmp_path)])

    def test_load_trace_names_bad_file(self, tmp_path):
        bad = tmp_path / "trace_rank00.json"
        bad.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
        with pytest.raises(TraceSchemaError, match="trace_rank00"):
            obs_report.load_trace(str(bad))

    def test_render_reports_dropped_records(self):
        p = _payload_for(0, [10.0])
        p["otherData"]["dropped"] = 42
        rep = obs_report.merge_report([p])
        assert rep["dropped_records"] == 42
        assert "42 records dropped" in obs_report.render(rep)


# ----------------------------------------------- control-plane instrumentation
class TestControlPlaneEvents:
    def _plane(self, n=4):
        from repro.runtime import ControlPlane
        return ControlPlane.create(
            n, detector_kw=dict(alpha=0.5, patience=2, cooldown=4,
                                probation=2))

    def _policy_events(self, tr):
        return [(r[3], r[6]) for r in tr.records() if r[4] == "policy"]

    def test_eject_emits_policy_event_with_cause(self):
        from repro.runtime import StepTelemetry
        tr = trace.configure(True, capacity=256)
        plane = self._plane()
        times = (1.0, 1.0, 1.0, 9.0)
        for step in range(12):
            plane.observe(StepTelemetry(step=step, loss_frac=0.0,
                                        peer_stage_times=times))
        evs = self._policy_events(tr)
        ejects = [a for n_, a in evs if n_ == "eject"]
        assert ejects and ejects[0]["peer"] == 3
        assert ejects[0]["cause"] == "score" and ejects[0]["from"] == "active"
        # the policy flip itself is summarized too
        assert any(n_ == "policy_change" for n_, _ in evs)

    def test_probation_and_readmit_events(self):
        from repro.runtime import StepTelemetry
        tr = trace.configure(True, capacity=512)
        plane = self._plane()
        step = 0
        for _ in range(10):                       # eject peer 3
            plane.observe(StepTelemetry(step=step, loss_frac=0.0,
                                        peer_stage_times=(1., 1., 1., 9.)))
            step += 1
        for _ in range(20):                       # heal: probation+readmit
            plane.observe(StepTelemetry(step=step, loss_frac=0.0,
                                        peer_stage_times=(1., 1., 1., 1.)))
            step += 1
        names = [n_ for n_, _ in self._policy_events(tr)]
        assert names.index("eject") < names.index("probation") \
            < names.index("readmit")

    def test_membership_event(self):
        tr = trace.configure(True, capacity=64)
        plane = self._plane()
        assert plane.apply_membership("death", 2, generation=3)
        evs = self._policy_events(tr)
        assert evs and evs[-1][0] == "membership"
        assert evs[-1][1]["kind"] == "death" and evs[-1][1]["peer"] == 2
        assert evs[-1][1]["generation"] == 3

    def test_no_tracer_no_events_same_decisions(self):
        """Tracing off must not change control behaviour (pure observer)."""
        from repro.runtime import StepTelemetry

        def run(traced):
            trace.reset()
            if traced:
                trace.configure(True, capacity=512)
            plane = self._plane()
            flips = []
            for step in range(15):
                flips.append(plane.observe(
                    StepTelemetry(step=step, loss_frac=0.0,
                                  peer_stage_times=(1., 1., 1., 9.))))
            return flips, plane.policy()

        assert run(False) == run(True)


# ------------------------------------------------------------------- flagship
@pytest.mark.slow
def test_multiproc_inproc_trace_roundtrip(tmp_path):
    """The acceptance criterion: a 4-peer inproc multiproc run with
    --trace-dir emits one valid Perfetto JSON per rank, and the merged
    report reproduces round-time tails and control-plane transitions."""
    from repro.launch.multiproc import main as mp_main

    td = str(tmp_path / "traces")
    report = mp_main(["--backend", "inproc", "--nprocs", "4",
                      "--steps", "3", "--elems", "2048",
                      "--drop-rate", "0.02", "--trace-dir", td])
    assert len(report["traces"]) == 4
    payloads = [obs_report.load_trace(p) for p in report["traces"]]
    assert sorted((p["otherData"] or {})["rank"] for p in payloads) \
        == [0, 1, 2, 3]
    rep = obs_report.merge_report(payloads)
    assert rep["ranks"] == [0, 1, 2, 3]
    # every rank observed 3 steps x (n-1) senders x rounds >= 1 — the
    # merged round table must carry all ranks and a finite tail
    tab = rep["tables"]["round"]
    assert set(tab["per_rank"]) == {"0", "1", "2", "3"}
    assert tab["merged"]["count"] >= 4 * 3
    assert math.isfinite(tab["merged"]["p999"])
    # with 2% drops the loss controllers move: policy events recorded
    cats = {e["cat"] for e in rep["timeline"]}
    assert "policy" in cats
    text = obs_report.render(rep)
    assert "control timeline" in text
