"""Op-order inspection of the software-pipelined bucket schedule.

The CI box runs Pallas in interpret mode, so on-TPU overlap cannot be timed
here; what CAN be pinned is the lowered HLO: with ``mode="pipelined"`` the
exchange collectives of bucket k-1 must be *emitted between* the encode
kernels of bucket k and the decode kernels of bucket k-2 (StableHLO emission
follows trace order for data-independent ops, and the skew removes the data
dependencies), which is exactly the program shape XLA's async collectives
need to overlap communication with neighboring buckets' codec work.

Runs in a 2-forced-host-device subprocess; identifies the codec kernels by
their ``randomized_fwht`` callee specializations (encode and decode lower to
distinct nested-jit functions) and the exchanges by the stablehlo collective
ops.
"""
import os
import subprocess
import sys

import pytest

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import re
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core import (OptiReduceConfig, SyncContext, sync_pytree,
                        sync_pytree_unfused)

mesh = make_mesh((2,), ("data",))
cfg = OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                       hadamard_block=256)

def lower(fn, nbuckets, **kw):
    tree = {"g": jnp.zeros((nbuckets * 2048,), jnp.float32)}
    def body(t):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(0))
        return fn(t, ctx, bucket_elems=2048, **kw)
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=({"g": P()},),
                          out_specs={"g": P()}, check_vma=False))
    return f.lower(tree).as_text()

def shmap_lines(txt):
    # the traced schedule lives in the shmap_body function
    lines = txt.splitlines()
    start = next(i for i, l in enumerate(lines)
                 if "func.func" in l and "shmap_body" in l)
    end = next((i for i in range(start + 1, len(lines))
                if "func.func" in lines[i]), len(lines))
    return lines[start:end]

def stages(body):
    a2a = [i for i, l in enumerate(body) if "stablehlo.all_to_all" in l]
    ag = [i for i, l in enumerate(body) if "stablehlo.all_gather" in l]
    fwht = [(i, l) for i, l in enumerate(body)
            if re.search(r"call @randomized_fwht[_0-9]*\(", l)]
    callee = lambda l: re.search(r"call @(randomized_fwht[_0-9]*)\(",
                                 l).group(1)
    enc_name = callee(fwht[0][1])     # the first rotation is an encode
    enc = [i for i, l in fwht if callee(l) == enc_name]
    dec = [i for i, l in fwht if callee(l) != enc_name]
    return a2a, ag, enc, dec

# ---- B=3 pipelined: the full skew unrolls ---------------------------------
# expected trace order: E0 E1 | X0 | E2 | X1 | D0 | X2 | D1 D2
body = shmap_lines(lower(sync_pytree, 3, mode="pipelined"))
a2a, ag, enc, dec = stages(body)
assert len(a2a) == 3 and len(ag) == 3, (len(a2a), len(ag))
assert len(enc) == 3 and len(dec) == 3, (len(enc), len(dec))
assert enc[0] < enc[1] < a2a[0], \
    "buckets 0 AND 1 must encode before bucket 0's exchange is issued"
assert ag[0] < enc[2] < a2a[1], \
    "bucket 2's encode must interleave between exchanges 0 and 1"
assert ag[1] < dec[0] < a2a[2], \
    "bucket 0's decode must interleave between exchanges 1 and 2"
assert ag[2] < dec[1] < dec[2], "epilogue drains decodes after the last exchange"
print("PIPELINED_ORDER OK")

# ---- negative control: the seed loop serializes ---------------------------
body_u = shmap_lines(lower(sync_pytree_unfused, 3))
a2a_u, ag_u, enc_u, dec_u = stages(body_u)
assert len([i for i in enc_u if i < a2a_u[0]]) == 1, \
    "seed loop: only bucket 0 encodes before bucket 0's exchange"
assert dec_u[0] < a2a_u[1], "seed loop: bucket 0 decodes before exchange 1"
print("SERIAL_CONTROL OK")

# ---- collective count stays constant in B ---------------------------------
# pipelined = prologue + one scan body + epilogue = 3 all_to_all at any B>3;
# scan = 1; the seed loop = B
n_pip = lambda b: lower(sync_pytree, b, mode="pipelined").count(
    "stablehlo.all_to_all")
assert n_pip(8) == 3 and n_pip(16) == 3, (n_pip(8), n_pip(16))
assert lower(sync_pytree, 8, mode="scan").count("stablehlo.all_to_all") == 1
print("CONSTANT_HLO OK")
"""


CHILD_QUANT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import re
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core import OptiReduceConfig, SyncContext, sync_pytree

mesh = make_mesh((2,), ("data",))
cfg = OptiReduceConfig(strategy="optireduce_q", drop_rate=0.0,
                       hadamard_block=256)

def lower(nbuckets, **kw):
    tree = {"g": jnp.zeros((nbuckets * 2048,), jnp.float32)}
    def body(t):
        ctx = SyncContext(cfg=cfg, key=jax.random.PRNGKey(0))
        return sync_pytree(t, ctx, bucket_elems=2048, **kw)
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=({"g": P()},),
                          out_specs={"g": P()}, check_vma=False))
    return f.lower(tree).as_text()

txt = lower(3, mode="pipelined")
lines = txt.splitlines()
start = next(i for i, l in enumerate(lines)
             if "func.func" in l and "shmap_body" in l)
end = next((i for i in range(start + 1, len(lines))
            if "func.func" in lines[i]), len(lines))
body = lines[start:end]
a2a = [i for i, l in enumerate(body) if "stablehlo.all_to_all" in l]
ag = [i for i, l in enumerate(body) if "stablehlo.all_gather" in l]
ar = [i for i, l in enumerate(body) if "stablehlo.all_reduce" in l]
fwht = [(i, l) for i, l in enumerate(body)
        if re.search(r"call @randomized_fwht[_0-9]*\(", l)]
callee = lambda l: re.search(r"call @(randomized_fwht[_0-9]*)\(",
                             l).group(1)
enc_name = callee(fwht[0][1])
enc = [i for i, l in fwht if callee(l) == enc_name]

# ---- the THC grid pmax rides the exchange stage ---------------------------
# split encode: encode_stage emits only the local amax; the pmax
# (stablehlo.all_reduce) is deferred into the exchange stage, so bucket k's
# grid collective is emitted alongside bucket k-1's exchange instead of
# serializing after bucket k's rotation.  B=3 expected trace order:
#   E0 E1 | ar0 X0 | E2 ar1 X1 | ... (exactly ONE pmax before exchange 0 —
# the encode-fused layout would put both buckets' pmaxes there)
assert len(ar) == 3, (len(ar), "one grid pmax per bucket")
assert enc[1] < ar[0] < a2a[0], \
    "bucket 0's grid pmax must defer past bucket 1's encode"
assert sum(1 for r in ar if r < a2a[0]) == 1, \
    "exactly one grid pmax precedes the first exchange (deferred placement)"
assert ag[0] < enc[2] < ar[1] < a2a[1], \
    "bucket 1's grid pmax must ride the exchange stage, after encode 2"
print("QUANT_PMAX OK")

# ---- collective count stays constant in B ---------------------------------
txt8 = lower(8, mode="pipelined")
assert txt8.count("stablehlo.all_to_all") == 3
assert txt8.count("stablehlo.all_reduce") == 3
print("QUANT_CONSTANT_HLO OK")
"""


def _run_child(code):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


@pytest.fixture(scope="module")
def schedule_output():
    return _run_child(CHILD)


@pytest.fixture(scope="module")
def quant_schedule_output():
    return _run_child(CHILD_QUANT)


@pytest.mark.slow
def test_exchange_interleaves_neighboring_codec_kernels(schedule_output):
    """Acceptance: the pipelined HLO shows exchange collectives emitted
    between neighboring buckets' encode/decode kernels."""
    assert "PIPELINED_ORDER OK" in schedule_output, schedule_output


@pytest.mark.slow
def test_seed_loop_is_the_serial_baseline(schedule_output):
    assert "SERIAL_CONTROL OK" in schedule_output, schedule_output


@pytest.mark.slow
def test_pipelined_hlo_constant_in_bucket_count(schedule_output):
    assert "CONSTANT_HLO OK" in schedule_output, schedule_output


@pytest.mark.slow
def test_grid_pmax_rides_the_exchange_stage(quant_schedule_output):
    """Acceptance: for quantized pipelined strategies the THC grid pmax is
    emitted inside the exchange stage (deferred split encode), not at the
    tail of the encode stage."""
    assert "QUANT_PMAX OK" in quant_schedule_output, quant_schedule_output


@pytest.mark.slow
def test_quant_pipelined_hlo_constant_in_bucket_count(quant_schedule_output):
    assert "QUANT_CONSTANT_HLO OK" in quant_schedule_output, \
        quant_schedule_output
