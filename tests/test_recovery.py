"""Loss-recovery subsystem (DESIGN §8, core/recovery.py): policy parsing,
registry wiring, StaleFill fill-then-mean semantics, and the EF
mass-conservation property that makes error feedback sound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import recovery as recovery_lib
from repro.core import tar as tar_lib
from repro.core.allreduce import OptiReduceConfig
from repro.core.hadamard import ht_decode, ht_encode
from repro.core.pipeline import (Encoded, HTQuant, Identity, SyncContext,
                                 resolve_spec)
from repro.core.recovery import StaleFill


def _cfg(**kw):
    base = dict(strategy="optireduce", drop_rate=0.3,
                drop_pattern="bernoulli", use_hadamard=False,
                hadamard_block=32, packet_elems=8)
    base.update(kw)
    return OptiReduceConfig(**base)


# --------------------------------------------------- policy + registry wiring
def test_parse_layering():
    assert not recovery_lib.parse("none").any
    st_ = recovery_lib.parse("stale")
    assert st_.stale and not st_.ef and not st_.budget
    ef = recovery_lib.parse("ef")
    assert ef.stale and ef.ef and not ef.budget       # ef implies stale
    full = recovery_lib.parse("ef+budget")
    assert full.stale and full.ef and full.budget
    with pytest.raises(ValueError):
        recovery_lib.parse("zero")


def test_disabled_recovery_is_inert():
    """recovery='none' must resolve to the exact seed spec — same codec
    type, no wrapper (the parity suites pin the traced program)."""
    plain = resolve_spec(_cfg(recovery="none"))
    assert not isinstance(plain.codec, StaleFill)
    armed = resolve_spec(_cfg(recovery="stale"))
    assert isinstance(armed.codec, StaleFill)
    assert type(armed.codec.inner) is type(plain.codec)


def test_wrap_codec_rejects_nonlinear_codec():
    with pytest.raises(ValueError, match="linear"):
        recovery_lib.wrap_codec(HTQuant(), _cfg(recovery="ef"))


def test_wrap_codec_rejects_degraded_participation():
    with pytest.raises(ValueError, match="active_peers"):
        recovery_lib.wrap_codec(Identity(),
                                _cfg(recovery="stale",
                                     active_peers=(0, 1, 2)))


# --------------------------------------------------------- StaleFill.reduce
def test_stalefill_fill_then_plain_mean():
    """Every lost (sender, span) entry takes the stale prediction; the
    reduce is the plain mean over all N (arrived entries weigh exactly
    1/N — the EF split depends on it)."""
    cfg = _cfg(recovery="stale")
    ctx = SyncContext(cfg, jax.random.PRNGKey(0))
    n, s = 4, 16
    rng = np.random.default_rng(0)
    received = jnp.asarray(rng.standard_normal((n, s)), jnp.float32)
    mask = jnp.asarray(rng.random((n, s)) < 0.7, jnp.float32)
    stale = jnp.asarray(rng.standard_normal(n * s), jnp.float32)
    codec = StaleFill(inner=Identity())
    out = codec.reduce(received, mask, jnp.int32(1),
                       Encoded(received, stale=stale), ctx)
    shard = np.asarray(stale).reshape(n, s)[1]
    want = np.mean(np.asarray(mask) * np.asarray(received)
                   + (1 - np.asarray(mask)) * shard[None], 0)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)
    assert float(ctx.stats["filled"]) == float(jnp.sum(1.0 - mask))


def test_stalefill_without_cache_matches_inner_bitwise():
    cfg = _cfg(recovery="stale")
    ctx = SyncContext(cfg, jax.random.PRNGKey(0))
    received = jnp.asarray(np.random.default_rng(1).standard_normal((4, 8)),
                           jnp.float32)
    mask = jnp.ones((4, 8), jnp.float32)
    enc = Encoded(received, stale=None)
    a = StaleFill(inner=Identity()).reduce(received, mask, jnp.int32(0),
                                           enc, ctx)
    b = Identity().reduce(received, mask, jnp.int32(0), enc, ctx)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------- EF mass conservation property
@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1), st.sampled_from([False, True]),
       st.integers(0, 3))
def test_ef_mass_conservation(seed, use_ht, me):
    """The split is exact for linear codecs: what the stale fill applied in
    rank ``me``'s stead this step plus the carried residual equals its full
    contribution — ``decode(m*w + (1-m)*w_stale) + residual == bucket`` —
    so dropped gradient mass is applied exactly once, never twice."""
    cfg = _cfg(use_hadamard=use_ht, drop_pattern="burst", drop_rate=0.4)
    n, length = 4, 200
    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    bucket = jnp.asarray(rng.standard_normal(length), jnp.float32)
    stale = jnp.asarray(rng.standard_normal(length), jnp.float32)

    block = cfg.hadamard_block if use_ht else 1
    x, _ = tar_lib.pad_for_tar(bucket, n, block)
    st_pad, _ = tar_lib.pad_for_tar(stale, n, block)
    if use_ht:
        w = ht_encode(x, key, block=block)
        w_st = ht_encode(st_pad, key, block=block)
    else:
        w, w_st = x, st_pad
    arrival = recovery_lib.sender_arrival_masks(cfg, key, n, x.shape[0] // n)
    mine = arrival[me]
    applied = mine * w + (1.0 - mine) * w_st
    if use_ht:
        applied = ht_decode(applied, key, block=block)
    resid = recovery_lib.ef_residual(bucket, key, cfg, n, jnp.int32(me),
                                    stale=stale)
    np.testing.assert_allclose(np.asarray(applied[:length] + resid),
                               np.asarray(bucket), rtol=2e-4, atol=2e-4)


def test_ef_residual_zero_without_drops():
    cfg = _cfg(drop_rate=0.0)
    bucket = jnp.ones(64)
    out = recovery_lib.ef_residual(bucket, jax.random.PRNGKey(0), cfg, 4,
                                   jnp.int32(0))
    assert float(jnp.abs(out).max()) == 0.0


def test_ef_residual_arena_uses_sync_engine_bucket_keys():
    """The arena wrapper must derive per-bucket keys exactly as the sync
    engine does (bucket_plan.bucket_keys) — a drifted fold would make the
    residual reconstruct the wrong arrival masks."""
    from repro.core.bucket_plan import bucket_keys
    cfg = _cfg(drop_rate=0.25)
    arena = jnp.asarray(np.random.default_rng(3).standard_normal((3, 96)),
                        jnp.float32)
    stale = jnp.zeros_like(arena)
    step_key = jax.random.PRNGKey(9)
    got = recovery_lib.ef_residual_arena(arena, step_key, cfg, 4,
                                         jnp.int32(2), stale=stale)
    keys = bucket_keys(step_key, 3)
    want = jnp.stack([recovery_lib.ef_residual(arena[b], keys[b], cfg, 4,
                                               jnp.int32(2))
                      for b in range(3)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_init_state_shapes():
    pol = recovery_lib.parse("ef")
    state = recovery_lib.init_state(pol, nbuckets=5, bucket_elems=32, n_dp=4)
    assert state["stale"].shape == (5, 32)
    assert state["ef"].shape == (4, 5, 32)
    assert not np.asarray(state["stale"]).any()
    assert "ef" not in recovery_lib.init_state(recovery_lib.parse("stale"),
                                               5, 32)
    assert recovery_lib.init_state(recovery_lib.parse("none"), 5, 32) == {}
