"""Network simulator invariants + paper-shape checks."""
import numpy as np
import pytest

from repro.sim.netsim import GASimulator, NetworkModel, simulate_job


def test_deterministic_in_seed():
    kw = dict(n_nodes=8, bucket_bytes=1e7, n_steps=20,
              compute_ms=0.0, overlap=0.0)
    a = simulate_job("gloo_ring", env=NetworkModel.environment("local_1.5",
                                                               seed=3), **kw)
    b = simulate_job("gloo_ring", env=NetworkModel.environment("local_1.5",
                                                               seed=3), **kw)
    assert a["total_ms"] == b["total_ms"]


def test_p99_calibration():
    env = NetworkModel(median_ms=1.0, p99_over_p50=3.0, stall_prob=0.0)
    s = env.base_ms(0, n=200_000)
    ratio = np.percentile(s, 99) / np.percentile(s, 50)
    assert ratio == pytest.approx(3.0, rel=0.05)


def test_optireduce_beats_ring_more_at_higher_tail():
    kw = dict(n_nodes=8, bucket_bytes=25 * 2**20, n_steps=100,
              compute_ms=0.0, overlap=0.0)
    gaps = {}
    for name in ("local_1.5", "local_3.0"):
        ring = simulate_job("gloo_ring",
                            env=NetworkModel.environment(name, 7), **kw)
        opti = simulate_job("optireduce",
                            env=NetworkModel.environment(name, 7), **kw)
        gaps[name] = ring["mean_ga_ms"] / opti["mean_ga_ms"]
    assert gaps["local_1.5"] > 1.0
    assert gaps["local_3.0"] > gaps["local_1.5"]    # paper's headline trend


def test_optireduce_drops_bounded():
    r = simulate_job("optireduce", n_nodes=8, bucket_bytes=25 * 2**20,
                     n_steps=150, compute_ms=0.0, overlap=0.0,
                     env=NetworkModel.environment("local_3.0", 3))
    assert 0.0 < r["mean_drop"] < 0.01    # paper Table 1: 0.05%-0.18%


def test_reliable_strategies_never_drop():
    for s in ("gloo_ring", "nccl_tree", "bcube", "tar_tcp"):
        r = simulate_job(s, n_nodes=8, bucket_bytes=1e7, n_steps=10,
                         compute_ms=0.0, overlap=0.0,
                         env=NetworkModel.environment("local_3.0", 1))
        assert r["mean_drop"] == 0.0


def test_tar_incast_reduces_rounds():
    env = NetworkModel.environment("local_1.5", 5)
    sim = GASimulator(env, 8)
    r1 = sim.tar_tcp(1e7, incast=1)
    r2 = sim.tar_tcp(1e7, incast=4)
    assert r2.rounds < r1.rounds
