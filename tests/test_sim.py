"""Network simulator invariants + paper-shape checks."""
import numpy as np
import pytest

from repro.sim.netsim import GASimulator, NetworkModel, simulate_job


def test_deterministic_in_seed():
    kw = dict(n_nodes=8, bucket_bytes=1e7, n_steps=20,
              compute_ms=0.0, overlap=0.0)
    a = simulate_job("gloo_ring", env=NetworkModel.environment("local_1.5",
                                                               seed=3), **kw)
    b = simulate_job("gloo_ring", env=NetworkModel.environment("local_1.5",
                                                               seed=3), **kw)
    assert a["total_ms"] == b["total_ms"]


def test_p99_calibration():
    env = NetworkModel(median_ms=1.0, p99_over_p50=3.0, stall_prob=0.0)
    s = env.base_ms(0, n=200_000)
    ratio = np.percentile(s, 99) / np.percentile(s, 50)
    assert ratio == pytest.approx(3.0, rel=0.05)


def test_optireduce_beats_ring_more_at_higher_tail():
    kw = dict(n_nodes=8, bucket_bytes=25 * 2**20, n_steps=100,
              compute_ms=0.0, overlap=0.0)
    gaps = {}
    for name in ("local_1.5", "local_3.0"):
        ring = simulate_job("gloo_ring",
                            env=NetworkModel.environment(name, 7), **kw)
        opti = simulate_job("optireduce",
                            env=NetworkModel.environment(name, 7), **kw)
        gaps[name] = ring["mean_ga_ms"] / opti["mean_ga_ms"]
    assert gaps["local_1.5"] > 1.0
    assert gaps["local_3.0"] > gaps["local_1.5"]    # paper's headline trend


def test_optireduce_drops_bounded():
    r = simulate_job("optireduce", n_nodes=8, bucket_bytes=25 * 2**20,
                     n_steps=150, compute_ms=0.0, overlap=0.0,
                     env=NetworkModel.environment("local_3.0", 3))
    assert 0.0 < r["mean_drop"] < 0.01    # paper Table 1: 0.05%-0.18%


def test_reliable_strategies_never_drop():
    for s in ("gloo_ring", "nccl_tree", "bcube", "tar_tcp"):
        r = simulate_job(s, n_nodes=8, bucket_bytes=1e7, n_steps=10,
                         compute_ms=0.0, overlap=0.0,
                         env=NetworkModel.environment("local_3.0", 1))
        assert r["mean_drop"] == 0.0


def test_tar_incast_reduces_rounds():
    env = NetworkModel.environment("local_1.5", 5)
    sim = GASimulator(env, 8)
    r1 = sim.tar_tcp(1e7, incast=1)
    r2 = sim.tar_tcp(1e7, incast=4)
    assert r2.rounds < r1.rounds


# ------------------------------------------------- wire-trace calibration
def test_network_model_calibrates_from_wire_drop_trace():
    """DESIGN §7 cross-validation: feed a *wire-observed* per-round loss
    trace (host transport, scripted bernoulli loss) into NetworkModel and
    the simulator's predicted loss_frac tracks the observed one."""
    import jax

    from repro.core.allreduce import OptiReduceConfig
    from repro.net import HostRing, bernoulli_drops

    n = 4
    ring = HostRing(n, OptiReduceConfig(strategy="optireduce", drop_rate=0.0,
                                        hadamard_block=256, packet_elems=64),
                    backend="inproc", drop_fn=bernoulli_drops(0.03, seed=5))
    buckets = np.random.default_rng(0).standard_normal(
        (n, 4096)).astype(np.float32)
    trace = []
    for step in range(10):
        _, tel = ring.allreduce(buckets, jax.random.PRNGKey(0), step=step)
        trace.extend(1.0 - f for f in tel.round_frac_received)
    observed = float(np.mean(trace))
    assert observed > 0.0

    env = NetworkModel.from_drop_trace(trace, seed=9)
    # the calibrated loss process: P(round lossy) and loss-per-stall both
    # moment-matched from the trace
    assert env.stall_prob == pytest.approx(
        np.mean(np.asarray(trace) > 0), abs=1e-9)
    # predicted per-flow loss over many simulated UBT transfers tracks the
    # observed mean (ubt_ms draws uniform(0.2, 1.8) x drop_frac_per_stall)
    _, lost = env.ubt_ms(1e6, n=20000)
    predicted = float(np.mean(lost))
    assert predicted == pytest.approx(observed, rel=0.25)

    # and an end-to-end simulated job reports drops of the same magnitude
    r = simulate_job("optireduce", n_nodes=n, bucket_bytes=1e6, n_steps=40,
                     compute_ms=0.0, overlap=0.0, env=env)
    assert 0.2 * observed < r["mean_drop"] < 3.0 * observed


def test_drop_trace_calibration_validates_input():
    with pytest.raises(ValueError):
        NetworkModel.from_drop_trace([])
    with pytest.raises(ValueError):
        NetworkModel.from_drop_trace([0.1, 1.5])
    with pytest.raises(ValueError):
        NetworkModel.from_drop_trace([0.1, float("nan")])
    lossless = NetworkModel.from_drop_trace([0.0, 0.0])
    assert lossless.stall_prob == 0.0
    _, lost = lossless.ubt_ms(1e6, n=100)
    assert float(np.max(lost)) == 0.0


def test_ge_fit_cross_validates_against_synthetic_burst_masks():
    """DESIGN §8 cross-validation: fit Gilbert–Elliott parameters from
    packet-granular synthetic burst masks (core.drops) and the fitted model
    must (a) match the generator's parameterization and (b) regenerate loss
    sequences with the same run-length statistics."""
    import jax

    from repro.core.drops import (BURST_MEAN_PKTS, burst_mask,
                                  gilbert_elliott_params)

    rate = 0.1
    masks = [burst_mask(jax.random.PRNGKey(s), 16, 256, rate=rate,
                        packet_elems=1) for s in range(20)]
    env = NetworkModel.from_drop_trace([rate], masks=masks, seed=4)
    true_p, true_r = gilbert_elliott_params(rate, BURST_MEAN_PKTS)
    # moment-matched parameters land near the generator's (bursty loss has
    # high sample variance — loose statistical bounds)
    assert env.burst_r == pytest.approx(true_r, rel=0.5)
    assert env.burst_p == pytest.approx(true_p, rel=0.6)

    # round trip: the fitted model's own loss sequence reproduces the
    # stationary rate and mean burst length it was fitted from
    seq = env.burst_loss_seq(200_000)
    assert float(np.mean(seq)) == pytest.approx(rate, abs=0.05)
    padded = np.concatenate([[0], seq.astype(np.int8), [0]])
    edges = np.flatnonzero(np.diff(padded))
    runs = edges[1::2] - edges[::2]
    assert float(np.mean(runs)) == pytest.approx(1.0 / env.burst_r, rel=0.3)


def test_ge_fit_absent_without_masks_or_losses():
    env = NetworkModel.from_drop_trace([0.05, 0.0], seed=1)
    assert env.burst_p is None
    with pytest.raises(ValueError, match="burst"):
        env.burst_loss_seq(10)
    # lossless masks: nothing to fit, burst params stay unset
    clean = NetworkModel.from_drop_trace([0.0], masks=[np.ones((4, 64))])
    assert clean.burst_p is None
