"""CI smoke for the kernel bench: ``python -m benchmarks.run --only
bench_kernels`` in quick mode must keep producing the schema the
PR-over-PR trajectory diffs consume — the parity rows for every kernel
family, an ``*_interpret_steady_us`` device row per family with its
dispersion sibling, and (only when a TPU backend exists) the
``*_compiled_steady_us`` rows. Off-TPU the compiled keys must simply be
absent — never present-but-bogus — so the checked-in CPU baseline stays
comparable across PRs.

Writes to a tmpdir via ``REPRO_BENCH_DIR`` so a test run never rewrites the
checked-in BENCH_kernels.json baseline.
"""
import json
import math
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# one device-timed row per kernel family (quick-mode key set)
_DEVICE_FAMILIES = (
    "kernels/fwht_b1024",
    "kernels/masked_sum_L16384",
    "kernels/quant_b8",
    "kernels/ht_amax_b1024",
    "kernels/ht_quant_b1024",
    "kernels/dequant_masked_mean_L8192",
)


@pytest.mark.slow
def test_bench_kernels_quick_schema(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_KERNEL_MODE", None)   # the bench scopes its own modes
    src = os.path.join(_REPO, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [_REPO, src, env.get("PYTHONPATH", "")])
    env["REPRO_BENCH_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "bench_kernels"],
        env=env, cwd=_REPO, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "FAILED" not in proc.stdout, proc.stdout

    path = tmp_path / "BENCH_kernels.json"
    assert path.exists(), "run.py did not honor REPRO_BENCH_DIR"
    payload = json.loads(path.read_text())
    assert payload["_meta"] == {"mode": "quick", "bench": "bench_kernels"}

    keys = set(payload) - {"_meta"}
    # parity rows: the jnp-form timing row per family carries the
    # pallas-vs-oracle parity number in its derived column
    for key, tag in (("kernels/fwht_b1024_float32", "pallas_vs_oracle_err"),
                     ("kernels/masked_sum_L16384", "pallas_vs_oracle_err"),
                     ("kernels/quant_b8", "pallas_vs_oracle_maxdiff"),
                     ("kernels/ht_quant_b1024", "pallas_vs_oracle_maxdiff"),
                     ("kernels/dequant_masked_mean_L8192",
                      "pallas_vs_oracle_err")):
        assert key in keys, key
        assert tag in payload[key]["derived"], (key, payload[key]["derived"])

    # device rows: interpret timings exist everywhere; compiled timings are
    # TPU-only and must be absent (not zero/NaN) on other backends
    import jax
    on_tpu = jax.default_backend() == "tpu"
    for fam in _DEVICE_FAMILIES:
        assert f"{fam}_interpret_steady_us" in keys, fam
        assert f"{fam}_interpret_steady_iqr_us" in keys, fam
        if not on_tpu:
            assert f"{fam}_compiled_steady_us" not in keys, fam
        else:
            assert f"{fam}_compiled_steady_us" in keys, fam
            assert f"{fam}_compiled_steady_iqr_us" in keys, fam

    # every steady row carries its dispersion sibling (run.py schema)
    for key in keys:
        if key.endswith("_steady_us"):
            assert key[:-len("_steady_us")] + "_steady_iqr_us" in keys, key
    # values are finite numbers (mirrors run.py's gate end-to-end)
    for key in keys:
        value = payload[key]["value"]
        assert isinstance(value, (int, float)) and math.isfinite(value), key

    # the checked-in baseline at the repo root was NOT rewritten
    repo_json = os.path.join(_REPO, "BENCH_kernels.json")
    if os.path.exists(repo_json):
        with open(repo_json) as fh:
            baseline = json.load(fh)
        assert baseline["_meta"]["bench"] == "bench_kernels"
