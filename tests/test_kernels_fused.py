"""Fused sync-engine kernels: ht_quant (sign+FWHT+quantize) and
dequant_masked_mean (dequant+compensated mean) vs the composed unfused
oracle pipelines they replace — the parity contract of the fused engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_fallback import given, strategies as st

from repro.core.hadamard import (ht_encode, ht_encode_amax, ht_encode_quant,
                                 rademacher_sign)
from repro.kernels.dequant_reduce import (dequant_masked_mean,
                                          dequant_masked_mean_ref)
from repro.kernels.ht_quant import ht_amax, ht_quant
from repro.kernels.masked_sum import masked_mean_ref
from repro.kernels.quant import uniform_quant_ref


@pytest.mark.parametrize("rows,block", [(4, 256), (37, 1024), (64, 4096)])
def test_ht_amax_matches_composed(rows, block):
    key = jax.random.PRNGKey(rows)
    x = jax.random.normal(key, (rows, block))
    sign = rademacher_sign(key, block)
    fused = ht_amax(x, sign, use_kernel=True)
    # composed: materialize the rotation, then reduce
    rot = ht_encode(x.reshape(-1), key, block=block).reshape(rows, block)
    composed = jnp.max(jnp.abs(rot), axis=1)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(composed))


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("rows,block", [(8, 256), (37, 1024)])
def test_ht_quant_matches_composed(bits, rows, block):
    """fused ht_quant == ht_encode -> uniform_quant_ref on per-block grids
    (bit-exact: same MXU rotation math, same grid arithmetic)."""
    key = jax.random.PRNGKey(bits * 100 + rows)
    x = jax.random.normal(key, (rows, block))
    sign = rademacher_sign(key, block)
    noise = jax.random.uniform(jax.random.fold_in(key, 1), x.shape)
    rot = ht_encode(x.reshape(-1), key, block=block).reshape(rows, block)
    amax = jnp.maximum(jnp.max(jnp.abs(rot), axis=1), 1e-12)
    levels = (1 << bits) - 1
    lo, step = -amax, 2.0 * amax / levels
    fused = ht_quant(x, sign, noise, lo, step, bits=bits, use_kernel=True)
    composed = jnp.stack([
        uniform_quant_ref(rot[r:r + 1], noise[r:r + 1], lo[r],
                          lo[r] + levels * step[r], bits=bits)[0]
        for r in range(rows)])
    np.testing.assert_array_equal(np.asarray(fused.astype(jnp.int32)),
                                  np.asarray(composed.astype(jnp.int32)))


def test_ht_quant_kernel_matches_jnp_path():
    """use_kernel=True and the jnp oracle path agree bit-exactly."""
    key = jax.random.PRNGKey(3)
    rows, block = 19, 512
    x = jax.random.normal(key, (rows, block))
    sign = rademacher_sign(key, block)
    noise = jax.random.uniform(jax.random.fold_in(key, 1), x.shape)
    amax = jnp.maximum(ht_amax(x, sign), 1e-12)
    lo, step = -amax, 2.0 * amax / 255
    a = ht_quant(x, sign, noise, lo, step, bits=8, use_kernel=True)
    b = ht_quant(x, sign, noise, lo, step, bits=8, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 2**31 - 1))
def test_ht_encode_amax_never_materializes_mismatch(seed):
    """hadamard-layer wrappers (key -> sign derivation) match ht_encode."""
    key = jax.random.PRNGKey(seed)
    block = 256
    x = jax.random.normal(key, (8 * block,))
    fused = ht_encode_amax(x, key, block=block, use_kernel=True)
    rot = ht_encode(x, key, block=block).reshape(-1, block)
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(jnp.max(jnp.abs(rot), axis=1)))


def test_ht_encode_quant_roundtrip_error_bound():
    """dequant(fused codes) stays within one grid step of the rotation."""
    key = jax.random.PRNGKey(9)
    block, bits = 1024, 8
    x = jax.random.normal(key, (4 * block,))
    amax = jnp.maximum(ht_encode_amax(x, key, block=block), 1e-12)
    levels = (1 << bits) - 1
    lo, step = -amax, 2.0 * amax / levels
    noise = jax.random.uniform(jax.random.fold_in(key, 1), (4, block))
    codes = ht_encode_quant(x, key, noise, lo, step, block=block, bits=bits,
                            use_kernel=True)
    deq = codes.astype(jnp.float32) * step[:, None] + lo[:, None]
    rot = ht_encode(x, key, block=block).reshape(4, block)
    assert float(jnp.max(jnp.abs(deq - rot) / step[:, None])) <= 1.0 + 1e-5


@pytest.mark.parametrize("with_mask", [False, True])
@pytest.mark.parametrize("n,nblk,block", [(2, 3, 128), (8, 5, 256),
                                          (16, 2, 1024)])
def test_dequant_masked_mean_matches_composed(with_mask, n, nblk, block):
    """fused dequant+reduce == dequant -> masked_mean_ref composed."""
    key = jax.random.PRNGKey(n + nblk)
    s = nblk * block
    codes = jax.random.randint(key, (n, s), 0, 256).astype(jnp.uint8)
    lo = jax.random.normal(key, (nblk,))
    step = jax.random.uniform(jax.random.fold_in(key, 1), (nblk,)) * 0.1 + 1e-3
    mask = None
    if with_mask:
        mask = (jax.random.uniform(jax.random.fold_in(key, 2), (n, s))
                > 0.1).astype(jnp.float32)
    fused = dequant_masked_mean(codes, lo, step, mask, block=block,
                                use_kernel=True)
    vals = (codes.reshape(n, nblk, block).astype(jnp.float32)
            * step[None, :, None] + lo[None, :, None]).reshape(n, s)
    composed = (jnp.mean(vals, axis=0) if mask is None
                else masked_mean_ref(vals, mask))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(composed),
                               atol=1e-5)


def test_dequant_masked_mean_kernel_matches_ref_path():
    key = jax.random.PRNGKey(4)
    n, nblk, block = 8, 7, 128
    s = nblk * block
    codes = jax.random.randint(key, (n, s), 0, 256).astype(jnp.uint8)
    lo = jax.random.normal(key, (nblk,))
    step = jax.random.uniform(key, (nblk,)) * 0.05 + 1e-3
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (n, s))
            > 0.3).astype(jnp.float32)
    a = dequant_masked_mean(codes, lo, step, mask, block=block,
                            use_kernel=True)
    b = dequant_masked_mean(codes, lo, step, mask, block=block,
                            use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_dequant_masked_mean_all_dropped_column_is_zero():
    """Columns nobody delivered reduce to 0 (skip-coordinate semantics)."""
    n, block = 4, 128
    codes = jnp.full((n, block), 200, jnp.uint8)
    lo = jnp.array([-1.0])
    step = jnp.array([0.01])
    mask = jnp.zeros((n, block))
    out = dequant_masked_mean(codes, lo, step, mask, block=block,
                              use_kernel=True)
    assert float(jnp.max(jnp.abs(out))) == 0.0
