"""CI smoke for the bench harness: ``python -m benchmarks.run --only
bench_pipeline`` in quick mode must keep producing the schema the
PR-over-PR trajectory diffs consume — the ``pipeline/pipelined_*`` rows,
the dispersion sibling of every steady row, and the
``pipelined_vs_scan_steady_pct`` headline — so the harness cannot rot
silently between PRs.

Writes to a tmpdir via ``REPRO_BENCH_DIR`` so a test run never rewrites the
checked-in BENCH_pipeline.json baseline.
"""
import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_bench_pipeline_quick_schema(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(_REPO, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [_REPO, src, env.get("PYTHONPATH", "")])
    env["REPRO_BENCH_DIR"] = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "bench_pipeline"],
        env=env, cwd=_REPO, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "FAILED" not in proc.stdout, proc.stdout

    path = tmp_path / "BENCH_pipeline.json"
    assert path.exists(), "run.py did not honor REPRO_BENCH_DIR"
    payload = json.loads(path.read_text())
    assert payload["_meta"] == {"mode": "quick", "bench": "bench_pipeline"}

    keys = set(payload) - {"_meta"}
    # the pipelined schedule rows the acceptance criteria pin
    for b in (1, 2, 4, 8):
        for suffix in ("trace_ms", "hlo_kb", "steady_us", "steady_iqr_us"):
            assert f"pipeline/pipelined_B{b}_{suffix}" in keys, (b, suffix)
    assert "pipeline/pipelined_vs_scan_steady_pct" in keys
    assert "pipeline/pipelined_per_bucket_us" in keys
    # every steady row carries its dispersion sibling (run.py schema)
    for key in keys:
        if key.endswith("_steady_us"):
            assert key[:-len("_steady_us")] + "_steady_iqr_us" in keys, key
    # values are finite numbers (mirrors run.py's gate end-to-end)
    for key in keys:
        value = payload[key]["value"]
        assert isinstance(value, (int, float)), key

    # the checked-in baseline at the repo root was NOT rewritten
    repo_json = os.path.join(_REPO, "BENCH_pipeline.json")
    if os.path.exists(repo_json):
        with open(repo_json) as fh:
            baseline = json.load(fh)
        assert baseline["_meta"]["bench"] == "bench_pipeline"
